//! Integration tests over the real PJRT runtime + built artifacts.
//!
//! These need `make artifacts` to have run; they are skipped (with a
//! note) when the artifacts directory is absent, so `cargo test` works
//! in a fresh checkout too.
//!
//! NOTE: every test that touches PJRT creates its own engine; tests run
//! in one process, so keep engine instantiations modest (HLO compilation
//! is the slow part).

use std::path::{Path, PathBuf};

use cnmt::coordinator::gateway::{Gateway, GatewayConfig};
use cnmt::coordinator::{PolicyKind, RouterBuilder};
use cnmt::net::{RttTrace, TraceGenerator};
use cnmt::net::trace::ConnectionProfile;
use cnmt::predictor::{N2mRegressor, TexeModel};
use cnmt::runtime::{ArtifactManifest, Seq2SeqEngine, TranslateOptions};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn manifest_covers_three_models_with_valid_files() {
    require_artifacts!();
    let man = ArtifactManifest::load(&artifacts_dir()).unwrap();
    let names: Vec<&str> = man.models.iter().map(|m| m.name.as_str()).collect();
    assert_eq!(
        names,
        vec!["bilstm_de_en", "gru_fr_en", "transformer_en_zh"]
    );
    for m in &man.models {
        assert!(m.encode_hlo.exists());
        assert!(m.decode_hlo.exists());
        let blob = cnmt::runtime::weights::read_blob(m).unwrap();
        cnmt::runtime::weights::verify_sha256(m, &blob).unwrap();
    }
}

#[test]
fn greedy_decode_emits_valid_tokens_and_is_deterministic() {
    require_artifacts!();
    let man = ArtifactManifest::load(&artifacts_dir()).unwrap();
    for model in ["gru_fr_en", "transformer_en_zh"] {
        let eng = Seq2SeqEngine::from_manifest(&man, model).unwrap();
        let src: Vec<u16> = vec![100, 200, 300, 400];
        let opts = TranslateOptions { force_steps: Some(6), ..Default::default() };
        let a = eng.translate(&src, opts).unwrap();
        let b = eng.translate(&src, opts).unwrap();
        assert_eq!(a.tokens, b.tokens, "{model}: nondeterministic");
        assert_eq!(a.steps, 6);
        assert!(a.tokens.iter().all(|&t| (0..4096).contains(&t)), "{model}");
        // Different source -> (generically) different decode.
        let c = eng
            .translate(&[999u16, 998, 997, 996, 995], opts)
            .unwrap();
        assert_ne!(a.tokens, c.tokens, "{model}: context ignored?");
    }
}

#[test]
fn decode_time_scales_linearly_with_m() {
    // The paper's core latency premise, measured on the real runtime:
    // decode wall time ~ alpha_m * M. Check monotonicity + rough
    // proportionality rather than exact fits (CI machines are noisy).
    require_artifacts!();
    let man = ArtifactManifest::load(&artifacts_dir()).unwrap();
    let eng = Seq2SeqEngine::from_manifest(&man, "gru_fr_en").unwrap();
    let src: Vec<u16> = (10..30).collect();
    // Warm up.
    for _ in 0..2 {
        eng.translate(&src, TranslateOptions { force_steps: Some(4), ..Default::default() })
            .unwrap();
    }
    let time_for = |m: usize| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let tr = eng
                .translate(
                    &src,
                    TranslateOptions { force_steps: Some(m), ..Default::default() },
                )
                .unwrap();
            best = best.min(tr.decode_s);
        }
        best
    };
    let t8 = time_for(8);
    let t48 = time_for(48);
    assert!(
        t48 > 3.0 * t8,
        "decode not ~linear in M: t8={t8} t48={t48} (expected ~6x)"
    );
    assert!(t48 < 20.0 * t8, "superlinear blowup: t8={t8} t48={t48}");
}

#[test]
fn transformer_encoder_flat_in_n_rnn_encoder_grows() {
    // Paper §II-A: transformer encoder ~constant in N (parallel), RNN
    // encoder linear in N (serial scan). Verify the *relative* claim on
    // the real runtime: encode(60)/encode(6) much larger for the RNN.
    require_artifacts!();
    let man = ArtifactManifest::load(&artifacts_dir()).unwrap();
    let ratio_for = |model: &str| -> f64 {
        let eng = Seq2SeqEngine::from_manifest(&man, model).unwrap();
        let short: Vec<u16> = (10..16).collect();
        let long: Vec<u16> = (10..70).map(|x| (x % 60) + 10).collect();
        let opts = TranslateOptions { force_steps: Some(1), ..Default::default() };
        for _ in 0..2 {
            eng.translate(&short, opts).unwrap();
        }
        let t_short = (0..3)
            .map(|_| eng.translate(&short, opts).unwrap().encode_s)
            .fold(f64::INFINITY, f64::min);
        let t_long = (0..3)
            .map(|_| eng.translate(&long, opts).unwrap().encode_s)
            .fold(f64::INFINITY, f64::min);
        t_long / t_short
    };
    let r_rnn = ratio_for("gru_fr_en");
    let r_tr = ratio_for("transformer_en_zh");
    // XLA pads to N_MAX=64 and masks, so the RNN scan always runs 64
    // steps — the *static-shape* runtime makes encode flat for both.
    // What must hold is that the transformer is at least as flat as the
    // RNN and neither blows up with N.
    assert!(r_tr < 3.0, "transformer encode grew with N: {r_tr}");
    assert!(r_rnn < 3.0, "rnn encode unexpectedly superlinear: {r_rnn}");
}

#[test]
fn gateway_serves_requests_and_tracks_ttx() {
    require_artifacts!();
    let trace = RttTrace { t: vec![0.0, 3600.0], rtt: vec![0.004, 0.004] };
    let router = RouterBuilder::new(PolicyKind::Cnmt)
        .texe(
            // Edge: cheap fixed cost, steep slopes; cloud: flat slopes,
            // large fixed cost — so short stays local, long offloads.
            TexeModel::from_coeffs(1e-3, 2e-3, 0.5e-3),
            TexeModel::from_coeffs(0.1e-3, 0.2e-3, 20e-3),
        )
        .n2m(N2mRegressor::from_coeffs(0.9, 0.5))
        .ttx(0.3, 0.004)
        .build()
        .unwrap();
    let gw = Gateway::start(
        GatewayConfig {
            artifacts_dir: artifacts_dir(),
            model: "gru_fr_en".to_string(),
            edge_slowdown: 1.0,
            trace: Some(trace),
            max_steps: Some(8),
        },
        router,
    )
    .unwrap();
    let mut edge = 0;
    let mut cloud = 0;
    for i in 0..10u64 {
        let n = if i % 2 == 0 { 3 } else { 40 };
        let src: Vec<u16> = (0..n).map(|k| 50 + k as u16).collect();
        let out = gw.submit(i, &src, Some(4)).unwrap();
        assert!(out.latency_s > 0.0);
        assert_eq!(out.steps, 4);
        match out.device {
            cnmt::devices::DeviceKind::Edge => edge += 1,
            cnmt::devices::DeviceKind::Cloud => cloud += 1,
        }
    }
    assert_eq!(gw.decisions(), 10);
    assert!(edge > 0, "no edge traffic");
    assert!(cloud > 0, "no cloud traffic (long requests should offload)");
    let metrics = gw.metrics();
    assert_eq!(
        metrics.get("all").unwrap().get("count").unwrap().as_i64().unwrap(),
        10
    );
}

#[test]
fn calibration_pipeline_smoke_on_real_runtime() {
    // End-to-end mini version of `cnmt calibrate`: measure a few real
    // translations, fit planes, instantiate devices, check sanity.
    require_artifacts!();
    let man = ArtifactManifest::load(&artifacts_dir()).unwrap();
    let eng = Seq2SeqEngine::from_manifest(&man, "gru_fr_en").unwrap();
    let mut samples = Vec::new();
    for _ in 0..2 {
        eng.translate(&[5u16; 6], TranslateOptions { force_steps: Some(2), ..Default::default() })
            .unwrap();
    }
    let grid = [
        (4usize, 4usize),
        (4, 24),
        (24, 4),
        (24, 24),
        (48, 12),
        (12, 48),
        (48, 48),
        (8, 40),
        (40, 8),
        (60, 60),
    ];
    for (n, m) in grid {
        let src: Vec<u16> = (0..n).map(|k| 60 + k as u16).collect();
        let tr = eng
            .translate(
                &src,
                TranslateOptions { force_steps: Some(m), ..Default::default() },
            )
            .unwrap();
        samples.push((n as f64, m as f64, tr.total_s()));
    }
    let mut map = std::collections::BTreeMap::new();
    map.insert("gru_fr_en".to_string(), samples);
    let cal = cnmt::devices::Calibration::from_measurements(&map, 1.0, 5.0).unwrap();
    let edge = cal.get(cnmt::devices::DeviceKind::Edge, "gru_fr_en").unwrap();
    let cloud = cal.get(cnmt::devices::DeviceKind::Cloud, "gru_fr_en").unwrap();
    assert!(edge.texe.alpha_m > 0.0, "alpha_m {}", edge.texe.alpha_m);
    assert!((edge.texe.alpha_m / cloud.texe.alpha_m - 5.0).abs() < 1e-6);
}
