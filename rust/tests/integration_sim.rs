//! Integration tests across the simulation stack: corpus → prefilter →
//! characterisation → router → harness → Table-I aggregation. No PJRT
//! needed; everything runs at smoke scale.

use cnmt::config::Config;
use cnmt::coordinator::PolicyKind;
use cnmt::corpus::LangPair;
use cnmt::devices::{Calibration, DeviceKind};
use cnmt::experiments::{fig2a, fig3, fig4, table1};
use cnmt::net::trace::ConnectionProfile;
use cnmt::sim::{run_all_policies, run_policy, TruthTable};

fn smoke_cfg() -> Config {
    let mut cfg = Config::smoke();
    cfg.requests = 4_000;
    cfg
}

#[test]
fn full_table1_grid_has_paper_sign_structure() {
    let t = table1::run(&smoke_cfg(), &Calibration::default_paper()).unwrap();
    assert_eq!(t.cells.len(), 6);
    for c in &t.cells {
        let (gw, srv, or) = c.vs_baselines("cnmt");
        // C-NMT never loses to a static mapping (beyond noise), never
        // beats the Oracle.
        assert!(gw <= 1.0, "{}/{} gw {gw}", c.pair.id(), c.profile.id());
        assert!(srv <= 1.0, "{}/{} srv {srv}", c.pair.id(), c.profile.id());
        assert!(or >= -1e-9, "{}/{} oracle {or}", c.pair.id(), c.profile.id());
        // And it actually mixes devices somewhere in the grid.
    }
    let any_mixed = t.cells.iter().any(|c| {
        let r = c.get("cnmt");
        r.edge_count > 0 && r.cloud_count > 0
    });
    assert!(any_mixed, "C-NMT degenerated to a static mapping everywhere");

    // Headlines in the paper's ballpark ("up to 44%" / "up to 21%"):
    // generous bands, the point is order-of-magnitude agreement.
    let h1 = t.headline_vs_static();
    assert!((15.0..70.0).contains(&h1), "vs-static headline {h1}");
    let h2 = t.headline_vs_naive();
    assert!(h2 > 0.0, "C-NMT never beats Naive: {h2}");
}

#[test]
fn slower_profile_shifts_traffic_to_edge() {
    // Paper: "the benefit of C-NMT w.r.t. a cloud based approach is
    // larger with CP1, which is slower on average" — mechanically, a
    // slower network must push C-NMT's mix toward the edge.
    let cfg = smoke_cfg();
    let cal = Calibration::default_paper();
    for pair in LangPair::ALL {
        let t1 = TruthTable::build(&cfg, pair, ConnectionProfile::Cp1, &cal).unwrap();
        let t2 = TruthTable::build(&cfg, pair, ConnectionProfile::Cp2, &cal).unwrap();
        let r1 = run_policy(&t1, PolicyKind::Cnmt).unwrap();
        let r2 = run_policy(&t2, PolicyKind::Cnmt).unwrap();
        let edge_frac_1 = r1.edge_count as f64 / r1.requests as f64;
        let edge_frac_2 = r2.edge_count as f64 / r2.requests as f64;
        assert!(
            edge_frac_1 >= edge_frac_2 - 0.02,
            "{}: edge fraction cp1 {edge_frac_1} < cp2 {edge_frac_2}",
            pair.id()
        );
    }
}

#[test]
fn transformer_pays_most_for_unknown_m() {
    // Paper: overhead vs Oracle is larger for EN-ZH (decode-dominated
    // transformer leans hardest on the N→M estimate).
    let cfg = smoke_cfg();
    let cal = Calibration::default_paper();
    let over = |pair: LangPair| -> f64 {
        let mut worst: f64 = 0.0;
        for profile in ConnectionProfile::ALL {
            let t = TruthTable::build(&cfg, pair, profile, &cal).unwrap();
            let rs = run_all_policies(&t).unwrap();
            let oracle = rs.iter().find(|r| r.policy == "oracle").unwrap().total_s;
            let cnmt = rs.iter().find(|r| r.policy == "cnmt").unwrap().total_s;
            worst = worst.max((cnmt - oracle) / oracle * 100.0);
        }
        worst
    };
    let zh = over(LangPair::EnZh);
    let fr = over(LangPair::FrEn);
    assert!(
        zh > fr * 0.8,
        "transformer overhead {zh}% not the largest (fr {fr}%)"
    );
}

#[test]
fn oracle_correctness_and_counts_consistent() {
    let cfg = smoke_cfg();
    let cal = Calibration::default_paper();
    let t = TruthTable::build(&cfg, LangPair::DeEn, ConnectionProfile::Cp2, &cal).unwrap();
    for r in run_all_policies(&t).unwrap() {
        assert_eq!(r.edge_count + r.cloud_count, r.requests);
        assert_eq!(r.requests, cfg.requests);
        assert!(r.total_s > 0.0);
        assert!((0.0..=1.0).contains(&r.correct_rate));
        if r.policy == "oracle" {
            assert!((r.correct_rate - 1.0).abs() < 1e-12);
        }
    }
}

#[test]
fn fig_drivers_produce_reports() {
    let cal = Calibration::default_paper();
    let f2 = fig2a::run(LangPair::EnZh, &cal, 2_000, 1).unwrap();
    assert_eq!(f2.series.len(), 2);
    let f3 = fig3::run(5_000, 1).unwrap();
    assert_eq!(f3.panels.len(), 3);
    let f4 = fig4::run(1).unwrap();
    assert_eq!(f4.stats.len(), 2);
    // JSON outputs parse back.
    for j in [fig2a::to_json(&f2), fig3::to_json(&f3), fig4::to_json(&f4)] {
        let text = j.to_string_pretty();
        cnmt::util::Json::parse(&text).unwrap();
    }
}

#[test]
fn measured_calibration_roundtrips_through_harness() {
    // A calibration written to disk and reloaded must drive the harness
    // identically (config --calibration path).
    let cal = Calibration::default_paper();
    let dir = std::env::temp_dir().join("cnmt_integration_cal");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cal.json");
    cal.save(&path).unwrap();
    let loaded = Calibration::load(&path).unwrap();
    let cfg = smoke_cfg();
    let a = TruthTable::build(&cfg, LangPair::FrEn, ConnectionProfile::Cp1, &cal).unwrap();
    let b = TruthTable::build(&cfg, LangPair::FrEn, ConnectionProfile::Cp1, &loaded).unwrap();
    for (x, y) in a.requests.iter().zip(&b.requests) {
        assert!((x.t_edge - y.t_edge).abs() < 1e-15);
        assert!((x.t_cloud - y.t_cloud).abs() < 1e-15);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn devices_honour_calibration_orderings() {
    let cal = Calibration::default_paper();
    for pair in LangPair::ALL {
        let model = pair.model_name();
        let mut e = cal.build_device(DeviceKind::Edge, 1).unwrap();
        let mut c = cal.build_device(DeviceKind::Cloud, 1).unwrap();
        // Execution time grows with m on both devices (statistically).
        let avg = |dev: &mut cnmt::devices::SimDevice, n: usize, m: usize| {
            (0..200).map(|_| dev.exec_time(model, n, m).unwrap()).sum::<f64>() / 200.0
        };
        assert!(avg(&mut e, 10, 40) > avg(&mut e, 10, 5));
        assert!(avg(&mut c, 10, 40) > avg(&mut c, 10, 5));
    }
}

#[test]
fn traced_detect_run_records_paired_alert_events() {
    // A crash-fault detect run with a flight recorder attached must put
    // the AlertRaised/AlertCleared events on the wire, and the offline
    // verifier must replay the whole window — including the alert
    // pairing invariant — and agree with the live detector's tallies.
    use cnmt::experiments::load::synth_workload;
    use cnmt::experiments::outage::outage_fault_spec;
    use cnmt::fleet::Topology;
    use cnmt::obs::{
        verify_blame, verify_trace, AlertKind, DetectCfg, Detector, FlightRecorder,
        TelemetryCfg,
    };
    use cnmt::scheduler::RetryPolicy;
    use cnmt::sim::{run_fleet_outage_detect, FleetOpts};

    let topo = Topology::hetero();
    let tiers: Vec<_> = topo.devices.iter().map(|d| d.tier).collect();
    let opts = FleetOpts {
        telemetry: Some(TelemetryCfg::default()),
        ..Default::default()
    };
    let retry = RetryPolicy::default();
    let (pool, ch) = synth_workload(0xA1E27, 2_000, 224.0);
    let fault = outage_fault_spec(&topo, 2_000, 224.0);
    let det = Detector::new(&tiers, DetectCfg::default());
    let rec = FlightRecorder::new(1 << 16);
    let (out, rec) = run_fleet_outage_detect(
        &pool,
        &ch,
        &topo,
        &opts,
        Some(&fault),
        &retry,
        det,
        Some(rec),
    )
    .unwrap();
    let rec = rec.unwrap();
    assert_eq!(rec.dropped(), 0, "ring truncated — bump the capacity");

    // The crash must be seen, attributed to the faulted lane, and the
    // blame partition must hold on every chain (including the retried
    // ones the crash produced).
    assert!(out.raised >= 1, "crash went undetected");
    assert!(out
        .alerts
        .iter()
        .any(|a| a.raised && a.kind == AlertKind::DeviceCrash && a.lane == fault.lane as u32));
    verify_blame(&out.blame).unwrap();
    assert!(out.blame.iter().any(|c| c.attempts > 1));

    // Offline replay of the window agrees with the live tallies.
    let v = verify_trace(&rec.window_jsonl()).unwrap();
    assert_eq!(v.alerts_raised, out.raised);
    assert_eq!(v.alerts_cleared, out.cleared);
    assert_eq!(v.dropped_prefix, 0);
    assert_eq!(v.ring_dropped, Some(0));
    assert_eq!(v.sink_ok, Some(true));
}
