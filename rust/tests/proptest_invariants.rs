//! Property-based tests (hand-rolled: the offline crate set has no
//! proptest, so properties are checked over seeded random sweeps — many
//! trials per property, deterministic across runs).

use cnmt::config::Config;
use cnmt::coordinator::{PolicyKind, RouterBuilder};
use cnmt::corpus::{prefilter, CorpusGenerator, LangPair, PrefilterRules};
use cnmt::devices::{Calibration, DeviceKind};
use cnmt::experiments::load::synth_workload;
use cnmt::metrics::stats::percentile_sorted;
use cnmt::metrics::{Histogram, OnlineStats};
use cnmt::net::trace::{ConnectionProfile, TraceGenerator};
use cnmt::predictor::fit::{fit_line, fit_plane};
use cnmt::predictor::{N2mRegressor, RlsPlane, TexeModel, TtxEstimator};
use cnmt::scheduler::{
    BaselineDispatcher, BatchExecutor, CompletionKind, Dispatcher, DispatcherConfig,
    HedgeOutcome, QueuedRequest,
};
use cnmt::sim::{
    run_all_policies, run_closed_loop, run_contended, AdaptiveOpts, ContentionOpts, TruthTable,
};
use cnmt::util::{Json, Rng, Slab, SlabKey};

const TRIALS: usize = 60;

#[test]
fn prop_ols_line_recovers_planted_coefficients() {
    let mut rng = Rng::new(0x11);
    for trial in 0..TRIALS {
        let slope = rng.uniform(-5.0, 5.0);
        let intercept = rng.uniform(-10.0, 10.0);
        let noise = rng.uniform(0.0, 0.2);
        let pts: Vec<(f64, f64)> = (0..500)
            .map(|_| {
                let x = rng.uniform(0.0, 50.0);
                (x, slope * x + intercept + rng.normal_ms(0.0, noise))
            })
            .collect();
        let f = fit_line(&pts).unwrap();
        assert!(
            (f.slope - slope).abs() < 0.05 + noise,
            "trial {trial}: slope {} vs {slope}",
            f.slope
        );
        assert!(
            (f.intercept - intercept).abs() < 0.5 + 3.0 * noise,
            "trial {trial}: intercept {} vs {intercept}",
            f.intercept
        );
    }
}

#[test]
fn prop_ols_plane_recovers_planted_coefficients() {
    let mut rng = Rng::new(0x22);
    for trial in 0..TRIALS {
        let (a, b) = (rng.uniform(0.0, 0.01), rng.uniform(0.0, 0.02));
        let c = rng.uniform(0.0, 0.1);
        let pts: Vec<(f64, f64, f64)> = (0..800)
            .map(|_| {
                let x = rng.uniform(1.0, 64.0);
                let y = rng.uniform(1.0, 64.0);
                (x, y, a * x + b * y + c + rng.normal_ms(0.0, 1e-3))
            })
            .collect();
        let f = fit_plane(&pts).unwrap();
        assert!((f.a - a).abs() < 5e-4, "trial {trial}: a {} vs {a}", f.a);
        assert!((f.b - b).abs() < 5e-4, "trial {trial}: b {} vs {b}", f.b);
        assert!((f.c - c).abs() < 2e-2, "trial {trial}: c {} vs {c}", f.c);
    }
}

#[test]
fn prop_router_decision_matches_eq1_exactly() {
    // For random model coefficients and RTTs, the router's choice must
    // equal a direct evaluation of paper eq. 1 + eq. 2.
    let mut rng = Rng::new(0x33);
    for trial in 0..TRIALS * 4 {
        let te = TexeModel::from_coeffs(
            rng.uniform(0.0, 5e-3),
            rng.uniform(0.0, 10e-3),
            rng.uniform(0.0, 30e-3),
        );
        let tc = TexeModel::from_coeffs(
            rng.uniform(0.0, 1e-3),
            rng.uniform(0.0, 2e-3),
            rng.uniform(0.0, 40e-3),
        );
        let n2m = N2mRegressor::from_coeffs(rng.uniform(0.4, 1.2), rng.uniform(0.0, 2.0));
        let rtt = rng.uniform(0.0, 0.3);
        let mut router = RouterBuilder::new(PolicyKind::Cnmt)
            .texe(te, tc)
            .n2m(n2m)
            .ttx(1.0, rtt) // alpha 1 => estimate == last observation
            .build()
            .unwrap();
        router.observe_ttx(0.0, rtt);
        let n = 1 + rng.usize(61);
        let d = router.decide(n);
        let m_est = n2m.predict(n);
        let want_edge = te.estimate(n, m_est) <= rtt + tc.estimate(n, m_est);
        assert_eq!(
            d.device == DeviceKind::Edge,
            want_edge,
            "trial {trial}: n={n} {d:?}"
        );
    }
}

#[test]
fn prop_edge_region_grows_with_rtt() {
    // If C-NMT keeps a request at the edge under some RTT, it must also
    // keep it at the edge under any larger RTT (monotone boundary).
    let mut rng = Rng::new(0x44);
    for trial in 0..TRIALS {
        let te = TexeModel::from_coeffs(2e-3, 5e-3, rng.uniform(0.0, 20e-3));
        let tc = TexeModel::from_coeffs(0.3e-3, 0.8e-3, rng.uniform(0.0, 40e-3));
        let n2m = N2mRegressor::from_coeffs(0.8, 0.5);
        let n = 1 + rng.usize(61);
        let mut prev_edge = false;
        for step in 0..20 {
            let rtt = step as f64 * 0.02;
            let mut router = RouterBuilder::new(PolicyKind::Cnmt)
                .texe(te, tc)
                .n2m(n2m)
                .ttx(1.0, rtt)
                .build()
                .unwrap();
            router.observe_ttx(0.0, rtt);
            let edge = router.decide(n).device == DeviceKind::Edge;
            assert!(
                edge || !prev_edge,
                "trial {trial}: edge region shrank with rising RTT at n={n}"
            );
            prev_edge = edge;
        }
    }
}

#[test]
fn prop_prefilter_sound_and_complete_bookkeeping() {
    let mut rng = Rng::new(0x55);
    for trial in 0..TRIALS {
        let pair = *rng.choice(&LangPair::ALL);
        let mut gen = CorpusGenerator::new(pair, trial as u64);
        let pairs = gen.take(2_000);
        let rules = PrefilterRules::default();
        let (kept, stats) = prefilter(&pairs, &rules);
        assert_eq!(stats.total, pairs.len());
        assert_eq!(stats.kept + stats.dropped_len + stats.dropped_ratio, stats.total);
        assert_eq!(kept.len(), stats.kept);
        // Soundness: every kept pair satisfies the length rules.
        for p in &kept {
            assert!(p.n() >= rules.min_len && p.n() <= rules.max_len);
            assert!(p.m_real >= rules.min_len && p.m_real <= rules.max_len);
        }
        // Kept is a subsequence of the input.
        let mut it = pairs.iter();
        for k in &kept {
            assert!(it.any(|p| p == k), "kept pair not found in order");
        }
    }
}

#[test]
fn prop_trace_replay_values_come_from_trace() {
    let mut rng = Rng::new(0x66);
    for _ in 0..TRIALS {
        let profile = *rng.choice(&ConnectionProfile::ALL);
        let trace = TraceGenerator::new(rng.next_u64()).profile(profile);
        for _ in 0..50 {
            let t = rng.uniform(0.0, 3.0 * trace.duration());
            let v = trace.rtt_at(t);
            assert!(trace.rtt.iter().any(|&r| (r - v).abs() < 1e-12));
            assert!(v > 0.0);
        }
    }
}

#[test]
fn prop_histogram_quantiles_monotone_and_bounded() {
    let mut rng = Rng::new(0x77);
    for _ in 0..TRIALS {
        let mut h = Histogram::latency();
        let mut max_v: f64 = 0.0;
        for _ in 0..500 {
            let v = rng.lognormal(-4.0, 1.5);
            max_v = max_v.max(v);
            h.record(v);
        }
        let mut prev = 0.0;
        for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let x = h.quantile(q);
            assert!(x >= prev, "quantile not monotone at {q}");
            prev = x;
        }
        // p100 within one bucket of the true max.
        assert!(h.quantile(1.0) >= max_v * 0.95);
    }
}

#[test]
fn prop_histogram_quantiles_track_exact_percentiles() {
    // The geometric-bucket quantile must sit within one bucket-growth
    // factor of the exact order statistic — the precision the queue-wait
    // tail estimates depend on.
    let mut rng = Rng::new(0x7A);
    for trial in 0..TRIALS {
        let mut h = Histogram::latency();
        let mut xs: Vec<f64> = (0..2_000)
            .map(|_| rng.lognormal(-3.0, 1.0))
            .collect();
        for &x in &xs {
            h.record(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.9, 0.95, 0.99] {
            let approx = h.quantile(q);
            let exact = percentile_sorted(&xs, q * 100.0);
            let ratio = approx / exact;
            assert!(
                (0.9..=1.1).contains(&ratio),
                "trial {trial} q={q}: approx {approx} vs exact {exact}"
            );
        }
    }
}

#[test]
fn prop_histogram_empty_and_single_sample() {
    // Empty histogram: every quantile and the mean are NaN (not 0 — a
    // zero would silently poison wait estimates).
    let h = Histogram::latency();
    for q in [0.0, 0.5, 0.99, 1.0] {
        assert!(h.quantile(q).is_nan());
    }
    assert!(h.mean().is_nan());
    // Single sample: every quantile lands in that sample's bucket.
    let mut rng = Rng::new(0x7B);
    for _ in 0..TRIALS {
        let v = rng.lognormal(-4.0, 2.0);
        let mut h = Histogram::latency();
        h.record(v);
        for q in [0.01, 0.5, 1.0] {
            let x = h.quantile(q);
            assert!(
                x >= v * 0.95 && x <= v * 1.05,
                "single sample {v}: quantile({q}) = {x}"
            );
        }
        assert!((h.mean() - v).abs() < 1e-15);
    }
}

#[test]
fn prop_ttx_empty_and_single_sample() {
    let mut rng = Rng::new(0x7C);
    for _ in 0..TRIALS {
        let fallback = rng.uniform(0.0, 1.0);
        let e = TtxEstimator::new(rng.uniform(0.05, 1.0));
        // Empty: the configured prior wins, and the estimate is stale.
        assert_eq!(e.estimate_or(fallback), fallback);
        assert_eq!(e.count(), 0);
        assert!(e.is_stale(rng.uniform(0.0, 1e6), 60.0));
        // Single sample: the estimate is exactly that sample, whatever
        // the smoothing factor.
        let mut e = TtxEstimator::new(rng.uniform(0.05, 1.0));
        let rtt = rng.uniform(0.0, 0.5);
        e.observe(0.0, rtt);
        assert!((e.estimate_or(fallback) - rtt).abs() < 1e-15);
        assert!(!e.is_stale(1.0, 60.0));
    }
}

#[test]
fn prop_ttx_monotone_rtt_keeps_estimate_monotone_and_bounded() {
    // Feeding a non-decreasing RTT series must produce a non-decreasing
    // estimate that never leaves [first, last] — the EWMA cannot
    // overshoot. (The queue-wait estimator leans on this: a degrading
    // network can only push the boundary monotonically.)
    let mut rng = Rng::new(0x7D);
    for trial in 0..TRIALS {
        let alpha = rng.uniform(0.05, 1.0);
        let mut e = TtxEstimator::new(alpha);
        let mut rtt = rng.uniform(0.001, 0.05);
        let first = rtt;
        let mut prev_est = f64::NEG_INFINITY;
        let mut last = rtt;
        for step in 0..200 {
            rtt += rng.exponential(1.0 / 0.002); // non-decreasing drift
            last = rtt;
            e.observe(step as f64, rtt);
            let est = e.estimate_or(0.0);
            assert!(
                est >= prev_est - 1e-15,
                "trial {trial}: estimate decreased under rising RTT"
            );
            assert!(
                est >= first - 1e-15 && est <= last + 1e-15,
                "trial {trial}: estimate {est} left [{first}, {last}]"
            );
            prev_est = est;
        }
    }
}

#[test]
fn prop_contended_run_conserves_requests() {
    // Open-loop contention: every offered request is either completed or
    // shed, whatever the load, policy or scheduler sizing.
    let mut rng = Rng::new(0x7E);
    for trial in 0..8 {
        let load = rng.uniform(2.0, 250.0);
        let (requests, ch) = synth_workload(trial as u64, 1_500, load);
        for policy in [PolicyKind::Cnmt, PolicyKind::EdgeOnly, PolicyKind::CloudOnly] {
            let mut opts = ContentionOpts {
                queue_aware: trial % 2 == 0,
                ..Default::default()
            };
            opts.dispatcher.max_queue_depth = 16 + rng.usize(512);
            let r = run_contended(&requests, &ch, policy, &opts).unwrap();
            assert_eq!(
                r.completed + r.rejected,
                r.offered,
                "trial {trial} {}: conservation broken",
                r.policy
            );
            assert_eq!(r.edge_count + r.cloud_count, r.completed);
            if r.completed > 0 {
                assert!(r.p50_s <= r.p99_s + 1e-12);
                assert!(r.makespan_s > 0.0 && r.throughput_rps > 0.0);
            }
        }
    }
}

#[test]
fn prop_hedged_dispatch_invariants() {
    // Across random loads, hedge margins and queue bounds: every hedged
    // request has exactly one winner, its twin resolves exactly one way
    // (cancelled unrun XOR ran as waste), wasted work never counts
    // toward goodput, and logical-request conservation holds.
    let mut rng = Rng::new(0x8ED6E);
    for trial in 0..6u64 {
        let load = rng.uniform(8.0, 160.0);
        let margin = rng.uniform(0.001, 0.08);
        let (requests, ch) = synth_workload(100 + trial, 2_000, load);
        let mut opts = ContentionOpts {
            adaptive: Some(AdaptiveOpts {
                hedge_margin_s: margin,
                ..Default::default()
            }),
            ..Default::default()
        };
        opts.dispatcher.max_queue_depth = 64 + rng.usize(512);
        let r = run_contended(&requests, &ch, PolicyKind::Cnmt, &opts).unwrap();
        assert_eq!(
            r.hedge_wins_edge + r.hedge_wins_cloud,
            r.hedged,
            "trial {trial}: winners != hedged"
        );
        assert_eq!(
            r.hedge_cancelled + r.hedge_wasted,
            r.hedged,
            "trial {trial}: twin fates don't partition the hedges"
        );
        assert_eq!(
            r.completed + r.rejected,
            r.offered,
            "trial {trial}: logical-request conservation broken"
        );
        assert_eq!(r.edge_count + r.cloud_count, r.completed);
        // Wasted work is exactly the loser-ran case.
        assert_eq!(
            r.hedge_wasted == 0,
            r.wasted_work_s == 0.0,
            "trial {trial}: waste accounting out of sync"
        );
        assert!(r.wasted_frac() < 1.0, "trial {trial}: all work wasted?");
    }
}

#[test]
fn prop_closed_loop_conserves_and_bounds_outstanding() {
    // Bounded-outstanding clients: nothing is shed (K ≪ queue bound),
    // conservation holds, and no queue can ever hold more than K
    // entries because each client has at most one request in flight.
    let mut rng = Rng::new(0xC705);
    for trial in 0..4u64 {
        let clients = 1 + rng.usize(32);
        let think_s = rng.uniform(0.0, 0.05);
        let (pool, ch) = synth_workload(500 + trial, 1_000, 1.0);
        let opts = ContentionOpts::default();
        let r =
            run_closed_loop(&pool, &ch, PolicyKind::Cnmt, &opts, clients, think_s).unwrap();
        assert_eq!(r.completed + r.rejected, r.offered, "trial {trial}");
        assert_eq!(r.rejected, 0, "trial {trial}: closed loop shed load");
        assert!(
            r.edge_peak_depth <= clients && r.cloud_peak_depth <= clients,
            "trial {trial}: queue depth {}/{} exceeded {clients} outstanding",
            r.edge_peak_depth,
            r.cloud_peak_depth
        );
        assert!(r.makespan_s > 0.0 && r.throughput_rps > 0.0, "trial {trial}");
    }
}

#[test]
fn prop_rls_refit_converges_to_true_plane() {
    // RLS under stationary noise must recover a planted T_exe plane,
    // with and without forgetting — the property the drift scenario's
    // recovery rests on.
    let mut rng = Rng::new(0xCC);
    for trial in 0..12u64 {
        let truth = TexeModel::from_coeffs(
            rng.uniform(1e-4, 5e-3),
            rng.uniform(1e-3, 1e-2),
            rng.uniform(0.0, 0.05),
        );
        let lambda = if trial % 2 == 0 { 1.0 } else { 0.995 };
        let mut rls =
            RlsPlane::new(TexeModel::from_coeffs(0.0, 0.0, 0.0), lambda, 1e4).unwrap();
        for _ in 0..3_000 {
            let n = (1 + rng.usize(61)) as f64;
            let m = (1 + rng.usize(61)) as f64;
            let t = (truth.estimate(n as usize, m) + rng.normal_ms(0.0, 1e-4)).max(0.0);
            rls.observe(n, m, t);
        }
        let fit = rls.model();
        assert!(
            (fit.alpha_n - truth.alpha_n).abs() < 5e-4,
            "trial {trial}: alpha_n {} vs {}",
            fit.alpha_n,
            truth.alpha_n
        );
        assert!(
            (fit.alpha_m - truth.alpha_m).abs() < 5e-4,
            "trial {trial}: alpha_m {} vs {}",
            fit.alpha_m,
            truth.alpha_m
        );
        assert!(
            (fit.beta - truth.beta).abs() < 5e-3,
            "trial {trial}: beta {} vs {}",
            fit.beta,
            truth.beta
        );
    }
}

#[test]
fn prop_slab_recycled_slots_never_alias_stale_keys() {
    // The arena's load-bearing safety property: whatever the
    // insert/remove interleaving, a key whose entry was removed must
    // never read, mutate or remove a later occupant of the recycled
    // slot — and live keys must always see exactly their own value.
    let mut rng = Rng::new(0x51AB);
    for trial in 0..TRIALS {
        let mut slab: Slab<u64> = Slab::new();
        let mut live: Vec<(SlabKey, u64)> = Vec::new();
        let mut stale: Vec<SlabKey> = Vec::new();
        let mut inserts = 0usize;
        let mut next_value = (trial as u64) << 32;
        for _ in 0..400 {
            match rng.usize(10) {
                // Insert-heavy mix keeps slots cycling through reuse.
                0..=4 => {
                    let key = slab.insert(next_value);
                    live.push((key, next_value));
                    inserts += 1;
                    next_value += 1;
                }
                5..=7 if !live.is_empty() => {
                    let (key, value) = live.swap_remove(rng.usize(live.len()));
                    assert_eq!(slab.remove(key), Some(value), "trial {trial}");
                    stale.push(key);
                }
                _ => {}
            }
            if !stale.is_empty() {
                let key = stale[rng.usize(stale.len())];
                assert_eq!(slab.get(key), None, "trial {trial}: stale key read");
                assert_eq!(slab.remove(key), None, "trial {trial}: stale key removed");
            }
            for &(key, value) in &live {
                assert_eq!(slab.get(key), Some(&value), "trial {trial}: live key lost");
            }
            assert_eq!(slab.len(), live.len(), "trial {trial}: population drifted");
        }
        // Slots were genuinely recycled, so the aliasing property was
        // actually exercised (fewer physical slots than inserts).
        assert!(
            stale.is_empty() || slab.capacity() < inserts,
            "trial {trial}: arena never recycled a slot"
        );
    }
}

/// Deterministic per-device batch times for the dispatcher properties.
struct PropExec {
    edge_s: f64,
    cloud_s: f64,
}

impl BatchExecutor for PropExec {
    fn execute(
        &mut self,
        d: cnmt::devices::DeviceKind,
        batch: &[QueuedRequest],
        _s: f64,
    ) -> f64 {
        let each = match d {
            cnmt::devices::DeviceKind::Edge => self.edge_s,
            cnmt::devices::DeviceKind::Cloud => self.cloud_s,
        };
        each * (1.0 + 0.1 * (batch.len() - 1) as f64)
    }
}

#[test]
fn prop_dense_dispatch_conserves_across_purge_and_cancel() {
    // Direct-dispatcher conservation under the slab/ring paths: across
    // random rates, sizes and hedge mixes, every admitted logical
    // request produces exactly one result completion, twin fates
    // partition the hedges, ghosts release their admission slots, and a
    // drained dispatcher leaves an empty arena (nothing leaks).
    let mut rng = Rng::new(0xD15B);
    for trial in 0..20u64 {
        let cfg = DispatcherConfig {
            edge_workers: 1,
            cloud_workers: 1 + rng.usize(4),
            max_queue_depth: 4 + rng.usize(64),
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        let mut exec = PropExec {
            edge_s: rng.uniform(1e-3, 2e-2),
            cloud_s: rng.uniform(1e-3, 2e-2),
        };
        let interarrival = rng.uniform(5e-4, 1e-2);
        let hedge_p = rng.uniform(0.0, 0.6);
        let requests = 800usize;
        let mut admitted = 0u64;
        let mut results = 0u64;
        let mut losses = 0u64;
        let mut t = 0.0f64;
        let mut on_c = |c: cnmt::scheduler::Completion| {
            if c.kind.is_result() {
                results += 1;
            } else {
                losses += 1;
            }
        };
        for i in 0..requests as u64 {
            t += interarrival;
            disp.run_until(t, &mut exec, &mut on_c);
            let rq = QueuedRequest {
                id: i,
                payload: i as usize,
                n: 1 + rng.usize(61),
                m_est: rng.uniform(1.0, 60.0),
                est_service_s: rng.uniform(1e-3, 2e-2),
                arrival_s: t,
                bucket: 0,
                hedge: None,
            };
            if rng.bool(hedge_p) {
                match disp.submit_hedged(rq, exec.edge_s, exec.cloud_s) {
                    HedgeOutcome::Hedged | HedgeOutcome::Single(_) => admitted += 1,
                    HedgeOutcome::Rejected => {}
                }
            } else {
                let device = if rng.bool(0.5) {
                    cnmt::devices::DeviceKind::Edge
                } else {
                    cnmt::devices::DeviceKind::Cloud
                };
                if disp.submit(device, rq).is_admitted() {
                    admitted += 1;
                }
            }
        }
        disp.run_until(f64::INFINITY, &mut exec, &mut on_c);
        let hs = disp.hedge_stats();
        assert_eq!(results, admitted, "trial {trial}: results != admitted requests");
        assert_eq!(losses, hs.losers_run, "trial {trial}: loss accounting drifted");
        assert_eq!(
            hs.wins_edge + hs.wins_cloud,
            hs.hedged,
            "trial {trial}: winners != hedged"
        );
        assert_eq!(
            hs.cancelled_unrun + hs.losers_run,
            hs.hedged,
            "trial {trial}: twin fates don't partition"
        );
        assert!(disp.idle(), "trial {trial}: dispatcher not drained");
        assert_eq!(
            disp.hedges_in_flight(),
            0,
            "trial {trial}: hedge arena leaked entries"
        );
        for device in [cnmt::devices::DeviceKind::Edge, cnmt::devices::DeviceKind::Cloud] {
            assert_eq!(disp.depth(device), 0, "trial {trial}: ghost left in queue");
            // All in-flight work charged to the trackers was released
            // (up to add/sub float dust from interleaved batches).
            assert!(
                disp.expected_wait_s(device, t + 1e6) < 1e-9,
                "trial {trial}: backlog estimate leaked"
            );
        }
    }
}

#[test]
fn prop_dense_dispatcher_is_bit_equivalent_to_frozen_baseline() {
    // THE rewrite-correctness oracle: the zero-churn dispatcher must be
    // a pure data-structure change. Random solo/hedged streams through
    // the dense implementation and the frozen pre-rewrite baseline
    // (`scheduler::baseline`) must produce identical completion
    // sequences — same ids, devices, kinds, batch sizes, and bit-equal
    // times — and identical hedge statistics.
    let mut rng = Rng::new(0xD1FF);
    for trial in 0..12u64 {
        let cfg = DispatcherConfig {
            edge_workers: 1 + rng.usize(2),
            cloud_workers: 1 + rng.usize(4),
            max_queue_depth: 4 + rng.usize(48),
            ..Default::default()
        };
        let mut dense = Dispatcher::new(&cfg);
        let mut base = BaselineDispatcher::new(&cfg);
        let edge_s = rng.uniform(1e-3, 3e-2);
        let cloud_s = rng.uniform(1e-3, 3e-2);
        let mut exec = PropExec { edge_s, cloud_s };
        let interarrival = rng.uniform(5e-4, 8e-3);
        let hedge_p = rng.uniform(0.0, 0.7);
        let mut cd: Vec<(u64, cnmt::devices::DeviceKind, CompletionKind, usize, u64, u64)> =
            Vec::new();
        let mut cb = cd.clone();
        let mut t = 0.0f64;
        for i in 0..600u64 {
            t += interarrival;
            dense.run_until(t, &mut exec, &mut |c| {
                cd.push((
                    c.request.id,
                    c.device,
                    c.kind,
                    c.batch_size,
                    c.done_s.to_bits(),
                    c.start_s.to_bits(),
                ))
            });
            base.run_until(t, &mut exec, &mut |c| {
                cb.push((
                    c.request.id,
                    c.device,
                    c.kind,
                    c.batch_size,
                    c.done_s.to_bits(),
                    c.start_s.to_bits(),
                ))
            });
            let rq = QueuedRequest {
                id: i,
                payload: i as usize,
                n: 1 + rng.usize(61),
                m_est: rng.uniform(1.0, 60.0),
                est_service_s: rng.uniform(1e-3, 2e-2),
                arrival_s: t,
                bucket: 0,
                hedge: None,
            };
            if rng.bool(hedge_p) {
                assert_eq!(
                    dense.submit_hedged(rq, edge_s, cloud_s),
                    base.submit_hedged(rq, edge_s, cloud_s),
                    "trial {trial} @ {i}: admission outcome diverged"
                );
            } else {
                let device = if rng.bool(0.5) {
                    cnmt::devices::DeviceKind::Edge
                } else {
                    cnmt::devices::DeviceKind::Cloud
                };
                assert_eq!(
                    dense.submit(device, rq).is_admitted(),
                    base.submit(device, rq).is_admitted(),
                    "trial {trial} @ {i}: admission diverged"
                );
            }
        }
        dense.run_until(f64::INFINITY, &mut exec, &mut |c| {
            cd.push((
                c.request.id,
                c.device,
                c.kind,
                c.batch_size,
                c.done_s.to_bits(),
                c.start_s.to_bits(),
            ))
        });
        base.run_until(f64::INFINITY, &mut exec, &mut |c| {
            cb.push((
                c.request.id,
                c.device,
                c.kind,
                c.batch_size,
                c.done_s.to_bits(),
                c.start_s.to_bits(),
            ))
        });
        assert_eq!(cd, cb, "trial {trial}: completion sequences diverged");
        let (hd, hb) = (dense.hedge_stats(), base.hedge_stats());
        assert_eq!(hd.hedged, hb.hedged, "trial {trial}");
        assert_eq!(hd.wins_edge, hb.wins_edge, "trial {trial}");
        assert_eq!(hd.wins_cloud, hb.wins_cloud, "trial {trial}");
        assert_eq!(hd.cancelled_unrun, hb.cancelled_unrun, "trial {trial}");
        assert_eq!(hd.losers_run, hb.losers_run, "trial {trial}");
        assert_eq!(
            dense.batch_stats().batches,
            base.batch_stats().batches,
            "trial {trial}: batch counts diverged"
        );
    }
}

#[test]
fn prop_fleet_pair_is_bit_equivalent_to_contended_across_random_loads() {
    // THE fleet-refactor oracle at harness scope: across random offered
    // loads, the fleet replay on the 1×1 topology must reproduce the
    // pair replay bit for bit — Static ≡ queue-blind cnmt, Select ≡
    // cnmt+queue, Hedged ≡ cnmt+adaptive with the RLS refit disabled.
    use cnmt::fleet::{FleetStrategy, Topology};
    use cnmt::sim::{run_fleet, ContendedResult, FleetOpts, FleetResult};
    fn assert_same(tag: &str, f: &FleetResult, p: &ContendedResult) {
        assert_eq!(f.offered, p.offered, "{tag}");
        assert_eq!(f.completed, p.completed, "{tag}");
        assert_eq!(f.rejected, p.rejected, "{tag}");
        assert_eq!(f.edge_count, p.edge_count, "{tag}");
        assert_eq!(f.cloud_count, p.cloud_count, "{tag}");
        assert_eq!(f.makespan_s.to_bits(), p.makespan_s.to_bits(), "{tag}");
        assert_eq!(f.mean_latency_s.to_bits(), p.mean_latency_s.to_bits(), "{tag}");
        assert_eq!(f.p50_s.to_bits(), p.p50_s.to_bits(), "{tag}");
        assert_eq!(f.p99_s.to_bits(), p.p99_s.to_bits(), "{tag}");
        assert_eq!(f.mean_batch.to_bits(), p.mean_batch.to_bits(), "{tag}");
        assert_eq!(f.hedged, p.hedged, "{tag}");
        assert_eq!(f.hedge_cancelled, p.hedge_cancelled, "{tag}");
        assert_eq!(f.hedge_wasted, p.hedge_wasted, "{tag}");
        assert_eq!(f.useful_work_s.to_bits(), p.useful_work_s.to_bits(), "{tag}");
        assert_eq!(f.wasted_work_s.to_bits(), p.wasted_work_s.to_bits(), "{tag}");
    }
    let mut rng = Rng::new(0xF1D1FF);
    let topo = Topology::pair();
    for trial in 0..4u64 {
        let load = rng.uniform(8.0, 200.0);
        let (requests, ch) = synth_workload(900 + trial, 2_000, load);
        let fleet = |strategy: FleetStrategy| {
            run_fleet(&requests, &ch, &topo, &FleetOpts { strategy, ..Default::default() })
                .unwrap()
        };
        let pair = |queue_aware: bool, adaptive: Option<AdaptiveOpts>| {
            let opts = ContentionOpts { queue_aware, adaptive, ..Default::default() };
            run_contended(&requests, &ch, PolicyKind::Cnmt, &opts).unwrap()
        };
        assert_same(
            &format!("trial {trial} static"),
            &fleet(FleetStrategy::Static),
            &pair(false, None),
        );
        assert_same(
            &format!("trial {trial} select"),
            &fleet(FleetStrategy::Select),
            &pair(true, None),
        );
        let no_refit = AdaptiveOpts {
            hedge_margin_s: 0.010,
            refit_min_obs: u64::MAX,
            refit_ttx: false,
            waste_budget: 0.0, // fixed margin, like the adaptive-less fleet side
            ..Default::default()
        };
        assert_same(
            &format!("trial {trial} hedge"),
            &fleet(FleetStrategy::Hedged { margin_s: 0.010 }),
            &pair(true, Some(no_refit)),
        );
        // Full adaptive stack on both sides: per-device refit + the
        // waste-budget margin controller.
        let full = AdaptiveOpts::default();
        let adaptive_fleet = run_fleet(
            &requests,
            &ch,
            &topo,
            &FleetOpts {
                strategy: FleetStrategy::Hedged { margin_s: full.hedge_margin_s },
                adaptive: Some(full),
                ..Default::default()
            },
        )
        .unwrap();
        assert_same(
            &format!("trial {trial} hedge+refit+budget"),
            &adaptive_fleet,
            &pair(true, Some(full)),
        );
    }
}

#[test]
fn prop_waste_budget_caps_wasted_frac_across_random_loads() {
    // THE controller acceptance property: across random offered loads
    // and budgets, an adaptive run's end-to-end wasted-work fraction
    // must settle within two points of (or below) the configured
    // budget — the margin self-tunes instead of burning blindly.
    let mut rng = Rng::new(0xB4D6E7);
    for trial in 0..6u64 {
        let load = rng.uniform(8.0, 160.0);
        let budget = rng.uniform(0.04, 0.15);
        let (requests, ch) = synth_workload(4_200 + trial, 4_000, load);
        let opts = ContentionOpts {
            adaptive: Some(AdaptiveOpts { waste_budget: budget, ..Default::default() }),
            ..Default::default()
        };
        let r = run_contended(&requests, &ch, PolicyKind::Cnmt, &opts).unwrap();
        assert_eq!(r.completed + r.rejected, r.offered, "trial {trial}");
        let wf = r.wasted_frac();
        assert!(
            wf <= budget + 0.02,
            "trial {trial}: wasted_frac {wf} blew the {budget} budget at {load} r/s"
        );
        // The controller genuinely ran (margin reported, inside bounds).
        assert!(
            r.hedge_final_margin_s.is_finite()
                && r.hedge_final_margin_s >= cnmt::scheduler::hedge::HEDGE_MIN_MARGIN_S
                && r.hedge_final_margin_s <= cnmt::scheduler::hedge::HEDGE_MAX_MARGIN_S,
            "trial {trial}: final margin {} out of bounds",
            r.hedge_final_margin_s
        );
    }
}

#[test]
fn prop_fleet_drift_moves_only_the_pinned_device_results() {
    // Lane-pinned drift at fleet scope: with refit on, replaying the
    // same workload with and without the drift must leave the *other*
    // devices' planes untouched inside the refit bank — asserted
    // indirectly here at run scope via conservation, and directly at
    // selector scope in fleet::select's isolation test. Here we assert
    // the run-level contract: the drifted run still conserves, labels
    // carry +refit, and the pinned device genuinely lost traffic
    // relative to the stationary replay.
    use cnmt::fleet::Topology;
    use cnmt::sim::{run_fleet, DriftSpec, FleetOpts};
    let topo = Topology::hetero();
    let (requests, ch) = synth_workload(0xD81F8, 4_000, 224.0);
    let pinned = 4usize; // hetero cloud0
    let run = |drift: Option<DriftSpec>| {
        let opts = FleetOpts {
            adaptive: Some(AdaptiveOpts::default()),
            drift,
            ..Default::default()
        };
        run_fleet(&requests, &ch, &topo, &opts).unwrap()
    };
    let stationary = run(None);
    let drifted = run(Some(DriftSpec {
        device: cnmt::devices::DeviceKind::Cloud,
        lane: Some(pinned),
        start_s: 4.0,
        ramp_s: 5.0,
        factor: 2.5,
    }));
    for r in [&stationary, &drifted] {
        assert_eq!(r.policy, "fleet+select+refit");
        assert_eq!(r.completed + r.rejected, r.offered);
        assert_eq!(r.device_results.iter().sum::<usize>(), r.completed);
    }
    assert!(
        drifted.device_results[pinned] < stationary.device_results[pinned],
        "throttled replica kept its traffic: {} vs {}",
        drifted.device_results[pinned],
        stationary.device_results[pinned]
    );
}

#[test]
fn prop_fleet_runs_conserve_across_random_topologies() {
    // Random fleet shapes, speeds, links and loads: every strategy
    // conserves logical requests, per-device results sum to completed,
    // and the hedge bookkeeping partitions.
    use cnmt::fleet::{DeviceSpec, FleetStrategy, Topology};
    use cnmt::sim::{run_fleet, FleetOpts};
    let mut rng = Rng::new(0xF1EE7C);
    for trial in 0..6u64 {
        let edges = 1 + rng.usize(6);
        let clouds = 1 + rng.usize(3);
        let mut devices = Vec::new();
        for i in 0..edges {
            devices.push(DeviceSpec::edge(&format!("e{i}"), rng.uniform(0.4, 2.5)));
        }
        for i in 0..clouds {
            devices.push(DeviceSpec::cloud(
                &format!("c{i}"),
                rng.uniform(0.4, 2.0),
                rng.uniform(0.8, 2.0),
            ));
        }
        let topo = Topology { name: format!("rand{trial}"), devices };
        let load = rng.uniform(20.0, 400.0);
        let (requests, ch) = synth_workload(7_000 + trial, 1_500, load);
        for strategy in [
            FleetStrategy::Static,
            FleetStrategy::Random { seed: trial },
            FleetStrategy::Select,
            FleetStrategy::Hedged { margin_s: rng.uniform(0.001, 0.05) },
        ] {
            let r = run_fleet(
                &requests,
                &ch,
                &topo,
                &FleetOpts { strategy, ..Default::default() },
            )
            .unwrap();
            let tag = format!("trial {trial} {}", r.policy);
            assert_eq!(r.completed + r.rejected, r.offered, "{tag}");
            assert_eq!(r.edge_count + r.cloud_count, r.completed, "{tag}");
            assert_eq!(r.device_results.iter().sum::<usize>(), r.completed, "{tag}");
            assert_eq!(r.device_results.len(), topo.len(), "{tag}");
            assert_eq!(r.hedge_wins_edge + r.hedge_wins_cloud, r.hedged, "{tag}");
            assert_eq!(r.hedge_cancelled + r.hedge_wasted, r.hedged, "{tag}");
            assert!(r.wasted_frac() < 1.0 || r.completed == 0, "{tag}");
        }
    }
}

#[test]
fn prop_online_stats_merge_equals_concat() {
    let mut rng = Rng::new(0x88);
    for _ in 0..TRIALS {
        let n = 10 + rng.usize(500);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal_ms(5.0, 3.0)).collect();
        let cut = rng.usize(n);
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut whole = OnlineStats::new();
        for (i, &x) in xs.iter().enumerate() {
            if i < cut { a.push(x) } else { b.push(x) }
            whole.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-7);
    }
}

#[test]
fn prop_json_roundtrip_random_trees() {
    let mut rng = Rng::new(0x99);
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.usize(4) } else { rng.usize(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.normal_ms(0.0, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.usize(12);
                let alphabet = ['a', 'é', '"', '\\', '\n', '😀', 'z'];
                Json::Str((0..n).map(|_| *rng.choice(&alphabet)).collect())
            }
            4 => Json::Array((0..rng.usize(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::object();
                for i in 0..rng.usize(5) {
                    o.set(&format!("k{i}"), gen(rng, depth - 1));
                }
                o
            }
        }
    }
    for _ in 0..TRIALS * 3 {
        let v = gen(&mut rng, 3);
        let compact = Json::parse(&v.to_string()).unwrap();
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(compact, v);
        assert_eq!(pretty, v);
    }
}

#[test]
fn prop_oracle_dominates_across_random_configs() {
    // The Oracle invariant under randomised scale parameters — the load-
    // bearing property of the whole evaluation.
    let mut rng = Rng::new(0xAA);
    for trial in 0..8 {
        let mut cfg = Config::smoke();
        cfg.requests = 800;
        cfg.fit_inferences = 400;
        cfg.eval_pool = 800;
        cfg.seed = rng.next_u64();
        cfg.mean_interarrival_s = rng.uniform(0.05, 1.0);
        let pair = *rng.choice(&LangPair::ALL);
        let profile = *rng.choice(&ConnectionProfile::ALL);
        let table =
            TruthTable::build(&cfg, pair, profile, &Calibration::default_paper())
                .unwrap();
        let results = run_all_policies(&table).unwrap();
        let oracle = results.iter().find(|r| r.policy == "oracle").unwrap();
        for r in &results {
            assert!(
                oracle.total_s <= r.total_s + 1e-9,
                "trial {trial} {}/{}: oracle beaten by {}",
                pair.id(),
                profile.id(),
                r.policy
            );
        }
    }
}

#[test]
fn prop_texe_estimates_nonnegative_and_monotone_in_m() {
    let mut rng = Rng::new(0xBB);
    for _ in 0..TRIALS {
        let t = TexeModel::from_coeffs(
            rng.uniform(-1e-4, 5e-3),
            rng.uniform(0.0, 10e-3),
            rng.uniform(-5e-3, 30e-3),
        );
        let n = 1 + rng.usize(61);
        let mut prev = 0.0;
        for m in 0..64 {
            let est = t.estimate(n, m as f64);
            assert!(est >= 0.0);
            assert!(est + 1e-12 >= prev, "not monotone in m");
            prev = est;
        }
    }
}

#[test]
fn prop_detector_quiescent_on_stationary_fault_free_workloads() {
    // Detection-quality floor: on a fault-free stationary workload the
    // online detector must raise NOTHING, across seeds and operating
    // points (idle → the tuned contended load). A single false raise
    // here is a mistuned chart, and the blame partition must re-verify
    // bit-exactly on every completed chain while it stays quiet.
    use cnmt::fleet::Topology;
    use cnmt::obs::{verify_blame, DetectCfg, Detector, TelemetryCfg};
    use cnmt::scheduler::RetryPolicy;
    use cnmt::sim::{run_fleet_outage_detect, FleetOpts};
    let topo = Topology::hetero();
    let tiers: Vec<_> = topo.devices.iter().map(|d| d.tier).collect();
    let opts = FleetOpts {
        telemetry: Some(TelemetryCfg::default()),
        ..Default::default()
    };
    let retry = RetryPolicy::default();
    for trial in 0..9u64 {
        for load in [96.0, 160.0, 224.0] {
            let (pool, ch) = synth_workload(0xDE7EC7 + trial * 131, 2_000, load);
            let det = Detector::new(&tiers, DetectCfg::default());
            let (out, _rec) = run_fleet_outage_detect(
                &pool, &ch, &topo, &opts, None, &retry, det, None,
            )
            .unwrap();
            assert_eq!(
                out.raised, 0,
                "trial {trial} load {load}: false alert(s) {:?}",
                out.alerts
            );
            assert!(out.alerts.is_empty());
            assert_eq!(out.cleared, 0);
            verify_blame(&out.blame).unwrap();
            // Fault-free failover run: nothing strands, every chain is
            // a clean single attempt.
            assert_eq!(out.result.stranded, 0, "trial {trial} load {load}");
            assert!(
                out.blame.iter().all(|c| c.attempts == 1),
                "trial {trial} load {load}: retries without a fault"
            );
        }
    }
}
