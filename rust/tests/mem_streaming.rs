//! Counting-allocator proof that the streaming harness really runs in
//! O(outstanding) memory: the live-byte **peak** of a streamed
//! contended run stays flat as the workload grows 10×, and sits far
//! below what materializing the truth table would cost.
//!
//! A global allocator wrapper tracks live bytes and their high-water
//! mark (realloc included). Each measurement builds the arrival stream
//! lazily with `synth_stream`, resets the watermark to the current
//! live level, runs `run_contended_streamed`, and reads back the peak
//! delta. A materialized run would hold `requests ×
//! size_of::<RequestTruth>()` alive throughout, so a flat peak across
//! a 10× size jump is only reachable by actually streaming.
//!
//! This file deliberately contains exactly one `#[test]`: the harness
//! runs tests within a binary on multiple threads, and any concurrent
//! test's allocations would pollute the (process-global) watermark.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicIsize, Ordering};

use cnmt::coordinator::PolicyKind;
use cnmt::experiments::load::{synth_characterization, synth_stream};
use cnmt::sim::{run_contended_streamed, AdaptiveOpts, ContentionOpts, RequestTruth};

struct WatermarkAlloc;

static LIVE: AtomicIsize = AtomicIsize::new(0);
static PEAK: AtomicIsize = AtomicIsize::new(0);

fn bump(delta: isize) {
    let now = LIVE.fetch_add(delta, Ordering::SeqCst) + delta;
    PEAK.fetch_max(now, Ordering::SeqCst);
}

unsafe impl GlobalAlloc for WatermarkAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            bump(layout.size() as isize);
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            bump(layout.size() as isize);
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            bump(new_size as isize - layout.size() as isize);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size() as isize, Ordering::SeqCst);
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: WatermarkAlloc = WatermarkAlloc;

const SEED: u64 = 20220315;
const LOAD_RPS: f64 = 96.0;

/// Run the streamed contended harness over `requests` lazily generated
/// arrivals and return the peak of live bytes above the pre-run level.
fn streamed_peak(requests: usize) -> isize {
    let ch = synth_characterization(SEED, requests, LOAD_RPS);
    let opts = ContentionOpts {
        queue_aware: true,
        adaptive: Some(AdaptiveOpts::default()),
        ..Default::default()
    };
    let base = LIVE.load(Ordering::SeqCst);
    PEAK.store(base, Ordering::SeqCst);
    let arrivals = synth_stream(SEED, requests, LOAD_RPS).map(Ok);
    let res = run_contended_streamed(arrivals, &ch, PolicyKind::Cnmt, &opts)
        .expect("streamed run");
    assert_eq!(res.offered, requests);
    assert!(res.completed > 0, "no request completed");
    PEAK.load(Ordering::SeqCst) - base
}

#[test]
fn streamed_peak_memory_is_flat_in_total_requests() {
    const SMALL: usize = 20_000;
    const BIG: usize = 10 * SMALL;

    // Warm-up: lazy globals, histogram tables, dispatcher rings reach
    // their steady shapes before anything is measured.
    let _ = streamed_peak(2_000);

    let peak_small = streamed_peak(SMALL);
    let peak_big = streamed_peak(BIG);
    assert!(peak_small > 0, "allocator saw nothing ({peak_small})");

    // O(outstanding), not O(total): 10× the requests may not even
    // double the peak (generous slack for allocator rounding).
    let bound = 2 * peak_small + (256 << 10);
    assert!(
        peak_big <= bound,
        "peak grew with workload size: {peak_small} B at {SMALL} requests but \
         {peak_big} B at {BIG} requests (bound {bound} B)"
    );

    // And it is nowhere near the cost of materializing the truth
    // table, which is what the non-streaming paths pay.
    let materialized_floor = (BIG * std::mem::size_of::<RequestTruth>()) as isize;
    assert!(
        peak_big < materialized_floor / 4,
        "peak {peak_big} B is within 4x of a materialized truth table \
         ({materialized_floor} B) — is the stream being collected?"
    );
}
