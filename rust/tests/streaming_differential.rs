//! Differential proof that the streaming harness changes nothing:
//! every streamed twin (`run_contended_streamed`,
//! `run_closed_loop_streamed`, `run_fleet_streamed`,
//! `run_fleet_closed_streamed` and the experiment-layer sweeps built on
//! them) must reproduce its materialized original **byte-for-byte** at
//! the report-JSON level, with the materialized accounting as the
//! oracle. The `obs` flight recorder rides along on the traced pair to
//! prove the decision log survives streaming event-for-event, and a
//! recorded binary trace replays identically to the live generator.

use std::io::Cursor;

use cnmt::coordinator::PolicyKind;
use cnmt::experiments::{fleet, load};
use cnmt::obs::FlightRecorder;
use cnmt::sim::{
    run_contended, run_contended_streamed, run_contended_streamed_traced, run_contended_traced,
    AdaptiveOpts, ContentionOpts, RequestTruth,
};
use cnmt::trace::{record_synth, SynthSpec, SynthTrace, TraceReader};

fn adaptive_opts() -> ContentionOpts {
    ContentionOpts {
        queue_aware: true,
        adaptive: Some(AdaptiveOpts::default()),
        ..Default::default()
    }
}

#[test]
fn load_sweep_streamed_is_bit_identical() {
    let cfg = load::LoadConfig {
        requests_per_point: 3_000,
        loads_rps: vec![8.0, 96.0],
        ..Default::default()
    };
    let materialized = load::run(&cfg).expect("materialized sweep");
    let streamed = load::run_streamed(&cfg).expect("streamed sweep");
    assert_eq!(
        load::to_json(&materialized).to_string_pretty(),
        load::to_json(&streamed).to_string_pretty(),
        "streamed load sweep diverged from the materialized oracle"
    );
    // The streamed cells are pure functions of the cell index too:
    // sharding them over threads must not move a byte.
    let sharded_cfg = load::LoadConfig {
        requests_per_point: 3_000,
        loads_rps: vec![8.0, 96.0],
        threads: 4,
        ..Default::default()
    };
    let sharded = load::run_streamed(&sharded_cfg).expect("sharded streamed sweep");
    assert_eq!(
        load::to_json(&materialized).to_string_pretty(),
        load::to_json(&sharded).to_string_pretty(),
        "streamed load sweep is thread-count dependent"
    );
}

#[test]
fn closed_loop_streamed_is_bit_identical() {
    let cfg = load::ClosedLoopConfig {
        requests_per_point: 2_000,
        clients: vec![1, 8],
        ..Default::default()
    };
    let materialized = load::run_closed(&cfg).expect("materialized closed loop");
    let streamed = load::run_closed_streamed(&cfg).expect("streamed closed loop");
    assert_eq!(
        load::closed_to_json(&materialized).to_string_pretty(),
        load::closed_to_json(&streamed).to_string_pretty(),
        "streamed closed loop diverged from the materialized oracle"
    );
}

fn smoke_shapes() -> Vec<fleet::ShapeSpec> {
    ["1x1", "4x2"]
        .iter()
        .map(|s| {
            let topo = cnmt::fleet::Topology::preset(s).expect("built-in preset");
            let offered_rps = fleet::default_offered_rps(&topo);
            fleet::ShapeSpec { topo, offered_rps }
        })
        .collect()
}

#[test]
fn fleet_sweep_streamed_is_bit_identical() {
    let cfg = fleet::FleetConfig {
        requests_per_point: 1_500,
        shapes: smoke_shapes(),
        ..Default::default()
    };
    let materialized = fleet::run(&cfg).expect("materialized fleet sweep");
    let streamed = fleet::run_streamed(&cfg).expect("streamed fleet sweep");
    assert_eq!(
        fleet::to_json(&materialized).to_string_pretty(),
        fleet::to_json(&streamed).to_string_pretty(),
        "streamed fleet sweep diverged from the materialized oracle"
    );
}

#[test]
fn fleet_closed_streamed_is_bit_identical() {
    let cfg = fleet::FleetClosedConfig {
        requests_per_point: 1_500,
        clients: vec![8],
        ..Default::default()
    };
    let materialized = fleet::run_closed(&cfg).expect("materialized fleet closed loop");
    let streamed = fleet::run_closed_streamed(&cfg).expect("streamed fleet closed loop");
    assert_eq!(
        fleet::closed_to_json(&materialized).to_string_pretty(),
        fleet::closed_to_json(&streamed).to_string_pretty(),
        "streamed fleet closed loop diverged from the materialized oracle"
    );
}

#[test]
fn flight_recorder_event_stream_survives_streaming() {
    let (truths, ch) = load::synth_workload(777, 4_000, 120.0);
    let opts = adaptive_opts();
    let (res_m, rec_m) = run_contended_traced(
        &truths,
        &ch,
        PolicyKind::Cnmt,
        &opts,
        FlightRecorder::new(1 << 15),
    )
    .expect("materialized traced run");
    let (res_s, rec_s) = run_contended_streamed_traced(
        truths.iter().copied().map(Ok),
        &ch,
        PolicyKind::Cnmt,
        &opts,
        FlightRecorder::new(1 << 15),
    )
    .expect("streamed traced run");
    assert_eq!(
        res_m.to_json().to_string_pretty(),
        res_s.to_json().to_string_pretty(),
        "traced result diverged under streaming"
    );
    assert!(rec_m.total() > 0, "recorder saw no events");
    assert_eq!(rec_m.total(), rec_s.total(), "event counts diverged");
    assert_eq!(
        rec_m.window_jsonl(),
        rec_s.window_jsonl(),
        "decision-log event stream diverged under streaming"
    );
}

#[test]
fn recorded_trace_replays_identically_to_the_live_generator() {
    let spec =
        SynthSpec { seed: 4242, requests: 5_000, offered_rps: 96.0, exec_noise_std: 0.0 };
    let (header, bytes) = record_synth(&spec, Vec::new()).expect("record");
    let ch = header.characterization();
    let live: Vec<RequestTruth> = SynthTrace::new(&spec).collect();
    let opts = adaptive_opts();
    let from_live =
        run_contended(&live, &ch, PolicyKind::Cnmt, &opts).expect("live run");
    let reader = TraceReader::open(Cursor::new(&bytes)).expect("open trace");
    let from_trace = run_contended_streamed(reader, &ch, PolicyKind::Cnmt, &opts)
        .expect("trace replay");
    assert_eq!(
        from_live.to_json().to_string_pretty(),
        from_trace.to_json().to_string_pretty(),
        "trace replay diverged from the live run"
    );
}
