//! Decision-log replay differential: the offline trace verifier must
//! re-derive the contended harness's accounting — admitted, shed,
//! completed, hedge fates, wasted work, final hedge margin — from the
//! dumped event stream alone, across random loads, policies and
//! scheduler sizings. This is `cnmt trace verify`'s guarantee: the
//! flight recorder's log is a complete, self-consistent account of the
//! run, not a best-effort annotation.

use cnmt::coordinator::PolicyKind;
use cnmt::experiments::load::synth_workload;
use cnmt::obs::{verify_trace, FlightRecorder};
use cnmt::sim::{run_contended_traced, AdaptiveOpts, ContentionOpts};
use cnmt::util::Rng;

/// Ring bound comfortably above the event volume of every trial below
/// (~8 events per request plus margin/refit ticks), so no trial's trace
/// is truncated — the verifier rejects incomplete windows by design.
const RING_CAP: usize = 1 << 18;

#[test]
fn prop_trace_verify_matches_harness_accounting() {
    let mut rng = Rng::new(0x7ACE);
    for trial in 0..6u64 {
        let load = rng.uniform(8.0, 200.0);
        let adaptive = trial % 2 == 0;
        let (requests, ch) = synth_workload(300 + trial, 2_000, load);
        let mut opts = ContentionOpts {
            queue_aware: true,
            adaptive: if adaptive {
                Some(AdaptiveOpts {
                    hedge_margin_s: rng.uniform(0.002, 0.04),
                    ..Default::default()
                })
            } else {
                None
            },
            ..Default::default()
        };
        opts.dispatcher.max_queue_depth = 32 + rng.usize(256);

        let rec = FlightRecorder::new(RING_CAP);
        let (r, rec) =
            run_contended_traced(&requests, &ch, PolicyKind::Cnmt, &opts, rec)
                .unwrap();
        assert_eq!(
            rec.dropped(),
            0,
            "trial {trial}: ring truncated ({} events) — bump RING_CAP",
            rec.total()
        );

        let v = verify_trace(&rec.window_jsonl()).unwrap_or_else(|e| {
            panic!("trial {trial} ({}): {e}", r.policy)
        });

        // The replay must land on the harness's own books exactly.
        assert_eq!(v.offered, r.offered as u64, "trial {trial}: offered");
        assert_eq!(v.shed, r.rejected as u64, "trial {trial}: shed");
        assert_eq!(
            v.admitted,
            (r.offered - r.rejected) as u64,
            "trial {trial}: admitted"
        );
        assert_eq!(v.results, r.completed as u64, "trial {trial}: results");
        assert_eq!(v.hedged, r.hedged as u64, "trial {trial}: hedged");
        assert_eq!(
            v.hedge_wins,
            (r.hedge_wins_edge + r.hedge_wins_cloud) as u64,
            "trial {trial}: hedge wins"
        );
        assert_eq!(
            v.hedge_losses,
            r.hedge_wasted as u64,
            "trial {trial}: executed losers"
        );
        assert_eq!(
            v.hedge_cancelled,
            r.hedge_cancelled as u64,
            "trial {trial}: cancelled twins"
        );
        // One placement scoring per routed (non-shed at scoring time)
        // arrival; every admit is preceded by a placement.
        assert!(v.placements >= v.admitted, "trial {trial}: placements");

        if adaptive {
            // Margin-law replay: the final margin the verifier recomputes
            // from MarginAdjust events must equal the controller's own
            // final state, bit for bit.
            assert_eq!(
                v.final_margin_s.map(f64::to_bits),
                Some(r.hedge_final_margin_s.to_bits()),
                "trial {trial}: final margin diverged"
            );
            // The inverted decayed window reconstructs the raw wasted
            // fraction to float error (each step recovers one
            // observation's work content up to one rounding).
            let have = v.reconstructed_wasted_frac.unwrap();
            let want = r.wasted_frac();
            assert!(
                (have - want).abs() < 1e-6,
                "trial {trial}: reconstructed waste {have} vs harness {want}"
            );
        } else {
            assert_eq!(v.hedged, 0, "trial {trial}: hedges without adaptive");
            assert!(v.final_margin_s.is_none());
        }
    }
}

#[test]
fn blind_policies_trace_with_nonfinite_scores() {
    // EdgeOnly / CloudOnly route without scoring both sides (their
    // decision traces carry NaN estimates); the verifier must accept
    // those placements (score checks are gated on finiteness) and still
    // prove conservation.
    let (requests, ch) = synth_workload(77, 1_200, 40.0);
    for policy in [PolicyKind::EdgeOnly, PolicyKind::CloudOnly] {
        let opts = ContentionOpts::default();
        let rec = FlightRecorder::new(RING_CAP);
        let (r, rec) =
            run_contended_traced(&requests, &ch, policy, &opts, rec).unwrap();
        assert_eq!(rec.dropped(), 0);
        let v = verify_trace(&rec.window_jsonl())
            .unwrap_or_else(|e| panic!("{}: {e}", r.policy));
        assert_eq!(v.results, r.completed as u64);
        assert_eq!(v.shed, r.rejected as u64);
        assert_eq!(v.hedged, 0);
    }
}

#[test]
fn tracing_does_not_perturb_the_run() {
    // The recorder only observes: a traced run and an untraced run of
    // the same scenario must produce identical results field for field.
    use cnmt::sim::run_contended;
    let (requests, ch) = synth_workload(9, 1_500, 96.0);
    let opts = ContentionOpts {
        adaptive: Some(AdaptiveOpts::default()),
        ..Default::default()
    };
    let plain = run_contended(&requests, &ch, PolicyKind::Cnmt, &opts).unwrap();
    let (traced, rec) = run_contended_traced(
        &requests,
        &ch,
        PolicyKind::Cnmt,
        &opts,
        FlightRecorder::new(RING_CAP),
    )
    .unwrap();
    assert!(rec.total() > 0);
    assert_eq!(plain.offered, traced.offered);
    assert_eq!(plain.completed, traced.completed);
    assert_eq!(plain.rejected, traced.rejected);
    assert_eq!(plain.hedged, traced.hedged);
    assert_eq!(plain.hedge_cancelled, traced.hedge_cancelled);
    assert_eq!(plain.hedge_wasted, traced.hedge_wasted);
    assert_eq!(plain.p99_s.to_bits(), traced.p99_s.to_bits());
    assert_eq!(plain.mean_latency_s.to_bits(), traced.mean_latency_s.to_bits());
    assert_eq!(
        plain.hedge_final_margin_s.to_bits(),
        traced.hedge_final_margin_s.to_bits()
    );
}
