//! Counting-allocator proof that the steady-state dispatch path is
//! allocation-free.
//!
//! A global allocator wrapper counts every `alloc`/`realloc` call. The
//! test drives the full scheduler cycle (route-shaped submission mix
//! including hedges, event loop, completions) through one warm-up pass
//! — which is allowed to allocate while ring buffers, the pending heap,
//! the hedge arena and the batch scratch grow to their peak populations
//! — then repeats the *same* traffic pattern and asserts the allocation
//! counter does not move at all. The dispatcher carries an attached
//! [`FlightRecorder`] throughout: the decision log's preallocated ring
//! (including its wrap-around eviction path) must preserve the
//! zero-alloc guarantee, event for event.
//!
//! The same pass also pins two regression fixes: `LatencyRecorder`'s
//! hot path (`record` on an already-seen label probes by `&str` and
//! must not build an owned key), measured under the same counter.
//!
//! This file deliberately contains exactly one `#[test]`: the harness
//! runs tests within a binary on multiple threads, and any concurrent
//! test's allocations would show up in the (process-global) counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use cnmt::devices::DeviceKind;
use cnmt::metrics::LatencyRecorder;
use cnmt::obs::FlightRecorder;
use cnmt::scheduler::{
    BatchExecutor, Dispatcher, DispatcherConfig, QueuedRequest,
};
use cnmt::util::Rng;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Deterministic service times so the run is identical across passes.
struct FixedExec;

impl BatchExecutor for FixedExec {
    fn execute(&mut self, device: DeviceKind, batch: &[QueuedRequest], _s: f64) -> f64 {
        let each = match device {
            DeviceKind::Edge => 9e-3,
            DeviceKind::Cloud => 4e-3,
        };
        each + 0.15 * each * (batch.len() - 1) as f64
    }
}

/// One pass of steady-state traffic: a mixed solo/hedged stream at a
/// rate that keeps queues busy (and sheds a little), with the event
/// loop drained between arrivals — the exact per-request cycle the
/// contended harness drives. `t0` offsets the clock so later passes
/// replay the same *pattern* on a warm dispatcher; the pass ends fully
/// drained.
fn drive(
    disp: &mut Dispatcher,
    seed: u64,
    t0: f64,
    requests: u64,
    interarrival_s: f64,
    hedge_every: u64,
) -> u64 {
    let mut rng = Rng::new(seed);
    let mut exec = FixedExec;
    let mut completions = 0u64;
    let mut t = t0;
    for i in 0..requests {
        t += interarrival_s;
        disp.run_until(t, &mut exec, &mut |_c| completions += 1);
        let n = 1 + rng.usize(61);
        let m_est = 0.95 * n as f64 + 0.8;
        let rq = QueuedRequest {
            id: i,
            payload: n, // payload unused by FixedExec
            n,
            m_est,
            est_service_s: 8e-3,
            arrival_s: t,
            bucket: 0,
            hedge: None,
        };
        // Periodic hedges keep the arena, cancel and purge paths hot.
        if i % hedge_every == 0 {
            disp.submit_hedged(rq, 9e-3, 4e-3);
        } else {
            let device = if i % 3 == 0 { DeviceKind::Edge } else { DeviceKind::Cloud };
            disp.submit(device, rq);
        }
    }
    disp.run_until(f64::INFINITY, &mut exec, &mut |_c| completions += 1);
    completions
}

#[test]
fn steady_state_dispatch_allocates_nothing() {
    let cfg = DispatcherConfig {
        edge_workers: 1,
        cloud_workers: 2,
        max_queue_depth: 256,
        ..Default::default()
    };
    let mut disp = Dispatcher::new(&cfg);
    // The decision log rides along for the whole test: a bounded ring
    // far smaller than the event volume, so the measured pass runs
    // entirely in the wrap-around (evict-then-push) regime.
    disp.attach_recorder(FlightRecorder::new(2_048));

    // Warm-up 1: *heavier* traffic than the measured pass (faster
    // arrivals, more hedges), so every container's peak population —
    // ring depths incl. ghosts, pending heap, hedge arena, free lists —
    // strictly dominates what the measured pass can reach.
    let warm = drive(&mut disp, 0xA110C, 0.0, 6_000, 2.0e-3, 3);
    assert!(warm > 0, "warm-up produced no completions");
    // Warm-up 2: the measured pattern itself, once, for belt and
    // braces (any pattern-specific peak is reached here at the latest).
    drive(&mut disp, 0xA110C, 1_000.0, 4_000, 2.5e-3, 5);
    let warm_events = disp
        .recorder_mut()
        .map(|r| r.total())
        .expect("recorder still attached");

    // Measured pass: identical pattern, warm dispatcher — the dispatch
    // path, decision log included, must not touch the allocator at all.
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    let completions = drive(&mut disp, 0xA110C, 2_000.0, 4_000, 2.5e-3, 5);
    let after = ALLOC_CALLS.load(Ordering::SeqCst);

    assert!(completions > 0, "measured pass produced no completions");
    assert_eq!(
        after - before,
        0,
        "steady-state dispatch path allocated {} time(s)",
        after - before
    );
    // The recorder really was live and overflowing during the measured
    // pass (events advanced well past the ring bound).
    let rec = disp.take_recorder().expect("recorder attached");
    assert!(
        rec.total() > warm_events,
        "measured pass recorded no events ({warm_events})"
    );
    assert!(rec.dropped() > 0, "ring never wrapped — eviction path untested");
    assert_eq!(rec.len(), rec.capacity());

    // LatencyRecorder regression (see metrics::recorder): recording
    // under an already-seen label must not build an owned key. Warm the
    // map with every label once, then measure the hot path.
    let mut lat = LatencyRecorder::new();
    const LABELS: [&str; 3] = ["edge", "cloud", "decision"];
    for label in LABELS {
        lat.record(label, 1e-3);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for i in 0..10_000u64 {
        lat.record(LABELS[(i % 3) as usize], (i % 97) as f64 * 1e-4);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "LatencyRecorder::record allocated {} time(s) on seen labels",
        after - before
    );
    assert_eq!(lat.count("edge"), 1 + 3_334);
}
