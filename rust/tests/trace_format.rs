//! Binary workload-trace format (`cnmt::trace`) round-trip and
//! fail-closed properties:
//!
//! * random explicit-mode workloads and the derived-mode synthetic
//!   scenario survive write → read → re-write **byte-identically**
//!   (the encoder is a pure function of the record stream);
//! * every structural defect — bad magic, unsupported version, flipped
//!   payload byte, truncation at any boundary, end-marker count
//!   mismatch — surfaces as a typed [`Error::Trace`], never a panic
//!   and never a silently short stream.

use std::io::Cursor;

use cnmt::sim::RequestTruth;
use cnmt::trace::{
    crc32, record_synth, s_to_us, summarize, us_to_s, SynthSpec, SynthTrace, TraceHeader,
    TraceReader, TraceWriter, BLOCK_RECORDS, FLAG_TIMES_EXPLICIT, HEADER_LEN, TRACE_VERSION,
};
use cnmt::util::Rng;
use cnmt::{Error, Result};

/// A random explicit-mode workload: arbitrary lengths and service
/// times, every duration pre-quantized to the µs grid the format
/// stores (so the truth stream is exactly representable).
fn random_workload(seed: u64, count: usize) -> Vec<RequestTruth> {
    let mut rng = Rng::new(seed);
    let mut cum_us = 0u64;
    (0..count)
        .map(|_| {
            cum_us += rng.usize(30_000) as u64;
            let tx_us = 1 + rng.usize(90_000) as u64;
            RequestTruth {
                n: 1 + rng.usize(61),
                m_real: 1 + rng.usize(61),
                arrival_s: us_to_s(cum_us),
                t_edge: us_to_s(1 + rng.usize(400_000) as u64),
                t_cloud: us_to_s(1 + rng.usize(80_000) as u64),
                t_tx: us_to_s(tx_us),
                rtt: us_to_s(tx_us),
            }
        })
        .collect()
}

fn explicit_header() -> TraceHeader {
    TraceHeader {
        version: TRACE_VERSION,
        flags: FLAG_TIMES_EXPLICIT,
        edge_plane: (1.2e-3, 3.0e-3, 6.0e-3),
        cloud_plane: (0.22e-3, 0.55e-3, 26.0e-3),
        n2m_gamma: 0.95,
        n2m_delta: 0.8,
        mean_m: 17.0,
        rtt_s: 0.042,
    }
}

fn encode(header: &TraceHeader, truths: &[RequestTruth]) -> Vec<u8> {
    let mut w = TraceWriter::create(Vec::new(), header).expect("create");
    for t in truths {
        w.push(t).expect("push");
    }
    w.finish().expect("finish")
}

fn decode(bytes: &[u8]) -> Result<Vec<RequestTruth>> {
    TraceReader::open(Cursor::new(bytes))?.collect()
}

fn assert_truths_bit_identical(a: &[RequestTruth], b: &[RequestTruth]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.n, y.n, "record {i}");
        assert_eq!(x.m_real, y.m_real, "record {i}");
        assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits(), "record {i}");
        assert_eq!(x.t_edge.to_bits(), y.t_edge.to_bits(), "record {i}");
        assert_eq!(x.t_cloud.to_bits(), y.t_cloud.to_bits(), "record {i}");
        assert_eq!(x.t_tx.to_bits(), y.t_tx.to_bits(), "record {i}");
        assert_eq!(x.rtt.to_bits(), y.rtt.to_bits(), "record {i}");
    }
}

#[test]
fn random_explicit_workloads_round_trip_byte_identically() {
    let header = explicit_header();
    // Sizes straddle the block boundary: sub-block, exactly one block,
    // and a multi-block stream with a partial tail.
    for (seed, count) in [
        (0xF00D, 1),
        (0xF00E, 257),
        (0xF00F, BLOCK_RECORDS as usize),
        (0xF010, 2 * BLOCK_RECORDS as usize + 777),
    ] {
        let truths = random_workload(seed, count);
        let bytes = encode(&header, &truths);
        let decoded = decode(&bytes).expect("clean trace decodes");
        assert_truths_bit_identical(&truths, &decoded);
        // Re-encoding the decoded stream reproduces the exact bytes:
        // the format has one canonical encoding per record stream.
        let reencoded = encode(&header, &decoded);
        assert_eq!(bytes, reencoded, "seed {seed:#x}: re-encode diverged");
    }
}

#[test]
fn derived_synth_round_trips_and_reencodes() {
    let spec = SynthSpec { seed: 99, requests: 6_000, offered_rps: 96.0, exec_noise_std: 0.0 };
    let (header, bytes) = record_synth(&spec, Vec::new()).expect("record");
    assert!(!header.times_explicit());
    let decoded = decode(&bytes).expect("decode");
    let live: Vec<RequestTruth> = SynthTrace::new(&spec).collect();
    assert_truths_bit_identical(&live, &decoded);
    assert_eq!(bytes, encode(&header, &decoded), "re-encode diverged");
    // Noisy specs flip to explicit mode and still round-trip.
    let noisy = SynthSpec { exec_noise_std: 0.05, ..spec };
    let (nh, nbytes) = record_synth(&noisy, Vec::new()).expect("record noisy");
    assert!(nh.times_explicit());
    let ndecoded = decode(&nbytes).expect("decode noisy");
    let nlive: Vec<RequestTruth> = SynthTrace::new(&noisy).collect();
    assert_truths_bit_identical(&nlive, &ndecoded);
}

#[test]
fn wrong_version_fails_with_typed_error() {
    let truths = random_workload(0xBAD0, 50);
    let mut bytes = encode(&explicit_header(), &truths);
    // Patch the version and re-seal the header CRC, so the version
    // check (not the CRC) is what fires.
    bytes[8..10].copy_from_slice(&2u16.to_le_bytes());
    let crc = crc32(&bytes[..92]);
    bytes[92..96].copy_from_slice(&crc.to_le_bytes());
    let err = TraceReader::open(Cursor::new(&bytes)).err().expect("must fail");
    assert!(matches!(err, Error::Trace(ref m) if m.contains("version")), "{err}");
}

#[test]
fn bad_magic_fails_with_typed_error() {
    let mut bytes = encode(&explicit_header(), &random_workload(0xBAD1, 50));
    bytes[0] ^= 0x20;
    let err = TraceReader::open(Cursor::new(&bytes)).err().expect("must fail");
    assert!(matches!(err, Error::Trace(ref m) if m.contains("magic")), "{err}");
}

#[test]
fn corrupted_block_fails_with_typed_error() {
    let truths = random_workload(0xBAD2, 500);
    let mut bytes = encode(&explicit_header(), &truths);
    bytes[HEADER_LEN + 9] ^= 0x40;
    let err = decode(&bytes).err().expect("must fail");
    assert!(matches!(err, Error::Trace(ref m) if m.contains("crc")), "{err}");
}

#[test]
fn truncation_at_every_boundary_fails_closed() {
    let truths = random_workload(0xBAD3, 500);
    let bytes = encode(&explicit_header(), &truths);
    // Mid-header, just after the header, mid-block, mid-end-marker.
    for cut in [HEADER_LEN - 7, HEADER_LEN + 3, HEADER_LEN + 200, bytes.len() - 5] {
        let err = match TraceReader::open(Cursor::new(&bytes[..cut])) {
            Err(e) => e,
            Ok(r) => r
                .collect::<Result<Vec<_>>>()
                .err()
                .unwrap_or_else(|| panic!("cut at {cut} decoded cleanly")),
        };
        assert!(
            matches!(err, Error::Trace(ref m) if m.contains("truncated")),
            "cut at {cut}: {err}"
        );
    }
}

#[test]
fn end_marker_count_mismatch_fails_closed() {
    let truths = random_workload(0xBAD4, 64);
    let mut bytes = encode(&explicit_header(), &truths);
    // The end marker is the final block: 4+4 prefix, 8-byte count
    // payload, 4-byte CRC. Rewrite the count and re-seal its CRC so
    // only the conservation check can catch the lie.
    let payload_at = bytes.len() - 12;
    bytes[payload_at..payload_at + 8].copy_from_slice(&63u64.to_le_bytes());
    let crc = crc32(&bytes[payload_at..payload_at + 8]);
    bytes[payload_at + 8..].copy_from_slice(&crc.to_le_bytes());
    let err = decode(&bytes).err().expect("must fail");
    assert!(matches!(err, Error::Trace(ref m) if m.contains("count")), "{err}");
}

#[test]
fn summarize_agrees_with_the_record_stream() {
    let spec = SynthSpec { seed: 5, requests: 2_000, offered_rps: 120.0, exec_noise_std: 0.0 };
    let (header, bytes) = record_synth(&spec, Vec::new()).expect("record");
    let s = summarize(Cursor::new(&bytes)).expect("summarize");
    assert_eq!(s.records, 2_000);
    assert_eq!(s.version, TRACE_VERSION);
    let live: Vec<RequestTruth> = SynthTrace::new(&spec).collect();
    let mean_m =
        live.iter().map(|t| t.m_real as f64).sum::<f64>() / live.len() as f64;
    assert!((s.mean_m - mean_m).abs() < 1e-12);
    assert!((s.mean_m - header.mean_m).abs() < 1e-12);
    assert_eq!(
        s.duration_s.to_bits(),
        live.last().expect("non-empty").arrival_s.to_bits()
    );
    // µs quantization really is the storage grid.
    assert_eq!(s_to_us(s.duration_s), s_to_us(live.last().unwrap().arrival_s));
}
