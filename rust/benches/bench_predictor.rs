//! Predictor-stack benches: OLS fitting (offline characterisation cost)
//! and the per-request prediction primitives.
//!
//! The offline fit is "once-for-all" in the paper, but it reruns per
//! (device, model) whenever the deployment recalibrates, so its cost on
//! 10k-sample inputs is worth tracking.

use cnmt::corpus::{CorpusGenerator, LangPair, PrefilterRules};
use cnmt::predictor::fit::{fit_line, fit_plane};
use cnmt::predictor::{N2mRegressor, TexeModel};
use cnmt::util::bench::{bench, bench_throughput, report, BenchConfig};
use cnmt::util::Rng;

fn main() {
    let mut results = Vec::new();
    let mut rng = Rng::new(2);

    // 10k-sample plane fit (the paper's per-device characterisation).
    let truth = TexeModel::from_coeffs(1.8e-3, 4.8e-3, 8e-3);
    let plane_samples: Vec<(f64, f64, f64)> = (0..10_000)
        .map(|_| {
            let n = 1.0 + rng.usize(61) as f64;
            let m = 1.0 + rng.usize(61) as f64;
            (n, m, truth.estimate(n as usize, m) + rng.normal_ms(0.0, 1e-3))
        })
        .collect();
    let ps = plane_samples.clone();
    results.push(bench_throughput(
        "fit_plane/10k_samples",
        BenchConfig { warmup_iters: 3, samples: 30, iters_per_sample: 1 },
        10_000.0,
        move || fit_plane(&ps).unwrap().a,
    ));

    let line_samples: Vec<(f64, f64)> =
        plane_samples.iter().map(|&(n, m, _)| (n, m)).collect();
    let ls = line_samples.clone();
    results.push(bench_throughput(
        "fit_line/10k_samples",
        BenchConfig { warmup_iters: 3, samples: 30, iters_per_sample: 1 },
        10_000.0,
        move || fit_line(&ls).unwrap().slope,
    ));

    // N→M fit including prefiltering (what `characterize` runs).
    let mut gen = CorpusGenerator::new(LangPair::EnZh, 3);
    let pairs = gen.take(10_000);
    results.push(bench_throughput(
        "n2m_fit_with_prefilter/10k_pairs",
        BenchConfig { warmup_iters: 2, samples: 20, iters_per_sample: 1 },
        10_000.0,
        move || N2mRegressor::fit(&pairs, &PrefilterRules::default()).unwrap().gamma,
    ));

    // Per-request estimate (hot path of the router).
    let texe = TexeModel::from_coeffs(1.8e-3, 4.8e-3, 8e-3);
    let n2m = N2mRegressor::from_coeffs(0.82, 0.6);
    let mut i = 0usize;
    results.push(bench("texe_estimate_with_n2m", BenchConfig::fast(), move || {
        i = (i + 1) & 63;
        texe.estimate_with_n2m(1 + i, &n2m)
    }));

    report("predictor stack", &results);
}
