//! Substrate benches: corpus generation, prefiltering, trace synthesis
//! and replay — the per-request costs of the experiment harness itself
//! (they bound how fast `experiment table1` can go).

use cnmt::corpus::{prefilter, CorpusGenerator, LangPair, PrefilterRules, Tokenizer};
use cnmt::net::trace::ConnectionProfile;
use cnmt::net::TraceGenerator;
use cnmt::util::bench::{bench, bench_throughput, report, BenchConfig};
use cnmt::util::Rng;

fn main() {
    let mut results = Vec::new();

    // Corpus generation throughput.
    for pair in [LangPair::DeEn, LangPair::EnZh] {
        let mut gen = CorpusGenerator::new(pair, 1);
        results.push(bench_throughput(
            &format!("corpus_gen/{}", pair.id()),
            BenchConfig { warmup_iters: 2, samples: 20, iters_per_sample: 1 },
            10_000.0,
            move || gen.take(10_000).len(),
        ));
    }

    // Prefiltering throughput.
    let mut gen = CorpusGenerator::new(LangPair::FrEn, 2);
    let pairs = gen.take(20_000);
    results.push(bench_throughput(
        "prefilter/20k_pairs",
        BenchConfig { warmup_iters: 2, samples: 20, iters_per_sample: 1 },
        20_000.0,
        move || prefilter(&pairs, &PrefilterRules::default()).1.kept,
    ));

    // Trace synthesis (4h CP1 profile).
    let mut tg = TraceGenerator::new(3);
    results.push(bench(
        "trace_gen/cp1_4h",
        BenchConfig { warmup_iters: 2, samples: 20, iters_per_sample: 1 },
        move || tg.profile(ConnectionProfile::Cp1).len(),
    ));

    // Trace replay lookup (binary search, hot in the truth-table build).
    let trace = TraceGenerator::new(4).profile(ConnectionProfile::Cp1);
    let mut rng = Rng::new(5);
    let times: Vec<f64> = (0..1024).map(|_| rng.uniform(0.0, 14_400.0)).collect();
    let mut i = 0usize;
    results.push(bench("trace_rtt_at", BenchConfig::fast(), move || {
        i = (i + 1) & 1023;
        trace.rtt_at(times[i])
    }));

    // Tokenizer round trip.
    let tok = Tokenizer::new(4096);
    let mut i2 = 0u16;
    results.push(bench("tokenizer_word_id_roundtrip", BenchConfig::fast(), move || {
        i2 = 3 + (i2 + 1) % 4000;
        tok.id(&tok.word(i2)).unwrap()
    }));

    report("corpus + net substrates", &results);
}
