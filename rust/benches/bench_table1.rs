//! End-to-end Table-I bench: regenerates the paper's headline experiment
//! at bench scale and times the full pipeline (corpus → characterise →
//! trace → truth table → 5 policies), per dataset × profile.
//!
//! This is the "one bench per paper table" target for Table I; the
//! bench-scale policy table is printed alongside timings so a change
//! that shifts *results* is as visible as one that shifts speed.

use cnmt::config::Config;
use cnmt::coordinator::PolicyKind;
use cnmt::corpus::LangPair;
use cnmt::devices::Calibration;
use cnmt::net::trace::ConnectionProfile;
use cnmt::sim::{run_all_policies, run_policy, TruthTable};
use cnmt::util::bench::{bench, bench_throughput, report, BenchConfig};

fn main() {
    let mut cfg = Config::smoke();
    cfg.requests = 10_000;
    cfg.fit_inferences = 2_000;
    let cal = Calibration::default_paper();
    let mut results = Vec::new();

    // Truth-table construction (dominated by corpus + device sampling).
    for pair in LangPair::ALL {
        let cfg2 = cfg.clone();
        let cal2 = cal.clone();
        results.push(bench_throughput(
            &format!("truth_table/{}", pair.id()),
            BenchConfig::slow(),
            cfg.requests as f64,
            move || {
                TruthTable::build(&cfg2, pair, ConnectionProfile::Cp1, &cal2).unwrap()
            },
        ));
    }

    // Policy evaluation throughput (requests routed per second).
    let table =
        TruthTable::build(&cfg, LangPair::DeEn, ConnectionProfile::Cp1, &cal).unwrap();
    for policy in [
        PolicyKind::Cnmt,
        PolicyKind::Naive { mean_m: 12.0 },
        PolicyKind::Oracle,
    ] {
        let t = table.clone();
        results.push(bench_throughput(
            &format!("run_policy/{}", policy.id()),
            BenchConfig { warmup_iters: 2, samples: 15, iters_per_sample: 1 },
            cfg.requests as f64,
            move || run_policy(&t, policy).unwrap().total_s,
        ));
    }

    // Full grid end-to-end (what `cnmt experiment table1` does).
    let cfg3 = cfg.clone();
    let cal3 = cal.clone();
    results.push(bench(
        "table1/full_grid_6cells",
        BenchConfig { warmup_iters: 1, samples: 5, iters_per_sample: 1 },
        move || {
            let mut acc = 0.0;
            for pair in LangPair::ALL {
                for profile in ConnectionProfile::ALL {
                    let t = TruthTable::build(&cfg3, pair, profile, &cal3).unwrap();
                    for r in run_all_policies(&t).unwrap() {
                        acc += r.total_s;
                    }
                }
            }
            acc
        },
    ));

    report("table1 end-to-end", &results);

    // Result snapshot at bench scale.
    let t = cnmt::experiments::table1::run(&cfg, &cal).unwrap();
    println!("\n{}", cnmt::experiments::table1::render_text(&t));
}
