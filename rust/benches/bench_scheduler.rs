//! Scheduler hot-path bench: enqueue → decide → dispatch, no execution.
//!
//! The routing decision was already sub-microsecond (bench_decision);
//! queue-awareness must keep it that way. This bench measures
//!
//! * the queue-aware decision (`decide_loaded` + two `expected_wait_s`),
//! * the full per-request cycle (wait query → decide → submit →
//!   dispatch via `run_until` with a no-op executor),
//! * `submit` against a deliberately deep backlog,
//!
//! and asserts the hot path is O(1): per-request cost must not grow
//! with queue depth, and the whole cycle stays under 1 µs.

use cnmt::coordinator::{PolicyKind, RouterBuilder};
use cnmt::devices::DeviceKind;
use cnmt::experiments::load::{CLOUD_PLANE, EDGE_PLANE, N2M_DELTA, N2M_GAMMA, RTT_S};
use cnmt::predictor::{N2mRegressor, TexeModel};
use cnmt::scheduler::{
    BatchExecutor, BatchPolicy, Dispatcher, DispatcherConfig, QueuedRequest,
};
use cnmt::util::bench::{bench, report, BenchConfig};
use cnmt::util::Rng;

struct NoopExec;

impl BatchExecutor for NoopExec {
    fn execute(&mut self, _d: DeviceKind, batch: &[QueuedRequest], _s: f64) -> f64 {
        // Tiny but non-zero so workers cycle realistically.
        1e-7 * batch.len() as f64
    }
}

// Same operating point as the load sweep (constants shared with
// experiments::load so a recalibration cannot desync the perf gate).
fn edge_plane() -> TexeModel {
    TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2)
}

fn mk_router() -> cnmt::coordinator::Router {
    let mut router = RouterBuilder::new(PolicyKind::Cnmt)
        .texe(
            edge_plane(),
            TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2),
        )
        .n2m(N2mRegressor::from_coeffs(N2M_GAMMA, N2M_DELTA))
        .ttx(0.3, RTT_S)
        .build()
        .unwrap();
    router.observe_ttx(0.0, RTT_S);
    router
}

fn rq(id: u64, n: usize, arrival_s: f64) -> QueuedRequest {
    let m_est = (N2M_GAMMA * n as f64 + N2M_DELTA).max(1.0);
    QueuedRequest {
        id,
        payload: id as usize,
        n,
        m_est,
        est_service_s: edge_plane().estimate(n, m_est),
        arrival_s,
        bucket: 0,
        hedge: None,
    }
}

fn main() {
    let mut results = Vec::new();
    let mut rng = Rng::new(3);
    let ns: Vec<usize> = (0..1024).map(|_| 1 + rng.usize(61)).collect();

    // Queue-aware decision alone (two wait queries + eq. 1 + waits).
    {
        let mut router = mk_router();
        let disp = Dispatcher::new(&DispatcherConfig::default());
        let ns = ns.clone();
        let mut i = 0usize;
        let mut t = 0.0f64;
        results.push(bench("decide_loaded/cnmt", BenchConfig::fast(), move || {
            i = (i + 1) & 1023;
            t += 1e-4;
            let ew = disp.expected_wait_s(DeviceKind::Edge, t);
            let cw = disp.expected_wait_s(DeviceKind::Cloud, t);
            router.decide_loaded(ns[i], ew, cw).device
        }));
    }

    // Full per-request cycle: dispatch backlog → wait query → decide →
    // submit. The no-op executor keeps queues shallow, so this is the
    // steady-state (uncongested) hot path.
    let shallow = {
        let mut router = mk_router();
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = NoopExec;
        let ns = ns.clone();
        let mut i = 0usize;
        let mut t = 0.0f64;
        let mut id = 0u64;
        bench("enqueue_decide_dispatch/shallow", BenchConfig::fast(), move || {
            i = (i + 1) & 1023;
            t += 1e-4;
            disp.run_until(t, &mut exec, &mut |_c| {});
            let ew = disp.expected_wait_s(DeviceKind::Edge, t);
            let cw = disp.expected_wait_s(DeviceKind::Cloud, t);
            let device = router.decide_loaded(ns[i], ew, cw).device;
            id += 1;
            disp.submit(device, rq(id, ns[i], t))
        })
    };
    results.push(shallow.clone());

    // Same submit path against a queue that is already ~600k deep and
    // never drains (workers pinned): if anything on the hot path scaled
    // with depth, this would blow up.
    let deep = {
        let mut router = mk_router();
        let cfg = DispatcherConfig {
            max_queue_depth: 4_000_000,
            batch: BatchPolicy::default(),
            ..Default::default()
        };
        let mut disp = Dispatcher::new(&cfg);
        for id in 0..600_000u64 {
            disp.submit(DeviceKind::Edge, rq(id, 1 + (id % 61) as usize, 0.0));
        }
        let ns = ns.clone();
        let mut i = 0usize;
        let mut t = 0.0f64;
        let mut id = 1_000_000u64;
        bench("enqueue_decide_dispatch/deep600k", BenchConfig::fast(), move || {
            i = (i + 1) & 1023;
            t += 1e-4;
            let ew = disp.expected_wait_s(DeviceKind::Edge, t);
            let cw = disp.expected_wait_s(DeviceKind::Cloud, t);
            let device = router.decide_loaded(ns[i], ew, cw).device;
            id += 1;
            disp.submit(device, rq(id, ns[i], t))
        })
    };
    results.push(deep.clone());

    // Hedged per-request cycle: both-lane admission + slab race entry +
    // win/cancel resolution on every request — the arena hot path.
    let hedged = {
        let mut disp = Dispatcher::new(&DispatcherConfig::default());
        let mut exec = NoopExec;
        let ns = ns.clone();
        let mut i = 0usize;
        let mut t = 0.0f64;
        let mut id = 0u64;
        bench("enqueue_decide_dispatch/hedged", BenchConfig::fast(), move || {
            i = (i + 1) & 1023;
            t += 1e-4;
            disp.run_until(t, &mut exec, &mut |_c| {});
            id += 1;
            let est = edge_plane().estimate(ns[i], 10.0);
            disp.submit_hedged(rq(id, ns[i], t), est, est)
        })
    };
    results.push(hedged.clone());

    report("scheduler hot path (enqueue→decide→dispatch)", &results);

    // Perf gates. The load-bearing one is *relative* (depth
    // independence ⇒ O(1)); the absolute bound is deliberately loose so
    // a noisy shared CI runner cannot flake it.
    assert!(
        deep.mean_ns < shallow.mean_ns * 8.0 + 1_000.0,
        "hot path scales with queue depth: shallow {} ns vs deep {} ns",
        shallow.mean_ns,
        deep.mean_ns
    );
    assert!(
        shallow.mean_ns < 5_000.0,
        "hot path too slow: {} ns",
        shallow.mean_ns
    );
    // Hedging doubles the admission work (two lanes + one arena entry
    // per request) but must stay the same order of magnitude: the slab
    // keeps race bookkeeping O(1) with no hashing.
    assert!(
        hedged.mean_ns < shallow.mean_ns * 6.0 + 2_000.0,
        "hedged path disproportionate: {} ns vs solo {} ns",
        hedged.mean_ns,
        shallow.mean_ns
    );
    println!(
        "\nPASS: hot path {:.0} ns shallow / {:.0} ns hedged / {:.0} ns at 600k \
         depth (O(1))",
        shallow.mean_ns, hedged.mean_ns, deep.mean_ns
    );
}
