//! Real-runtime benches over the PJRT engines: encoder latency, decode
//! per-step latency, and full translations per model. These are the
//! numbers `cnmt calibrate` feeds the T_exe fit, and the L2/L1 targets
//! of the perf pass (EXPERIMENTS.md §Perf).
//!
//! Skips (cleanly) if `make artifacts` hasn't run.

use std::path::Path;

use cnmt::runtime::{ArtifactManifest, Seq2SeqEngine, TranslateOptions};
use cnmt::util::bench::{bench, report, BenchConfig, BenchResult};

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP bench_runtime: artifacts not built (run `make artifacts`)");
        return;
    }
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let mut results: Vec<BenchResult> = Vec::new();

    for model in &manifest.models {
        let engine = Seq2SeqEngine::from_manifest(&manifest, &model.name).unwrap();
        let short: Vec<u16> = (10..18).collect();
        let long: Vec<u16> = (100..160).collect();

        // Warmup is handled by BenchConfig; cfg tuned for ms-scale work.
        let cfg = BenchConfig { warmup_iters: 3, samples: 12, iters_per_sample: 1 };

        let e1 = &engine;
        let s1 = short.clone();
        results.push(bench(&format!("{}/encode_n8", model.name), cfg, move || {
            e1.translate(&s1, TranslateOptions { force_steps: Some(1), ..Default::default() })
                .unwrap()
                .encode_s
        }));

        let e2 = &engine;
        let l2 = long.clone();
        results.push(bench(&format!("{}/encode_n60", model.name), cfg, move || {
            e2.translate(&l2, TranslateOptions { force_steps: Some(1), ..Default::default() })
                .unwrap()
                .encode_s
        }));

        // Decode cost per step: (T(m=33) - T(m=1)) / 32 measured inside
        // one bench body to cancel encode cost.
        let e3 = &engine;
        let s3 = short.clone();
        results.push(bench(&format!("{}/decode_32steps", model.name), cfg, move || {
            e3.translate(&s3, TranslateOptions { force_steps: Some(33), ..Default::default() })
                .unwrap()
                .decode_s
        }));

        let e4 = &engine;
        let s4 = short.clone();
        results.push(bench(
            &format!("{}/translate_full_greedy", model.name),
            BenchConfig { warmup_iters: 1, samples: 6, iters_per_sample: 1 },
            move || {
                e4.translate(&s4, TranslateOptions::default()).unwrap().steps
            },
        ));
    }

    report("runtime (real PJRT, CPU)", &results);

    // Per-step summary (the paper's alpha_M analog on this hardware).
    println!("\nper-decode-step (ms), derived from decode_32steps/33:");
    for r in &results {
        if r.name.ends_with("decode_32steps") {
            println!("  {:<40} {:.3} ms/step", r.name, r.mean_ns / 33.0 / 1e6);
        }
    }
}
