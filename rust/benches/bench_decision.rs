//! Decision-overhead bench — validates the paper's §II-C claim that "the
//! C-NMT decision has negligible overheads, as it simply consists of
//! evaluating (2) and (1)".
//!
//! Target: C-NMT decide() well under 1 µs — i.e. 4-6 orders of magnitude
//! below the millisecond-scale inference it routes.

use cnmt::coordinator::{PolicyKind, RouterBuilder};
use cnmt::predictor::{N2mRegressor, TexeModel, TtxEstimator};
use cnmt::util::bench::{bench, report, BenchConfig};
use cnmt::util::Rng;

fn mk_router(policy: PolicyKind) -> cnmt::coordinator::Router {
    RouterBuilder::new(policy)
        .texe(
            TexeModel::from_coeffs(1.8e-3, 4.8e-3, 8e-3),
            TexeModel::from_coeffs(0.3e-3, 0.8e-3, 33e-3),
        )
        .n2m(N2mRegressor::from_coeffs(1.05, 0.4))
        .ttx(0.3, 0.05)
        .build()
        .unwrap()
}

fn main() {
    let mut results = Vec::new();
    let mut rng = Rng::new(1);
    let ns: Vec<usize> = (0..1024).map(|_| 1 + rng.usize(61)).collect();

    for policy in [
        PolicyKind::Cnmt,
        PolicyKind::Naive { mean_m: 12.3 },
        PolicyKind::EdgeOnly,
    ] {
        let mut router = mk_router(policy);
        router.observe_ttx(0.0, 0.05);
        let ns_local = ns.clone();
        let mut i = 0usize;
        results.push(bench(
            &format!("decide/{}", policy.id()),
            BenchConfig::fast(),
            move || {
                i = (i + 1) & 1023;
                router.decide(ns_local[i]).device
            },
        ));
    }

    // T_tx estimator update (per offloaded request).
    let mut est = TtxEstimator::new(0.3);
    let mut t = 0.0f64;
    results.push(bench("ttx_observe", BenchConfig::fast(), move || {
        t += 0.1;
        est.observe(t, 0.05);
        est.estimate_or(0.0)
    }));

    // N→M prediction alone.
    let reg = N2mRegressor::from_coeffs(0.82, 0.6);
    let ns2 = ns.clone();
    let mut i = 0usize;
    results.push(bench("n2m_predict", BenchConfig::fast(), move || {
        i = (i + 1) & 1023;
        reg.predict(ns2[i])
    }));

    report("decision overhead (paper §II-C: negligible)", &results);

    // Hard assertion for the perf gate: decision must be sub-microsecond.
    let cnmt = &results[0];
    assert!(
        cnmt.mean_ns < 1_000.0,
        "C-NMT decision too slow: {} ns",
        cnmt.mean_ns
    );
    println!("\nPASS: C-NMT decision {:.0} ns < 1 µs", cnmt.mean_ns);
}
