//! Fleet subsystem: N-device heterogeneous topologies and fleet-wide
//! queue-aware placement.
//!
//! The paper — and everything in the repo through the scheduler v2 —
//! pairs *one* edge gateway with *one* cloud server. The north star is a
//! production-scale system, and production means a fleet: many edge
//! devices of different speeds sharing a pool of cloud replicas behind
//! links of different quality. This module supplies the two pieces that
//! generalise the pair:
//!
//! * [`topology`] — the declarative fleet description: an ordered
//!   [`DeviceSpec`] list (position = [`DeviceId`] = dispatcher lane)
//!   with per-device tier, speed factor, worker count and link scale;
//!   built-in presets (`1x1`, `4x2`, `8x4`, `hetero`) plus a JSON spec
//!   loader.
//! * [`select`] — eq. 1 extended to fleet scope: every feasible
//!   placement is scored `T̂_exe,d + Ŵ_d` (edges) or
//!   `T̂_tx·link_d + T̂_exe,d + Ŵ_d` (cloud replicas) and the arg-min
//!   wins; the per-tier bests feed hedged dispatch (best edge raced
//!   against best cloud inside the error bar).
//!
//! The scheduler side is the N-lane [`crate::scheduler::Dispatcher`]
//! (one lane per device, same slab/ring machinery per lane);
//! [`crate::sim::harness::run_fleet`] replays contended traffic over a
//! topology, and [`crate::experiments::fleet`] sweeps fleet shapes to
//! produce `reports/fleet_sweep.json`.
//!
//! **The 1×1 anchor:** on [`Topology::pair`] every fleet multiplier is
//! the identity and the selector's arithmetic matches
//! [`crate::coordinator::Router::decide_loaded`] operation for
//! operation, so the fleet path is bit-identical to the classic pair
//! path — asserted at the decision level (`select` unit tests) and the
//! full-harness level (`tests/proptest_invariants.rs` differential).

pub mod select;
pub mod topology;

pub use select::{DeviceHealth, FleetSelector, FleetStrategy, Placement, PlacementTrace};
pub use topology::{DeviceId, DeviceSpec, Topology};
