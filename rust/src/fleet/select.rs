//! Fleet-scope placement selection: eq. 1 over every feasible device.
//!
//! [`crate::coordinator::Router::decide_loaded`] compares exactly two
//! placements — *the* edge against *the* cloud. The fleet selector
//! generalises that comparison to N devices: every device `d` gets a
//! score
//!
//! ```text
//! edge tier:   score_d = T̂_exe,d(n, M̂) + Ŵ_d
//! cloud tier:  score_d = T̂_tx·link_d + T̂_exe,d(n, M̂) + Ŵ_d
//! ```
//!
//! where `T̂_exe,d` is the tier's calibrated plane scaled by the device's
//! speed factor, `T̂_tx` the shared network estimate (one gateway EWMA —
//! the fleet observes the network once, each replica pays its own
//! `link_d` multiple of it) and `Ŵ_d` the device's expected queueing
//! delay ([`crate::scheduler::Dispatcher::expected_wait_lane`]). The
//! decision is the arg-min over all devices; ties resolve to the lowest
//! device id, and an edge/cloud tie resolves to the edge — exactly the
//! `≤` of the pair router, so on the 1×1 topology the selector's choice
//! is **bit-identical** to `decide_loaded` (same float operations in the
//! same order; the unit tests assert it).
//!
//! The trace additionally reports the best placement *per tier*, so the
//! dispatcher can hedge the best edge placement against the best cloud
//! placement when the [`PlacementTrace::margin_s`] between them sits
//! inside the error bar — the fleet generalisation of the pair's hedged
//! dispatch.
//!
//! The selector is the install point of the **per-device online refit**
//! ([`crate::predictor::PlaneBank`] / [`crate::predictor::LineBank`]):
//! [`FleetSelector::set_texe`] replaces one device's plane and
//! [`FleetSelector::set_ttx_line`] one cloud replica's payload-size →
//! T̂_tx law, without moving any sibling's score — so one throttling
//! device can be re-learned in isolation (the isolation test below
//! asserts bit-identity of every other device's scores).
//!
//! [`FleetStrategy`] names the routing policies the fleet sweep
//! compares: blind replica assignment (static round-robin or uniformly
//! random within the eq. 1 tier) against fleet-wide queue-aware
//! selection, with and without hedging.

use crate::devices::DeviceKind;
use crate::predictor::{N2mRegressor, TexeModel, TtxEstimator, TtxLine};
use crate::Result;

use super::topology::{DeviceId, Topology};

/// One scored placement (a device plus its expected total latency).
#[derive(Debug, Clone, Copy)]
pub struct Placement {
    /// The device.
    pub device: DeviceId,
    /// Expected total latency of running there now (seconds): execution
    /// + expected wait, plus the scaled T̂_tx for cloud replicas.
    pub score_s: f64,
    /// The device's execution-time estimate alone (the service estimate
    /// handed to the dispatcher's capacity tracker).
    pub est_service_s: f64,
}

/// Everything the selector computed for one decision.
#[derive(Debug, Clone, Copy)]
pub struct PlacementTrace {
    /// The arg-min placement's device.
    pub device: DeviceId,
    /// M̂ used for every plane evaluation.
    pub m_est: f64,
    /// The shared (unscaled) T̂_tx estimate used.
    pub ttx_est: f64,
    /// The chosen placement's execution-time estimate (service estimate
    /// for the dispatcher).
    pub est_service_s: f64,
    /// Best edge-tier placement.
    pub best_edge: Placement,
    /// Best cloud-tier placement.
    pub best_cloud: Placement,
}

impl PlacementTrace {
    /// Signed expected-latency gap between the best edge and the best
    /// cloud placement — negative means the edge looked faster. The
    /// fleet analogue of
    /// [`crate::coordinator::DecisionTrace::loaded_margin_s`]: when
    /// `|margin|` sits inside the model's error bar, racing the two
    /// placements ([`crate::scheduler::Dispatcher::submit_hedged_lanes`])
    /// beats committing to either.
    pub fn margin_s(&self) -> f64 {
        self.best_edge.score_s - self.best_cloud.score_s
    }
}

/// The routing strategies compared by the fleet sweep
/// ([`crate::experiments::fleet`]).
#[derive(Debug, Clone, Copy)]
pub enum FleetStrategy {
    /// Tier by idle eq. 1, replica by per-tier round-robin — the
    /// queue-blind "static assignment" baseline.
    Static,
    /// Tier by idle eq. 1, replica drawn uniformly at random within the
    /// tier (seeded — runs are deterministic).
    Random {
        /// Seed of the replica-pick stream.
        seed: u64,
    },
    /// Fleet-wide queue-aware arg-min placement (the tentpole policy).
    Select,
    /// [`FleetStrategy::Select`], plus hedging the best edge placement
    /// against the best cloud placement when `|margin| ≤ margin_s`.
    Hedged {
        /// Hedge error bar (seconds); must be finite and ≥ 0 — 0
        /// disables hedging, degenerating to plain `Select` (the same
        /// convention as [`crate::sim::AdaptiveOpts::hedge_margin_s`]).
        margin_s: f64,
    },
}

impl FleetStrategy {
    /// Report label (`fleet+static`, `fleet+random`, `fleet+select`,
    /// `fleet+hedge`).
    pub fn label(&self) -> &'static str {
        match self {
            FleetStrategy::Static => "fleet+static",
            FleetStrategy::Random { .. } => "fleet+random",
            FleetStrategy::Select => "fleet+select",
            FleetStrategy::Hedged { .. } => "fleet+hedge",
        }
    }

    /// Does this strategy feed the live expected-wait terms into the
    /// placement scores? (The blind baselines score as if every queue
    /// were empty.)
    pub fn queue_aware(&self) -> bool {
        matches!(self, FleetStrategy::Select | FleetStrategy::Hedged { .. })
    }
}

/// Health of one fleet device, as the selector sees it.
///
/// The state machine is driven by fault injection
/// ([`crate::sim::FaultSpec`] → [`FleetSelector::set_health`]):
/// `Up → Down` when the device crashes, `Down → Up` on recovery, with
/// `Draining` as the administrative half-way point (no new placements,
/// existing queue keeps running — a planned decommission rather than a
/// crash). Only `Up` devices participate in the placement arg-min; a
/// fleet whose devices are all `Up` scores bit-identically to a
/// health-blind selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceHealth {
    /// Serving: eligible for placement.
    Up,
    /// No new placements; already-queued work keeps running.
    Draining,
    /// Crashed: excluded from the arg-min until recovery.
    Down,
}

/// The fleet decision engine: per-device T_exe planes plus the shared
/// network estimate, scoring every placement in O(devices).
#[derive(Debug, Clone)]
pub struct FleetSelector {
    tier: Vec<DeviceKind>,
    /// Per-device plane: the tier's calibrated plane × the device's
    /// slowdown (1/speed) at construction; replaced per device by the
    /// online refit once warmed ([`FleetSelector::set_texe`]).
    texe: Vec<TexeModel>,
    link_scale: Vec<f64>,
    /// Per-device refit T_tx law; while installed, that device's net
    /// cost is `a·(N + M̂) + b` instead of the link-scaled shared EWMA
    /// ([`FleetSelector::set_ttx_line`]).
    ttx_line: Vec<Option<TtxLine>>,
    edge_ids: Vec<DeviceId>,
    cloud_ids: Vec<DeviceId>,
    n2m: N2mRegressor,
    ttx: TtxEstimator,
    ttx_prior_s: f64,
    decisions: u64,
    /// Per-device health ([`FleetSelector::set_health`]); all
    /// [`DeviceHealth::Up`] at construction, in which case scoring is
    /// bit-identical to the pre-health selector.
    health: Vec<DeviceHealth>,
}

impl FleetSelector {
    /// Build the selector for `topo` from the shared characterisation
    /// (the same planes and regressor the pair router uses; T_tx EWMA at
    /// the pair router's defaults, α = 0.3 over a 50 ms prior).
    pub fn new(
        topo: &Topology,
        texe_edge: TexeModel,
        texe_cloud: TexeModel,
        n2m: N2mRegressor,
    ) -> Result<FleetSelector> {
        topo.validate()?;
        let mut tier = Vec::with_capacity(topo.len());
        let mut texe = Vec::with_capacity(topo.len());
        let mut link_scale = Vec::with_capacity(topo.len());
        for d in &topo.devices {
            let base = match d.tier {
                DeviceKind::Edge => &texe_edge,
                DeviceKind::Cloud => &texe_cloud,
            };
            let slow = d.slowdown();
            tier.push(d.tier);
            // speed 1.0 ⇒ slow 1.0 ⇒ every coefficient × 1.0 — the
            // scaled plane is bit-identical to the tier plane.
            texe.push(TexeModel::from_coeffs(
                base.alpha_n * slow,
                base.alpha_m * slow,
                base.beta * slow,
            ));
            link_scale.push(d.link_scale);
        }
        let n_dev = topo.len();
        Ok(FleetSelector {
            tier,
            texe,
            link_scale,
            ttx_line: vec![None; n_dev],
            edge_ids: topo.edge_ids(),
            cloud_ids: topo.cloud_ids(),
            n2m,
            ttx: TtxEstimator::new(0.3),
            ttx_prior_s: 0.05,
            decisions: 0,
            health: vec![DeviceHealth::Up; n_dev],
        })
    }

    /// Set device `d`'s health state. Non-`Up` devices are excluded
    /// from the placement arg-min ([`FleetSelector::select`]); flipping
    /// a device back to [`DeviceHealth::Up`] re-admits it with its
    /// plane, link law and refit state untouched.
    pub fn set_health(&mut self, d: DeviceId, health: DeviceHealth) {
        self.health[d] = health;
    }

    /// Device `d`'s current health state.
    pub fn health(&self, d: DeviceId) -> DeviceHealth {
        self.health[d]
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.tier.len()
    }

    /// True when the selector has no devices (unreachable — the
    /// topology is validated at construction).
    pub fn is_empty(&self) -> bool {
        self.tier.is_empty()
    }

    /// Decisions made so far.
    pub fn decisions(&self) -> u64 {
        self.decisions
    }

    /// Device ids of the edge tier.
    pub fn edge_ids(&self) -> &[DeviceId] {
        &self.edge_ids
    }

    /// Device ids of the cloud tier.
    pub fn cloud_ids(&self) -> &[DeviceId] {
        &self.cloud_ids
    }

    /// Tier of device `d`.
    pub fn tier(&self, d: DeviceId) -> DeviceKind {
        self.tier[d]
    }

    /// Device `d`'s execution-time estimate at `(n, m_est)` — used to
    /// price a blind replica assignment that overrides the arg-min.
    pub fn est_service_s(&self, d: DeviceId, n: usize, m_est: f64) -> f64 {
        self.texe[d].estimate(n, m_est)
    }

    /// The per-device T_exe planes currently used for scoring, in
    /// device-id order (the priors an adaptive harness seeds its
    /// [`crate::predictor::PlaneBank`] from).
    pub fn texe_models(&self) -> &[TexeModel] {
        &self.texe
    }

    /// Replace device `d`'s T_exe plane — the per-device online-refit
    /// hook, the fleet analogue of
    /// [`crate::coordinator::Router::set_texe`]. Only device `d`'s
    /// scores move; every other device keeps its plane bit-identically
    /// (the isolation test below asserts it).
    pub fn set_texe(&mut self, d: DeviceId, model: TexeModel) {
        self.texe[d] = model;
    }

    /// Install (or clear) device `d`'s refit payload-size → T_tx law —
    /// the per-link analogue of
    /// [`crate::coordinator::Router::set_ttx_line`]. While installed,
    /// `d`'s network cost is `a·(N + M̂) + b` (the link's own observed
    /// law, link scale already folded into the observations) instead of
    /// the link-scaled shared EWMA.
    pub fn set_ttx_line(&mut self, d: DeviceId, line: Option<TtxLine>) {
        self.ttx_line[d] = line;
    }

    /// The refit T_tx law installed on device `d`, if any.
    pub fn ttx_line(&self, d: DeviceId) -> Option<TtxLine> {
        self.ttx_line[d]
    }

    /// Feed a timestamped network observation (same semantics as
    /// [`crate::coordinator::Router::observe_ttx`]: the fleet gateway
    /// observes the network once, shared by every replica).
    pub fn observe_ttx(&mut self, now_s: f64, rtt_s: f64) {
        self.ttx.observe(now_s, rtt_s);
    }

    /// Is the shared T_tx estimate stale at `now_s`?
    pub fn ttx_stale(&self, now_s: f64, max_age_s: f64) -> bool {
        self.ttx.is_stale(now_s, max_age_s)
    }

    /// Score every placement and return the arg-min plus the per-tier
    /// bests. `waits[d]` is device `d`'s expected queueing delay (all
    /// zeros = the idle eq. 1, the blind baselines' view). O(devices),
    /// allocation-free. Non-[`DeviceHealth::Up`] devices are skipped;
    /// when *every* device of both tiers is unavailable the returned
    /// trace carries the sentinel `device == usize::MAX` with an
    /// infinite score — callers must treat it as "no placement".
    pub fn select(&mut self, n: usize, waits: &[f64]) -> PlacementTrace {
        debug_assert_eq!(waits.len(), self.tier.len());
        self.decisions += 1;
        let m_est = self.n2m.predict(n);
        let ttx_est = self.ttx.estimate_or(self.ttx_prior_s);
        let best_edge = self.best_of(&self.edge_ids, n, m_est, ttx_est, waits);
        let best_cloud = self.best_of(&self.cloud_ids, n, m_est, ttx_est, waits);
        // Tie goes to the edge — the pair router's `≤`.
        let best = if best_edge.score_s <= best_cloud.score_s {
            best_edge
        } else {
            best_cloud
        };
        PlacementTrace {
            device: best.device,
            m_est,
            ttx_est,
            est_service_s: best.est_service_s,
            best_edge,
            best_cloud,
        }
    }

    /// Best placement within one tier (strict `<` scan ⇒ lowest device
    /// id wins ties). `ids` is non-empty (topology validated).
    fn best_of(
        &self,
        ids: &[DeviceId],
        n: usize,
        m_est: f64,
        ttx_est: f64,
        waits: &[f64],
    ) -> Placement {
        let mut best = Placement {
            device: usize::MAX,
            score_s: f64::INFINITY,
            est_service_s: f64::INFINITY,
        };
        for &d in ids {
            if self.health[d] != DeviceHealth::Up {
                // Draining/Down: excluded from the arg-min. With every
                // device Up this branch never fires and the scan is
                // operation-for-operation the health-blind one.
                continue;
            }
            let est = self.texe[d].estimate(n, m_est);
            // Same grouping as the pair router's eq. 1 sides:
            // (T̂_exe + Ŵ) for edges, ((T̂_tx + T̂_exe) + Ŵ) for clouds —
            // with link_scale 1.0 the product is the identity. A warmed
            // per-link refit law replaces the link-scaled EWMA with the
            // size-aware estimate, exactly as the pair router's
            // `decide_with_m` does when a line is installed.
            let score = match self.tier[d] {
                DeviceKind::Edge => est + waits[d],
                DeviceKind::Cloud => {
                    let net = match self.ttx_line[d] {
                        Some(line) => line.estimate(n as f64 + m_est),
                        None => ttx_est * self.link_scale[d],
                    };
                    net + est + waits[d]
                }
            };
            if score < best.score_s {
                best = Placement { device: d, score_s: score, est_service_s: est };
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{PolicyKind, RouterBuilder};
    use crate::fleet::topology::DeviceSpec;

    fn planes() -> (TexeModel, TexeModel, N2mRegressor) {
        (
            TexeModel::from_coeffs(1.2e-3, 3.0e-3, 6.0e-3),
            TexeModel::from_coeffs(0.22e-3, 0.55e-3, 26.0e-3),
            N2mRegressor::from_coeffs(0.95, 0.8),
        )
    }

    fn selector(topo: &Topology) -> FleetSelector {
        let (e, c, n2m) = planes();
        FleetSelector::new(topo, e, c, n2m).unwrap()
    }

    #[test]
    fn pair_selection_is_bit_identical_to_decide_loaded() {
        // THE 1×1 equivalence at the decision level: same device, same
        // estimates, bit-equal margin, across lengths, RTTs and waits.
        let (e, c, n2m) = planes();
        let mut sel = selector(&Topology::pair());
        let mut router = RouterBuilder::new(PolicyKind::Cnmt)
            .texe(e, c)
            .n2m(n2m)
            .build()
            .unwrap();
        let scenarios = [
            (0.040, 0.0, 0.0),
            (0.040, 0.3, 0.0),
            (0.010, 0.0, 0.4),
            (0.100, 0.05, 0.06),
        ];
        for (rtt, ew, cw) in scenarios {
            sel.observe_ttx(0.0, rtt);
            router.observe_ttx(0.0, rtt);
            for n in [1usize, 3, 10, 17, 30, 45, 62] {
                let ft = sel.select(n, &[ew, cw]);
                let rt = router.decide_loaded(n, ew, cw);
                let fleet_edge = ft.device == 0;
                assert_eq!(
                    fleet_edge,
                    rt.device == DeviceKind::Edge,
                    "n={n} rtt={rtt}: decisions diverged"
                );
                assert_eq!(ft.m_est.to_bits(), rt.m_est.to_bits());
                assert_eq!(ft.ttx_est.to_bits(), rt.ttx_est.to_bits());
                assert_eq!(ft.best_edge.est_service_s.to_bits(), rt.t_edge_est.to_bits());
                assert_eq!(ft.best_cloud.est_service_s.to_bits(), rt.t_cloud_est.to_bits());
                assert_eq!(
                    ft.margin_s().to_bits(),
                    rt.loaded_margin_s(ew, cw).to_bits(),
                    "n={n}: hedge margins diverged"
                );
            }
        }
    }

    #[test]
    fn argmin_prefers_less_loaded_replica() {
        let topo = Topology::uniform(1, 3);
        let mut sel = selector(&topo);
        sel.observe_ttx(0.0, 0.042);
        let n = 60; // firmly cloud when idle
        // All idle: the lowest-id replica wins the tie.
        let idle = sel.select(n, &[0.0; 4]);
        assert_eq!(idle.device, 1);
        // Load replica 1 and 2: the arg-min moves to replica 3.
        let loaded = sel.select(n, &[0.0, 5.0, 5.0, 0.0]);
        assert_eq!(loaded.device, 3);
        // Load every cloud replica enough and the request stays local.
        let swamped = sel.select(n, &[0.0, 5.0, 5.0, 5.0]);
        assert_eq!(swamped.device, 0);
        assert_eq!(sel.decisions(), 3);
    }

    #[test]
    fn speed_scaling_shifts_the_boundary() {
        // A 2× edge keeps requests local that a baseline edge offloads.
        let fast = Topology {
            name: "fast-edge".into(),
            devices: vec![DeviceSpec::edge("e", 2.0), DeviceSpec::cloud("c", 1.0, 1.0)],
        };
        let mut base_sel = selector(&Topology::pair());
        let mut fast_sel = selector(&fast);
        base_sel.observe_ttx(0.0, 0.042);
        fast_sel.observe_ttx(0.0, 0.042);
        let mut flipped = 0;
        for n in 1..=62 {
            let b = base_sel.select(n, &[0.0, 0.0]).device;
            let f = fast_sel.select(n, &[0.0, 0.0]).device;
            // A faster edge can only expand the edge region.
            if b == 0 {
                assert_eq!(f, 0, "n={n}: fast edge offloaded what baseline kept");
            }
            if b != 0 && f == 0 {
                flipped += 1;
            }
        }
        assert!(flipped > 0, "a 2x edge never expanded the edge region");
    }

    #[test]
    fn link_scale_penalises_remote_replicas() {
        // Two equal-speed replicas, one behind a 3× link: the clean one
        // wins until it is loaded enough.
        let topo = Topology {
            name: "links".into(),
            devices: vec![
                DeviceSpec::edge("e", 1.0),
                DeviceSpec::cloud("near", 1.0, 1.0),
                DeviceSpec::cloud("far", 1.0, 3.0),
            ],
        };
        let mut sel = selector(&topo);
        sel.observe_ttx(0.0, 0.042);
        let n = 60;
        assert_eq!(sel.select(n, &[0.0; 3]).device, 1);
        // 2·RTT of extra wait on the near replica outweighs the link
        // penalty (0.042·2 = 84 ms of queue vs 84 ms of extra link).
        let t = sel.select(n, &[0.0, 0.090, 0.0]);
        assert_eq!(t.device, 2, "loaded near replica should lose to the far one");
    }

    #[test]
    fn per_device_refit_moves_only_the_target_device() {
        // THE isolation property of per-device refit (the reason the
        // fleet carries a PlaneBank instead of tier-shared planes): after
        // installing a refit plane and a refit T_tx law on one device,
        // every other device's score — and any decision that does not
        // involve the refit device — is bit-identical to before.
        use crate::predictor::PlaneBank;
        let topo = Topology::hetero();
        let mut sel = selector(&topo);
        sel.observe_ttx(0.0, 0.042);
        let target = 4usize; // hetero cloud0
        let others: Vec<usize> = (0..topo.len()).filter(|&d| d != target).collect();
        // Scores before, per device, over a length sweep (idle waits so
        // the scores are pure model evaluations).
        let n_dev = topo.len();
        let score_of = |sel: &mut FleetSelector, d: usize, n: usize| {
            // Probe one device by swamping every other with a huge (but
            // finite — infinities would tie) wait.
            let mut w = vec![1e12f64; n_dev];
            w[d] = 0.0;
            let t = sel.select(n, &w);
            assert_eq!(t.device, d, "probe did not isolate device {d}");
            if sel.tier(d) == DeviceKind::Edge {
                t.best_edge.score_s
            } else {
                t.best_cloud.score_s
            }
        };
        let ns = [1usize, 7, 19, 33, 48, 62];
        let mut before = Vec::new();
        for &d in &others {
            for &n in &ns {
                before.push(score_of(&mut sel, d, n).to_bits());
            }
        }
        // Warm a bank on the target device only (2.5x slower truth) and
        // install its plane + a refit link law.
        let mut bank = PlaneBank::new(sel.texe_models(), 0.998, 1.0).unwrap();
        let truth = TexeModel::from_coeffs(0.55e-3, 1.375e-3, 65.0e-3);
        for i in 0..400usize {
            let (n, m) = (1 + i % 40, 1 + (i * 7) % 40);
            bank.observe(target, n as f64, m as f64, truth.estimate(n, m as f64));
        }
        sel.set_texe(target, bank.model(target));
        sel.set_ttx_line(target, Some(TtxLine { slope: 2e-4, intercept: 0.008 }));
        // Every other device's scores are bit-identical...
        let mut after = Vec::new();
        for &d in &others {
            for &n in &ns {
                after.push(score_of(&mut sel, d, n).to_bits());
            }
        }
        assert_eq!(before, after, "refit on device {target} moved another device");
        // ...while the target's own score genuinely moved.
        assert_ne!(
            score_of(&mut sel, target, 33).to_bits(),
            {
                let fresh = &mut selector(&topo);
                fresh.observe_ttx(0.0, 0.042);
                score_of(fresh, target, 33).to_bits()
            },
            "refit never moved the target device"
        );
    }

    #[test]
    fn pair_refit_line_matches_router_ttx_line() {
        // With the same refit T_tx law installed on the fleet's cloud
        // device and on the pair router, the 1×1 decision equivalence
        // must keep holding bit for bit — the line path included.
        let (e, c, n2m) = planes();
        let mut sel = selector(&Topology::pair());
        let mut router = RouterBuilder::new(PolicyKind::Cnmt)
            .texe(e, c)
            .n2m(n2m)
            .build()
            .unwrap();
        sel.observe_ttx(0.0, 0.090);
        router.observe_ttx(0.0, 0.090);
        let law = TtxLine { slope: 0.2e-3, intercept: 0.008 };
        sel.set_ttx_line(1, Some(law));
        router.set_ttx_line(Some(law));
        for n in [1usize, 3, 10, 17, 30, 45, 62] {
            let ft = sel.select(n, &[0.0, 0.0]);
            let rt = router.decide_loaded(n, 0.0, 0.0);
            assert_eq!(
                ft.device == 0,
                rt.device == DeviceKind::Edge,
                "n={n}: line-path decisions diverged"
            );
            assert_eq!(
                ft.margin_s().to_bits(),
                rt.loaded_margin_s(0.0, 0.0).to_bits(),
                "n={n}: line-path margins diverged"
            );
        }
    }

    #[test]
    fn strategy_labels_and_awareness() {
        assert_eq!(FleetStrategy::Static.label(), "fleet+static");
        assert_eq!(FleetStrategy::Random { seed: 1 }.label(), "fleet+random");
        assert_eq!(FleetStrategy::Select.label(), "fleet+select");
        assert_eq!(FleetStrategy::Hedged { margin_s: 0.01 }.label(), "fleet+hedge");
        assert!(!FleetStrategy::Static.queue_aware());
        assert!(!FleetStrategy::Random { seed: 1 }.queue_aware());
        assert!(FleetStrategy::Select.queue_aware());
        assert!(FleetStrategy::Hedged { margin_s: 0.01 }.queue_aware());
    }

    #[test]
    fn down_devices_are_excluded_until_recovery() {
        let topo = Topology::uniform(2, 2); // edges 0,1; clouds 2,3
        let mut sel = selector(&topo);
        sel.observe_ttx(0.0, 0.042);
        let n = 5; // firmly edge when idle; lowest id wins the tie
        assert_eq!(sel.select(n, &[0.0; 4]).device, 0);
        // Crash edge 0: the arg-min moves to its sibling without the
        // scores of any other device changing.
        sel.set_health(0, DeviceHealth::Down);
        assert_eq!(sel.health(0), DeviceHealth::Down);
        let t = sel.select(n, &[0.0; 4]);
        assert_eq!(t.device, 1, "down device must not win placement");
        // Draining is excluded exactly like Down.
        sel.set_health(1, DeviceHealth::Draining);
        let t = sel.select(n, &[0.0; 4]);
        assert_ne!(t.device, 0);
        assert_ne!(t.device, 1, "draining device must not win placement");
        // A whole tier down: the other tier serves.
        sel.set_health(1, DeviceHealth::Up);
        let big = 62; // firmly cloud when idle
        sel.set_health(2, DeviceHealth::Down);
        sel.set_health(3, DeviceHealth::Down);
        let t = sel.select(big, &[0.0; 4]);
        assert!(t.best_cloud.score_s.is_infinite());
        assert_eq!(t.best_cloud.device, usize::MAX);
        assert!(t.device == 0 || t.device == 1);
        // Every device down: the sentinel trace.
        sel.set_health(0, DeviceHealth::Down);
        sel.set_health(1, DeviceHealth::Down);
        let t = sel.select(big, &[0.0; 4]);
        assert_eq!(t.device, usize::MAX, "no placement when all devices are down");
        // Recovery re-admits with scores bit-identical to a fresh
        // selector fed the same observations.
        for d in 0..4 {
            sel.set_health(d, DeviceHealth::Up);
        }
        let mut fresh = selector(&topo);
        fresh.observe_ttx(0.0, 0.042);
        let a = sel.select(big, &[0.0; 4]);
        let b = fresh.select(big, &[0.0; 4]);
        assert_eq!(a.device, b.device);
        assert_eq!(a.best_edge.score_s.to_bits(), b.best_edge.score_s.to_bits());
        assert_eq!(a.best_cloud.score_s.to_bits(), b.best_cloud.score_s.to_bits());
    }

    #[test]
    fn all_up_health_is_bit_identical_to_health_blind_scoring() {
        // The health gate must be invisible while every device is Up —
        // this is what keeps every legacy report byte-identical.
        let topo = Topology::hetero();
        let mut sel = selector(&topo);
        sel.observe_ttx(0.0, 0.042);
        let mut witness = selector(&topo);
        witness.observe_ttx(0.0, 0.042);
        // Round-trip one device through Down and back before comparing.
        sel.set_health(3, DeviceHealth::Down);
        let _ = sel.select(10, &[0.0; 6]);
        sel.set_health(3, DeviceHealth::Up);
        let _ = witness.select(10, &[0.0; 6]);
        let n_dev = topo.len();
        for n in [1usize, 9, 23, 41, 62] {
            let w: Vec<f64> = (0..n_dev).map(|d| d as f64 * 0.01).collect();
            let a = sel.select(n, &w);
            let b = witness.select(n, &w);
            assert_eq!(a.device, b.device);
            assert_eq!(a.best_edge.score_s.to_bits(), b.best_edge.score_s.to_bits());
            assert_eq!(a.best_cloud.score_s.to_bits(), b.best_cloud.score_s.to_bits());
            assert_eq!(a.est_service_s.to_bits(), b.est_service_s.to_bits());
        }
    }

    #[test]
    fn selector_rejects_invalid_topologies() {
        let (e, c, n2m) = planes();
        let no_cloud = Topology {
            name: "bad".into(),
            devices: vec![DeviceSpec::edge("e", 1.0)],
        };
        assert!(FleetSelector::new(&no_cloud, e, c, n2m).is_err());
    }
}
