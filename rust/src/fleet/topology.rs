//! Fleet topology: N heterogeneous edge devices × M cloud replicas.
//!
//! The paper's testbed is one edge gateway paired with one cloud server;
//! a production deployment is a *fleet* — many gateways of different
//! speeds sharing a pool of cloud replicas behind links of different
//! quality (CoFormer's heterogeneous-edge collaboration and Galaxy's
//! multi-device serving make the same generalisation; see PAPERS.md).
//! A [`Topology`] describes that fleet declaratively: one
//! [`DeviceSpec`] per device, ordered so the device's position **is**
//! its [`DeviceId`] — and, downstream, its dispatcher lane index
//! ([`crate::scheduler::Dispatcher::with_lanes`]).
//!
//! Speeds are expressed relative to the tier's calibrated baseline: a
//! device with `speed = 2.0` executes in half the tier's ground-truth
//! time, `speed = 0.5` in double. Cloud replicas additionally carry a
//! `link_scale` multiplying the shared T_tx estimate — a replica behind
//! a worse route costs proportionally more to reach. The 1×1 preset
//! ([`Topology::pair`]) reproduces the classic pair *exactly* (speeds
//! and link scales of 1.0 multiply through as identity), which is what
//! makes the fleet path bit-identical to the pair path on that shape.
//!
//! Topologies come from built-in presets ([`Topology::preset`]) or a
//! JSON spec ([`Topology::load`] / [`Topology::from_json`]).

use std::path::Path;

use crate::devices::DeviceKind;
use crate::util::Json;
use crate::{Error, Result};

/// Index of a device in its [`Topology`] — also its dispatcher lane.
pub type DeviceId = usize;

/// One device of the fleet.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Human-readable name (report labels; e.g. `edge0`, `cloud1`).
    pub name: String,
    /// Tier: edge gateway or cloud replica.
    pub tier: DeviceKind,
    /// Execution speed relative to the tier's calibrated baseline
    /// (> 0; 2.0 = twice as fast, 0.5 = half as fast).
    pub speed: f64,
    /// Worker slots (serial execution streams) on this device.
    pub workers: usize,
    /// Multiplier on the shared T_tx estimate for reaching this device
    /// (> 0; only meaningful for cloud replicas — edges are local and
    /// keep 1.0).
    pub link_scale: f64,
}

impl DeviceSpec {
    /// An edge gateway at `speed`, one worker (the paper's serial
    /// execution stream).
    pub fn edge(name: &str, speed: f64) -> DeviceSpec {
        DeviceSpec {
            name: name.to_string(),
            tier: DeviceKind::Edge,
            speed,
            workers: 1,
            link_scale: 1.0,
        }
    }

    /// A cloud replica at `speed` behind `link_scale`, four workers
    /// (the pair dispatcher's default cloud pool).
    pub fn cloud(name: &str, speed: f64, link_scale: f64) -> DeviceSpec {
        DeviceSpec {
            name: name.to_string(),
            tier: DeviceKind::Cloud,
            speed,
            workers: 4,
            link_scale,
        }
    }

    /// The ground-truth (and estimate) slowdown this device applies to
    /// its tier's base execution time: `1 / speed`. Exactly 1.0 for
    /// `speed = 1.0` — the identity the 1×1 bit-equivalence rests on.
    pub fn slowdown(&self) -> f64 {
        1.0 / self.speed
    }

    fn validate(&self) -> Result<()> {
        if !(self.speed.is_finite() && self.speed > 0.0) {
            return Err(Error::Config(format!(
                "device {}: speed {} must be finite and > 0",
                self.name, self.speed
            )));
        }
        if self.workers == 0 {
            return Err(Error::Config(format!(
                "device {}: needs at least one worker",
                self.name
            )));
        }
        if !(self.link_scale.is_finite() && self.link_scale > 0.0) {
            return Err(Error::Config(format!(
                "device {}: link_scale {} must be finite and > 0",
                self.name, self.link_scale
            )));
        }
        Ok(())
    }

    /// Serialise for reports / spec round-trips.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("name", Json::Str(self.name.clone()))
            .set("tier", Json::Str(self.tier.id().to_string()))
            .set("speed", Json::Num(self.speed))
            .set("workers", Json::Num(self.workers as f64))
            .set("link_scale", Json::Num(self.link_scale));
        o
    }
}

/// A fleet shape: the ordered device list (position = [`DeviceId`]).
#[derive(Debug, Clone)]
pub struct Topology {
    /// Shape label used in reports (`1x1`, `4x2`, `hetero`, …).
    pub name: String,
    /// The devices, in lane order.
    pub devices: Vec<DeviceSpec>,
}

impl Topology {
    /// The classic paper pair — one baseline edge (1 worker), one
    /// baseline cloud (4 workers), clean link. The fleet path on this
    /// topology is bit-identical to the two-lane pair path.
    pub fn pair() -> Topology {
        Topology {
            name: "1x1".to_string(),
            devices: vec![DeviceSpec::edge("edge0", 1.0), DeviceSpec::cloud("cloud0", 1.0, 1.0)],
        }
    }

    /// `edges` baseline edge gateways × `clouds` baseline cloud
    /// replicas, all at speed 1.0 over clean links.
    pub fn uniform(edges: usize, clouds: usize) -> Topology {
        let mut devices = Vec::with_capacity(edges + clouds);
        for i in 0..edges {
            devices.push(DeviceSpec::edge(&format!("edge{i}"), 1.0));
        }
        for i in 0..clouds {
            devices.push(DeviceSpec::cloud(&format!("cloud{i}"), 1.0, 1.0));
        }
        Topology { name: format!("{edges}x{clouds}"), devices }
    }

    /// A heterogeneous-speed mix: four edges spanning 4× in speed
    /// (a fast desktop-class gateway down to a throttled embedded one)
    /// and two cloud replicas, the second slower *and* behind a worse
    /// link — the shape where blind replica assignment hurts most.
    pub fn hetero() -> Topology {
        Topology {
            name: "hetero".to_string(),
            devices: vec![
                DeviceSpec::edge("edge0", 2.0),
                DeviceSpec::edge("edge1", 1.0),
                DeviceSpec::edge("edge2", 1.0),
                DeviceSpec::edge("edge3", 0.5),
                DeviceSpec::cloud("cloud0", 1.0, 1.0),
                DeviceSpec::cloud("cloud1", 0.5, 1.5),
            ],
        }
    }

    /// Resolve a built-in preset by name: `1x1`, `4x2`, `8x4`, `hetero`,
    /// or any `<e>x<c>` uniform shape.
    pub fn preset(name: &str) -> Result<Topology> {
        match name {
            "1x1" => return Ok(Topology::pair()),
            "hetero" => return Ok(Topology::hetero()),
            _ => {}
        }
        if let Some((e, c)) = name.split_once('x') {
            if let (Ok(e), Ok(c)) = (e.parse::<usize>(), c.parse::<usize>()) {
                if e > 0 && c > 0 {
                    return Ok(Topology::uniform(e, c));
                }
            }
        }
        Err(Error::Config(format!(
            "unknown topology preset `{name}` (try 1x1, 4x2, 8x4, hetero, or <e>x<c>)"
        )))
    }

    /// Parse a topology from its JSON spec:
    ///
    /// ```json
    /// { "name": "lab",
    ///   "devices": [
    ///     { "name": "edge0", "tier": "edge", "speed": 2.0 },
    ///     { "name": "cloud0", "tier": "cloud", "workers": 8, "link_scale": 1.2 }
    ///   ] }
    /// ```
    ///
    /// `speed` defaults to 1.0, `link_scale` to 1.0, and `workers` to
    /// the tier default (1 edge / 4 cloud).
    pub fn from_json(j: &Json) -> Result<Topology> {
        let name = match j.get_opt("name")? {
            Some(n) => n.as_str()?.to_string(),
            None => "custom".to_string(),
        };
        let mut devices = Vec::new();
        for (i, d) in j.get("devices")?.as_array()?.iter().enumerate() {
            let tier = match d.get("tier")?.as_str()? {
                "edge" => DeviceKind::Edge,
                "cloud" => DeviceKind::Cloud,
                other => {
                    return Err(Error::Config(format!(
                        "device {i}: tier `{other}` is not edge|cloud"
                    )))
                }
            };
            let dev_name = match d.get_opt("name")? {
                Some(n) => n.as_str()?.to_string(),
                None => format!("{}{i}", tier.id()),
            };
            let speed = match d.get_opt("speed")? {
                Some(s) => s.as_f64()?,
                None => 1.0,
            };
            let workers = match d.get_opt("workers")? {
                Some(w) => w.as_usize()?,
                None => match tier {
                    DeviceKind::Edge => 1,
                    DeviceKind::Cloud => 4,
                },
            };
            let link_scale = match d.get_opt("link_scale")? {
                Some(l) => l.as_f64()?,
                None => 1.0,
            };
            devices.push(DeviceSpec { name: dev_name, tier, speed, workers, link_scale });
        }
        let topo = Topology { name, devices };
        topo.validate()?;
        Ok(topo)
    }

    /// Load a topology spec from a JSON file.
    pub fn load(path: &Path) -> Result<Topology> {
        Topology::from_json(&Json::parse_file(path)?)
    }

    /// Serialise for reports / spec round-trips.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("name", Json::Str(self.name.clone())).set(
            "devices",
            Json::Array(self.devices.iter().map(|d| d.to_json()).collect()),
        );
        o
    }

    /// Number of devices (dispatcher lanes).
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True when the topology has no devices (invalid; see
    /// [`Topology::validate`]).
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Device ids of the edge tier, in lane order.
    pub fn edge_ids(&self) -> Vec<DeviceId> {
        self.tier_ids(DeviceKind::Edge)
    }

    /// Device ids of the cloud tier, in lane order.
    pub fn cloud_ids(&self) -> Vec<DeviceId> {
        self.tier_ids(DeviceKind::Cloud)
    }

    fn tier_ids(&self, tier: DeviceKind) -> Vec<DeviceId> {
        self.devices
            .iter()
            .enumerate()
            .filter(|(_i, d)| d.tier == tier)
            .map(|(i, _d)| i)
            .collect()
    }

    /// `(edge devices, cloud replicas)` counts.
    pub fn shape(&self) -> (usize, usize) {
        (self.edge_ids().len(), self.cloud_ids().len())
    }

    /// A routable fleet needs both tiers populated, and every device
    /// well-formed.
    pub fn validate(&self) -> Result<()> {
        let (edges, clouds) = self.shape();
        if edges == 0 || clouds == 0 {
            return Err(Error::Config(format!(
                "topology {}: needs at least one edge and one cloud (got {edges}x{clouds})",
                self.name
            )));
        }
        for d in &self.devices {
            d.validate()?;
        }
        Ok(())
    }

    /// The dispatcher lane list for this fleet (one lane per device, in
    /// id order).
    pub fn lane_specs(&self, max_queue_depth: usize) -> Vec<crate::scheduler::LaneSpec> {
        self.devices
            .iter()
            .map(|d| crate::scheduler::LaneSpec {
                kind: d.tier,
                workers: d.workers,
                max_queue_depth,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_preset_matches_classic_sizing() {
        let t = Topology::pair();
        assert_eq!(t.name, "1x1");
        assert_eq!(t.shape(), (1, 1));
        assert_eq!(t.devices[0].tier, DeviceKind::Edge);
        assert_eq!(t.devices[0].workers, 1);
        assert_eq!(t.devices[1].tier, DeviceKind::Cloud);
        assert_eq!(t.devices[1].workers, 4);
        // Identity multipliers: the bit-equivalence precondition.
        assert_eq!(t.devices[0].slowdown(), 1.0);
        assert_eq!(t.devices[1].slowdown(), 1.0);
        assert_eq!(t.devices[1].link_scale, 1.0);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn uniform_and_named_presets_resolve() {
        assert_eq!(Topology::preset("4x2").unwrap().shape(), (4, 2));
        assert_eq!(Topology::preset("8x4").unwrap().shape(), (8, 4));
        assert_eq!(Topology::preset("1x1").unwrap().shape(), (1, 1));
        let h = Topology::preset("hetero").unwrap();
        assert_eq!(h.shape(), (4, 2));
        assert!(h.devices.iter().any(|d| d.speed != 1.0));
        assert!(Topology::preset("bogus").is_err());
        assert!(Topology::preset("0x3").is_err());
    }

    #[test]
    fn device_ids_are_lane_order() {
        let t = Topology::preset("4x2").unwrap();
        assert_eq!(t.edge_ids(), vec![0, 1, 2, 3]);
        assert_eq!(t.cloud_ids(), vec![4, 5]);
        let specs = t.lane_specs(128);
        assert_eq!(specs.len(), 6);
        assert_eq!(specs[0].kind, DeviceKind::Edge);
        assert_eq!(specs[0].workers, 1);
        assert_eq!(specs[5].kind, DeviceKind::Cloud);
        assert_eq!(specs[5].workers, 4);
        assert!(specs.iter().all(|s| s.max_queue_depth == 128));
    }

    #[test]
    fn json_spec_round_trips_with_defaults() {
        let spec = r#"{
            "name": "lab",
            "devices": [
                { "tier": "edge", "speed": 2.0 },
                { "name": "slowcloud", "tier": "cloud", "link_scale": 1.5 }
            ]
        }"#;
        let t = Topology::from_json(&Json::parse(spec).unwrap()).unwrap();
        assert_eq!(t.name, "lab");
        assert_eq!(t.shape(), (1, 1));
        assert_eq!(t.devices[0].name, "edge0"); // defaulted name
        assert_eq!(t.devices[0].workers, 1); // edge tier default
        assert_eq!(t.devices[1].name, "slowcloud");
        assert_eq!(t.devices[1].workers, 4); // cloud tier default
        assert!((t.devices[1].link_scale - 1.5).abs() < 1e-15);
        // Round trip through to_json.
        let again = Topology::from_json(&t.to_json()).unwrap();
        assert_eq!(again.name, t.name);
        assert_eq!(again.devices.len(), t.devices.len());
        assert_eq!(again.devices[1].name, "slowcloud");
    }

    #[test]
    fn validation_rejects_degenerate_fleets() {
        // No cloud tier.
        let t = Topology {
            name: "edges".into(),
            devices: vec![DeviceSpec::edge("e", 1.0)],
        };
        assert!(t.validate().is_err());
        // Bad speed.
        let t = Topology {
            name: "bad".into(),
            devices: vec![DeviceSpec::edge("e", 0.0), DeviceSpec::cloud("c", 1.0, 1.0)],
        };
        assert!(t.validate().is_err());
        // Bad link.
        let mut c = DeviceSpec::cloud("c", 1.0, 1.0);
        c.link_scale = f64::NAN;
        let t = Topology {
            name: "bad".into(),
            devices: vec![DeviceSpec::edge("e", 1.0), c],
        };
        assert!(t.validate().is_err());
        // Zero workers.
        let mut e = DeviceSpec::edge("e", 1.0);
        e.workers = 0;
        let t = Topology {
            name: "bad".into(),
            devices: vec![e, DeviceSpec::cloud("c", 1.0, 1.0)],
        };
        assert!(t.validate().is_err());
        let bad_tier = Json::parse(r#"{"devices":[{"tier":"fog"}]}"#).unwrap();
        assert!(Topology::from_json(&bad_tier).is_err());
    }

    /// A malformed spec file must never produce a routable fleet: every
    /// degenerate field the validator guards is also rejected when it
    /// arrives through the JSON front door (`--topology` on the CLI).
    #[test]
    fn json_spec_fails_closed() {
        let parse = |s: &str| Topology::from_json(&Json::parse(s).unwrap());
        // Missing the devices key entirely.
        assert!(parse(r#"{"name":"x"}"#).is_err());
        // Present but empty — no tiers to route between.
        assert!(parse(r#"{"devices":[]}"#).is_err());
        // One tier only.
        assert!(parse(r#"{"devices":[{"tier":"edge"}]}"#).is_err());
        assert!(parse(r#"{"devices":[{"tier":"cloud"}]}"#).is_err());
        // Degenerate numerics through the spec, not the struct.
        assert!(parse(
            r#"{"devices":[{"tier":"edge","speed":0.0},{"tier":"cloud"}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"devices":[{"tier":"edge","speed":-2.0},{"tier":"cloud"}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"devices":[{"tier":"edge","workers":0},{"tier":"cloud"}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"devices":[{"tier":"edge"},{"tier":"cloud","link_scale":0.0}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"devices":[{"tier":"edge"},{"tier":"cloud","link_scale":-1.0}]}"#
        )
        .is_err());
        // Wrong shapes: devices not an array, speed not a number.
        assert!(parse(r#"{"devices":{"tier":"edge"}}"#).is_err());
        assert!(parse(
            r#"{"devices":[{"tier":"edge","speed":"fast"},{"tier":"cloud"}]}"#
        )
        .is_err());
        // The minimal well-formed spec still parses (the guard is not
        // over-broad).
        let ok = parse(r#"{"devices":[{"tier":"edge"},{"tier":"cloud"}]}"#).unwrap();
        assert_eq!(ok.shape(), (1, 1));
        assert_eq!(ok.name, "custom");
    }
}
