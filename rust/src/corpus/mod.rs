//! Synthetic parallel corpora — the substrate standing in for IWSLT'14
//! DE-EN and OPUS-100 FR-EN / EN-ZH (DESIGN.md §4 substitution table).
//!
//! C-NMT consumes only the *length statistics* of a corpus: the joint
//! distribution of source length `N` and target length `M` drives both
//! the N→M regressor (paper Fig. 3) and the per-request work the router
//! must place. The generators here reproduce those statistics per language
//! pair — verbosity slope γ, offset δ, heteroscedastic noise, plus a
//! configurable fraction of misaligned "outlier" pairs that the
//! ParaCrawl-style [`prefilter`] must remove before fitting (paper §III).

pub mod dataset;
pub mod prefilter;
pub mod synth;
pub mod tokenizer;

pub use dataset::{Dataset, SentencePair};
pub use prefilter::{prefilter, PrefilterRules, PrefilterStats};
pub use synth::{CorpusGenerator, LangPair, LangPairParams};
pub use tokenizer::Tokenizer;
