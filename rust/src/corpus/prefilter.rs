//! ParaCrawl-style outlier pre-filtering (paper §III: "when computing γ
//! and δ, we remove outliers (e.g., wrongly matched sentence pairs)
//! following the pre-filtering rules described in [21]").
//!
//! ParaCrawl's bicleaner hard rules drop pairs that are (a) too short,
//! (b) too long, or (c) have an implausible length *ratio*. Rule (c) must
//! be language-pair aware — a legitimate EN→ZH pair routinely has
//! M/N ≈ 0.6 — so the ratio test is taken relative to the corpus' own
//! median verbosity rather than an absolute constant.

use super::dataset::SentencePair;

/// Tunable pre-filtering rules.
#[derive(Debug, Clone, Copy)]
pub struct PrefilterRules {
    /// Minimum length (both sides).
    pub min_len: usize,
    /// Maximum length (both sides).
    pub max_len: usize,
    /// Allowed multiplicative deviation of M from the corpus-median
    /// verbosity ratio: keep if `M ∈ [ratio·N/dev, ratio·N·dev]` (with an
    /// additive slack floor for very short sentences).
    pub max_ratio_dev: f64,
    /// Additive slack (tokens) applied on top of the ratio band.
    pub slack: f64,
}

impl Default for PrefilterRules {
    fn default() -> Self {
        PrefilterRules {
            min_len: 1,
            max_len: 62,
            max_ratio_dev: 1.6,
            slack: 2.0,
        }
    }
}

/// Outcome counts of a pre-filtering pass.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefilterStats {
    /// Pairs examined.
    pub total: usize,
    /// Pairs kept.
    pub kept: usize,
    /// Pairs dropped by the length bounds.
    pub dropped_len: usize,
    /// Pairs dropped by the length-ratio rule.
    pub dropped_ratio: usize,
}

impl PrefilterStats {
    /// Fraction of pairs dropped.
    pub fn drop_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            1.0 - self.kept as f64 / self.total as f64
        }
    }
}

/// Median M/N ratio of a corpus (the language-pair verbosity anchor).
pub fn median_ratio(pairs: &[SentencePair]) -> f64 {
    if pairs.is_empty() {
        return 1.0;
    }
    let mut ratios: Vec<f64> = pairs
        .iter()
        .map(|p| p.m_real as f64 / p.n() as f64)
        .collect();
    ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ratios[ratios.len() / 2]
}

/// Apply the rules; returns kept pairs (cloned) and stats.
pub fn prefilter(
    pairs: &[SentencePair],
    rules: &PrefilterRules,
) -> (Vec<SentencePair>, PrefilterStats) {
    let ratio = median_ratio(pairs);
    let mut kept = Vec::with_capacity(pairs.len());
    let mut stats = PrefilterStats { total: pairs.len(), ..Default::default() };
    for p in pairs {
        let n = p.n();
        let m = p.m_real;
        if n < rules.min_len
            || n > rules.max_len
            || m < rules.min_len
            || m > rules.max_len
        {
            stats.dropped_len += 1;
            continue;
        }
        let expected = ratio * n as f64;
        let lo = expected / rules.max_ratio_dev - rules.slack;
        let hi = expected * rules.max_ratio_dev + rules.slack;
        if (m as f64) < lo || (m as f64) > hi {
            stats.dropped_ratio += 1;
            continue;
        }
        kept.push(p.clone());
        stats.kept += 1;
    }
    (kept, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synth::{CorpusGenerator, LangPair};

    fn pair(n: usize, m: usize) -> SentencePair {
        SentencePair { src: vec![5; n], m_real: m, outlier: false }
    }

    #[test]
    fn drops_length_violations() {
        let pairs = vec![pair(1, 70), pair(70, 10), pair(10, 10)];
        let rules = PrefilterRules { max_len: 62, ..Default::default() };
        let (kept, stats) = prefilter(&pairs, &rules);
        assert_eq!(stats.dropped_len, 2);
        assert_eq!(kept.len(), 1);
        assert_eq!(kept[0].n(), 10);
    }

    #[test]
    fn ratio_filter_is_verbosity_aware() {
        // A compact-target corpus (ratio ~0.6): M = 0.6N is fine, M = 2N
        // is not — even though 2N would pass a naive |ratio|<2.2 rule for
        // a 1:1 language pair.
        let mut pairs: Vec<SentencePair> =
            (5..40).map(|n| pair(n, (n as f64 * 0.6).round() as usize)).collect();
        pairs.push(pair(20, 40)); // misaligned
        let (kept, stats) = prefilter(&pairs, &PrefilterRules::default());
        assert_eq!(stats.dropped_ratio, 1);
        assert!(kept.iter().all(|p| p.m_real != 40));
    }

    #[test]
    fn removes_most_injected_outliers_keeps_most_inliers() {
        for lp in LangPair::ALL {
            let mut g = CorpusGenerator::new(lp, 11);
            let pairs = g.take(20_000);
            let (kept, stats) = prefilter(&pairs, &PrefilterRules::default());
            let outliers_in = pairs.iter().filter(|p| p.outlier).count();
            let outliers_kept = kept.iter().filter(|p| p.outlier).count();
            let inliers_in = pairs.len() - outliers_in;
            let inliers_kept = kept.len() - outliers_kept;
            // Most outliers gone. (An outlier can land inside the
            // plausible band by chance, so not all.)
            assert!(
                (outliers_kept as f64) < 0.45 * outliers_in as f64,
                "{}: kept {outliers_kept}/{outliers_in} outliers",
                lp.id()
            );
            // Very few legitimate pairs lost.
            assert!(
                (inliers_kept as f64) > 0.97 * inliers_in as f64,
                "{}: kept only {inliers_kept}/{inliers_in} inliers",
                lp.id()
            );
            assert_eq!(stats.kept, kept.len());
            assert_eq!(
                stats.total,
                stats.kept + stats.dropped_len + stats.dropped_ratio
            );
        }
    }

    #[test]
    fn median_ratio_reflects_verbosity() {
        let mut g = CorpusGenerator::new(LangPair::EnZh, 5);
        let pairs = g.take(10_000);
        let r = median_ratio(&pairs);
        assert!((0.55..0.80).contains(&r), "EN-ZH median ratio {r}");
        let mut g = CorpusGenerator::new(LangPair::DeEn, 5);
        let r = median_ratio(&g.take(10_000));
        assert!((0.95..1.25).contains(&r), "DE-EN median ratio {r}");
    }

    #[test]
    fn empty_input_ok() {
        let (kept, stats) = prefilter(&[], &PrefilterRules::default());
        assert!(kept.is_empty());
        assert_eq!(stats.drop_rate(), 0.0);
    }
}
