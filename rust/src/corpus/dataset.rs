//! Dataset container: holds generated sentence pairs, provides the
//! characterisation/evaluation split the paper uses (10k fitting
//! inferences vs 100k evaluation requests, §III) and summary statistics.

use crate::util::Rng;
use crate::{Error, Result};

use super::synth::{CorpusGenerator, LangPair};

/// One parallel sentence pair.
///
/// `src` holds content token ids (EOS/BOS are added by the runtime);
/// `m_real` is the ground-truth target length the corpus provides — the
/// quantity the paper's N→M regressor is fitted on, and the number of
/// decoder steps a request for this pair costs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SentencePair {
    /// Source token ids.
    pub src: Vec<u16>,
    /// True output length (tokens).
    pub m_real: usize,
    /// True if this pair was generated as misaligned (ground truth known
    /// only to the generator; the prefilter must *infer* it).
    pub outlier: bool,
}

impl SentencePair {
    /// Source length (tokens).
    pub fn n(&self) -> usize {
        self.src.len()
    }
}

/// A generated corpus with a fit/eval split.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Language pair this dataset was generated for.
    pub pair: LangPair,
    /// Pairs used for offline characterisation (T_exe fit, γ/δ fit).
    pub fit: Vec<SentencePair>,
    /// Pairs used as the evaluation request stream.
    pub eval: Vec<SentencePair>,
}

impl Dataset {
    /// Generate a dataset: `fit_count` characterisation pairs plus
    /// `eval_count` request pairs (disjoint streams, as in the paper:
    /// "fitted on the result of 10k inferences per device, with inputs
    /// not included in the 100k set").
    pub fn generate(
        pair: LangPair,
        fit_count: usize,
        eval_count: usize,
        seed: u64,
    ) -> Dataset {
        let mut g_fit = CorpusGenerator::new(pair, seed ^ 0xF17);
        let mut g_eval = CorpusGenerator::new(pair, seed ^ 0xE7A1);
        Dataset {
            pair,
            fit: g_fit.take(fit_count),
            eval: g_eval.take(eval_count),
        }
    }

    /// Mean target length over the *fit* split — what the paper's Naive
    /// baseline uses as its constant M estimate.
    pub fn mean_m_fit(&self) -> f64 {
        if self.fit.is_empty() {
            return f64::NAN;
        }
        self.fit.iter().map(|p| p.m_real as f64).sum::<f64>()
            / self.fit.len() as f64
    }

    /// Mean source length over the fit split.
    pub fn mean_n_fit(&self) -> f64 {
        if self.fit.is_empty() {
            return f64::NAN;
        }
        self.fit.iter().map(|p| p.n() as f64).sum::<f64>()
            / self.fit.len() as f64
    }

    /// (N, M) pairs of the fit split, for regression.
    pub fn fit_nm(&self) -> Vec<(f64, f64)> {
        self.fit
            .iter()
            .map(|p| (p.n() as f64, p.m_real as f64))
            .collect()
    }

    /// Sample `count` eval requests with replacement (request stream for
    /// experiments larger than the generated eval set).
    pub fn sample_eval(&self, count: usize, seed: u64) -> Vec<&SentencePair> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| &self.eval[rng.usize(self.eval.len())])
            .collect()
    }

    /// Check split sizes and length bounds.
    pub fn validate(&self) -> Result<()> {
        if self.fit.is_empty() || self.eval.is_empty() {
            return Err(Error::Corpus("empty dataset split".into()));
        }
        for p in self.fit.iter().chain(self.eval.iter()) {
            if p.src.is_empty() || p.src.len() > 62 || p.m_real == 0 || p.m_real > 62 {
                return Err(Error::Corpus(format!(
                    "pair out of bounds: n={} m={}",
                    p.src.len(),
                    p.m_real
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_splits_disjoint_streams() {
        let d = Dataset::generate(LangPair::DeEn, 500, 1000, 42);
        assert_eq!(d.fit.len(), 500);
        assert_eq!(d.eval.len(), 1000);
        d.validate().unwrap();
        // Streams are seeded differently: first pairs should differ.
        assert_ne!(d.fit[0], d.eval[0]);
    }

    #[test]
    fn mean_m_sane() {
        let d = Dataset::generate(LangPair::EnZh, 5000, 100, 1);
        let gamma = LangPair::EnZh.params().gamma;
        let delta = LangPair::EnZh.params().delta;
        let expect = gamma * d.mean_n_fit() + delta;
        // Outliers perturb slightly; tolerance generous.
        assert!(
            (d.mean_m_fit() - expect).abs() < 1.5,
            "mean_m {} expect {expect}",
            d.mean_m_fit()
        );
    }

    #[test]
    fn sample_eval_with_replacement() {
        let d = Dataset::generate(LangPair::FrEn, 10, 20, 9);
        let sample = d.sample_eval(500, 3);
        assert_eq!(sample.len(), 500);
        // All samples come from the eval split.
        for s in sample {
            assert!(d.eval.iter().any(|p| p == s));
        }
    }

    #[test]
    fn deterministic() {
        let a = Dataset::generate(LangPair::FrEn, 50, 50, 7);
        let b = Dataset::generate(LangPair::FrEn, 50, 50, 7);
        assert_eq!(a.fit, b.fit);
        assert_eq!(a.eval, b.eval);
    }
}
