//! Per-language-pair synthetic corpus generation.
//!
//! Length model (matched to the paper's Fig. 3 regressions):
//!
//! * `N ~ clip(LogNormal(ln_mean, ln_sigma), 1, n_cap)` — sentence lengths
//!   in translation corpora are right-skewed; IWSLT/OPUS means sit around
//!   10-20 tokens.
//! * `M = round(γ·N + δ + ε)`, `ε ~ Normal(0, σ0 + σ_slope·N)` — linear
//!   verbosity with noise growing in N, exactly the structure the paper's
//!   linear N→M fit exploits (R² ≈ 0.99 after pre-filtering).
//! * with probability `outlier_p` the pair is *misaligned*: `M` is drawn
//!   independently of `N` (uniform), modelling the wrongly-matched pairs
//!   the paper removes "following the pre-filtering rules described in
//!   [21] (ParaCrawl)".
//!
//! γ < 1 encodes lower target-language verbosity: the paper calls out
//! EN vs FR (Fig. 3b) and ZH vs EN (Fig. 3c).

use crate::util::Rng;

use super::dataset::SentencePair;

/// The three evaluated language pairs (Table I rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LangPair {
    /// IWSLT'14 German→English (BiLSTM model).
    DeEn,
    /// OPUS-100 French→English (GRU model).
    FrEn,
    /// OPUS-100 English→Chinese (Transformer model).
    EnZh,
}

impl LangPair {
    /// All three paper language pairs, in report order.
    pub const ALL: [LangPair; 3] = [LangPair::DeEn, LangPair::FrEn, LangPair::EnZh];

    /// Stable string id (used in flags and reports).
    pub fn id(&self) -> &'static str {
        match self {
            LangPair::DeEn => "de_en",
            LangPair::FrEn => "fr_en",
            LangPair::EnZh => "en_zh",
        }
    }

    /// Parse an id produced by [`LangPair::id`].
    pub fn from_id(id: &str) -> Option<LangPair> {
        match id {
            "de_en" => Some(LangPair::DeEn),
            "fr_en" => Some(LangPair::FrEn),
            "en_zh" => Some(LangPair::EnZh),
            _ => None,
        }
    }

    /// The NMT model evaluated on this pair (manifest model name).
    pub fn model_name(&self) -> &'static str {
        match self {
            LangPair::DeEn => "bilstm_de_en",
            LangPair::FrEn => "gru_fr_en",
            LangPair::EnZh => "transformer_en_zh",
        }
    }

    /// Ground-truth generation parameters for this pair.
    pub fn params(&self) -> LangPairParams {
        match self {
            // DE→EN: English slightly more verbose than German (compounds
            // split into several words). IWSLT'14 is conversational TED
            // speech: short-ish sentences.
            LangPair::DeEn => LangPairParams {
                gamma: 1.05,
                delta: 0.4,
                sigma0: 0.7,
                sigma_slope: 0.050,
                ln_mean: 2.45, // median ~ 11.6 tokens
                ln_sigma: 0.55,
                outlier_p: 0.02,
            },
            // FR→EN: English less verbose than French (paper: "γ < 1 is
            // needed to account for the lower verbosity of the English
            // language with respect to French").
            LangPair::FrEn => LangPairParams {
                gamma: 0.82,
                delta: 0.6,
                sigma0: 0.5,
                sigma_slope: 0.035,
                ln_mean: 2.60,
                ln_sigma: 0.60,
                outlier_p: 0.03, // OPUS-100 is web-crawled: noisier
            },
            // EN→ZH: Chinese is far more compact than English.
            LangPair::EnZh => LangPairParams {
                gamma: 0.62,
                delta: 0.9,
                sigma0: 0.8,
                sigma_slope: 0.055,
                ln_mean: 2.55,
                ln_sigma: 0.58,
                outlier_p: 0.03,
            },
        }
    }
}

/// Ground-truth corpus statistics for one language pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LangPairParams {
    /// Verbosity slope: E[M | N] ≈ γ·N + δ.
    pub gamma: f64,
    /// Verbosity offset.
    pub delta: f64,
    /// Noise std at N = 0.
    pub sigma0: f64,
    /// Noise std growth per source token.
    pub sigma_slope: f64,
    /// LogNormal location of N.
    pub ln_mean: f64,
    /// LogNormal scale of N.
    pub ln_sigma: f64,
    /// Probability a pair is misaligned (outlier).
    pub outlier_p: f64,
}

/// Streaming generator of [`SentencePair`]s for one language pair.
#[derive(Debug, Clone)]
pub struct CorpusGenerator {
    pair: LangPair,
    params: LangPairParams,
    rng: Rng,
    /// Content-token cap (leaves room for EOS within N_MAX=64).
    n_cap: usize,
    first_content_id: u16,
    vocab: u16,
}

impl CorpusGenerator {
    /// Generator for `pair` seeded with `seed`.
    pub fn new(pair: LangPair, seed: u64) -> Self {
        CorpusGenerator {
            pair,
            params: pair.params(),
            rng: Rng::new(seed ^ 0xC0_AB5E_u64.wrapping_mul(pair as u64 + 1)),
            n_cap: 62,
            first_content_id: 3, // 0=PAD, 1=BOS, 2=EOS
            vocab: 4096,
        }
    }

    /// Override generation parameters (used by tests and ablations).
    pub fn with_params(mut self, params: LangPairParams) -> Self {
        self.params = params;
        self
    }

    /// The language pair this generator produces.
    pub fn pair(&self) -> LangPair {
        self.pair
    }

    fn sample_n(&mut self) -> usize {
        let x = self.rng.lognormal(self.params.ln_mean, self.params.ln_sigma);
        (x.round() as usize).clamp(1, self.n_cap)
    }

    fn sample_m_given_n(&mut self, n: usize) -> usize {
        let p = &self.params;
        let mean = p.gamma * n as f64 + p.delta;
        let sigma = p.sigma0 + p.sigma_slope * n as f64;
        let m = self.rng.normal_ms(mean, sigma).round();
        (m as isize).clamp(1, self.n_cap as isize) as usize
    }

    /// Generate the next sentence pair.
    pub fn next_pair(&mut self) -> SentencePair {
        let n = self.sample_n();
        let outlier = self.rng.bool(self.params.outlier_p);
        let m = if outlier {
            // Misaligned pair: target length unrelated to source.
            self.rng.usize(self.n_cap) + 1
        } else {
            self.sample_m_given_n(n)
        };
        let span = (self.vocab - self.first_content_id) as usize;
        let src: Vec<u16> = (0..n)
            .map(|_| self.first_content_id + self.rng.usize(span) as u16)
            .collect();
        SentencePair { src, m_real: m, outlier }
    }

    /// Generate a batch.
    pub fn take(&mut self, count: usize) -> Vec<SentencePair> {
        (0..count).map(|_| self.next_pair()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::OnlineStats;

    #[test]
    fn lengths_within_bounds() {
        for pair in LangPair::ALL {
            let mut g = CorpusGenerator::new(pair, 1);
            for _ in 0..2000 {
                let p = g.next_pair();
                assert!((1..=62).contains(&p.src.len()));
                assert!((1..=62).contains(&p.m_real));
                assert!(p.src.iter().all(|&t| (3..4096).contains(&t)));
            }
        }
    }

    #[test]
    fn verbosity_slope_recoverable() {
        // Conditional mean of M should track γ·N + δ for inlier pairs.
        for pair in LangPair::ALL {
            let params = pair.params();
            let mut g = CorpusGenerator::new(pair, 2);
            let mut by_n: std::collections::BTreeMap<usize, OnlineStats> =
                Default::default();
            for _ in 0..30_000 {
                let p = g.next_pair();
                if p.outlier {
                    continue;
                }
                by_n.entry(p.src.len())
                    .or_insert_with(OnlineStats::new)
                    .push(p.m_real as f64);
            }
            // Check a couple of well-populated N bins.
            for n in [8usize, 14, 20] {
                let s = &by_n[&n];
                assert!(s.count() > 100, "bin {n} underpopulated");
                let expect = params.gamma * n as f64 + params.delta;
                assert!(
                    (s.mean() - expect).abs() < 0.8,
                    "{}: N={n} mean M {} vs expected {expect}",
                    pair.id(),
                    s.mean()
                );
            }
        }
    }

    #[test]
    fn outlier_rate_matches() {
        let mut g = CorpusGenerator::new(LangPair::FrEn, 3);
        let n = 50_000;
        let outliers = g.take(n).iter().filter(|p| p.outlier).count();
        let rate = outliers as f64 / n as f64;
        assert!((rate - 0.03).abs() < 0.005, "rate {rate}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = CorpusGenerator::new(LangPair::DeEn, 7);
        let mut b = CorpusGenerator::new(LangPair::DeEn, 7);
        for _ in 0..50 {
            let (x, y) = (a.next_pair(), b.next_pair());
            assert_eq!(x.src, y.src);
            assert_eq!(x.m_real, y.m_real);
        }
    }

    #[test]
    fn pairs_differ_across_langs() {
        let a = CorpusGenerator::new(LangPair::DeEn, 7).next_pair();
        let b = CorpusGenerator::new(LangPair::EnZh, 7).next_pair();
        assert!(a.src != b.src || a.m_real != b.m_real);
    }

    #[test]
    fn lang_pair_ids_roundtrip() {
        for p in LangPair::ALL {
            assert_eq!(LangPair::from_id(p.id()), Some(p));
        }
        assert_eq!(LangPair::from_id("xx_yy"), None);
    }
}
