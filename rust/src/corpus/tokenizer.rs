//! Deterministic synthetic tokenizer.
//!
//! Maps token ids to pronounceable pseudo-words (and back) so the examples
//! can print human-readable "sentences" and accept text input. The mapping
//! is a bijection over the whole vocabulary: id → syllable expansion in a
//! base-`(consonants × vowels)` positional code.

use crate::{Error, Result};

/// Special token ids shared with the python side (see manifest.json).
pub const PAD_ID: u16 = 0;
/// Beginning-of-sequence token id.
pub const BOS_ID: u16 = 1;
/// End-of-sequence token id.
pub const EOS_ID: u16 = 2;
/// First id usable for content words.
pub const FIRST_CONTENT_ID: u16 = 3;

const CONSONANTS: &[u8] = b"bdfgklmnprstvz";
const VOWELS: &[u8] = b"aeiou";

/// Bijective id ⇄ pseudo-word codec.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: u16,
}

impl Tokenizer {
    /// Tokenizer over a vocabulary of `vocab` ids.
    pub fn new(vocab: u16) -> Self {
        Tokenizer { vocab }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> u16 {
        self.vocab
    }

    /// id → pseudo-word. Special ids render as markers.
    pub fn word(&self, id: u16) -> String {
        match id {
            PAD_ID => "<pad>".into(),
            BOS_ID => "<bos>".into(),
            EOS_ID => "<eos>".into(),
            _ => {
                let base = (CONSONANTS.len() * VOWELS.len()) as u32; // 70
                let mut x = (id - FIRST_CONTENT_ID) as u32;
                let mut out = String::new();
                loop {
                    let syll = x % base;
                    out.push(CONSONANTS[(syll as usize) / VOWELS.len()] as char);
                    out.push(VOWELS[(syll as usize) % VOWELS.len()] as char);
                    x /= base;
                    if x == 0 {
                        break;
                    }
                    x -= 1; // bijective numeration
                }
                out
            }
        }
    }

    /// pseudo-word → id (inverse of [`word`](Self::word)).
    pub fn id(&self, word: &str) -> Result<u16> {
        match word {
            "<pad>" => return Ok(PAD_ID),
            "<bos>" => return Ok(BOS_ID),
            "<eos>" => return Ok(EOS_ID),
            _ => {}
        }
        let bytes = word.as_bytes();
        if bytes.is_empty() || bytes.len() % 2 != 0 {
            return Err(Error::Corpus(format!("malformed word `{word}`")));
        }
        let base = (CONSONANTS.len() * VOWELS.len()) as u64;
        let mut x: u64 = 0;
        let mut mult: u64 = 1;
        let mut first = true;
        for chunk in bytes.chunks(2) {
            let c = CONSONANTS
                .iter()
                .position(|&b| b == chunk[0])
                .ok_or_else(|| Error::Corpus(format!("bad consonant in `{word}`")))?;
            let v = VOWELS
                .iter()
                .position(|&b| b == chunk[1])
                .ok_or_else(|| Error::Corpus(format!("bad vowel in `{word}`")))?;
            let syll = (c * VOWELS.len() + v) as u64;
            if first {
                x = syll;
                first = false;
            } else {
                x += (syll + 1) * mult;
            }
            mult *= base;
        }
        let id = x + FIRST_CONTENT_ID as u64;
        if id >= self.vocab as u64 {
            return Err(Error::Corpus(format!(
                "word `{word}` maps to id {id} >= vocab {}",
                self.vocab
            )));
        }
        Ok(id as u16)
    }

    /// Render a token id sequence as a sentence.
    pub fn detokenize(&self, ids: &[u16]) -> String {
        ids.iter()
            .map(|&i| self.word(i))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Parse a whitespace-separated sentence into ids.
    pub fn tokenize(&self, text: &str) -> Result<Vec<u16>> {
        text.split_whitespace().map(|w| self.id(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_whole_vocab() {
        let t = Tokenizer::new(4096);
        for id in FIRST_CONTENT_ID..4096 {
            let w = t.word(id);
            assert_eq!(t.id(&w).unwrap(), id, "word {w}");
        }
    }

    #[test]
    fn specials() {
        let t = Tokenizer::new(4096);
        assert_eq!(t.word(PAD_ID), "<pad>");
        assert_eq!(t.id("<eos>").unwrap(), EOS_ID);
    }

    #[test]
    fn words_distinct() {
        let t = Tokenizer::new(4096);
        let mut seen = std::collections::HashSet::new();
        for id in FIRST_CONTENT_ID..4096 {
            assert!(seen.insert(t.word(id)), "duplicate word for id {id}");
        }
    }

    #[test]
    fn sentence_roundtrip() {
        let t = Tokenizer::new(4096);
        let ids = vec![3u16, 100, 999, 4095];
        let text = t.detokenize(&ids);
        assert_eq!(t.tokenize(&text).unwrap(), ids);
    }

    #[test]
    fn rejects_garbage() {
        let t = Tokenizer::new(4096);
        assert!(t.id("x").is_err());
        assert!(t.id("qq").is_err());
        assert!(t.id("").is_err());
    }
}
