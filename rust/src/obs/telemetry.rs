//! Control-loop telemetry: phase decomposition and gauge time-series.
//!
//! Two report-facing surfaces, both **off by default** (every legacy
//! checked-in report stays byte-identical):
//!
//! * [`Phases`] — per-request latency decomposition. Each result's
//!   end-to-end latency is attributed to four exhaustive phases that sum
//!   to it exactly:
//!   `queue_wait` (arrival → batch start: the eq. 1 wait term the
//!   selector *estimates*), `batch_wait` (the extra service time the
//!   request's batch needs beyond the request's own execution),
//!   `exec` (the request's own true execution time), and `tx` (the
//!   network transfer, cloud placements only). Aggregated into the same
//!   log-bucketed histograms the latency reports use, making the
//!   expected-wait estimate auditable against realized wait.
//!
//! * [`Telemetry`] — a fixed-cadence, fixed-capacity sampler of
//!   per-device gauges (queue depth, backlog expected-wait, in-flight)
//!   plus the adaptive-control state (installed RLS plane coefficients,
//!   hedge margin, windowed wasted-work fraction). Capacity is
//!   preallocated and never exceeded: when a run outlives the window,
//!   sampling stops and the series is flagged `truncated` rather than
//!   growing or rotating — time-series rows must stay aligned for the
//!   report mirror.
//!
//! Both are mirrored float-exactly by `python/tools/telemetry_mirror.py`.

use crate::metrics::Histogram;
use crate::util::Json;

/// Telemetry sampling parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TelemetryCfg {
    /// Sim-time cadence between gauge samples (seconds).
    pub interval_s: f64,
    /// Maximum samples retained (series are preallocated to this).
    pub capacity: usize,
}

impl Default for TelemetryCfg {
    fn default() -> Self {
        TelemetryCfg { interval_s: 2.0, capacity: 64 }
    }
}

/// Gauge series for one device (lane), all aligned with
/// [`Telemetry::t_s`].
#[derive(Debug, Clone)]
pub struct DeviceSeries {
    /// Device name (topology order).
    pub name: String,
    /// Queued requests (live entries; cancelled ghosts excluded).
    pub queue_depth: Vec<f64>,
    /// Backlog expected-wait at the sample instant (seconds) — the wait
    /// term the eq. 1 selector would see.
    pub expected_wait_s: Vec<f64>,
    /// Batches still executing at the sample instant.
    pub in_flight: Vec<f64>,
    /// Installed T_exe plane coefficients `[a_n, a_m, b]`, present on
    /// adaptive runs: the refit story in three time-series.
    pub plane: Option<[Vec<f64>; 3]>,
}

impl DeviceSeries {
    fn new(name: String, capacity: usize, adaptive: bool) -> Self {
        DeviceSeries {
            name,
            queue_depth: Vec::with_capacity(capacity),
            expected_wait_s: Vec::with_capacity(capacity),
            in_flight: Vec::with_capacity(capacity),
            plane: adaptive.then(|| {
                [
                    Vec::with_capacity(capacity),
                    Vec::with_capacity(capacity),
                    Vec::with_capacity(capacity),
                ]
            }),
        }
    }

    fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("name", Json::Str(self.name.clone()))
            .set("queue_depth", Json::from_f64_slice(&self.queue_depth))
            .set("expected_wait_s", Json::from_f64_slice(&self.expected_wait_s))
            .set("in_flight", Json::from_f64_slice(&self.in_flight));
        if let Some(plane) = &self.plane {
            o.set("plane_an", Json::from_f64_slice(&plane[0]))
                .set("plane_am", Json::from_f64_slice(&plane[1]))
                .set("plane_b", Json::from_f64_slice(&plane[2]));
        }
        o
    }
}

/// Fixed-cadence control-loop gauge sampler (see the module docs).
#[derive(Debug, Clone)]
pub struct Telemetry {
    /// Sampling cadence (seconds).
    pub interval_s: f64,
    capacity: usize,
    next_s: f64,
    /// Sample instants; every other series aligns with this.
    pub t_s: Vec<f64>,
    /// One gauge bundle per device, in topology order.
    pub devices: Vec<DeviceSeries>,
    /// Hedge controller margin per sample (controlled runs only).
    pub hedge_margin_s: Option<Vec<f64>>,
    /// Controller's decayed-window wasted-work fraction per sample
    /// (controlled runs only).
    pub wasted_frac: Option<Vec<f64>>,
    truncated: bool,
}

impl Telemetry {
    /// Sampler for `names` devices; `adaptive` adds plane-coefficient
    /// series, `controlled` adds hedge margin + wasted-frac series. The
    /// first sample lands at `interval_s` (the t=0 state is all zeros).
    pub fn new(cfg: TelemetryCfg, names: &[String], adaptive: bool, controlled: bool) -> Self {
        let cap = cfg.capacity.max(1);
        Telemetry {
            interval_s: cfg.interval_s,
            capacity: cap,
            next_s: cfg.interval_s,
            t_s: Vec::with_capacity(cap),
            devices: names
                .iter()
                .map(|n| DeviceSeries::new(n.clone(), cap, adaptive))
                .collect(),
            hedge_margin_s: controlled.then(|| Vec::with_capacity(cap)),
            wasted_frac: controlled.then(|| Vec::with_capacity(cap)),
            truncated: false,
        }
    }

    /// If a sample is due at or before `now_s` (and the window has
    /// room), claim it: the sample instant is pushed onto [`Self::t_s`],
    /// the cadence advances, and the caller must push one value onto
    /// every gauge series. Returns the claimed instant. When the window
    /// is full, a due sample flags `truncated` instead.
    pub fn next_due(&mut self, now_s: f64) -> Option<f64> {
        if self.next_s > now_s {
            return None;
        }
        if self.t_s.len() >= self.capacity {
            self.truncated = true;
            return None;
        }
        let t = self.next_s;
        self.next_s += self.interval_s;
        self.t_s.push(t);
        Some(t)
    }

    /// Samples taken.
    pub fn samples(&self) -> usize {
        self.t_s.len()
    }

    /// Did the run outlive the sampling window?
    pub fn truncated(&self) -> bool {
        self.truncated
    }

    /// Render the series block for a report.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("interval_s", Json::Num(self.interval_s))
            .set("samples", Json::Num(self.t_s.len() as f64))
            .set("truncated", Json::Bool(self.truncated))
            .set("t_s", Json::from_f64_slice(&self.t_s))
            .set(
                "devices",
                Json::Array(self.devices.iter().map(|d| d.to_json()).collect()),
            );
        if let Some(m) = &self.hedge_margin_s {
            o.set("hedge_margin_s", Json::from_f64_slice(m));
        }
        if let Some(w) = &self.wasted_frac {
            o.set("wasted_frac", Json::from_f64_slice(w));
        }
        o
    }
}

/// Per-request latency decomposition (see the module docs). The four
/// phases partition each result's latency exactly:
/// `queue_wait + batch_wait + exec + tx == latency`.
#[derive(Debug, Clone)]
pub struct Phases {
    /// Arrival → batch start (realized eq. 1 wait term).
    pub queue_wait: Histogram,
    /// Batch service time beyond the request's own execution.
    pub batch_wait: Histogram,
    /// The request's own true execution time.
    pub exec: Histogram,
    /// Network transfer (zero for edge placements).
    pub tx: Histogram,
}

impl Default for Phases {
    fn default() -> Self {
        Self::new()
    }
}

impl Phases {
    /// Empty decomposition with the standard latency buckets.
    pub fn new() -> Self {
        Phases {
            queue_wait: Histogram::latency(),
            batch_wait: Histogram::latency(),
            exec: Histogram::latency(),
            tx: Histogram::latency(),
        }
    }

    /// Record one result's decomposition.
    pub fn record(&mut self, queue_wait_s: f64, batch_wait_s: f64, exec_s: f64, tx_s: f64) {
        self.queue_wait.record(queue_wait_s);
        self.batch_wait.record(batch_wait_s);
        self.exec.record(exec_s);
        self.tx.record(tx_s);
    }

    /// Results recorded.
    pub fn count(&self) -> u64 {
        self.queue_wait.count()
    }

    fn phase_json(h: &Histogram) -> Json {
        let mut o = Json::object();
        o.set("count", Json::Num(h.count() as f64))
            .set("mean_s", Json::Num(h.mean()))
            .set("p50_s", Json::Num(h.p50()))
            .set("p95_s", Json::Num(h.p95()))
            .set("p99_s", Json::Num(h.p99()))
            .set("sum_s", Json::Num(h.sum()));
        o
    }

    /// Render the decomposition block for a report.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("queue_wait", Self::phase_json(&self.queue_wait))
            .set("batch_wait", Self::phase_json(&self.batch_wait))
            .set("exec", Self::phase_json(&self.exec))
            .set("tx", Self::phase_json(&self.tx));
        o
    }
}

/// Per-service-class latency phase decomposition: one [`Phases`] per
/// SLO class of a scenario run ([`crate::sim::scenario`]), so a report
/// can show *where* each class's latency goes — e.g. interactive
/// traffic dominated by queue wait under a flash crowd while batch
/// traffic eats the batch-amortisation slack.
#[derive(Debug, Clone)]
pub struct ClassPhases {
    names: Vec<String>,
    phases: Vec<Phases>,
}

impl ClassPhases {
    /// One empty decomposition per class name.
    pub fn new(names: &[String]) -> Self {
        ClassPhases {
            names: names.to_vec(),
            phases: names.iter().map(|_| Phases::new()).collect(),
        }
    }

    /// Record one result's decomposition under its class index.
    pub fn record(
        &mut self,
        class: usize,
        queue_wait_s: f64,
        batch_wait_s: f64,
        exec_s: f64,
        tx_s: f64,
    ) {
        self.phases[class].record(queue_wait_s, batch_wait_s, exec_s, tx_s);
    }

    /// The decomposition of one class.
    pub fn class(&self, class: usize) -> &Phases {
        &self.phases[class]
    }

    /// Render as an object keyed by class name (sorted by the JSON
    /// layer, like every report object).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        for (name, p) in self.names.iter().zip(&self.phases) {
            o.set(name, p.to_json());
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_claims_fixed_cadence_until_capacity() {
        let cfg = TelemetryCfg { interval_s: 2.0, capacity: 3 };
        let names = vec!["edge0".to_string(), "cloud0".to_string()];
        let mut tel = Telemetry::new(cfg, &names, true, true);
        assert_eq!(tel.devices.len(), 2);
        assert!(tel.devices[0].plane.is_some());
        assert!(tel.hedge_margin_s.is_some());

        // Nothing due before the first interval.
        assert_eq!(tel.next_due(1.9), None);
        // A big jump claims every elapsed cadence point, one at a time.
        assert_eq!(tel.next_due(7.0), Some(2.0));
        assert_eq!(tel.next_due(7.0), Some(4.0));
        assert_eq!(tel.next_due(7.0), Some(6.0));
        // Capacity 3 reached: the due sample at 8.0 flags truncation.
        assert_eq!(tel.next_due(100.0), None);
        assert!(tel.truncated());
        assert_eq!(tel.t_s, vec![2.0, 4.0, 6.0]);
        assert_eq!(tel.samples(), 3);
    }

    #[test]
    fn sampler_not_truncated_when_run_ends_inside_window() {
        let cfg = TelemetryCfg { interval_s: 1.0, capacity: 8 };
        let names = vec!["d".to_string()];
        let mut tel = Telemetry::new(cfg, &names, false, false);
        assert!(tel.devices[0].plane.is_none());
        assert!(tel.hedge_margin_s.is_none());
        while let Some(_t) = tel.next_due(3.5) {
            tel.devices[0].queue_depth.push(0.0);
            tel.devices[0].expected_wait_s.push(0.0);
            tel.devices[0].in_flight.push(0.0);
        }
        assert_eq!(tel.t_s, vec![1.0, 2.0, 3.0]);
        assert!(!tel.truncated());
        let j = tel.to_json();
        assert_eq!(j.get("samples").unwrap().as_i64().unwrap(), 3);
        assert!(!j.get("truncated").unwrap().as_bool().unwrap());
        assert!(j.get_opt("hedge_margin_s").is_none());
    }

    #[test]
    fn phases_partition_latency_exactly() {
        let mut p = Phases::new();
        // queue + batch + exec + tx must reassemble the latency.
        let cases = [
            (0.0, 0.001, 0.010, 0.0),
            (0.532, 0.0, 0.020, 0.042),
            (1.25, 0.004, 0.015, 0.042),
        ];
        let mut want = 0.0;
        for (q, b, e, t) in cases {
            p.record(q, b, e, t);
            want += q + b + e + t;
        }
        assert_eq!(p.count(), 3);
        let got = p.queue_wait.sum() + p.batch_wait.sum() + p.exec.sum() + p.tx.sum();
        assert!((got - want).abs() < 1e-12);
        let j = p.to_json();
        assert_eq!(
            j.get("exec").unwrap().get("count").unwrap().as_i64().unwrap(),
            3
        );
    }
}
