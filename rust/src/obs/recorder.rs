//! Bounded in-memory flight recorder with optional JSONL streaming.
//!
//! The recorder owns a preallocated ring of [`Stamped`] events: recording
//! into a non-full ring is a store plus a sequence increment (no heap
//! traffic — `Event` is `Copy` and the ring never grows past the bound
//! chosen at construction). When the ring is full the oldest event is
//! evicted and the exact `dropped` counter advances, so post-mortem
//! readers always know how much history the window lost. Attaching a
//! sink upgrades the recorder to a full streaming trace: every event is
//! also rendered as one JSONL line (into a reusable line buffer) and
//! handed to the writer, which is what `cnmt trace dump` uses to produce
//! logs the offline verifier can replay in their entirety.

use std::io::Write;

use crate::devices::DeviceKind;
use crate::util::ring::RingBuffer;

use super::event::{Event, Stamped};

/// Run-level context written as the first line of a trace dump; the
/// offline verifier needs it to name lanes and replay the margin law.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceMeta {
    /// Device tier per lane, in lane order.
    pub tiers: Vec<DeviceKind>,
    /// Waste budget fraction of the hedge controller, if one ran.
    pub waste_budget: Option<f64>,
    /// The controller's initial (clamped) hedge margin, if one ran.
    pub init_margin_s: Option<f64>,
}

impl TraceMeta {
    /// Render the meta header as one JSONL line.
    pub fn write_jsonl(&self, out: &mut String) {
        use std::fmt::Write as _;
        out.push_str("{\"meta\":{\"tiers\":[");
        for (i, tier) in self.tiers.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "\"{}\"", tier.id());
        }
        out.push_str("],\"waste_budget\":");
        match self.waste_budget {
            Some(b) => {
                let _ = write!(out, "{b}");
            }
            None => out.push_str("null"),
        }
        out.push_str(",\"init_margin_s\":");
        match self.init_margin_s {
            Some(m) => {
                let _ = write!(out, "{m}");
            }
            None => out.push_str("null"),
        }
        out.push_str("}}\n");
    }
}

/// Bounded decision-log recorder (see the module docs).
pub struct FlightRecorder {
    ring: RingBuffer<Stamped>,
    /// External bound — the ring's physical capacity is the next power
    /// of two, so the recorder enforces its own limit.
    cap: usize,
    seq: u64,
    dropped: u64,
    /// Largest stamp recorded so far: stamps are clamped to be
    /// non-decreasing, so a producer that learns of an event late (the
    /// harness accounts a drained completion batch after the dispatcher
    /// already logged later completions) records it at the time it
    /// learned, keeping the stream replayable in order.
    last_t_s: f64,
    meta: TraceMeta,
    sink: Option<Box<dyn Write>>,
    /// Reusable JSONL line buffer so streaming stays alloc-free once
    /// warm.
    line: String,
    sink_err: bool,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("cap", &self.cap)
            .field("len", &self.ring.len())
            .field("seq", &self.seq)
            .field("dropped", &self.dropped)
            .field("sink", &self.sink.is_some())
            .finish()
    }
}

impl FlightRecorder {
    /// Recorder keeping the most recent `capacity` events (minimum 1).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        FlightRecorder {
            ring: RingBuffer::with_capacity(cap),
            cap,
            seq: 0,
            dropped: 0,
            last_t_s: f64::NEG_INFINITY,
            meta: TraceMeta::default(),
            sink: None,
            line: String::with_capacity(256),
            sink_err: false,
        }
    }

    /// Attach a streaming sink: every subsequent event is also written
    /// as a JSONL line. The meta header (if already set) is written
    /// immediately.
    pub fn with_sink(mut self, sink: Box<dyn Write>) -> Self {
        self.sink = Some(sink);
        if !self.meta.tiers.is_empty() {
            let meta = self.meta.clone();
            self.emit_meta_line(&meta);
        }
        self
    }

    /// Set the run-level context (tiers, controller parameters). Written
    /// to the sink, when one is attached, before any events.
    pub fn set_meta(&mut self, meta: TraceMeta) {
        self.emit_meta_line(&meta);
        self.meta = meta;
    }

    fn emit_meta_line(&mut self, meta: &TraceMeta) {
        if self.sink.is_some() {
            self.line.clear();
            meta.write_jsonl(&mut self.line);
            self.flush_line();
        }
    }

    fn flush_line(&mut self) {
        if let Some(sink) = self.sink.as_mut() {
            if sink.write_all(self.line.as_bytes()).is_err() {
                self.sink_err = true;
            }
        }
    }

    /// Record one event at sim time `t_s`. O(1), allocation-free once
    /// the ring and line buffer are warm. Stamps are clamped to be
    /// non-decreasing (see `last_t_s`): a producer reporting an event it
    /// learned of late records it at the later of the event time and the
    /// newest stamp already in the log.
    #[inline]
    pub fn record(&mut self, t_s: f64, ev: Event) {
        let t_s = t_s.max(self.last_t_s);
        self.last_t_s = t_s;
        let st = Stamped { t_s, seq: self.seq, ev };
        self.seq += 1;
        if self.sink.is_some() {
            self.line.clear();
            st.write_jsonl(&mut self.line);
            self.flush_line();
        }
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(st);
    }

    /// Events currently held in the ring window.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// Is the window empty?
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// The window bound this recorder was built with.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Events evicted from the ring because the window was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Total events ever recorded (`len() + dropped()`).
    pub fn total(&self) -> u64 {
        self.seq
    }

    /// Run-level context.
    pub fn meta(&self) -> &TraceMeta {
        &self.meta
    }

    /// Did a sink write fail at any point?
    pub fn sink_ok(&self) -> bool {
        !self.sink_err
    }

    /// Iterate the retained window, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &Stamped> {
        (0..self.ring.len()).filter_map(|i| self.ring.get(i))
    }

    /// Flush the streaming sink, if any.
    pub fn flush(&mut self) {
        if let Some(sink) = self.sink.as_mut() {
            if sink.flush().is_err() {
                self.sink_err = true;
            }
        }
    }

    /// Render the health trailer — total events recorded, ring
    /// evictions, and sink status — as one JSONL line. In a streamed
    /// trace ring evictions do **not** mean lost lines (the sink saw
    /// every event); in a ring-window render they do, and the verifier
    /// refuses the window unless told otherwise.
    fn write_trailer_line(&self, out: &mut String) {
        use std::fmt::Write as _;
        let _ = write!(
            out,
            "{{\"trailer\":{{\"events\":{},\"ring_dropped\":{},\"sink_ok\":{}}}}}\n",
            self.seq,
            self.dropped,
            self.sink_ok()
        );
    }

    /// Close out a streamed trace: write the health trailer line and
    /// flush. Call once, after the last event — `cnmt trace summary`
    /// surfaces the trailer and `cnmt trace verify` fails a trace whose
    /// trailer admits a broken sink.
    pub fn finish(&mut self) {
        if self.sink.is_some() {
            self.line.clear();
            self.write_trailer_line(&mut self.line);
            self.flush_line();
        }
        self.flush();
    }

    /// Render the retained window (meta header first, health trailer
    /// last) as JSONL text. Note this is only the ring window — use a
    /// sink for full traces.
    pub fn window_jsonl(&self) -> String {
        let mut out = String::new();
        self.meta.write_jsonl(&mut out);
        for st in self.events() {
            st.write_jsonl(&mut out);
        }
        self.write_trailer_line(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64) -> Event {
        Event::Shed { id }
    }

    #[test]
    fn ring_overflow_truncates_with_exact_dropped_counter() {
        // Capacity 6 rounds to a physical ring of 8; the recorder must
        // still cap at 6 and count every eviction.
        let mut rec = FlightRecorder::new(6);
        for i in 0..25u64 {
            rec.record(i as f64 * 0.5, ev(i));
            assert!(rec.len() <= 6, "window exceeded bound at event {i}");
        }
        assert_eq!(rec.len(), 6);
        assert_eq!(rec.dropped(), 19);
        assert_eq!(rec.total(), 25);
        assert_eq!(rec.total(), rec.dropped() + rec.len() as u64);
        // The window holds exactly the newest 6 events, oldest first,
        // with contiguous sequence numbers.
        let seqs: Vec<u64> = rec.events().map(|s| s.seq).collect();
        assert_eq!(seqs, (19..25).collect::<Vec<u64>>());
        let ids: Vec<u64> = rec
            .events()
            .map(|s| match s.ev {
                Event::Shed { id } => id,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, (19..25).collect::<Vec<u64>>());
    }

    #[test]
    fn capacity_one_keeps_only_the_latest() {
        let mut rec = FlightRecorder::new(0); // clamps to 1
        assert_eq!(rec.capacity(), 1);
        for i in 0..5u64 {
            rec.record(0.0, ev(i));
        }
        assert_eq!(rec.len(), 1);
        assert_eq!(rec.dropped(), 4);
        assert_eq!(rec.events().next().unwrap().seq, 4);
    }

    #[test]
    fn stamps_are_clamped_monotone() {
        // A late report (t=1.0 after t=5.0) is recorded at 5.0 so the
        // stream stays replayable in order; later times pass through.
        let mut rec = FlightRecorder::new(8);
        rec.record(5.0, ev(0));
        rec.record(1.0, ev(1));
        rec.record(7.0, ev(2));
        let ts: Vec<f64> = rec.events().map(|s| s.t_s).collect();
        assert_eq!(ts, vec![5.0, 5.0, 7.0]);
    }

    #[test]
    fn window_render_ends_with_health_trailer() {
        let mut rec = FlightRecorder::new(2);
        for i in 0..5u64 {
            rec.record(i as f64, ev(i));
        }
        let text = rec.window_jsonl();
        let last = text.lines().last().unwrap();
        assert_eq!(
            last,
            "{\"trailer\":{\"events\":5,\"ring_dropped\":3,\"sink_ok\":true}}"
        );
    }

    #[test]
    fn sink_streams_everything_ring_keeps_window() {
        use std::cell::RefCell;
        use std::rc::Rc;

        // Shared Vec<u8> sink so the test can read back what streamed.
        #[derive(Clone)]
        struct Shared(Rc<RefCell<Vec<u8>>>);
        impl std::io::Write for Shared {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.borrow_mut().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }

        let buf = Shared(Rc::new(RefCell::new(Vec::new())));
        let mut rec = FlightRecorder::new(4).with_sink(Box::new(buf.clone()));
        rec.set_meta(TraceMeta {
            tiers: vec![DeviceKind::Edge, DeviceKind::Cloud],
            waste_budget: Some(0.10),
            init_margin_s: Some(0.010),
        });
        for i in 0..10u64 {
            rec.record(i as f64, ev(i));
        }
        rec.flush();
        assert!(rec.sink_ok());
        assert_eq!(rec.len(), 4, "ring truncated to the window");
        assert_eq!(rec.dropped(), 6);
        let text = String::from_utf8(buf.0.borrow().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        // Meta header + all 10 events streamed despite the 4-slot ring.
        assert_eq!(lines.len(), 11);
        assert!(lines[0].contains("\"meta\""));
        assert!(lines[0].contains("\"tiers\":[\"edge\",\"cloud\"]"));
        for (i, line) in lines[1..].iter().enumerate() {
            let parsed =
                Stamped::from_json(&crate::util::Json::parse(line).unwrap()).unwrap();
            assert_eq!(parsed.seq, i as u64);
        }
    }
}
