//! Root-cause attribution support: per-request *blame* decomposition
//! across retry/failover chains, and alert-vs-ground-truth scoring.
//!
//! PR 6's phase decomposition partitions a single attempt's latency
//! exactly (queue → batch-wait → exec → tx). PR 8 added retry and
//! failover chains, where one admitted request can burn several
//! attempts before completing. [`BlameLedger`] extends the partition
//! across the whole chain: every second between first admission and
//! final delivery lands in exactly one named segment, so a latency
//! regression can be blamed on the queue, the retry policy, a sick
//! device, or the link — not just "the chain was slow".
//!
//! For an admitted request the chain is
//!
//! ```text
//! enq_0 … kill_0   enq_1 … kill_1   …   enq_n … start … done (+ tx)
//! \__________/ \__/                      \___/ \____________/
//!  queue_wasted retry_wait                queue  batch_wait+exec, tx
//! ```
//!
//! * `queue_wasted_s` — time buried in queues on attempts that were
//!   later killed (deadline timeout or lane crash),
//! * `retry_wait_s`  — backoff gaps between a kill and the next
//!   attempt's admission,
//! * `queue_s`       — the final attempt's admission-to-dispatch wait,
//! * `batch_wait_s`  — dispatch-to-completion time beyond the true
//!   compute cost (micro-batch queueing inside the lane),
//! * `exec_s`        — the true compute cost,
//! * `tx_s`          — payload transfer (cloud lanes).
//!
//! [`BlameChain::total_s`] is the **left-fold** of those segments in
//! that order; `obs::verify::verify_blame` recomputes every segment
//! from the raw chain marks and re-folds, demanding bit-equality —
//! the blame partition is an invariant, not a summary statistic.
//!
//! The ledger lives harness-side (it keyes on request ids across
//! attempts, which the dispatcher deliberately does not track) and is
//! observation-only, like everything in `obs`.

use std::collections::HashMap;

use super::detect::AlertRec;
use super::event::AlertKind;

/// In-flight chain marks for one admitted request.
#[derive(Debug, Clone, Default)]
struct ChainMarks {
    /// Admission instant of each attempt, in order.
    enq: Vec<f64>,
    /// Kill instant of each killed attempt (`true` = deadline timeout,
    /// `false` = lane crash / failover kill).
    kill: Vec<(f64, bool)>,
}

/// The finished blame decomposition of one request chain.
#[derive(Debug, Clone, PartialEq)]
pub struct BlameChain {
    /// Request id.
    pub id: u64,
    /// Attempts admitted (killed attempts + the one that completed).
    pub attempts: u32,
    /// Killed attempts that died to a deadline timeout.
    pub timeout_kills: u32,
    /// Killed attempts that died with their lane.
    pub crash_kills: u32,
    /// Raw chain marks, for exact re-verification: admission instants
    /// per attempt and kill instants per killed attempt.
    pub enq_s: Vec<f64>,
    pub kill_s: Vec<f64>,
    /// Final attempt dispatch / completion instants.
    pub start_s: f64,
    pub done_s: f64,
    /// Queue time buried in killed attempts.
    pub queue_wasted_s: f64,
    /// Backoff gaps between kills and re-admissions.
    pub retry_wait_s: f64,
    /// Final attempt's admission-to-dispatch wait.
    pub queue_s: f64,
    /// Final attempt's in-lane wait beyond the true compute cost.
    pub batch_wait_s: f64,
    /// True compute cost of the completing attempt.
    pub exec_s: f64,
    /// Payload transfer time (0 for edge lanes).
    pub tx_s: f64,
    /// Left-fold of the six segments, in documented order.
    pub total_s: f64,
}

/// Fold the six blame segments in their canonical order. `verify_blame`
/// re-runs this exact fold; keep the order in sync with the module docs.
pub fn fold_total(
    queue_wasted_s: f64,
    retry_wait_s: f64,
    queue_s: f64,
    batch_wait_s: f64,
    exec_s: f64,
    tx_s: f64,
) -> f64 {
    queue_wasted_s + retry_wait_s + queue_s + batch_wait_s + exec_s + tx_s
}

/// Harness-side collector that turns submit/kill/complete marks into
/// [`BlameChain`]s.
#[derive(Debug, Clone, Default)]
pub struct BlameLedger {
    open: HashMap<u64, ChainMarks>,
    done: Vec<BlameChain>,
}

impl BlameLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// An attempt of request `id` was admitted at `t_s` (first or
    /// retried).
    pub fn attempt_start(&mut self, id: u64, t_s: f64) {
        self.open.entry(id).or_default().enq.push(t_s);
    }

    /// The latest attempt of `id` was killed at `t_s` (`was_timeout`
    /// false means the lane died under it).
    pub fn attempt_killed(&mut self, id: u64, t_s: f64, was_timeout: bool) {
        self.open.entry(id).or_default().kill.push((t_s, was_timeout));
    }

    /// The surviving attempt completed: `exec_s` is its true compute
    /// cost, `tx_s` the transfer charge (0 off-cloud). Finalizes the
    /// chain.
    pub fn complete(&mut self, id: u64, start_s: f64, done_s: f64, exec_s: f64, tx_s: f64) {
        let marks = self.open.remove(&id).unwrap_or_default();
        debug_assert_eq!(
            marks.enq.len(),
            marks.kill.len() + 1,
            "blame chain {id}: every non-final attempt must have a kill mark"
        );
        let mut queue_wasted_s = 0.0;
        let mut retry_wait_s = 0.0;
        let mut timeout_kills = 0u32;
        let mut crash_kills = 0u32;
        for (i, &(kill, was_timeout)) in marks.kill.iter().enumerate() {
            queue_wasted_s += kill - marks.enq[i];
            retry_wait_s += marks.enq[i + 1] - kill;
            if was_timeout {
                timeout_kills += 1;
            } else {
                crash_kills += 1;
            }
        }
        let last_enq = marks.enq.last().copied().unwrap_or(start_s);
        let queue_s = start_s - last_enq;
        let batch_wait_s = (done_s - start_s) - exec_s;
        let total_s = fold_total(queue_wasted_s, retry_wait_s, queue_s, batch_wait_s, exec_s, tx_s);
        self.done.push(BlameChain {
            id,
            attempts: marks.enq.len() as u32,
            timeout_kills,
            crash_kills,
            enq_s: marks.enq,
            kill_s: marks.kill.iter().map(|&(t, _)| t).collect(),
            start_s,
            done_s,
            queue_wasted_s,
            retry_wait_s,
            queue_s,
            batch_wait_s,
            exec_s,
            tx_s,
            total_s,
        });
    }

    /// Finished chains, in completion order.
    pub fn chains(&self) -> &[BlameChain] {
        &self.done
    }

    /// Chains still open (admitted, not yet completed) — stranded or
    /// in flight when the run ended.
    pub fn open_chains(&self) -> usize {
        self.open.len()
    }

    /// Consume the ledger, yielding the finished chains.
    pub fn into_chains(self) -> Vec<BlameChain> {
        self.done
    }
}

/// How one scenario's alert stream compares to its injected ground
/// truth (the experiment scorer; also reused by tests).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlertScore {
    /// A raise of the expected kind was observed at/after fault onset.
    pub detected: bool,
    /// Onset-to-first-matching-raise latency (`NaN` when undetected).
    pub detection_latency_s: f64,
    /// The first matching raise named the faulted lane.
    pub correct_lane: bool,
    /// Raises that do not match the expected kind+window (all raises,
    /// for a fault-free run).
    pub false_alerts: u32,
}

/// Score an alert stream against an injected fault: `expect` is the
/// fault's kind + lane, `onset_s` its start. `expect = None` means a
/// fault-free run, where *every* raise is false.
pub fn score_alerts(alerts: &[AlertRec], expect: Option<(AlertKind, u32)>, onset_s: f64) -> AlertScore {
    let mut score = AlertScore {
        detected: false,
        detection_latency_s: f64::NAN,
        correct_lane: false,
        false_alerts: 0,
    };
    for a in alerts.iter().filter(|a| a.raised) {
        match expect {
            Some((kind, lane)) if a.kind == kind && a.t_s >= onset_s => {
                if !score.detected {
                    score.detected = true;
                    score.detection_latency_s = a.t_s - onset_s;
                    score.correct_lane = a.lane == lane;
                }
            }
            _ => score.false_alerts += 1,
        }
    }
    score
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_attempt_chain_partitions_exactly() {
        let mut led = BlameLedger::new();
        led.attempt_start(7, 1.0);
        led.complete(7, 1.25, 1.40, 0.10, 0.02);
        let c = &led.chains()[0];
        assert_eq!(c.attempts, 1);
        assert_eq!(c.queue_wasted_s, 0.0);
        assert_eq!(c.retry_wait_s, 0.0);
        assert_eq!(c.queue_s, 1.25 - 1.0);
        assert_eq!(c.exec_s, 0.10);
        assert_eq!(c.batch_wait_s, (1.40 - 1.25) - 0.10);
        assert_eq!(
            c.total_s,
            fold_total(0.0, 0.0, c.queue_s, c.batch_wait_s, c.exec_s, c.tx_s)
        );
    }

    #[test]
    fn retried_chain_accumulates_waste_and_backoff() {
        let mut led = BlameLedger::new();
        led.attempt_start(3, 10.0);
        led.attempt_killed(3, 10.5, true); // timeout at 10.5
        led.attempt_start(3, 10.6); // backoff 0.1
        led.attempt_killed(3, 11.0, false); // lane died at 11.0
        led.attempt_start(3, 11.2); // backoff 0.2
        led.complete(3, 11.5, 11.8, 0.25, 0.0);
        let c = &led.chains()[0];
        assert_eq!(c.attempts, 3);
        assert_eq!(c.timeout_kills, 1);
        assert_eq!(c.crash_kills, 1);
        assert_eq!(c.queue_wasted_s, (10.5 - 10.0) + (11.0 - 10.6));
        assert_eq!(c.retry_wait_s, (10.6 - 10.5) + (11.2 - 11.0));
        assert_eq!(c.queue_s, 11.5 - 11.2);
        assert_eq!(
            c.total_s,
            fold_total(
                c.queue_wasted_s,
                c.retry_wait_s,
                c.queue_s,
                c.batch_wait_s,
                c.exec_s,
                c.tx_s
            )
        );
        assert_eq!(led.open_chains(), 0);
    }

    #[test]
    fn scoring_matches_kind_lane_and_window() {
        let alerts = [
            AlertRec { t_s: 9.0, lane: 2, kind: AlertKind::LoadSurge, score: 2.0, raised: true },
            AlertRec { t_s: 12.0, lane: 0, kind: AlertKind::DeviceCrash, score: 1.0, raised: true },
            AlertRec { t_s: 40.0, lane: 0, kind: AlertKind::DeviceCrash, score: 0.0, raised: false },
        ];
        let s = score_alerts(&alerts, Some((AlertKind::DeviceCrash, 0)), 11.5);
        assert!(s.detected);
        assert_eq!(s.detection_latency_s, 0.5);
        assert!(s.correct_lane);
        assert_eq!(s.false_alerts, 1, "the surge raise is off-spec");
        // Fault-free: every raise is false, clears are ignored.
        let s = score_alerts(&alerts, None, 0.0);
        assert!(!s.detected);
        assert_eq!(s.false_alerts, 2);
    }
}
