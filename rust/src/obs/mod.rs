//! Observability: flight recorder, offline trace verification, latency
//! decomposition, and control-loop telemetry.
//!
//! The simulation stack proves aggregate outcomes (p99, goodput, waste),
//! but the paper's claim is about *decisions* — whether eq. 1/2
//! estimates place each request well. This module records the decisions
//! themselves and makes them auditable:
//!
//! * [`event`] / [`recorder`] — the structured decision log. Every
//!   placement scoring, admission, shed, batch, dispatch, completion,
//!   hedge cancellation, refit install, margin adjustment, and drift
//!   charge becomes one `Copy` [`Event`] in a preallocated bounded ring
//!   ([`FlightRecorder`]), preserving the dispatcher's zero-allocation
//!   steady state. An optional streaming sink upgrades the ring window
//!   to a complete JSONL trace (`cnmt trace dump`).
//! * [`verify`] — the offline checker behind `cnmt trace verify`:
//!   replays a dumped log and re-proves conservation, hedge-fate
//!   partitioning, the margin control law (bit-exact), and waste-budget
//!   compliance with no access to harness internals — the stepping
//!   stone to a live ≡ sim replay differential.
//! * [`telemetry`] — report-facing, off-by-default instrumentation:
//!   per-request latency decomposition ([`Phases`]) and fixed-cadence
//!   control-loop gauge series ([`Telemetry`]), both mirrored
//!   float-exactly by `python/tools/telemetry_mirror.py` and checked in
//!   as `reports/telemetry_drift.json`.
//! * [`detect`] / [`attribute`] — the analysis half: online CUSUM/EWMA
//!   change-point detectors over per-device prediction residuals and
//!   gauge streams ([`Detector`]), emitting typed
//!   [`Event::AlertRaised`]/[`Event::AlertCleared`] transitions into
//!   the flight recorder, plus root-cause scoring and the per-request
//!   blame decomposition across retry/failover chains
//!   ([`BlameLedger`]), scored against injected ground truth by
//!   `cnmt experiment detect`.

pub mod attribute;
pub mod detect;
pub mod event;
pub mod recorder;
pub mod telemetry;
pub mod verify;

pub use attribute::{score_alerts, AlertScore, BlameChain, BlameLedger};
pub use detect::{AlertRec, DetectCfg, Detector};
pub use event::{AlertKind, Event, Stamped};
pub use recorder::{FlightRecorder, TraceMeta};
pub use telemetry::{ClassPhases, DeviceSeries, Phases, Telemetry, TelemetryCfg};
pub use verify::{
    parse_trace, parse_trace_full, summarize_trace, verify_blame, verify_events,
    verify_trace, verify_trace_allow_truncated, TraceTrailer, VerifyReport,
};
