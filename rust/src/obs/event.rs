//! The decision-log event taxonomy.
//!
//! One [`Event`] is recorded for every decision the scheduling stack
//! takes about a request — placement scoring, admission/shed, batch
//! formation, dispatch, completion classification, hedge cancellation —
//! plus the control-loop actions that change future decisions (refit
//! installs, hedge-margin adjustments, drift charging). Events are
//! plain `Copy` data (no strings, no heap) so recording them preserves
//! the dispatcher's zero-allocation steady state; the sim-time stamp
//! and a monotonically increasing sequence number are added by the
//! recorder ([`super::FlightRecorder`]) as a [`Stamped`] envelope.
//!
//! The JSONL wire form (one event per line, `{"t":…,"seq":…,"ev":…}`)
//! is written by [`Stamped::write_jsonl`] and parsed back by
//! [`Stamped::from_json`]; the offline checker ([`super::verify`])
//! re-derives the harness's conservation and hedge-fate invariants from
//! these lines alone.

use std::fmt::Write as _;

use crate::scheduler::CompletionKind;
use crate::util::Json;
use crate::{Error, Result};

/// One decision-log event (see the module docs for the taxonomy).
///
/// Lanes are dispatcher lane indices (pair runs: 0 = edge, 1 = cloud;
/// fleet runs: the topology's device order — the trace meta line names
/// each lane's tier).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event {
    /// A request copy entered a lane's admission queue. Hedged requests
    /// admit two copies (two `Admit` events with `hedged: true`).
    Admit {
        /// Request id.
        id: u64,
        /// Admitting lane.
        lane: u32,
        /// Part of a two-lane hedge race?
        hedged: bool,
    },
    /// The request was rejected by admission control (no queue room).
    Shed {
        /// Request id.
        id: u64,
    },
    /// The router scored the placements (eq. 1): the best edge and best
    /// cloud candidate with their scores, the chosen lane, and the
    /// edge−cloud margin the hedge test inspects.
    Placement {
        /// Request id.
        id: u64,
        /// Best edge lane.
        edge_lane: u32,
        /// Best edge score (seconds; eq. 1 with wait term).
        edge_score_s: f64,
        /// Best cloud lane.
        cloud_lane: u32,
        /// Best cloud score (seconds; T̂_tx + eq. 1 with wait term).
        cloud_score_s: f64,
        /// The lane the placement chose.
        chosen: u32,
        /// `edge_score − cloud_score` (the hedge test's margin).
        margin_s: f64,
    },
    /// The batcher closed a batch at the head of a lane's queue.
    BatchFormed {
        /// Dispatching lane.
        lane: u32,
        /// Requests in the batch.
        size: u32,
        /// Batch start time (seconds).
        start_s: f64,
    },
    /// The batch was handed to a worker; `done_s` is the completion
    /// time the executor charged.
    DispatchStart {
        /// Dispatching lane.
        lane: u32,
        /// Requests in the batch.
        size: u32,
        /// Charged completion time (seconds).
        done_s: f64,
    },
    /// A request copy finished executing and was classified.
    Complete {
        /// Request id.
        id: u64,
        /// Completing lane.
        lane: u32,
        /// Solo result, hedge winner, or hedge loser (wasted work).
        kind: CompletionKind,
    },
    /// A hedge race's queued twin was cancelled before running.
    HedgeCancel {
        /// Request id.
        id: u64,
        /// Lane whose queued copy died.
        lane: u32,
    },
    /// A warmed RLS model was installed over a lane's prior (first
    /// installation only — coefficients keep updating afterwards).
    RefitInstall {
        /// Lane whose model warmed up.
        lane: u32,
        /// `false`: the T_exe plane; `true`: the T_tx line.
        ttx: bool,
    },
    /// The waste-budget controller adjusted the hedge margin. Carries
    /// the controller's decayed work window so the control law is
    /// replayable offline.
    MarginAdjust {
        /// New margin (seconds).
        margin_s: f64,
        /// Decayed useful-work window (seconds).
        useful_s: f64,
        /// Decayed wasted-work window (seconds).
        wasted_s: f64,
    },
    /// A completion on a drifting lane was charged at this slowdown
    /// factor.
    DriftTick {
        /// Drifting lane.
        lane: u32,
        /// Current slowdown factor (1.0 before onset).
        factor: f64,
    },
    /// A device crashed ([`crate::scheduler::Dispatcher::fail_lane`]):
    /// its queue and in-flight batches are lost and admissions refuse
    /// until the matching [`Event::DeviceUp`].
    DeviceDown {
        /// The crashed lane.
        lane: u32,
    },
    /// A crashed device recovered
    /// ([`crate::scheduler::Dispatcher::recover_lane`]): empty queue,
    /// idle workers, admissions accepted again.
    DeviceUp {
        /// The recovered lane.
        lane: u32,
    },
    /// A queue-wait deadline timer fired: the request was still queued
    /// at its deadline and was pulled out for requeueing
    /// ([`crate::scheduler::Dispatcher::fire_timeouts`]).
    TimeoutFired {
        /// Request id.
        id: u64,
        /// Lane the request was stuck on.
        lane: u32,
    },
    /// A timed-out or failed-over request was re-admitted after its
    /// backoff (`attempt` = 1-based retry count of its chain).
    RetryDispatched {
        /// Request id.
        id: u64,
        /// Lane the retry was placed on.
        lane: u32,
        /// 1-based attempt number within the retry budget.
        attempt: u32,
    },
    /// A dead lane's request (queued or in-flight at the crash) was
    /// handed back to the selector for re-routing.
    FailoverReroute {
        /// Request id.
        id: u64,
        /// The lane that died with the request on it.
        from_lane: u32,
    },
    /// The online anomaly detector ([`crate::obs::detect::Detector`])
    /// crossed a decision threshold: a change-point in a lane's
    /// prediction-residual or gauge streams, classified by the root-cause
    /// attributor.
    AlertRaised {
        /// Lane the alert attributes the anomaly to (for
        /// [`AlertKind::LoadSurge`]: the lowest breaching lane of a
        /// fleet-wide surge).
        lane: u32,
        /// Root-cause classification.
        kind: AlertKind,
        /// Detector statistic at the crossing (CUSUM score in σ units;
        /// crash evidence counts kills).
        score: f64,
    },
    /// A previously raised alert's evidence returned in-control and the
    /// detector retired it.
    AlertCleared {
        /// The alerted lane.
        lane: u32,
        /// The retired alert's classification.
        kind: AlertKind,
    },
    /// A scenario run tagged an admitted request with its SLO service
    /// class ([`crate::sim::scenario`]): `class` indexes the scenario
    /// spec's class list. Recorded once per request, right after its
    /// admission events, so per-class conservation and attainment are
    /// re-derivable from the log alone.
    ClassTag {
        /// Request id.
        id: u64,
        /// Service-class index into the scenario spec's class list.
        class: u32,
    },
}

/// Root-cause classification attached to [`Event::AlertRaised`] /
/// [`Event::AlertCleared`] (see [`crate::obs::attribute`] for the
/// decision rules).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlertKind {
    /// The lane's execution residuals shifted up (its T̂_exe plane is
    /// now optimistic): a throttled / degraded device.
    DeviceSlowdown,
    /// A cloud lane's per-token transfer residuals shifted up while its
    /// execution residuals stayed in control: the link degraded, not
    /// the device.
    LinkDegradation,
    /// The lane destroyed queued/in-flight copies (failover reroutes):
    /// a crash, not a slowdown.
    DeviceCrash,
    /// Queue-depth / expected-wait gauges breached on several lanes at
    /// once with every residual chart in control: the offered load
    /// surged, no device is to blame.
    LoadSurge,
}

impl AlertKind {
    /// The wire tag this kind serialises under.
    pub fn tag(self) -> &'static str {
        match self {
            AlertKind::DeviceSlowdown => "device_slowdown",
            AlertKind::LinkDegradation => "link_degradation",
            AlertKind::DeviceCrash => "device_crash",
            AlertKind::LoadSurge => "load_surge",
        }
    }

    /// Parse a wire tag back (fail-closed on unknown kinds).
    pub fn from_tag(tag: &str) -> Result<AlertKind> {
        match tag {
            "device_slowdown" => Ok(AlertKind::DeviceSlowdown),
            "link_degradation" => Ok(AlertKind::LinkDegradation),
            "device_crash" => Ok(AlertKind::DeviceCrash),
            "load_surge" => Ok(AlertKind::LoadSurge),
            other => Err(Error::Config(format!("unknown alert kind `{other}`"))),
        }
    }
}

/// An [`Event`] stamped with its simulation time and sequence number.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stamped {
    /// Simulation time the event was recorded at (seconds).
    pub t_s: f64,
    /// Monotonically increasing per-recorder sequence number.
    pub seq: u64,
    /// The event itself.
    pub ev: Event,
}

/// `write!` an f64 as JSON: integral values without the trailing `.0`
/// (like `util::json::write_num`), non-finite values as `null`.
fn write_f64(out: &mut String, x: f64) {
    if !x.is_finite() {
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

/// Parse a JSON number field that may be `null` (→ NaN).
fn read_f64(v: &Json, key: &str) -> Result<f64> {
    match v.get(key)? {
        Json::Null => Ok(f64::NAN),
        other => other.as_f64(),
    }
}

fn read_u64(v: &Json, key: &str) -> Result<u64> {
    Ok(v.get(key)?.as_i64()? as u64)
}

fn read_u32(v: &Json, key: &str) -> Result<u32> {
    Ok(v.get(key)?.as_i64()? as u32)
}

impl CompletionKind {
    fn tag(self) -> &'static str {
        match self {
            CompletionKind::Solo => "solo",
            CompletionKind::HedgeWin => "hedge_win",
            CompletionKind::HedgeLoss => "hedge_loss",
        }
    }

    fn from_tag(tag: &str) -> Result<CompletionKind> {
        match tag {
            "solo" => Ok(CompletionKind::Solo),
            "hedge_win" => Ok(CompletionKind::HedgeWin),
            "hedge_loss" => Ok(CompletionKind::HedgeLoss),
            other => Err(Error::Config(format!("unknown completion kind `{other}`"))),
        }
    }
}

impl Event {
    /// The `"ev"` tag this event serialises under.
    pub fn tag(&self) -> &'static str {
        match self {
            Event::Admit { .. } => "admit",
            Event::Shed { .. } => "shed",
            Event::Placement { .. } => "placement",
            Event::BatchFormed { .. } => "batch_formed",
            Event::DispatchStart { .. } => "dispatch_start",
            Event::Complete { .. } => "complete",
            Event::HedgeCancel { .. } => "hedge_cancel",
            Event::RefitInstall { .. } => "refit_install",
            Event::MarginAdjust { .. } => "margin_adjust",
            Event::DriftTick { .. } => "drift_tick",
            Event::DeviceDown { .. } => "device_down",
            Event::DeviceUp { .. } => "device_up",
            Event::TimeoutFired { .. } => "timeout_fired",
            Event::RetryDispatched { .. } => "retry_dispatched",
            Event::FailoverReroute { .. } => "failover_reroute",
            Event::AlertRaised { .. } => "alert_raised",
            Event::AlertCleared { .. } => "alert_cleared",
            Event::ClassTag { .. } => "class_tag",
        }
    }
}

/// Fail-closed field check for the alert events: exactly the expected
/// keys, nothing extra, nothing missing. (The legacy taxonomy predates
/// this check; new event families must not inherit its leniency.)
fn check_keys(v: &Json, tag: &str, want: &[&str]) -> Result<()> {
    let obj = v.as_object()?;
    for key in obj.keys() {
        if !want.contains(&key.as_str()) {
            return Err(Error::Config(format!(
                "event `{tag}`: unknown field `{key}`"
            )));
        }
    }
    for want in want {
        if !obj.contains_key(*want) {
            return Err(Error::Config(format!(
                "event `{tag}`: missing field `{want}`"
            )));
        }
    }
    Ok(())
}

impl Stamped {
    /// Append this event as one JSONL line (including the trailing
    /// newline) to `out`. Allocation-free once `out`'s capacity covers
    /// the longest line.
    pub fn write_jsonl(&self, out: &mut String) {
        out.push_str("{\"t\":");
        write_f64(out, self.t_s);
        let _ = write!(out, ",\"seq\":{},\"ev\":\"{}\"", self.seq, self.ev.tag());
        match self.ev {
            Event::Admit { id, lane, hedged } => {
                let _ = write!(out, ",\"id\":{id},\"lane\":{lane},\"hedged\":{hedged}");
            }
            Event::Shed { id } => {
                let _ = write!(out, ",\"id\":{id}");
            }
            Event::Placement {
                id,
                edge_lane,
                edge_score_s,
                cloud_lane,
                cloud_score_s,
                chosen,
                margin_s,
            } => {
                let _ = write!(out, ",\"id\":{id},\"edge_lane\":{edge_lane},\"edge_score_s\":");
                write_f64(out, edge_score_s);
                let _ = write!(out, ",\"cloud_lane\":{cloud_lane},\"cloud_score_s\":");
                write_f64(out, cloud_score_s);
                let _ = write!(out, ",\"chosen\":{chosen},\"margin_s\":");
                write_f64(out, margin_s);
            }
            Event::BatchFormed { lane, size, start_s } => {
                let _ = write!(out, ",\"lane\":{lane},\"size\":{size},\"start_s\":");
                write_f64(out, start_s);
            }
            Event::DispatchStart { lane, size, done_s } => {
                let _ = write!(out, ",\"lane\":{lane},\"size\":{size},\"done_s\":");
                write_f64(out, done_s);
            }
            Event::Complete { id, lane, kind } => {
                let _ = write!(out, ",\"id\":{id},\"lane\":{lane},\"kind\":\"{}\"", kind.tag());
            }
            Event::HedgeCancel { id, lane } => {
                let _ = write!(out, ",\"id\":{id},\"lane\":{lane}");
            }
            Event::RefitInstall { lane, ttx } => {
                let _ = write!(out, ",\"lane\":{lane},\"ttx\":{ttx}");
            }
            Event::MarginAdjust { margin_s, useful_s, wasted_s } => {
                out.push_str(",\"margin_s\":");
                write_f64(out, margin_s);
                out.push_str(",\"useful_s\":");
                write_f64(out, useful_s);
                out.push_str(",\"wasted_s\":");
                write_f64(out, wasted_s);
            }
            Event::DriftTick { lane, factor } => {
                let _ = write!(out, ",\"lane\":{lane},\"factor\":");
                write_f64(out, factor);
            }
            Event::DeviceDown { lane } | Event::DeviceUp { lane } => {
                let _ = write!(out, ",\"lane\":{lane}");
            }
            Event::TimeoutFired { id, lane } => {
                let _ = write!(out, ",\"id\":{id},\"lane\":{lane}");
            }
            Event::RetryDispatched { id, lane, attempt } => {
                let _ = write!(out, ",\"id\":{id},\"lane\":{lane},\"attempt\":{attempt}");
            }
            Event::FailoverReroute { id, from_lane } => {
                let _ = write!(out, ",\"id\":{id},\"from_lane\":{from_lane}");
            }
            Event::AlertRaised { lane, kind, score } => {
                let _ = write!(out, ",\"lane\":{lane},\"kind\":\"{}\",\"score\":", kind.tag());
                write_f64(out, score);
            }
            Event::AlertCleared { lane, kind } => {
                let _ = write!(out, ",\"lane\":{lane},\"kind\":\"{}\"", kind.tag());
            }
            Event::ClassTag { id, class } => {
                let _ = write!(out, ",\"id\":{id},\"class\":{class}");
            }
        }
        out.push_str("}\n");
    }

    /// Parse one JSONL line's parsed JSON back into a stamped event.
    pub fn from_json(v: &Json) -> Result<Stamped> {
        let t_s = read_f64(v, "t")?;
        let seq = read_u64(v, "seq")?;
        let ev = match v.get("ev")?.as_str()? {
            "admit" => Event::Admit {
                id: read_u64(v, "id")?,
                lane: read_u32(v, "lane")?,
                hedged: v.get("hedged")?.as_bool()?,
            },
            "shed" => Event::Shed { id: read_u64(v, "id")? },
            "placement" => Event::Placement {
                id: read_u64(v, "id")?,
                edge_lane: read_u32(v, "edge_lane")?,
                edge_score_s: read_f64(v, "edge_score_s")?,
                cloud_lane: read_u32(v, "cloud_lane")?,
                cloud_score_s: read_f64(v, "cloud_score_s")?,
                chosen: read_u32(v, "chosen")?,
                margin_s: read_f64(v, "margin_s")?,
            },
            "batch_formed" => Event::BatchFormed {
                lane: read_u32(v, "lane")?,
                size: read_u32(v, "size")?,
                start_s: read_f64(v, "start_s")?,
            },
            "dispatch_start" => Event::DispatchStart {
                lane: read_u32(v, "lane")?,
                size: read_u32(v, "size")?,
                done_s: read_f64(v, "done_s")?,
            },
            "complete" => Event::Complete {
                id: read_u64(v, "id")?,
                lane: read_u32(v, "lane")?,
                kind: CompletionKind::from_tag(v.get("kind")?.as_str()?)?,
            },
            "hedge_cancel" => Event::HedgeCancel {
                id: read_u64(v, "id")?,
                lane: read_u32(v, "lane")?,
            },
            "refit_install" => Event::RefitInstall {
                lane: read_u32(v, "lane")?,
                ttx: v.get("ttx")?.as_bool()?,
            },
            "margin_adjust" => Event::MarginAdjust {
                margin_s: read_f64(v, "margin_s")?,
                useful_s: read_f64(v, "useful_s")?,
                wasted_s: read_f64(v, "wasted_s")?,
            },
            "drift_tick" => Event::DriftTick {
                lane: read_u32(v, "lane")?,
                factor: read_f64(v, "factor")?,
            },
            "device_down" => Event::DeviceDown { lane: read_u32(v, "lane")? },
            "device_up" => Event::DeviceUp { lane: read_u32(v, "lane")? },
            "timeout_fired" => Event::TimeoutFired {
                id: read_u64(v, "id")?,
                lane: read_u32(v, "lane")?,
            },
            "retry_dispatched" => Event::RetryDispatched {
                id: read_u64(v, "id")?,
                lane: read_u32(v, "lane")?,
                attempt: read_u32(v, "attempt")?,
            },
            "failover_reroute" => Event::FailoverReroute {
                id: read_u64(v, "id")?,
                from_lane: read_u32(v, "from_lane")?,
            },
            "alert_raised" => {
                check_keys(v, "alert_raised", &["t", "seq", "ev", "lane", "kind", "score"])?;
                Event::AlertRaised {
                    lane: read_u32(v, "lane")?,
                    kind: AlertKind::from_tag(v.get("kind")?.as_str()?)?,
                    score: read_f64(v, "score")?,
                }
            }
            "alert_cleared" => {
                check_keys(v, "alert_cleared", &["t", "seq", "ev", "lane", "kind"])?;
                Event::AlertCleared {
                    lane: read_u32(v, "lane")?,
                    kind: AlertKind::from_tag(v.get("kind")?.as_str()?)?,
                }
            }
            "class_tag" => {
                check_keys(v, "class_tag", &["t", "seq", "ev", "id", "class"])?;
                Event::ClassTag {
                    id: read_u64(v, "id")?,
                    class: read_u32(v, "class")?,
                }
            }
            other => return Err(Error::Config(format!("unknown event tag `{other}`"))),
        };
        Ok(Stamped { t_s, seq, ev })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(ev: Event) {
        let st = Stamped { t_s: 1.25, seq: 42, ev };
        let mut line = String::new();
        st.write_jsonl(&mut line);
        assert!(line.ends_with('\n'));
        let parsed = Stamped::from_json(&Json::parse(line.trim_end()).unwrap()).unwrap();
        assert_eq!(parsed, st);
    }

    #[test]
    fn jsonl_roundtrips_every_variant() {
        roundtrip(Event::Admit { id: 7, lane: 1, hedged: true });
        roundtrip(Event::Shed { id: 8 });
        roundtrip(Event::Placement {
            id: 9,
            edge_lane: 0,
            edge_score_s: 0.0123,
            cloud_lane: 5,
            cloud_score_s: 0.0456,
            chosen: 0,
            margin_s: -0.0333,
        });
        roundtrip(Event::BatchFormed { lane: 0, size: 3, start_s: 2.5 });
        roundtrip(Event::DispatchStart { lane: 0, size: 3, done_s: 2.75 });
        roundtrip(Event::Complete { id: 9, lane: 0, kind: CompletionKind::HedgeWin });
        roundtrip(Event::HedgeCancel { id: 9, lane: 5 });
        roundtrip(Event::RefitInstall { lane: 4, ttx: true });
        roundtrip(Event::MarginAdjust {
            margin_s: 0.0101,
            useful_s: 12.5,
            wasted_s: 1.25,
        });
        roundtrip(Event::DriftTick { lane: 0, factor: 2.5 });
        roundtrip(Event::DeviceDown { lane: 2 });
        roundtrip(Event::DeviceUp { lane: 2 });
        roundtrip(Event::TimeoutFired { id: 11, lane: 3 });
        roundtrip(Event::RetryDispatched { id: 11, lane: 4, attempt: 2 });
        roundtrip(Event::FailoverReroute { id: 12, from_lane: 2 });
        for kind in [
            AlertKind::DeviceSlowdown,
            AlertKind::LinkDegradation,
            AlertKind::DeviceCrash,
            AlertKind::LoadSurge,
        ] {
            roundtrip(Event::AlertRaised { lane: 3, kind, score: 13.25 });
            roundtrip(Event::AlertCleared { lane: 3, kind });
        }
        roundtrip(Event::ClassTag { id: 13, class: 2 });
    }

    #[test]
    fn class_tag_fails_closed_on_malformed_lines() {
        let malformed = [
            // unknown extra field
            "{\"t\":1,\"seq\":0,\"ev\":\"class_tag\",\"id\":0,\"class\":1,\"lane\":2}",
            // missing field
            "{\"t\":1,\"seq\":0,\"ev\":\"class_tag\",\"id\":0}",
            "{\"t\":1,\"seq\":0,\"ev\":\"class_tag\",\"class\":1}",
        ];
        for line in malformed {
            let v = Json::parse(line).unwrap();
            assert!(Stamped::from_json(&v).is_err(), "accepted: {line}");
        }
    }

    #[test]
    fn alert_events_fail_closed_on_malformed_lines() {
        // Unknown fields, missing fields, and unknown kinds are all
        // rejected — the new event family must not silently tolerate a
        // writer drifting away from the parser.
        let malformed = [
            // unknown extra field
            "{\"t\":1,\"seq\":0,\"ev\":\"alert_raised\",\"lane\":0,\
             \"kind\":\"device_crash\",\"score\":1,\"bogus\":2}",
            "{\"t\":1,\"seq\":0,\"ev\":\"alert_cleared\",\"lane\":0,\
             \"kind\":\"device_crash\",\"score\":1}",
            // missing field
            "{\"t\":1,\"seq\":0,\"ev\":\"alert_raised\",\"lane\":0,\
             \"kind\":\"device_crash\"}",
            "{\"t\":1,\"seq\":0,\"ev\":\"alert_raised\",\"kind\":\
             \"device_crash\",\"score\":1}",
            "{\"t\":1,\"seq\":0,\"ev\":\"alert_cleared\",\"lane\":0}",
            // unknown kind
            "{\"t\":1,\"seq\":0,\"ev\":\"alert_raised\",\"lane\":0,\
             \"kind\":\"gremlins\",\"score\":1}",
            "{\"t\":1,\"seq\":0,\"ev\":\"alert_cleared\",\"lane\":0,\
             \"kind\":\"\"}",
        ];
        for line in malformed {
            let v = Json::parse(line).unwrap();
            assert!(Stamped::from_json(&v).is_err(), "accepted: {line}");
        }
    }

    #[test]
    fn alert_kind_tags_roundtrip() {
        for kind in [
            AlertKind::DeviceSlowdown,
            AlertKind::LinkDegradation,
            AlertKind::DeviceCrash,
            AlertKind::LoadSurge,
        ] {
            assert_eq!(AlertKind::from_tag(kind.tag()).unwrap(), kind);
        }
        assert!(AlertKind::from_tag("device crash").is_err());
    }

    #[test]
    fn float_roundtrip_is_bit_exact() {
        // Display prints the shortest roundtripping decimal; parsing it
        // back must reproduce the exact bits (the verify margin-law
        // replay depends on this).
        let gnarly = 0.1 + 0.2 + 1e-17;
        let st = Stamped {
            t_s: gnarly,
            seq: 0,
            ev: Event::MarginAdjust {
                margin_s: gnarly * 0.05,
                useful_s: gnarly * 3.0,
                wasted_s: gnarly / 7.0,
            },
        };
        let mut line = String::new();
        st.write_jsonl(&mut line);
        let parsed = Stamped::from_json(&Json::parse(line.trim_end()).unwrap()).unwrap();
        match (parsed.ev, st.ev) {
            (
                Event::MarginAdjust { margin_s: a, useful_s: b, wasted_s: c },
                Event::MarginAdjust { margin_s: x, useful_s: y, wasted_s: z },
            ) => {
                assert_eq!(a.to_bits(), x.to_bits());
                assert_eq!(b.to_bits(), y.to_bits());
                assert_eq!(c.to_bits(), z.to_bits());
            }
            _ => unreachable!(),
        }
        assert_eq!(parsed.t_s.to_bits(), st.t_s.to_bits());
    }

    #[test]
    fn non_finite_scores_serialise_as_null_and_parse_as_nan() {
        let st = Stamped {
            t_s: 0.0,
            seq: 1,
            ev: Event::Placement {
                id: 1,
                edge_lane: 0,
                edge_score_s: f64::NAN,
                cloud_lane: 1,
                cloud_score_s: f64::INFINITY,
                chosen: 1,
                margin_s: f64::NAN,
            },
        };
        let mut line = String::new();
        st.write_jsonl(&mut line);
        assert!(line.contains("\"edge_score_s\":null"));
        let parsed = Stamped::from_json(&Json::parse(line.trim_end()).unwrap()).unwrap();
        match parsed.ev {
            Event::Placement { edge_score_s, cloud_score_s, .. } => {
                assert!(edge_score_s.is_nan());
                assert!(cloud_score_s.is_nan());
            }
            _ => unreachable!(),
        }
    }
}
