//! Online anomaly detection over the scheduler's own observables.
//!
//! The routing story rests on predicted latencies (the T̂_exe planes
//! and the payload→T̂_tx line), so the most valuable live signal is how
//! wrong those predictions are, per device, right now. [`Detector`]
//! watches exactly what the scheduler can see — no fault-spec ground
//! truth — and turns sustained shifts into typed
//! [`Event::AlertRaised`] / [`Event::AlertCleared`] records:
//!
//! * **Execution residuals** (every completion, tapped by
//!   [`crate::scheduler::Dispatcher`]): `x = ln(observed batch service
//!   / installed per-request estimate)`. Each lane runs a one-sided
//!   CUSUM control chart over the standardized residual: the first
//!   [`DetectCfg::warmup`] observations freeze a Welford baseline
//!   `(μ, σ)`, then `s ← max(0, s + z − k)` with `z = (x − μ)/σ`
//!   raises [`AlertKind::DeviceSlowdown`] at `s > h`.
//! * **Transfer residuals** (cloud completions, tapped by the harness
//!   accounting): `x = ln(tx_s / tokens)` — the per-token transfer
//!   time. Same chart, raising [`AlertKind::LinkDegradation`]: a link
//!   fault moves this stream while the execution stream stays in
//!   control.
//! * **Kill evidence**: a failover reroute means the lane destroyed
//!   admitted copies — definitive [`AlertKind::DeviceCrash`] evidence,
//!   raised on the first kill and cleared by the lane's first
//!   completion after recovery. Deadline timeouts are tallied as
//!   corroborating evidence but never raise on their own.
//! * **Gauge streams** (telemetry-cadence samples): per-lane EWMA
//!   control charts over queue depth and expected wait. A simultaneous
//!   breach on [`DetectCfg::surge_lanes`]+ lanes with every residual
//!   chart in control is [`AlertKind::LoadSurge`] — the fleet is
//!   drowning, no single device is to blame.
//!
//! **Collateral absorption** (the root-cause half, with
//! [`super::attribute`]): while a device-level alert is active, the
//! other lanes' residual charts hold and surge raises are suppressed —
//! the load they absorb from the sick lane is attributed to the root
//! cause, not re-alerted as a second anomaly. After a device alert
//! clears, surges stay blocked until the gauges produce one fully calm
//! sample (queues draining back down are aftermath, not a surge).
//!
//! The detector is **observation-only** (it never influences routing;
//! every checked-in report is byte-identical with it detached) and
//! allocation-free while quiescent: charts are preallocated per lane
//! and the pending-event/alert buffers only grow when an alert
//! actually fires. It is mirrored float-exactly by
//! `python/tools/detect_mirror.py`.

use crate::devices::DeviceKind;

pub use super::event::AlertKind;
use super::event::Event;

/// Detector tuning. The defaults are deliberately conservative: the
/// quiescence property (zero alerts on stationary fault-free workloads,
/// enforced by tests and the fault-free twin of
/// `reports/detect_eval.json`) outranks detection latency, and the
/// injected faults are order-of-magnitude shifts that still detect in
/// well under a second of simulated time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DetectCfg {
    /// Residual observations per chart before the baseline freezes.
    pub warmup: u32,
    /// CUSUM slack `k` (σ units): drift below this never accumulates.
    /// Sized above the residual drift a pure load surge induces through
    /// larger micro-batches (z ≲ 3 at the evaluated operating points),
    /// so congestion reads as a surge — not as a per-device fault —
    /// while the injected order-of-magnitude faults (z ≈ 4–8) still
    /// accumulate within a second.
    pub cusum_k: f64,
    /// CUSUM decision threshold `h` (σ units).
    pub cusum_h: f64,
    /// Baseline σ floor (log-residual units) — a suspiciously tight
    /// warmup must not turn the chart into a hair trigger.
    pub sigma_floor: f64,
    /// Consecutive in-control observations that retire a residual
    /// alert (the chart then resets).
    pub clear_after: u32,
    /// Gauge samples per chart before its baseline freezes.
    pub gauge_warmup: u32,
    /// EWMA smoothing weight λ for the gauge charts.
    pub gauge_lambda: f64,
    /// Gauge control limit `L` (units of the EWMA's σ·√(λ/(2−λ))).
    pub gauge_l: f64,
    /// Lanes that must breach in the same sample to call a load surge.
    pub surge_lanes: u32,
    /// Consecutive all-calm samples that retire a surge alert.
    pub surge_clear: u32,
}

impl Default for DetectCfg {
    fn default() -> Self {
        DetectCfg {
            warmup: 64,
            cusum_k: 3.0,
            cusum_h: 25.0,
            sigma_floor: 0.25,
            clear_after: 8,
            gauge_warmup: 8,
            gauge_lambda: 0.25,
            gauge_l: 8.0,
            surge_lanes: 2,
            surge_clear: 3,
        }
    }
}

/// One raised or cleared alert, in detection order — the experiment
/// scorer's view (the flight recorder gets the same transitions as
/// events).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlertRec {
    /// Sim time of the transition.
    pub t_s: f64,
    /// Attributed lane.
    pub lane: u32,
    /// Root-cause classification.
    pub kind: AlertKind,
    /// Detector statistic at a raise (0 for clears).
    pub score: f64,
    /// `true` = raised, `false` = cleared.
    pub raised: bool,
}

/// What a chart observation did.
enum Step {
    None,
    Raise(f64),
    Clear,
}

/// One-sided CUSUM chart over standardized log residuals.
#[derive(Debug, Clone, Copy, Default)]
struct Chart {
    seen: u32,
    mean: f64,
    m2: f64,
    mu: f64,
    sigma: f64,
    s: f64,
    calm: u32,
    alerted: bool,
}

impl Chart {
    fn observe(&mut self, x: f64, cfg: &DetectCfg) -> Step {
        self.seen += 1;
        if self.seen <= cfg.warmup {
            // Welford warmup; the baseline freezes at the boundary so a
            // later anomaly can never contaminate its own yardstick.
            let d = x - self.mean;
            self.mean += d / self.seen as f64;
            self.m2 += d * (x - self.mean);
            if self.seen == cfg.warmup {
                self.mu = self.mean;
                let var = self.m2 / (cfg.warmup - 1).max(1) as f64;
                self.sigma = var.sqrt().max(cfg.sigma_floor);
            }
            return Step::None;
        }
        let z = (x - self.mu) / self.sigma;
        self.s = (self.s + z - cfg.cusum_k).max(0.0);
        if !self.alerted {
            if self.s > cfg.cusum_h {
                self.alerted = true;
                self.calm = 0;
                return Step::Raise(self.s);
            }
        } else if z <= cfg.cusum_k {
            self.calm += 1;
            if self.calm >= cfg.clear_after {
                self.alerted = false;
                self.s = 0.0;
                self.calm = 0;
                return Step::Clear;
            }
        } else {
            self.calm = 0;
        }
        Step::None
    }
}

/// EWMA control chart over one gauge stream.
#[derive(Debug, Clone, Copy)]
struct Gauge {
    floor: f64,
    seen: u32,
    mean: f64,
    m2: f64,
    limit: f64,
    z: f64,
}

impl Gauge {
    fn new(floor: f64) -> Self {
        Gauge { floor, seen: 0, mean: 0.0, m2: 0.0, limit: f64::INFINITY, z: 0.0 }
    }

    /// Feed one sample; returns whether the smoothed gauge is above its
    /// control limit.
    fn observe(&mut self, x: f64, cfg: &DetectCfg) -> bool {
        self.seen += 1;
        if self.seen <= cfg.gauge_warmup {
            let d = x - self.mean;
            self.mean += d / self.seen as f64;
            self.m2 += d * (x - self.mean);
            if self.seen == cfg.gauge_warmup {
                let var = self.m2 / (cfg.gauge_warmup - 1).max(1) as f64;
                let sigma = var.sqrt().max(self.floor);
                let sigma_z = sigma * (cfg.gauge_lambda / (2.0 - cfg.gauge_lambda)).sqrt();
                self.limit = self.mean + cfg.gauge_l * sigma_z;
                self.z = self.mean;
            }
            return false;
        }
        self.z = cfg.gauge_lambda * x + (1.0 - cfg.gauge_lambda) * self.z;
        self.z > self.limit
    }
}

/// σ floor of the queue-depth gauge charts (requests).
const DEPTH_FLOOR: f64 = 1.0;
/// σ floor of the expected-wait gauge charts (seconds).
const WAIT_FLOOR: f64 = 0.05;

/// The per-fleet detector bank (see the module docs).
#[derive(Debug, Clone)]
pub struct Detector {
    cfg: DetectCfg,
    cloud: Vec<bool>,
    exec: Vec<Chart>,
    tx: Vec<Chart>,
    depth: Vec<Gauge>,
    wait: Vec<Gauge>,
    crash_active: Vec<bool>,
    /// Active device-level alerts (crash + slowdown + link), fleet-wide.
    device_alerts: u32,
    surge_active: bool,
    surge_blocked: bool,
    surge_breach: u32,
    surge_first: u32,
    surge_calm: u32,
    /// Raised-but-undrained alert events (FIFO; `head` indexes the next
    /// to pop, the vec is reset whenever it drains empty).
    pending: Vec<Event>,
    head: usize,
    log: Vec<AlertRec>,
    raised: u64,
    cleared: u64,
    timeouts_seen: u64,
    reroutes_seen: u64,
}

impl Detector {
    /// Detector bank for one fleet (`tiers` in lane order).
    pub fn new(tiers: &[DeviceKind], cfg: DetectCfg) -> Self {
        let n = tiers.len();
        Detector {
            cfg,
            cloud: tiers.iter().map(|t| *t == DeviceKind::Cloud).collect(),
            exec: vec![Chart::default(); n],
            tx: vec![Chart::default(); n],
            depth: vec![Gauge::new(DEPTH_FLOOR); n],
            wait: vec![Gauge::new(WAIT_FLOOR); n],
            crash_active: vec![false; n],
            device_alerts: 0,
            surge_active: false,
            surge_blocked: false,
            surge_breach: 0,
            surge_first: u32::MAX,
            surge_calm: 0,
            pending: Vec::with_capacity(8),
            head: 0,
            log: Vec::with_capacity(16),
            raised: 0,
            cleared: 0,
            timeouts_seen: 0,
            reroutes_seen: 0,
        }
    }

    /// The configured tuning.
    pub fn cfg(&self) -> &DetectCfg {
        &self.cfg
    }

    /// Lanes covered.
    pub fn num_lanes(&self) -> usize {
        self.exec.len()
    }

    fn emit(&mut self, t_s: f64, lane: u32, kind: AlertKind, score: f64, raise: bool) {
        if raise {
            self.raised += 1;
            self.pending.push(Event::AlertRaised { lane, kind, score });
        } else {
            self.cleared += 1;
            self.pending.push(Event::AlertCleared { lane, kind });
        }
        self.log.push(AlertRec { t_s, lane, kind, score, raised: raise });
    }

    /// Is a device-level alert active on a lane other than `lane`?
    /// (Its collateral is absorbed: see the module docs.)
    fn other_device_alert(&self, lane: usize) -> bool {
        let own = self.exec[lane].alerted as u32
            + self.tx[lane].alerted as u32
            + self.crash_active[lane] as u32;
        self.device_alerts > own
    }

    fn device_alert_cleared(&mut self) {
        self.device_alerts -= 1;
        // Queues draining after the root cause healed must not read as
        // a fresh surge.
        self.surge_blocked = true;
    }

    /// One execution-residual observation: `obs_s` is the completed
    /// batch's service time, `est_s` the request's installed per-request
    /// estimate. Also the lane-liveness signal that retires a crash
    /// alert.
    pub fn observe_exec(&mut self, lane: u32, t_s: f64, obs_s: f64, est_s: f64) {
        let li = lane as usize;
        if self.crash_active[li] {
            // The lane completed work: it is serving again.
            self.crash_active[li] = false;
            self.emit(t_s, lane, AlertKind::DeviceCrash, 0.0, false);
            self.device_alert_cleared();
        }
        if !(obs_s > 0.0 && est_s > 0.0) || self.other_device_alert(li) {
            return;
        }
        let x = (obs_s / est_s).ln();
        match self.exec[li].observe(x, &self.cfg) {
            Step::Raise(score) => {
                self.device_alerts += 1;
                self.emit(t_s, lane, AlertKind::DeviceSlowdown, score, true);
            }
            Step::Clear => {
                self.emit(t_s, lane, AlertKind::DeviceSlowdown, 0.0, false);
                self.device_alert_cleared();
            }
            Step::None => {}
        }
    }

    /// One transfer-residual observation (cloud completions): `tx_s`
    /// the realized transfer time, `tokens` the request's size proxy
    /// (`n + m̂`).
    pub fn observe_tx(&mut self, lane: u32, t_s: f64, tx_s: f64, tokens: f64) {
        let li = lane as usize;
        if !self.cloud[li]
            || !(tx_s > 0.0 && tokens > 0.0)
            || self.other_device_alert(li)
        {
            return;
        }
        let x = (tx_s / tokens).ln();
        match self.tx[li].observe(x, &self.cfg) {
            Step::Raise(score) => {
                self.device_alerts += 1;
                self.emit(t_s, lane, AlertKind::LinkDegradation, score, true);
            }
            Step::Clear => {
                self.emit(t_s, lane, AlertKind::LinkDegradation, 0.0, false);
                self.device_alert_cleared();
            }
            Step::None => {}
        }
    }

    /// A failover reroute off `lane`: the lane destroyed an admitted
    /// copy — definitive crash evidence.
    pub fn observe_reroute(&mut self, lane: u32, t_s: f64) {
        self.reroutes_seen += 1;
        let li = lane as usize;
        if !self.crash_active[li] {
            self.crash_active[li] = true;
            self.device_alerts += 1;
            self.emit(t_s, lane, AlertKind::DeviceCrash, 1.0, true);
        }
    }

    /// A queue-deadline timeout fired: tallied as corroborating
    /// evidence (a crashed lane starves its queue), never a raise on
    /// its own — healthy queues time out too under transient load.
    pub fn observe_timeout(&mut self, _t_s: f64) {
        self.timeouts_seen += 1;
    }

    /// One lane's gauges for the current telemetry sample. Call for
    /// every lane, then [`Detector::commit_sample`].
    pub fn observe_gauge(&mut self, lane: u32, depth: f64, wait_s: f64) {
        let li = lane as usize;
        let d = self.depth[li].observe(depth, &self.cfg);
        let w = self.wait[li].observe(wait_s, &self.cfg);
        if d || w {
            self.surge_breach += 1;
            if lane < self.surge_first {
                self.surge_first = lane;
            }
        }
    }

    /// Close the current telemetry sample: decide surge raises/clears
    /// from this sample's breach count.
    pub fn commit_sample(&mut self, t_s: f64) {
        let breach = self.surge_breach;
        let first = self.surge_first;
        self.surge_breach = 0;
        self.surge_first = u32::MAX;
        if self.surge_active {
            if breach == 0 {
                self.surge_calm += 1;
                if self.surge_calm >= self.cfg.surge_clear {
                    self.surge_active = false;
                    self.surge_calm = 0;
                    self.emit(t_s, 0, AlertKind::LoadSurge, 0.0, false);
                }
            } else {
                self.surge_calm = 0;
            }
            return;
        }
        if breach == 0 {
            self.surge_blocked = false;
            return;
        }
        if breach >= self.cfg.surge_lanes
            && self.device_alerts == 0
            && !self.surge_blocked
        {
            self.surge_active = true;
            self.surge_calm = 0;
            self.emit(t_s, first, AlertKind::LoadSurge, breach as f64, true);
        }
    }

    /// Drain one pending alert event (FIFO) for the flight recorder.
    pub fn pop_event(&mut self) -> Option<Event> {
        if self.head < self.pending.len() {
            let ev = self.pending[self.head];
            self.head += 1;
            if self.head == self.pending.len() {
                self.pending.clear();
                self.head = 0;
            }
            Some(ev)
        } else {
            None
        }
    }

    /// Every raise/clear transition, in detection order.
    pub fn alerts(&self) -> &[AlertRec] {
        &self.log
    }

    /// Alerts raised.
    pub fn raised(&self) -> u64 {
        self.raised
    }

    /// Alerts cleared.
    pub fn cleared(&self) -> u64 {
        self.cleared
    }

    /// Alerts still active (raised and never cleared).
    pub fn active(&self) -> u64 {
        self.raised - self.cleared
    }

    /// Deadline timeouts tallied as corroborating evidence.
    pub fn timeouts_seen(&self) -> u64 {
        self.timeouts_seen
    }

    /// Failover reroutes observed (kill evidence).
    pub fn reroutes_seen(&self) -> u64 {
        self.reroutes_seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pair() -> Detector {
        Detector::new(&[DeviceKind::Edge, DeviceKind::Cloud], DetectCfg::default())
    }

    /// Drive a chart with a stationary stream: alternating small
    /// residuals around a fixed level.
    fn feed_stationary(det: &mut Detector, lane: u32, n: u32, scale: f64) {
        for i in 0..n {
            let obs = scale * (1.0 + 0.1 * ((i % 7) as f64 - 3.0) / 3.0);
            det.observe_exec(lane, i as f64 * 0.01, obs, scale);
        }
    }

    #[test]
    fn stationary_stream_stays_quiet() {
        let mut det = pair();
        feed_stationary(&mut det, 0, 5_000, 0.02);
        feed_stationary(&mut det, 1, 5_000, 0.05);
        assert_eq!(det.raised(), 0);
        assert!(det.pop_event().is_none());
    }

    #[test]
    fn sustained_exec_shift_raises_then_clears() {
        let mut det = pair();
        feed_stationary(&mut det, 0, 200, 0.02);
        // 4x slowdown: the standardized log residual jumps ~ln 4 / σ.
        for i in 0..50 {
            det.observe_exec(0, 10.0 + i as f64 * 0.01, 0.08, 0.02);
        }
        assert_eq!(det.raised(), 1);
        let raise = det.alerts()[0];
        assert!(raise.raised);
        assert_eq!(raise.kind, AlertKind::DeviceSlowdown);
        assert_eq!(raise.lane, 0);
        // Recovery: enough in-control observations retire the alert.
        for i in 0..50 {
            det.observe_exec(0, 20.0 + i as f64 * 0.01, 0.02, 0.02);
        }
        assert_eq!(det.cleared(), 1);
        assert_eq!(det.active(), 0);
        // The pending buffer drains the raise then the clear.
        assert!(matches!(
            det.pop_event(),
            Some(Event::AlertRaised { lane: 0, kind: AlertKind::DeviceSlowdown, .. })
        ));
        assert!(matches!(
            det.pop_event(),
            Some(Event::AlertCleared { lane: 0, kind: AlertKind::DeviceSlowdown })
        ));
        assert!(det.pop_event().is_none());
    }

    #[test]
    fn reroute_raises_crash_once_and_completion_clears_it() {
        let mut det = pair();
        det.observe_reroute(0, 5.0);
        det.observe_reroute(0, 5.0);
        det.observe_reroute(0, 5.0);
        assert_eq!(det.raised(), 1, "kill burst must dedupe to one alert");
        assert_eq!(det.alerts()[0].kind, AlertKind::DeviceCrash);
        // First completion on the lane after recovery retires it.
        det.observe_exec(0, 40.0, 0.02, 0.02);
        assert_eq!(det.cleared(), 1);
        assert_eq!(det.active(), 0);
        assert_eq!(det.reroutes_seen(), 3);
    }

    #[test]
    fn collateral_lanes_hold_while_a_device_alert_is_active() {
        let mut det = pair();
        feed_stationary(&mut det, 1, 200, 0.05);
        det.observe_reroute(0, 5.0);
        // Lane 1 now sees a big shift (the load lane 0 shed onto it) —
        // absorbed by the active crash alert, not re-alerted.
        for i in 0..200 {
            det.observe_exec(1, 5.0 + i as f64 * 0.01, 0.25, 0.05);
        }
        assert_eq!(det.raised(), 1);
    }

    #[test]
    fn tx_shift_raises_link_degradation_on_cloud_lanes_only() {
        let mut det = pair();
        for i in 0..100 {
            det.observe_tx(1, i as f64 * 0.01, 0.042, 96.0);
            det.observe_tx(0, i as f64 * 0.01, 0.042, 96.0); // edge: ignored
        }
        for i in 0..40 {
            det.observe_tx(1, 10.0 + i as f64 * 0.01, 8.0 * 0.042, 96.0);
        }
        assert_eq!(det.raised(), 1);
        assert_eq!(det.alerts()[0].kind, AlertKind::LinkDegradation);
        assert_eq!(det.alerts()[0].lane, 1);
    }

    #[test]
    fn multi_lane_gauge_breach_raises_one_surge() {
        let mut det = pair();
        for _ in 0..8 {
            det.observe_gauge(0, 3.0, 0.02);
            det.observe_gauge(1, 3.0, 0.02);
            det.commit_sample(0.0);
        }
        // Both lanes' queues explode: one fleet-level surge alert.
        for i in 0..10 {
            det.observe_gauge(0, 300.0, 2.0);
            det.observe_gauge(1, 300.0, 2.0);
            det.commit_sample(16.0 + 2.0 * i as f64);
        }
        assert_eq!(det.raised(), 1);
        assert_eq!(det.alerts()[0].kind, AlertKind::LoadSurge);
        // Calm samples retire it.
        for i in 0..20 {
            det.observe_gauge(0, 3.0, 0.02);
            det.observe_gauge(1, 3.0, 0.02);
            det.commit_sample(40.0 + 2.0 * i as f64);
        }
        assert_eq!(det.cleared(), 1);
    }

    #[test]
    fn surge_is_suppressed_while_a_device_alert_is_active() {
        let mut det = pair();
        for _ in 0..8 {
            det.observe_gauge(0, 3.0, 0.02);
            det.observe_gauge(1, 3.0, 0.02);
            det.commit_sample(0.0);
        }
        det.observe_reroute(0, 16.0);
        for i in 0..10 {
            det.observe_gauge(0, 300.0, 2.0);
            det.observe_gauge(1, 300.0, 2.0);
            det.commit_sample(16.0 + 2.0 * i as f64);
        }
        // Only the crash alert; the gauge breach is its collateral.
        assert_eq!(det.raised(), 1);
        assert_eq!(det.alerts()[0].kind, AlertKind::DeviceCrash);
        // Clear the crash; surges stay blocked until a calm sample.
        det.observe_exec(0, 50.0, 0.02, 0.02);
        for i in 0..3 {
            det.observe_gauge(0, 300.0, 2.0);
            det.observe_gauge(1, 300.0, 2.0);
            det.commit_sample(50.0 + 2.0 * i as f64);
        }
        assert_eq!(det.raised(), 1, "draining queues are aftermath, not a surge");
    }
}
