//! Offline decision-log checker.
//!
//! [`verify_trace`] re-proves the harness's accounting invariants from a
//! dumped JSONL trace alone — no access to the simulator, dispatcher,
//! or `Acct` internals:
//!
//! * **Conservation** — every offered request is shed or admitted; every
//!   admitted request produces exactly one result, is still in flight at
//!   the end of the trace, or (retry chains only) was terminally shed
//!   after exhausting its retry budget; batch membership covers every
//!   completion. Retry chains (requests named by `TimeoutFired` /
//!   `RetryDispatched` / `FailoverReroute` events) may admit many times
//!   but are counted **once**, with fate precedence completed >
//!   in-flight > shed.
//! * **Hedge-fate partitioning** — every hedged request admits exactly
//!   two copies on distinct lanes and resolves as exactly one win plus
//!   exactly one loss-or-cancellation, on the admitted lanes. A pair
//!   whose winner is logged but whose loser's resolution fell off the
//!   tail of the dump (or was destroyed by a device fault) is reported
//!   as an *open race*, not an error.
//! * **Failure discipline** — no lane admits between its `DeviceDown`
//!   and `DeviceUp` events.
//! * **Control-law replay** — the hedge margin trajectory in the
//!   `MarginAdjust` stream is recomputed step by step from the meta
//!   header's budget and initial margin; every event's margin must match
//!   the replayed value bit for bit. The controller's decayed work
//!   window is also inverted (`t_k = w_k − λ·w_{k−1}`) to reconstruct
//!   the raw useful/wasted work totals, re-deriving waste-budget
//!   compliance without trusting any aggregate.
//!
//! The checker demands a trace that is complete *from the start*
//! (sequence numbers contiguous from zero): a ring window that dropped
//! leading events cannot prove conservation, and is rejected with the
//! dropped-prefix size. A dump cut short at the **tail** is
//! indistinguishable from a run that ended with work outstanding, so
//! unresolved requests are tallied as `in_flight` rather than rejected.

use std::collections::HashMap;
use std::fmt::Write as _;

use crate::scheduler::{
    CompletionKind, HEDGE_GAIN, HEDGE_MAX_MARGIN_S, HEDGE_MIN_MARGIN_S,
    HEDGE_WINDOW_DECAY,
};
use crate::util::Json;
use crate::{Error, Result};

use super::attribute::{fold_total, BlameChain};
use super::event::{AlertKind, Event, Stamped};
use super::recorder::TraceMeta;

/// What the offline replay re-derived from a trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct VerifyReport {
    /// Events in the trace (excluding the meta header).
    pub events: u64,
    /// Requests that reached admission (admitted + shed).
    pub offered: u64,
    /// Requests admitted (hedged pairs count once).
    pub admitted: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Requests admitted as two-lane hedge races.
    pub hedged: u64,
    /// Results produced (solo completions + hedge wins).
    pub results: u64,
    /// Solo completions.
    pub completed_solo: u64,
    /// Hedge race winners.
    pub hedge_wins: u64,
    /// Hedge losers that executed (wasted work).
    pub hedge_losses: u64,
    /// Hedge losers cancelled while still queued.
    pub hedge_cancelled: u64,
    /// Batches formed.
    pub batches: u64,
    /// Requests dispatched through batches (Σ batch sizes).
    pub batched_requests: u64,
    /// Placement scorings logged.
    pub placements: u64,
    /// Margin-controller adjustments replayed.
    pub margin_updates: u64,
    /// Final replayed margin (controlled runs only).
    pub final_margin_s: Option<f64>,
    /// Final decayed-window wasted-work fraction (controlled runs only).
    pub final_window_frac: Option<f64>,
    /// Raw wasted-work fraction reconstructed by inverting the decayed
    /// window (controlled runs only).
    pub reconstructed_wasted_frac: Option<f64>,
    /// The waste budget from the meta header (controlled runs only).
    pub waste_budget: Option<f64>,
    /// RLS model installations observed.
    pub refits: u64,
    /// Completions charged at a drift factor ≠ 1.
    pub drift_ticks: u64,
    /// Largest drift slowdown factor seen.
    pub max_drift_factor: f64,
    /// Admitted requests with no result by the end of the trace (still
    /// queued, running, or waiting out a retry backoff).
    pub in_flight: u64,
    /// Hedge races whose winner is logged but whose loser's resolution
    /// is missing (tail truncation or a device fault destroyed it).
    pub open_races: u64,
    /// Distinct requests that went through the timeout/failover retry
    /// machinery (each chain counted once everywhere else).
    pub retried: u64,
    /// Retry chains terminally shed after exhausting their budget.
    pub shed_failed: u64,
    /// Queue-deadline timers that fired.
    pub timeouts_fired: u64,
    /// Retry re-admissions dispatched.
    pub retry_dispatches: u64,
    /// Requests re-routed off a dead lane.
    pub failover_reroutes: u64,
    /// Device crash events.
    pub device_down: u64,
    /// Device recovery events.
    pub device_up: u64,
    /// Detector alerts raised.
    pub alerts_raised: u64,
    /// Detector alerts cleared (each must pair with an active raise on
    /// the same lane and kind).
    pub alerts_cleared: u64,
    /// Leading events dropped from the window (0 for a complete trace;
    /// non-zero only under [`verify_trace_allow_truncated`]).
    pub dropped_prefix: u64,
    /// Ring evictions reported by the health trailer (`None` on dumps
    /// without one).
    pub ring_dropped: Option<u64>,
    /// Sink health reported by the trailer (`None` on dumps without
    /// one).
    pub sink_ok: Option<bool>,
}

impl VerifyReport {
    /// Render the replay's findings as JSON (for `cnmt trace verify`).
    pub fn to_json(&self) -> Json {
        fn opt(x: Option<f64>) -> Json {
            x.map_or(Json::Null, Json::Num)
        }
        let mut o = Json::object();
        o.set("events", Json::Num(self.events as f64))
            .set("offered", Json::Num(self.offered as f64))
            .set("admitted", Json::Num(self.admitted as f64))
            .set("shed", Json::Num(self.shed as f64))
            .set("hedged", Json::Num(self.hedged as f64))
            .set("results", Json::Num(self.results as f64))
            .set("completed_solo", Json::Num(self.completed_solo as f64))
            .set("hedge_wins", Json::Num(self.hedge_wins as f64))
            .set("hedge_losses", Json::Num(self.hedge_losses as f64))
            .set("hedge_cancelled", Json::Num(self.hedge_cancelled as f64))
            .set("batches", Json::Num(self.batches as f64))
            .set("batched_requests", Json::Num(self.batched_requests as f64))
            .set("placements", Json::Num(self.placements as f64))
            .set("margin_updates", Json::Num(self.margin_updates as f64))
            .set("final_margin_s", opt(self.final_margin_s))
            .set("final_window_frac", opt(self.final_window_frac))
            .set(
                "reconstructed_wasted_frac",
                opt(self.reconstructed_wasted_frac),
            )
            .set("waste_budget", opt(self.waste_budget))
            .set("refits", Json::Num(self.refits as f64))
            .set("drift_ticks", Json::Num(self.drift_ticks as f64))
            .set("max_drift_factor", Json::Num(self.max_drift_factor))
            .set("in_flight", Json::Num(self.in_flight as f64))
            .set("open_races", Json::Num(self.open_races as f64))
            .set("retried", Json::Num(self.retried as f64))
            .set("shed_failed", Json::Num(self.shed_failed as f64))
            .set("timeouts_fired", Json::Num(self.timeouts_fired as f64))
            .set("retry_dispatches", Json::Num(self.retry_dispatches as f64))
            .set("failover_reroutes", Json::Num(self.failover_reroutes as f64))
            .set("device_down", Json::Num(self.device_down as f64))
            .set("device_up", Json::Num(self.device_up as f64))
            .set("alerts_raised", Json::Num(self.alerts_raised as f64))
            .set("alerts_cleared", Json::Num(self.alerts_cleared as f64))
            .set("dropped_prefix", Json::Num(self.dropped_prefix as f64))
            .set(
                "ring_dropped",
                match self.ring_dropped {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            )
            .set(
                "sink_ok",
                match self.sink_ok {
                    Some(b) => Json::Bool(b),
                    None => Json::Null,
                },
            );
        o
    }
}

/// Per-request fate accumulated while scanning. Retry chains may cycle
/// through many admissions; the lane arrays only capture the first two
/// (enough for the strict non-retried checks).
#[derive(Debug, Clone, Copy, Default)]
struct IdState {
    admits: u32,
    admit_lanes: [u32; 2],
    hedged: bool,
    sheds: u32,
    wins: u32,
    solos: u32,
    losses: u32,
    cancels: u32,
    resolve_lanes: [u32; 2],
    resolves: u32,
    /// Copies destroyed by a timeout pull or a lane failure.
    kills: u32,
}

/// The recorder-health trailer line of a trace dump
/// (`{"trailer":{...}}`): how many events were ever recorded, how many
/// the bounded ring evicted, and whether every sink write succeeded.
/// Ring evictions do **not** imply missing lines in a streamed dump —
/// the sink saw every event — but in a ring-window render they do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceTrailer {
    /// Total events recorded over the run (`FlightRecorder::total`).
    pub events: u64,
    /// Events evicted from the bounded ring
    /// (`FlightRecorder::dropped`).
    pub ring_dropped: u64,
    /// Whether every sink write succeeded
    /// (`FlightRecorder::sink_ok`).
    pub sink_ok: bool,
}

/// Parse a JSONL trace into its meta header and event list. Lines are
/// independent JSON documents; the meta header may appear anywhere but
/// conventionally leads. Drops the health trailer — use
/// [`parse_trace_full`] to keep it.
pub fn parse_trace(text: &str) -> Result<(TraceMeta, Vec<Stamped>)> {
    let (meta, events, _trailer) = parse_trace_full(text)?;
    Ok((meta, events))
}

/// [`parse_trace`], also returning the health trailer when the dump has
/// one (`None` on older dumps).
pub fn parse_trace_full(text: &str) -> Result<(TraceMeta, Vec<Stamped>, Option<TraceTrailer>)> {
    let mut meta = TraceMeta::default();
    let mut seen_meta = false;
    let mut trailer = None;
    let mut events = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = Json::parse(line).map_err(|e| {
            Error::Config(format!("trace line {}: {e}", lineno + 1))
        })?;
        if let Ok(Some(tr)) = v.get_opt("trailer") {
            if trailer.is_some() {
                return Err(Error::Config(format!(
                    "trace line {}: duplicate trailer",
                    lineno + 1
                )));
            }
            trailer = Some(TraceTrailer {
                events: tr.get("events")?.as_i64()? as u64,
                ring_dropped: tr.get("ring_dropped")?.as_i64()? as u64,
                sink_ok: tr.get("sink_ok")?.as_bool()?,
            });
            continue;
        }
        if let Ok(Some(m)) = v.get_opt("meta") {
            if seen_meta {
                return Err(Error::Config(format!(
                    "trace line {}: duplicate meta header",
                    lineno + 1
                )));
            }
            seen_meta = true;
            let mut tiers = Vec::new();
            if let Json::Array(items) = m.get("tiers")? {
                for t in items {
                    let id = t.as_str()?;
                    let kind = crate::devices::DeviceKind::from_id(id).ok_or_else(
                        || Error::Config(format!("unknown tier `{id}` in meta")),
                    )?;
                    tiers.push(kind);
                }
            } else {
                return Err(Error::Config("meta tiers is not an array".into()));
            }
            meta.tiers = tiers;
            meta.waste_budget = match m.get("waste_budget")? {
                Json::Null => None,
                other => Some(other.as_f64()?),
            };
            meta.init_margin_s = match m.get("init_margin_s")? {
                Json::Null => None,
                other => Some(other.as_f64()?),
            };
            continue;
        }
        events.push(Stamped::from_json(&v).map_err(|e| {
            Error::Config(format!("trace line {}: {e}", lineno + 1))
        })?);
    }
    Ok((meta, events))
}

fn fail(msg: String) -> Error {
    Error::Config(format!("trace verify failed: {msg}"))
}

/// Replay a dumped trace and re-prove the accounting invariants (see
/// the module docs). Returns the re-derived counts on success. A
/// truncated window or an unhealthy trailer is an error — see
/// [`verify_trace_allow_truncated`] for the relaxed mode.
pub fn verify_trace(text: &str) -> Result<VerifyReport> {
    let (meta, events, trailer) = parse_trace_full(text)?;
    verify_events_full(&meta, &events, trailer.as_ref(), false)
}

/// [`verify_trace`], accepting a ring-window render whose prefix was
/// evicted (and a trailer reporting lost tail lines). Conservation and
/// replay proofs need the full history, so a truncated window only gets
/// the local checks: interior seq contiguity, monotone time, and the
/// simple tallies. `dropped_prefix` in the report says how much is
/// missing.
pub fn verify_trace_allow_truncated(text: &str) -> Result<VerifyReport> {
    let (meta, events, trailer) = parse_trace_full(text)?;
    verify_events_full(&meta, &events, trailer.as_ref(), true)
}

/// [`verify_trace`] over already-parsed events (strict mode, no
/// trailer).
pub fn verify_events(meta: &TraceMeta, events: &[Stamped]) -> Result<VerifyReport> {
    verify_events_full(meta, events, None, false)
}

/// [`verify_trace`] over already-parsed events plus the optional health
/// trailer. `allow_truncated` downgrades *incompleteness* (evicted
/// prefix, lost tail, failed sink writes) from error to relaxed
/// verification; *inconsistency* (a trailer claiming fewer events than
/// the dump holds) is always an error.
pub fn verify_events_full(
    meta: &TraceMeta,
    events: &[Stamped],
    trailer: Option<&TraceTrailer>,
    allow_truncated: bool,
) -> Result<VerifyReport> {
    let mut report = VerifyReport {
        events: events.len() as u64,
        max_drift_factor: 1.0,
        ..VerifyReport::default()
    };

    if let Some(tr) = trailer {
        report.ring_dropped = Some(tr.ring_dropped);
        report.sink_ok = Some(tr.sink_ok);
        if !tr.sink_ok && !allow_truncated {
            return Err(fail(
                "trailer reports failed sink writes; the dump may be \
                 missing events (pass --allow-truncated to verify what \
                 survived)"
                    .into(),
            ));
        }
        if let Some(last) = events.last() {
            let expect = last.seq + 1;
            if tr.events < expect {
                // More lines than the recorder claims to have produced:
                // never legitimate, regardless of mode.
                return Err(fail(format!(
                    "trailer claims {} events but the dump reaches seq {}",
                    tr.events, last.seq
                )));
            }
            if tr.events > expect && !allow_truncated {
                return Err(fail(format!(
                    "trailer claims {} events but the dump ends at seq {} \
                     ({} tail lines lost)",
                    tr.events,
                    last.seq,
                    tr.events - expect
                )));
            }
        }
    }

    // A complete trace is a prerequisite for conservation proofs.
    let first_seq = events.first().map(|f| f.seq).unwrap_or(0);
    if first_seq != 0 && !allow_truncated {
        return Err(fail(format!(
            "trace is a truncated window ({first_seq} leading events \
             dropped); conservation needs a full streamed dump"
        )));
    }
    for (i, st) in events.iter().enumerate() {
        if st.seq != first_seq + i as u64 {
            return Err(fail(format!(
                "sequence gap at index {i}: expected seq {}, found {}",
                first_seq + i as u64,
                st.seq
            )));
        }
        if i > 0 && st.t_s < events[i - 1].t_s {
            return Err(fail(format!(
                "time went backwards at seq {}: {} after {}",
                st.seq,
                st.t_s,
                events[i - 1].t_s
            )));
        }
    }
    if first_seq != 0 {
        // Relaxed path: the prefix is gone, so per-id fates and
        // conservation cannot be proven. Tally what each surviving line
        // says on its own and stop there.
        report.dropped_prefix = first_seq;
        for st in events {
            match st.ev {
                Event::Placement { .. } => report.placements += 1,
                Event::BatchFormed { size, .. } => {
                    report.batches += 1;
                    report.batched_requests += size as u64;
                }
                Event::RefitInstall { .. } => report.refits += 1,
                Event::DriftTick { factor, .. } => {
                    report.drift_ticks += 1;
                    if factor > report.max_drift_factor {
                        report.max_drift_factor = factor;
                    }
                }
                Event::DeviceDown { .. } => report.device_down += 1,
                Event::DeviceUp { .. } => report.device_up += 1,
                Event::TimeoutFired { .. } => report.timeouts_fired += 1,
                Event::RetryDispatched { .. } => report.retry_dispatches += 1,
                Event::FailoverReroute { .. } => report.failover_reroutes += 1,
                Event::AlertRaised { .. } => report.alerts_raised += 1,
                Event::AlertCleared { .. } => report.alerts_cleared += 1,
                _ => {}
            }
        }
        return Ok(report);
    }

    // --- Pass 0: which ids went through the retry machinery? -------------
    // A retry chain re-admits under the same id, so the strict
    // once-per-request caps below must not apply to it. The retry events
    // name the chain explicitly.
    let mut retried_ids: std::collections::HashSet<u64> =
        std::collections::HashSet::new();
    for st in events {
        match st.ev {
            Event::TimeoutFired { id, .. }
            | Event::RetryDispatched { id, .. }
            | Event::FailoverReroute { id, .. } => {
                retried_ids.insert(id);
            }
            _ => {}
        }
    }

    // --- Pass 1: per-id fates and global tallies. -----------------------
    let mut ids: HashMap<u64, IdState> = HashMap::new();
    let mut down_lanes: std::collections::HashSet<u32> =
        std::collections::HashSet::new();
    let mut active_alerts: std::collections::HashSet<(u32, AlertKind)> =
        std::collections::HashSet::new();
    let mut dispatch_batches = 0u64;
    let mut dispatched_requests = 0u64;
    for st in events {
        match st.ev {
            Event::Admit { id, lane, hedged } => {
                if down_lanes.contains(&lane) {
                    return Err(fail(format!(
                        "request {id} admitted on lane {lane} while it was down"
                    )));
                }
                let retried = retried_ids.contains(&id);
                let s = ids.entry(id).or_default();
                if s.sheds > 0 && !retried {
                    return Err(fail(format!("request {id} admitted after shed")));
                }
                if s.admits >= 2 && !retried {
                    return Err(fail(format!("request {id} admitted 3+ times")));
                }
                if s.admits < 2 {
                    s.admit_lanes[s.admits as usize] = lane;
                }
                s.admits += 1;
                s.hedged |= hedged;
            }
            Event::Shed { id } => {
                let retried = retried_ids.contains(&id);
                let s = ids.entry(id).or_default();
                if (s.admits > 0 || s.sheds > 0) && !retried {
                    return Err(fail(format!(
                        "request {id} shed after admit or shed twice"
                    )));
                }
                s.sheds += 1;
            }
            Event::Placement {
                id,
                edge_lane,
                edge_score_s,
                cloud_lane,
                cloud_score_s,
                chosen,
                margin_s,
            } => {
                report.placements += 1;
                if chosen != edge_lane && chosen != cloud_lane {
                    return Err(fail(format!(
                        "request {id}: chose lane {chosen}, candidates were \
                         {edge_lane}/{cloud_lane}"
                    )));
                }
                if edge_score_s.is_finite() && cloud_score_s.is_finite() {
                    let want = edge_score_s - cloud_score_s;
                    if margin_s.to_bits() != want.to_bits() {
                        return Err(fail(format!(
                            "request {id}: margin {margin_s} ≠ edge−cloud {want}"
                        )));
                    }
                    let best = if edge_score_s <= cloud_score_s {
                        edge_lane
                    } else {
                        cloud_lane
                    };
                    if chosen != best {
                        return Err(fail(format!(
                            "request {id}: chose lane {chosen} over better lane {best}"
                        )));
                    }
                }
            }
            Event::BatchFormed { size, .. } => {
                report.batches += 1;
                report.batched_requests += size as u64;
            }
            Event::DispatchStart { size, .. } => {
                dispatch_batches += 1;
                dispatched_requests += size as u64;
            }
            Event::Complete { id, lane, kind } => {
                let retried = retried_ids.contains(&id);
                let s = ids.entry(id).or_default();
                if s.resolves >= 2 && !retried {
                    return Err(fail(format!("request {id} resolved 3+ times")));
                }
                if s.resolves < 2 {
                    s.resolve_lanes[s.resolves as usize] = lane;
                }
                s.resolves += 1;
                match kind {
                    CompletionKind::Solo => s.solos += 1,
                    CompletionKind::HedgeWin => s.wins += 1,
                    CompletionKind::HedgeLoss => s.losses += 1,
                }
            }
            Event::HedgeCancel { id, lane } => {
                let retried = retried_ids.contains(&id);
                let s = ids.entry(id).or_default();
                if s.resolves >= 2 && !retried {
                    return Err(fail(format!("request {id} resolved 3+ times")));
                }
                if s.resolves < 2 {
                    s.resolve_lanes[s.resolves as usize] = lane;
                }
                s.resolves += 1;
                s.cancels += 1;
            }
            Event::RefitInstall { .. } => report.refits += 1,
            Event::DriftTick { factor, .. } => {
                report.drift_ticks += 1;
                if factor > report.max_drift_factor {
                    report.max_drift_factor = factor;
                }
            }
            Event::DeviceDown { lane } => {
                report.device_down += 1;
                down_lanes.insert(lane);
            }
            Event::DeviceUp { lane } => {
                report.device_up += 1;
                down_lanes.remove(&lane);
            }
            Event::TimeoutFired { id, .. } => {
                report.timeouts_fired += 1;
                ids.entry(id).or_default().kills += 1;
            }
            Event::RetryDispatched { .. } => report.retry_dispatches += 1,
            Event::FailoverReroute { id, .. } => {
                report.failover_reroutes += 1;
                ids.entry(id).or_default().kills += 1;
            }
            Event::AlertRaised { lane, kind, .. } => {
                // Alerts are edge-triggered: a lane/kind pair may hold
                // at most one active alert at a time.
                if !active_alerts.insert((lane, kind)) {
                    return Err(fail(format!(
                        "{} alert raised twice on lane {lane} without an \
                         intervening clear",
                        kind.tag()
                    )));
                }
                report.alerts_raised += 1;
            }
            Event::AlertCleared { lane, kind } => {
                if !active_alerts.remove(&(lane, kind)) {
                    return Err(fail(format!(
                        "{} alert cleared on lane {lane} with no active \
                         raise",
                        kind.tag()
                    )));
                }
                report.alerts_cleared += 1;
            }
            Event::MarginAdjust { .. } => {}
            // Class tags are pure annotation: per-class accounting is
            // checked by the scenario harness itself, not the verifier.
            Event::ClassTag { .. } => {}
        }
    }

    // --- Pass 2: per-id invariants. --------------------------------------
    for (&id, s) in &ids {
        if retried_ids.contains(&id) {
            // A retry chain: many admits under one id, counted once.
            // Fate precedence: completed > in-flight > shed — a chain is
            // terminally shed only if it never completed and nothing of
            // it remains in the system.
            report.retried += 1;
            if s.admits == 0 {
                if s.sheds > 0 {
                    report.shed += 1;
                    continue;
                }
                return Err(fail(format!(
                    "retry events for request {id} that was never admitted \
                     or shed"
                )));
            }
            report.admitted += 1;
            if s.hedged {
                report.hedged += 1;
            }
            let done = s.wins + s.solos;
            if done > 1 {
                return Err(fail(format!(
                    "retried request {id} produced {done} results, want at \
                     most one per chain"
                )));
            }
            report.completed_solo += s.solos as u64;
            report.hedge_wins += s.wins as u64;
            report.hedge_losses += s.losses as u64;
            report.hedge_cancelled += s.cancels as u64;
            if done == 0 {
                // Copies admitted minus copies resolved or destroyed: a
                // positive balance means part of the chain is still in
                // the dispatcher; a zero balance with a shed on record is
                // the budget-exhausted terminal shed, and a zero balance
                // without one is a chain waiting out its retry backoff.
                let balance =
                    s.admits as i64 - s.resolves as i64 - s.kills as i64;
                if balance > 0 || s.sheds == 0 {
                    report.in_flight += 1;
                } else {
                    report.shed_failed += 1;
                }
            }
            continue;
        }
        if s.sheds > 0 {
            report.shed += 1;
            if s.resolves > 0 {
                return Err(fail(format!("shed request {id} has completions")));
            }
            continue;
        }
        if s.admits == 0 {
            return Err(fail(format!(
                "request {id} completed without an admit event"
            )));
        }
        report.admitted += 1;
        if s.hedged {
            // Hedge-fate partition: two admits on distinct lanes; exactly
            // one winner plus exactly one executed loser or cancellation,
            // each on one of the admitted lanes, on distinct lanes. A
            // pair with no resolutions (or only the loser's) is still in
            // flight; a winner whose loser resolution is missing is an
            // open race (tail truncation, or a fault destroyed the
            // loser's copy).
            report.hedged += 1;
            if s.admits == 1 && s.resolves == 0 {
                report.in_flight += 1;
                continue;
            }
            if s.admits != 2 {
                return Err(fail(format!(
                    "hedged request {id} admitted {} times, want 2",
                    s.admits
                )));
            }
            if s.admit_lanes[0] == s.admit_lanes[1] {
                return Err(fail(format!(
                    "hedged request {id} admitted twice on lane {}",
                    s.admit_lanes[0]
                )));
            }
            if s.solos != 0 || s.wins > 1 || s.losses + s.cancels > 1 {
                return Err(fail(format!(
                    "hedged request {id} fates: wins={} solos={} losses={} \
                     cancels={}, want exactly one win and one loss-or-cancel",
                    s.wins, s.solos, s.losses, s.cancels
                )));
            }
            report.hedge_losses += s.losses as u64;
            report.hedge_cancelled += s.cancels as u64;
            if s.wins == 0 {
                report.in_flight += 1;
                continue;
            }
            report.hedge_wins += 1;
            if s.losses + s.cancels == 0 {
                for lane in s.resolve_lanes.iter().take(s.resolves as usize) {
                    if *lane != s.admit_lanes[0] && *lane != s.admit_lanes[1] {
                        return Err(fail(format!(
                            "hedged request {id} resolved on lane {lane}, \
                             admitted on {}/{}",
                            s.admit_lanes[0], s.admit_lanes[1]
                        )));
                    }
                }
                report.open_races += 1;
                continue;
            }
            if s.resolve_lanes[0] == s.resolve_lanes[1] {
                return Err(fail(format!(
                    "hedged request {id} resolved twice on lane {}",
                    s.resolve_lanes[0]
                )));
            }
            for lane in s.resolve_lanes {
                if lane != s.admit_lanes[0] && lane != s.admit_lanes[1] {
                    return Err(fail(format!(
                        "hedged request {id} resolved on lane {lane}, admitted \
                         on {}/{}",
                        s.admit_lanes[0], s.admit_lanes[1]
                    )));
                }
            }
        } else {
            if s.admits != 1 {
                return Err(fail(format!(
                    "solo request {id} admitted {} times",
                    s.admits
                )));
            }
            if s.resolves == 0 {
                report.in_flight += 1;
                continue;
            }
            if s.solos != 1 || s.wins + s.losses + s.cancels != 0 {
                return Err(fail(format!(
                    "solo request {id} fates: solos={} wins={} losses={} \
                     cancels={}, want exactly one solo completion",
                    s.solos, s.wins, s.losses, s.cancels
                )));
            }
            if s.resolve_lanes[0] != s.admit_lanes[0] {
                return Err(fail(format!(
                    "solo request {id} completed on lane {}, admitted on {}",
                    s.resolve_lanes[0], s.admit_lanes[0]
                )));
            }
            report.completed_solo += 1;
        }
    }
    report.offered = report.admitted + report.shed;
    report.results = report.completed_solo + report.hedge_wins;

    // Conservation: every admitted request (retry chains counted once)
    // is accounted for exactly once — a result, still in flight, or a
    // budget-exhausted terminal shed.
    if report.results + report.in_flight + report.shed_failed != report.admitted
    {
        return Err(fail(format!(
            "conservation: {} results + {} in flight + {} shed for {} \
             admitted requests",
            report.results, report.in_flight, report.shed_failed, report.admitted
        )));
    }
    let executions =
        report.completed_solo + report.hedge_wins + report.hedge_losses;
    if report.batches != dispatch_batches
        || report.batched_requests != dispatched_requests
    {
        return Err(fail(format!(
            "batch accounting: formed {} batches/{} requests, dispatched \
             {}/{}",
            report.batches,
            report.batched_requests,
            dispatch_batches,
            dispatched_requests
        )));
    }
    // With faults, retries, or outstanding work, dispatched copies may
    // have been destroyed before completing — membership then bounds the
    // execution count instead of equalling it.
    let relaxed = report.in_flight > 0
        || report.open_races > 0
        || report.retried > 0
        || report.device_down > 0;
    if report.batched_requests != executions
        && !(relaxed && report.batched_requests > executions)
    {
        return Err(fail(format!(
            "batch accounting: {} requests dispatched, {} executed",
            report.batched_requests, executions
        )));
    }

    // --- Pass 3: margin-law replay. --------------------------------------
    let has_margin = events
        .iter()
        .any(|st| matches!(st.ev, Event::MarginAdjust { .. }));
    if has_margin {
        let (budget, init) = match (meta.waste_budget, meta.init_margin_s) {
            (Some(b), Some(m)) => (b, m),
            _ => {
                return Err(fail(
                    "MarginAdjust events but meta lacks waste_budget/init_margin_s"
                        .into(),
                ))
            }
        };
        report.waste_budget = Some(budget);
        let mut margin = init.clamp(HEDGE_MIN_MARGIN_S, HEDGE_MAX_MARGIN_S);
        let mut prev_useful = 0.0f64;
        let mut prev_wasted = 0.0f64;
        let mut raw_useful = 0.0f64;
        let mut raw_wasted = 0.0f64;
        let mut window_frac = 0.0f64;
        for st in events {
            if let Event::MarginAdjust { margin_s, useful_s, wasted_s } = st.ev {
                report.margin_updates += 1;
                // Replay the control law from the event's (post-update)
                // decayed window; must match the logged margin exactly.
                let total = useful_s + wasted_s;
                if total > 0.0 {
                    let frac = wasted_s / total;
                    let err = (budget - frac) / budget;
                    margin = (margin * (1.0 + HEDGE_GAIN * err))
                        .clamp(HEDGE_MIN_MARGIN_S, HEDGE_MAX_MARGIN_S);
                    window_frac = frac;
                }
                if margin_s.to_bits() != margin.to_bits() {
                    return Err(fail(format!(
                        "margin-law replay diverged at seq {}: logged {}, \
                         replayed {margin}",
                        st.seq, margin_s
                    )));
                }
                // Invert the decayed window to recover this observation's
                // raw work content (one side gets ≈t, the other ≈0).
                let du = useful_s - HEDGE_WINDOW_DECAY * prev_useful;
                let dw = wasted_s - HEDGE_WINDOW_DECAY * prev_wasted;
                raw_useful += du.max(0.0);
                raw_wasted += dw.max(0.0);
                prev_useful = useful_s;
                prev_wasted = wasted_s;
            }
        }
        report.final_margin_s = Some(margin);
        report.final_window_frac = Some(window_frac);
        let raw_total = raw_useful + raw_wasted;
        let raw_frac = if raw_total > 0.0 { raw_wasted / raw_total } else { 0.0 };
        report.reconstructed_wasted_frac = Some(raw_frac);
        // Waste-budget compliance: the realized wasted-work fraction must
        // sit at or under the budget (small slack for the controller's
        // settling transient on short traces).
        let bar = budget + 0.05;
        if raw_frac > bar {
            return Err(fail(format!(
                "waste budget violated: reconstructed wasted fraction {raw_frac} \
                 exceeds budget {budget} (+0.05 slack)"
            )));
        }
    }

    Ok(report)
}

/// Tag-by-tag event counts and trace span, as JSON (for
/// `cnmt trace summary`). Unlike [`verify_trace`], this accepts
/// truncated windows.
pub fn summarize_trace(text: &str) -> Result<Json> {
    let (meta, events, trailer) = parse_trace_full(text)?;
    let mut counts: HashMap<&'static str, u64> = HashMap::new();
    for st in &events {
        *counts.entry(st.ev.tag()).or_insert(0) += 1;
    }
    let mut by_tag = Json::object();
    let mut tags: Vec<_> = counts.into_iter().collect();
    tags.sort_unstable();
    for (tag, n) in tags {
        by_tag.set(tag, Json::Num(n as f64));
    }
    let mut tier_names = String::new();
    for (i, t) in meta.tiers.iter().enumerate() {
        if i > 0 {
            let _ = write!(tier_names, ",");
        }
        let _ = write!(tier_names, "{}", t.id());
    }
    let mut o = Json::object();
    o.set("events", Json::Num(events.len() as f64))
        .set("by_event", by_tag)
        .set("tiers", Json::Str(tier_names))
        .set(
            "first_seq",
            events.first().map_or(Json::Null, |s| Json::Num(s.seq as f64)),
        )
        .set(
            "last_seq",
            events.last().map_or(Json::Null, |s| Json::Num(s.seq as f64)),
        )
        .set(
            "t_start_s",
            events.first().map_or(Json::Null, |s| Json::Num(s.t_s)),
        )
        .set(
            "t_end_s",
            events.last().map_or(Json::Null, |s| Json::Num(s.t_s)),
        )
        .set(
            "dropped_prefix",
            events.first().map_or(Json::Null, |s| Json::Num(s.seq as f64)),
        )
        .set(
            "ring_dropped",
            trailer.map_or(Json::Null, |t| Json::Num(t.ring_dropped as f64)),
        )
        .set(
            "sink_ok",
            trailer.map_or(Json::Null, |t| Json::Bool(t.sink_ok)),
        );
    Ok(o)
}

fn blame_fail(id: u64, msg: String) -> Error {
    Error::Config(format!("blame verify failed: chain {id}: {msg}"))
}

/// Re-prove the blame-partition invariant for a batch of finished
/// chains: marks are monotone, every segment is non-negative, each
/// segment recomputes **bit-identically** from the raw chain marks (same
/// accumulation order as [`super::BlameLedger::complete`]), and
/// `total_s` is exactly the canonical left-fold of the six segments.
/// The partition is exact by construction; this catches any ledger or
/// serialisation drift that would quietly break it.
pub fn verify_blame(chains: &[BlameChain]) -> Result<()> {
    for c in chains {
        let id = c.id;
        if c.attempts == 0 || c.enq_s.len() != c.attempts as usize {
            return Err(blame_fail(
                id,
                format!(
                    "{} attempts but {} admission marks",
                    c.attempts,
                    c.enq_s.len()
                ),
            ));
        }
        if c.kill_s.len() + 1 != c.enq_s.len() {
            return Err(blame_fail(
                id,
                format!(
                    "{} kill marks for {} admissions, want one fewer",
                    c.kill_s.len(),
                    c.enq_s.len()
                ),
            ));
        }
        if c.timeout_kills + c.crash_kills != c.kill_s.len() as u32 {
            return Err(blame_fail(
                id,
                format!(
                    "kill kinds ({} timeout + {} crash) don't cover {} kills",
                    c.timeout_kills,
                    c.crash_kills,
                    c.kill_s.len()
                ),
            ));
        }
        // Mark order: enq_i ≤ kill_i ≤ enq_{i+1}, then
        // enq_last ≤ start ≤ done, and a non-negative compute cost
        // inside the dispatch window.
        for (i, &kill) in c.kill_s.iter().enumerate() {
            if !(c.enq_s[i] <= kill && kill <= c.enq_s[i + 1]) {
                return Err(blame_fail(
                    id,
                    format!(
                        "attempt {i} marks out of order: enq {} kill {kill} \
                         next enq {}",
                        c.enq_s[i],
                        c.enq_s[i + 1]
                    ),
                ));
            }
        }
        let last_enq = *c.enq_s.last().unwrap();
        if !(last_enq <= c.start_s && c.start_s <= c.done_s) {
            return Err(blame_fail(
                id,
                format!(
                    "final attempt marks out of order: enq {last_enq} start \
                     {} done {}",
                    c.start_s, c.done_s
                ),
            ));
        }
        if !(c.exec_s >= 0.0 && c.exec_s <= c.done_s - c.start_s) {
            return Err(blame_fail(
                id,
                format!(
                    "exec {} outside the dispatch window {}..{}",
                    c.exec_s, c.start_s, c.done_s
                ),
            ));
        }
        if !(c.tx_s >= 0.0) {
            return Err(blame_fail(id, format!("negative tx {}", c.tx_s)));
        }
        // Recompute every segment from the raw marks in the ledger's
        // exact accumulation order and demand bit-equality.
        let mut queue_wasted_s = 0.0;
        let mut retry_wait_s = 0.0;
        for (i, &kill) in c.kill_s.iter().enumerate() {
            queue_wasted_s += kill - c.enq_s[i];
            retry_wait_s += c.enq_s[i + 1] - kill;
        }
        let queue_s = c.start_s - last_enq;
        let batch_wait_s = (c.done_s - c.start_s) - c.exec_s;
        let want = [
            ("queue_wasted_s", queue_wasted_s, c.queue_wasted_s),
            ("retry_wait_s", retry_wait_s, c.retry_wait_s),
            ("queue_s", queue_s, c.queue_s),
            ("batch_wait_s", batch_wait_s, c.batch_wait_s),
        ];
        for (name, want, got) in want {
            if want.to_bits() != got.to_bits() {
                return Err(blame_fail(
                    id,
                    format!("{name} {got} ≠ recomputed {want}"),
                ));
            }
        }
        let total = fold_total(
            queue_wasted_s,
            retry_wait_s,
            queue_s,
            batch_wait_s,
            c.exec_s,
            c.tx_s,
        );
        if total.to_bits() != c.total_s.to_bits() {
            return Err(blame_fail(
                id,
                format!("total {} ≠ re-folded {total}", c.total_s),
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::DeviceKind;
    use crate::obs::{FlightRecorder, TraceMeta};

    fn meta() -> TraceMeta {
        TraceMeta {
            tiers: vec![DeviceKind::Edge, DeviceKind::Cloud],
            waste_budget: Some(0.10),
            init_margin_s: Some(0.010),
        }
    }

    /// Hand-build a tiny, fully consistent trace: one shed request, one
    /// solo completion, one hedged pair (win + cancel).
    fn consistent_trace() -> String {
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        let mut t = 0.0;
        let mut tick = |rec: &mut FlightRecorder, ev| {
            rec.record(t, ev);
            t += 0.001;
        };
        tick(&mut rec, Event::Shed { id: 1 });
        tick(&mut rec, Event::Admit { id: 2, lane: 0, hedged: false });
        tick(
            &mut rec,
            Event::Placement {
                id: 3,
                edge_lane: 0,
                edge_score_s: 0.010,
                cloud_lane: 1,
                cloud_score_s: 0.012,
                chosen: 0,
                margin_s: 0.010 - 0.012,
            },
        );
        tick(&mut rec, Event::Admit { id: 3, lane: 0, hedged: true });
        tick(&mut rec, Event::Admit { id: 3, lane: 1, hedged: true });
        tick(&mut rec, Event::BatchFormed { lane: 0, size: 2, start_s: 0.004 });
        tick(&mut rec, Event::DispatchStart { lane: 0, size: 2, done_s: 0.02 });
        tick(&mut rec, Event::HedgeCancel { id: 3, lane: 1 });
        tick(
            &mut rec,
            Event::Complete { id: 2, lane: 0, kind: CompletionKind::Solo },
        );
        tick(
            &mut rec,
            Event::Complete { id: 3, lane: 0, kind: CompletionKind::HedgeWin },
        );
        rec.window_jsonl()
    }

    #[test]
    fn verifies_a_consistent_trace() {
        let r = verify_trace(&consistent_trace()).unwrap();
        assert_eq!(r.offered, 3);
        assert_eq!(r.admitted, 2);
        assert_eq!(r.shed, 1);
        assert_eq!(r.hedged, 1);
        assert_eq!(r.results, 2);
        assert_eq!(r.completed_solo, 1);
        assert_eq!(r.hedge_wins, 1);
        assert_eq!(r.hedge_cancelled, 1);
        assert_eq!(r.hedge_losses, 0);
        assert_eq!(r.batches, 1);
        assert_eq!(r.batched_requests, 2);
    }

    #[test]
    fn rejects_double_result_and_missing_result() {
        // Duplicate solo completion.
        let mut text = consistent_trace();
        text.push_str(
            "{\"t\":9,\"seq\":10,\"ev\":\"complete\",\"id\":2,\"lane\":0,\
             \"kind\":\"solo\"}\n",
        );
        assert!(verify_trace(&text).is_err());

        // Drop the solo completion: admitted without a result.
        let text: String = consistent_trace()
            .lines()
            .filter(|l| !(l.contains("\"id\":2") && l.contains("complete")))
            .map(|l| format!("{l}\n"))
            .collect();
        // (the seq gap alone must also be caught)
        assert!(verify_trace(&text).is_err());
    }

    #[test]
    fn rejects_truncated_windows() {
        let mut rec = FlightRecorder::new(2);
        rec.set_meta(meta());
        for i in 0..5u64 {
            rec.record(i as f64, Event::Shed { id: i });
        }
        let err = verify_trace(&rec.window_jsonl()).unwrap_err();
        assert!(format!("{err}").contains("truncated"), "{err}");
    }

    #[test]
    fn margin_law_replay_matches_a_real_controller() {
        use crate::scheduler::HedgeBudget;
        let mut ctl = HedgeBudget::new(0.10, 0.010).unwrap();
        let mut rec = FlightRecorder::new(4096);
        rec.set_meta(meta());
        let mut t = 0.0;
        // Mixed useful/wasted stream (every 7th observation wasted, under
        // budget on average so margins wander through the clamp range).
        for i in 0..600u64 {
            let wasted = i % 7 == 0;
            let work = 0.004 + (i % 13) as f64 * 0.001;
            ctl.observe(work, wasted);
            rec.record(
                t,
                Event::MarginAdjust {
                    margin_s: ctl.margin_s(),
                    useful_s: ctl.useful_s(),
                    wasted_s: ctl.wasted_s(),
                },
            );
            t += 0.01;
        }
        let r = verify_trace(&rec.window_jsonl()).unwrap();
        assert_eq!(r.margin_updates, 600);
        assert_eq!(r.final_margin_s.unwrap().to_bits(), ctl.margin_s().to_bits());
        // The inverted window must reconstruct the raw waste mix: 1-in-7
        // of roughly-equal work chunks ⇒ ≈ 14% wasted.
        let frac = r.reconstructed_wasted_frac.unwrap();
        assert!((frac - 1.0 / 7.0).abs() < 0.02, "reconstructed {frac}");
    }

    #[test]
    fn margin_law_replay_catches_tampering() {
        let mut ctl = crate::scheduler::HedgeBudget::new(0.10, 0.010).unwrap();
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        for i in 0..10u64 {
            ctl.observe(0.01, i % 2 == 0);
            let fudge = if i == 7 { 1.0 + 1e-12 } else { 1.0 };
            rec.record(
                i as f64,
                Event::MarginAdjust {
                    margin_s: ctl.margin_s() * fudge,
                    useful_s: ctl.useful_s(),
                    wasted_s: ctl.wasted_s(),
                },
            );
        }
        let err = verify_trace(&rec.window_jsonl()).unwrap_err();
        assert!(format!("{err}").contains("margin-law"), "{err}");
    }

    #[test]
    fn retry_chain_counts_once_in_conservation() {
        // id 5 is admitted on lane 0, killed by the lane-0 outage,
        // re-routed to lane 1 and completes there: one admitted request,
        // one result, despite two Admit events.
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        let mut t = 0.0;
        let mut tick = |rec: &mut FlightRecorder, ev| {
            rec.record(t, ev);
            t += 0.001;
        };
        tick(&mut rec, Event::Admit { id: 5, lane: 0, hedged: false });
        tick(&mut rec, Event::DeviceDown { lane: 0 });
        tick(&mut rec, Event::FailoverReroute { id: 5, from_lane: 0 });
        tick(&mut rec, Event::Admit { id: 5, lane: 1, hedged: false });
        tick(&mut rec, Event::RetryDispatched { id: 5, lane: 1, attempt: 1 });
        tick(&mut rec, Event::BatchFormed { lane: 1, size: 1, start_s: 0.005 });
        tick(&mut rec, Event::DispatchStart { lane: 1, size: 1, done_s: 0.02 });
        tick(
            &mut rec,
            Event::Complete { id: 5, lane: 1, kind: CompletionKind::Solo },
        );
        tick(&mut rec, Event::DeviceUp { lane: 0 });
        let r = verify_trace(&rec.window_jsonl()).unwrap();
        assert_eq!(r.admitted, 1);
        assert_eq!(r.results, 1);
        assert_eq!(r.retried, 1);
        assert_eq!(r.failover_reroutes, 1);
        assert_eq!(r.retry_dispatches, 1);
        assert_eq!(r.device_down, 1);
        assert_eq!(r.device_up, 1);
        assert_eq!(r.in_flight, 0);
        assert_eq!(r.shed_failed, 0);
    }

    #[test]
    fn truncated_tail_counts_open_race_and_in_flight() {
        // A hedged winner whose loser's cancellation fell off the end of
        // the dump, plus a solo request with no completion yet: both are
        // outstanding work, not inconsistencies.
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        let mut t = 0.0;
        let mut tick = |rec: &mut FlightRecorder, ev| {
            rec.record(t, ev);
            t += 0.001;
        };
        tick(&mut rec, Event::Admit { id: 1, lane: 0, hedged: true });
        tick(&mut rec, Event::Admit { id: 1, lane: 1, hedged: true });
        tick(&mut rec, Event::Admit { id: 2, lane: 0, hedged: false });
        tick(&mut rec, Event::BatchFormed { lane: 0, size: 2, start_s: 0.003 });
        tick(&mut rec, Event::DispatchStart { lane: 0, size: 2, done_s: 0.02 });
        tick(
            &mut rec,
            Event::Complete { id: 1, lane: 0, kind: CompletionKind::HedgeWin },
        );
        // ...the HedgeCancel for id 1 lane 1 and the Complete for id 2
        // were cut off the tail of the stream.
        let r = verify_trace(&rec.window_jsonl()).unwrap();
        assert_eq!(r.admitted, 2);
        assert_eq!(r.results, 1);
        assert_eq!(r.open_races, 1);
        assert_eq!(r.in_flight, 1);
        assert_eq!(r.hedge_wins, 1);
        assert_eq!(r.hedge_cancelled, 0);
    }

    #[test]
    fn exhausted_retry_budget_counts_as_terminal_shed() {
        // id 7 is admitted, pulled by a queue-deadline timer, and its
        // retry budget runs out: the harness logs the terminal shed.
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        rec.record(0.0, Event::Admit { id: 7, lane: 0, hedged: false });
        rec.record(0.5, Event::TimeoutFired { id: 7, lane: 0 });
        rec.record(0.6, Event::Shed { id: 7 });
        let r = verify_trace(&rec.window_jsonl()).unwrap();
        assert_eq!(r.admitted, 1);
        assert_eq!(r.results, 0);
        assert_eq!(r.shed_failed, 1);
        assert_eq!(r.in_flight, 0);
        assert_eq!(r.timeouts_fired, 1);

        // Same chain still waiting out its backoff (no shed yet): it is
        // in flight, not shed.
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        rec.record(0.0, Event::Admit { id: 7, lane: 0, hedged: false });
        rec.record(0.5, Event::TimeoutFired { id: 7, lane: 0 });
        let r = verify_trace(&rec.window_jsonl()).unwrap();
        assert_eq!(r.in_flight, 1);
        assert_eq!(r.shed_failed, 0);
    }

    #[test]
    fn admit_on_a_down_lane_is_rejected() {
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        rec.record(0.0, Event::DeviceDown { lane: 0 });
        rec.record(0.1, Event::Admit { id: 1, lane: 0, hedged: false });
        let err = verify_trace(&rec.window_jsonl()).unwrap_err();
        assert!(format!("{err}").contains("while it was down"), "{err}");

        // After recovery the lane admits again.
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        rec.record(0.0, Event::DeviceDown { lane: 0 });
        rec.record(0.1, Event::DeviceUp { lane: 0 });
        rec.record(0.2, Event::Admit { id: 1, lane: 0, hedged: false });
        rec.record(
            0.3,
            Event::BatchFormed { lane: 0, size: 1, start_s: 0.3 },
        );
        rec.record(
            0.4,
            Event::DispatchStart { lane: 0, size: 1, done_s: 0.5 },
        );
        rec.record(
            0.5,
            Event::Complete { id: 1, lane: 0, kind: CompletionKind::Solo },
        );
        verify_trace(&rec.window_jsonl()).unwrap();
    }

    #[test]
    fn summary_counts_by_tag() {
        let j = summarize_trace(&consistent_trace()).unwrap();
        assert_eq!(j.get("events").unwrap().as_i64().unwrap(), 10);
        let by = j.get("by_event").unwrap();
        assert_eq!(by.get("admit").unwrap().as_i64().unwrap(), 3);
        assert_eq!(by.get("complete").unwrap().as_i64().unwrap(), 2);
        assert_eq!(j.get("tiers").unwrap().as_str().unwrap(), "edge,cloud");
        // The health trailer surfaces in the summary without counting
        // as an event.
        assert_eq!(j.get("dropped_prefix").unwrap().as_i64().unwrap(), 0);
        assert_eq!(j.get("ring_dropped").unwrap().as_i64().unwrap(), 0);
        assert!(j.get("sink_ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn trailer_health_lands_in_the_report() {
        let r = verify_trace(&consistent_trace()).unwrap();
        assert_eq!(r.ring_dropped, Some(0));
        assert_eq!(r.sink_ok, Some(true));
        assert_eq!(r.dropped_prefix, 0);
    }

    #[test]
    fn truncated_window_verifies_in_relaxed_mode() {
        let mut rec = FlightRecorder::new(2);
        rec.set_meta(meta());
        for i in 0..5u64 {
            rec.record(i as f64, Event::Shed { id: i });
        }
        let text = rec.window_jsonl();
        assert!(verify_trace(&text).is_err());
        let r = verify_trace_allow_truncated(&text).unwrap();
        assert_eq!(r.events, 2);
        assert_eq!(r.dropped_prefix, 3);
        assert_eq!(r.ring_dropped, Some(3));
        // Relaxed mode still rejects interior gaps.
        let gapped: String = text
            .lines()
            .filter(|l| !l.contains("\"seq\":3"))
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(verify_trace_allow_truncated(&gapped).is_err());
    }

    #[test]
    fn unhealthy_trailer_fails_closed() {
        // Failed sink writes: strict verify refuses, relaxed proceeds.
        let text = consistent_trace().replace("\"sink_ok\":true", "\"sink_ok\":false");
        let err = verify_trace(&text).unwrap_err();
        assert!(format!("{err}").contains("sink"), "{err}");
        let r = verify_trace_allow_truncated(&text).unwrap();
        assert_eq!(r.sink_ok, Some(false));

        // Trailer claims more events than the dump holds (lost tail):
        // strict refuses, relaxed proceeds.
        let text = consistent_trace().replace("\"events\":10", "\"events\":12");
        let err = verify_trace(&text).unwrap_err();
        assert!(format!("{err}").contains("tail"), "{err}");
        verify_trace_allow_truncated(&text).unwrap();

        // Trailer claims fewer events than the dump holds: inconsistent
        // in any mode.
        let text = consistent_trace().replace("\"events\":10", "\"events\":9");
        assert!(verify_trace(&text).is_err());
        assert!(verify_trace_allow_truncated(&text).is_err());
    }

    #[test]
    fn alert_transitions_must_pair_per_lane_and_kind() {
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        rec.record(
            0.0,
            Event::AlertRaised { lane: 0, kind: AlertKind::DeviceSlowdown, score: 30.0 },
        );
        // A different kind on the same lane may overlap.
        rec.record(
            0.1,
            Event::AlertRaised { lane: 0, kind: AlertKind::DeviceCrash, score: 1.0 },
        );
        rec.record(0.2, Event::AlertCleared { lane: 0, kind: AlertKind::DeviceCrash });
        rec.record(
            0.3,
            Event::AlertCleared { lane: 0, kind: AlertKind::DeviceSlowdown },
        );
        let r = verify_trace(&rec.window_jsonl()).unwrap();
        assert_eq!(r.alerts_raised, 2);
        assert_eq!(r.alerts_cleared, 2);

        // Doubled raise without an intervening clear.
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        rec.record(
            0.0,
            Event::AlertRaised { lane: 1, kind: AlertKind::LoadSurge, score: 2.0 },
        );
        rec.record(
            0.1,
            Event::AlertRaised { lane: 1, kind: AlertKind::LoadSurge, score: 3.0 },
        );
        let err = verify_trace(&rec.window_jsonl()).unwrap_err();
        assert!(format!("{err}").contains("raised twice"), "{err}");

        // Clear with no active raise.
        let mut rec = FlightRecorder::new(64);
        rec.set_meta(meta());
        rec.record(0.0, Event::AlertCleared { lane: 2, kind: AlertKind::LinkDegradation });
        let err = verify_trace(&rec.window_jsonl()).unwrap_err();
        assert!(format!("{err}").contains("no active raise"), "{err}");
    }

    #[test]
    fn blame_chains_reverify_bit_exactly() {
        use crate::obs::BlameLedger;
        let mut led = BlameLedger::new();
        led.attempt_start(1, 0.125);
        led.complete(1, 0.375, 0.5, 0.0625, 0.03125);
        led.attempt_start(2, 10.1);
        led.attempt_killed(2, 10.7, true);
        led.attempt_start(2, 10.9);
        led.attempt_killed(2, 11.3, false);
        led.attempt_start(2, 11.45);
        led.complete(2, 11.6, 11.9, 0.2, 0.0);
        let chains = led.into_chains();
        verify_blame(&chains).unwrap();

        // Any bit of drift in a stored segment or the fold is caught.
        let mut bad = chains.clone();
        bad[1].total_s += 1e-12;
        assert!(verify_blame(&bad).is_err());
        let mut bad = chains.clone();
        bad[0].queue_wasted_s = 1e-9;
        assert!(verify_blame(&bad).is_err());
        let mut bad = chains;
        bad[1].kill_s[0] = 12.0; // after the next admission
        assert!(verify_blame(&bad).is_err());
    }
}
