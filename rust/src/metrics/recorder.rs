//! Per-label latency recording for the gateway and experiment drivers.

use std::collections::BTreeMap;

use super::{Histogram, OnlineStats};
use crate::util::Json;

/// Collects latency samples under string labels (e.g. "edge", "cloud",
/// "decision") and renders a JSON report.
#[derive(Debug, Default)]
pub struct LatencyRecorder {
    series: BTreeMap<String, (OnlineStats, Histogram)>,
}

impl LatencyRecorder {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency under `label`. Alloc-free for labels already
    /// seen: the map is probed by `&str` first, so the owned key is only
    /// built on a label's first appearance (the BTreeMap `entry` API
    /// would demand the `String` up front on every call).
    pub fn record(&mut self, label: &str, seconds: f64) {
        if let Some(entry) = self.series.get_mut(label) {
            entry.0.push(seconds);
            entry.1.record(seconds);
            return;
        }
        let mut stats = OnlineStats::new();
        let mut hist = Histogram::latency();
        stats.push(seconds);
        hist.record(seconds);
        self.series.insert(label.to_string(), (stats, hist));
    }

    /// Samples recorded under `label`.
    pub fn count(&self, label: &str) -> u64 {
        self.series.get(label).map_or(0, |(s, _)| s.count())
    }

    /// Mean latency for `label` (NaN when unseen).
    pub fn mean(&self, label: &str) -> f64 {
        self.series.get(label).map_or(f64::NAN, |(s, _)| s.mean())
    }

    /// Summed latency for `label`.
    pub fn sum(&self, label: &str) -> f64 {
        self.series.get(label).map_or(0.0, |(s, _)| s.sum())
    }

    /// 95th-percentile latency for `label` (NaN when unseen).
    pub fn p95(&self, label: &str) -> f64 {
        self.series.get(label).map_or(f64::NAN, |(_, h)| h.p95())
    }

    /// All labels seen.
    pub fn labels(&self) -> Vec<&str> {
        self.series.keys().map(|s| s.as_str()).collect()
    }

    /// JSON report: {label: {count, mean, std, min, max, p50, p95, p99}}.
    pub fn to_json(&self) -> Json {
        let mut out = Json::object();
        for (label, (stats, hist)) in &self.series {
            let s = stats.summary();
            let mut o = Json::object();
            o.set("count", Json::Num(s.count as f64))
                .set("mean_s", Json::Num(s.mean))
                .set("std_s", Json::Num(s.std))
                .set("min_s", Json::Num(s.min))
                .set("max_s", Json::Num(s.max))
                .set("sum_s", Json::Num(s.sum))
                .set("p50_s", Json::Num(hist.p50()))
                .set("p95_s", Json::Num(hist.p95()))
                .set("p99_s", Json::Num(hist.p99()));
            out.set(label, o);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_by_label() {
        let mut r = LatencyRecorder::new();
        for i in 1..=10 {
            r.record("edge", i as f64 * 0.01);
        }
        r.record("cloud", 0.5);
        assert_eq!(r.count("edge"), 10);
        assert_eq!(r.count("cloud"), 1);
        assert!((r.mean("edge") - 0.055).abs() < 1e-12);
        assert!((r.sum("edge") - 0.55).abs() < 1e-12);
        assert_eq!(r.count("nope"), 0);
        assert_eq!(r.labels(), vec!["cloud", "edge"]);
    }

    #[test]
    fn json_report_shape() {
        let mut r = LatencyRecorder::new();
        r.record("x", 0.1);
        let j = r.to_json();
        let x = j.get("x").unwrap();
        assert_eq!(x.get("count").unwrap().as_i64().unwrap(), 1);
        assert!(x.get("p95_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
