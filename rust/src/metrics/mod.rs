//! Metrics: online statistics, histograms and latency recording.
//!
//! Used by the gateway (per-device latency tracking), the simulator
//! (per-policy totals for Table I) and the bench harness.

pub mod histogram;
pub mod recorder;
pub mod stats;

pub use histogram::Histogram;
pub use recorder::LatencyRecorder;
pub use stats::{OnlineStats, Summary};
