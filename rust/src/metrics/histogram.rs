//! Log-bucketed latency histogram (HdrHistogram-lite).
//!
//! Buckets grow geometrically, giving ~4% relative precision over
//! microseconds-to-minutes with a few hundred buckets — good enough for
//! the p50/p95/p99 the gateway and benches report, with O(1) record.

/// Geometric-bucket histogram over positive values (e.g. seconds).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Lower bound of bucket 0.
    floor: f64,
    /// Geometric growth factor between bucket boundaries.
    growth: f64,
    ln_growth: f64,
    counts: Vec<u64>,
    total: u64,
    underflow: u64,
    sum: f64,
}

impl Histogram {
    /// `floor`: smallest resolvable value; `ceil`: largest; `per_decade`:
    /// buckets per 10x range (precision ~ 10^(1/per_decade) - 1).
    pub fn new(floor: f64, ceil: f64, per_decade: usize) -> Self {
        assert!(floor > 0.0 && ceil > floor && per_decade > 0);
        let growth = 10f64.powf(1.0 / per_decade as f64);
        let n = ((ceil / floor).ln() / growth.ln()).ceil() as usize + 1;
        Histogram {
            floor,
            growth,
            ln_growth: growth.ln(),
            counts: vec![0; n],
            total: 0,
            underflow: 0,
            sum: 0.0,
        }
    }

    /// Default latency histogram: 1µs .. 1000s, ~2.3% precision.
    pub fn latency() -> Self {
        Histogram::new(1e-6, 1e3, 100)
    }

    fn bucket(&self, x: f64) -> Option<usize> {
        if x < self.floor {
            return None;
        }
        let idx = ((x / self.floor).ln() / self.ln_growth) as usize;
        Some(idx.min(self.counts.len() - 1))
    }

    /// Record one sample.
    pub fn record(&mut self, x: f64) {
        self.total += 1;
        self.sum += x;
        match self.bucket(x) {
            Some(i) => self.counts[i] += 1,
            None => self.underflow += 1,
        }
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Sum of all samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean of all samples (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            f64::NAN
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile (bucket upper bound), q in [0,1].
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return f64::NAN;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut seen = self.underflow;
        if seen >= target && self.underflow > 0 {
            return self.floor;
        }
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.floor * self.growth.powi(i as i32 + 1);
            }
        }
        self.floor * self.growth.powi(self.counts.len() as i32)
    }

    /// Median (bucket upper bound).
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 95th percentile (bucket upper bound).
    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// 99th percentile (bucket upper bound).
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Merge another histogram of identical shape.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.counts.len(), other.counts.len(), "histogram shapes differ");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.underflow += other.underflow;
        self.sum += other.sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_bounded_error() {
        let mut h = Histogram::latency();
        // 1..=1000 ms uniform
        for i in 1..=1000 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.p50();
        assert!((0.45..0.58).contains(&p50), "p50 {p50}");
        let p99 = h.p99();
        assert!((0.93..1.1).contains(&p99), "p99 {p99}");
        assert!((h.mean() - 0.5005).abs() < 1e-9);
    }

    #[test]
    fn underflow_and_clamp() {
        let mut h = Histogram::new(1.0, 10.0, 10);
        h.record(0.01); // underflow
        h.record(1e9); // clamped to last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.01) >= 1.0);
    }

    #[test]
    fn empty_is_nan() {
        let h = Histogram::latency();
        assert!(h.p50().is_nan());
        assert!(h.mean().is_nan());
    }

    #[test]
    fn merge_adds() {
        let mut a = Histogram::latency();
        let mut b = Histogram::latency();
        for i in 1..=100 {
            a.record(i as f64 * 1e-3);
            b.record(i as f64 * 1e-2);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert!(a.quantile(1.0) >= 0.9);
    }
}
