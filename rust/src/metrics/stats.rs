//! Online (Welford) statistics and batch summaries.

/// Numerically-stable online mean/variance/min/max accumulator.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    sum: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Default::default() }
    }

    /// Absorb one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Absorb another accumulator (parallel merge).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let d = other.mean - self.mean;
        let n = n1 + n2;
        self.m2 += other.m2 + d * d * n1 * n2 / n;
        self.mean = (n1 * self.mean + n2 * other.mean) / n;
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples absorbed.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sum of samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum sample (∞ when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum sample (−∞ when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Snapshot of all statistics.
    pub fn summary(&self) -> Summary {
        Summary {
            count: self.n,
            mean: self.mean(),
            std: self.std(),
            min: if self.n == 0 { f64::NAN } else { self.min },
            max: if self.n == 0 { f64::NAN } else { self.max },
            sum: self.sum,
        }
    }
}

/// Immutable snapshot of an [`OnlineStats`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Samples absorbed.
    pub count: u64,
    /// Mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Sum.
    pub sum: f64,
}

/// Percentile of a *sorted* slice (linear interpolation, p in [0,100]).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_against_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set is 32/7
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert!((s.sum() - 40.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_nan_or_zero() {
        let s = OnlineStats::new();
        assert!(s.mean().is_nan());
        assert_eq!(s.variance(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn merge_equals_concat() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64) * 0.7 - 3.0).collect();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut whole = OnlineStats::new();
        for (i, &x) in xs.iter().enumerate() {
            if i < 37 {
                a.push(x);
            } else {
                b.push(x);
            }
            whole.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.variance() - whole.variance()).abs() < 1e-9);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile_sorted(&xs, 0.0), 1.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 100.0);
        assert!((percentile_sorted(&xs, 95.0) - 95.05).abs() < 1e-9);
        assert!(percentile_sorted(&[], 50.0).is_nan());
        assert_eq!(percentile_sorted(&[7.0], 99.0), 7.0);
    }
}
