//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! offline crate set has no `thiserror`).

use std::fmt;

/// Unified error for every C-NMT subsystem.
#[derive(Debug)]
pub enum Error {
    /// Errors surfaced by the PJRT runtime (`xla` crate).
    Xla(String),

    /// Artifact loading problems (missing files, bad manifest, shape
    /// mismatches between manifest and weights blob).
    Artifact(String),

    /// Configuration / CLI / JSON parsing and validation.
    Config(String),

    /// Corpus generation / loading.
    Corpus(String),

    /// Network trace problems.
    Net(String),

    /// Model fitting (degenerate design matrix, too few samples, ...).
    Fit(String),

    /// Simulation / experiment harness.
    Sim(String),

    /// Gateway / serving / scheduling errors (worker died, queue
    /// closed, ...).
    Serve(String),

    /// Binary workload-trace format problems (bad magic, unsupported
    /// version, CRC mismatch, truncated stream).
    Trace(String),

    /// Filesystem / IO failure (wraps `std::io::Error`).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla/pjrt: {m}"),
            Error::Artifact(m) => write!(f, "artifact: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Corpus(m) => write!(f, "corpus: {m}"),
            Error::Net(m) => write!(f, "net: {m}"),
            Error::Fit(m) => write!(f, "fit: {m}"),
            Error::Sim(m) => write!(f, "sim: {m}"),
            Error::Serve(m) => write!(f, "serve: {m}"),
            Error::Trace(m) => write!(f, "trace: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
