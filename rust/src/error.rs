//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every C-NMT subsystem.
#[derive(Error, Debug)]
pub enum Error {
    /// Errors surfaced by the PJRT runtime (`xla` crate).
    #[error("xla/pjrt: {0}")]
    Xla(String),

    /// Artifact loading problems (missing files, bad manifest, shape
    /// mismatches between manifest and weights blob).
    #[error("artifact: {0}")]
    Artifact(String),

    /// Configuration / CLI / JSON parsing and validation.
    #[error("config: {0}")]
    Config(String),

    /// Corpus generation / loading.
    #[error("corpus: {0}")]
    Corpus(String),

    /// Network trace problems.
    #[error("net: {0}")]
    Net(String),

    /// Model fitting (degenerate design matrix, too few samples, ...).
    #[error("fit: {0}")]
    Fit(String),

    /// Simulation / experiment harness.
    #[error("sim: {0}")]
    Sim(String),

    /// Gateway / serving errors (worker died, queue closed, ...).
    #[error("serve: {0}")]
    Serve(String),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
