//! Prediction stack: everything the C-NMT decision (paper eq. 1/2) needs.
//!
//! * [`fit`] — ordinary least squares (line and plane) with R²/MSE, the
//!   numerical core of the offline characterisation.
//! * [`n2m`] — the linear N→M output-length regressor (paper §II-B,
//!   Fig. 3): `M ≈ γ·N + δ`, fitted on prefiltered corpus pairs.
//! * [`texe`] — per-device linear execution-time model (paper eq. 2):
//!   `T_exe = αN·N + αM·M + β`, fitted on profiled inferences.
//! * [`ttx`] — online transmission-time estimator from timestamped
//!   request/response pairs (paper §II-C).
//! * [`rls`] — recursive-least-squares online refit of the T_exe planes
//!   from observed completions, with a forgetting factor (beyond the
//!   paper: keeps estimates honest under hardware drift).

pub mod estimators;
pub mod fit;
pub mod n2m;
pub mod rls;
pub mod texe;
pub mod ttx;

pub use estimators::LengthEstimator;
pub use fit::{LineFit, PlaneFit};
pub use n2m::N2mRegressor;
pub use rls::RlsPlane;
pub use texe::TexeModel;
pub use ttx::TtxEstimator;
