//! Prediction stack: everything the C-NMT decision (paper eq. 1/2) needs.
//!
//! * [`fit`] — ordinary least squares (line and plane) with R²/MSE, the
//!   numerical core of the offline characterisation.
//! * [`n2m`] — the linear N→M output-length regressor (paper §II-B,
//!   Fig. 3): `M ≈ γ·N + δ`, fitted on prefiltered corpus pairs.
//! * [`texe`] — per-device linear execution-time model (paper eq. 2):
//!   `T_exe = αN·N + αM·M + β`, fitted on profiled inferences.
//! * [`ttx`] — online transmission-time estimation (paper §II-C): the
//!   timestamped EWMA plus the payload-size-aware [`TtxLine`] law.
//! * [`rls`] — recursive-least-squares online refit with a forgetting
//!   factor (beyond the paper: keeps estimates honest under drift) —
//!   [`RlsPlane`] for the T_exe planes from observed completions,
//!   [`RlsLine`] for the size → T_tx law from observed transfers.
//! * [`bank`] — per-device banks of the above for fleet scope:
//!   [`PlaneBank`] (one independently-warmed plane per device) and
//!   [`LineBank`] (one T_tx law per cloud replica's link), so one
//!   drifting replica is re-learned without touching its tier siblings.

pub mod bank;
pub mod estimators;
pub mod fit;
pub mod n2m;
pub mod rls;
pub mod texe;
pub mod ttx;

pub use bank::{LineBank, PlaneBank};
pub use estimators::LengthEstimator;
pub use fit::{LineFit, PlaneFit};
pub use n2m::N2mRegressor;
pub use rls::{RlsLine, RlsPlane};
pub use texe::TexeModel;
pub use ttx::{TtxEstimator, TtxLine};
