//! Ordinary least squares: 1-D line fit and 2-D plane fit with fit-quality
//! scores (R², MSE) matching what the paper reports for its regressions
//! (Fig. 2a, Fig. 3 captions).

use crate::{Error, Result};

/// Result of fitting `y ≈ slope·x + intercept`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LineFit {
    /// Fitted slope.
    pub slope: f64,
    /// Fitted intercept.
    pub intercept: f64,
    /// Coefficient of determination on the fitting data.
    pub r2: f64,
    /// Mean squared error on the fitting data.
    pub mse: f64,
    /// Number of samples fitted.
    pub n_samples: usize,
}

impl LineFit {
    /// Evaluate the fitted line at `x`.
    pub fn predict(&self, x: f64) -> f64 {
        self.slope * x + self.intercept
    }
}

/// Fit a line by OLS. Requires ≥ 2 samples and non-degenerate x.
pub fn fit_line(points: &[(f64, f64)]) -> Result<LineFit> {
    let n = points.len();
    if n < 2 {
        return Err(Error::Fit(format!("line fit needs >= 2 samples, got {n}")));
    }
    let nf = n as f64;
    let (mut sx, mut sy) = (0.0, 0.0);
    for &(x, y) in points {
        sx += x;
        sy += y;
    }
    let (mx, my) = (sx / nf, sy / nf);
    let (mut sxx, mut sxy) = (0.0, 0.0);
    for &(x, y) in points {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    if sxx.abs() < 1e-12 {
        return Err(Error::Fit("degenerate line fit: constant x".into()));
    }
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let (mut ss_res, mut ss_tot) = (0.0, 0.0);
    for &(x, y) in points {
        let e = y - (slope * x + intercept);
        ss_res += e * e;
        ss_tot += (y - my) * (y - my);
    }
    let r2 = if ss_tot < 1e-12 { 1.0 } else { 1.0 - ss_res / ss_tot };
    Ok(LineFit { slope, intercept, r2, mse: ss_res / nf, n_samples: n })
}

/// Result of fitting `z ≈ a·x + b·y + c`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlaneFit {
    /// Coefficient on x.
    pub a: f64,
    /// Coefficient on y.
    pub b: f64,
    /// Intercept.
    pub c: f64,
    /// Coefficient of determination on the fitting data.
    pub r2: f64,
    /// Mean squared error on the fitting data.
    pub mse: f64,
    /// Number of samples fitted.
    pub n_samples: usize,
}

impl PlaneFit {
    /// Evaluate the fitted plane at `(x, y)`.
    pub fn predict(&self, x: f64, y: f64) -> f64 {
        self.a * x + self.b * y + self.c
    }
}

/// Fit a plane by OLS via the 3×3 normal equations.
pub fn fit_plane(points: &[(f64, f64, f64)]) -> Result<PlaneFit> {
    let n = points.len();
    if n < 3 {
        return Err(Error::Fit(format!("plane fit needs >= 3 samples, got {n}")));
    }
    // Normal equations A^T A w = A^T z with rows [x, y, 1].
    let mut ata = [[0.0f64; 3]; 3];
    let mut atz = [0.0f64; 3];
    for &(x, y, z) in points {
        let row = [x, y, 1.0];
        for i in 0..3 {
            for j in 0..3 {
                ata[i][j] += row[i] * row[j];
            }
            atz[i] += row[i] * z;
        }
    }
    let w = solve3(ata, atz)
        .ok_or_else(|| Error::Fit("degenerate plane fit (singular normal equations)".into()))?;
    let (a, b, c) = (w[0], w[1], w[2]);
    let mz: f64 = points.iter().map(|p| p.2).sum::<f64>() / n as f64;
    let (mut ss_res, mut ss_tot) = (0.0, 0.0);
    for &(x, y, z) in points {
        let e = z - (a * x + b * y + c);
        ss_res += e * e;
        ss_tot += (z - mz) * (z - mz);
    }
    let r2 = if ss_tot < 1e-12 { 1.0 } else { 1.0 - ss_res / ss_tot };
    Ok(PlaneFit { a, b, c, r2, mse: ss_res / n as f64, n_samples: n })
}

/// Solve a 3×3 linear system by Gaussian elimination with partial
/// pivoting. Returns `None` when singular.
fn solve3(mut m: [[f64; 3]; 3], mut v: [f64; 3]) -> Option<[f64; 3]> {
    for col in 0..3 {
        // Pivot.
        let piv = (col..3).max_by(|&i, &j| {
            m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap()
        })?;
        if m[piv][col].abs() < 1e-12 {
            return None;
        }
        m.swap(col, piv);
        v.swap(col, piv);
        // Eliminate below.
        for row in col + 1..3 {
            let f = m[row][col] / m[col][col];
            for k in col..3 {
                m[row][k] -= f * m[col][k];
            }
            v[row] -= f * v[col];
        }
    }
    // Back substitution.
    let mut out = [0.0; 3];
    for row in (0..3).rev() {
        let mut acc = v[row];
        for k in row + 1..3 {
            acc -= m[row][k] * out[k];
        }
        out[row] = acc / m[row][row];
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn line_recovers_planted_coefficients() {
        let mut rng = Rng::new(1);
        let pts: Vec<(f64, f64)> = (0..2000)
            .map(|_| {
                let x = rng.uniform(0.0, 60.0);
                (x, 0.82 * x + 0.6 + rng.normal_ms(0.0, 0.5))
            })
            .collect();
        let f = fit_line(&pts).unwrap();
        assert!((f.slope - 0.82).abs() < 0.01, "slope {}", f.slope);
        assert!((f.intercept - 0.6).abs() < 0.2, "intercept {}", f.intercept);
        assert!(f.r2 > 0.99, "r2 {}", f.r2);
        assert!((f.mse - 0.25).abs() < 0.05, "mse {}", f.mse);
    }

    #[test]
    fn line_exact_fit() {
        let pts: Vec<(f64, f64)> =
            (0..10).map(|i| (i as f64, 3.0 * i as f64 - 2.0)).collect();
        let f = fit_line(&pts).unwrap();
        assert!((f.slope - 3.0).abs() < 1e-10);
        assert!((f.intercept + 2.0).abs() < 1e-10);
        assert!((f.r2 - 1.0).abs() < 1e-12);
        assert!(f.mse < 1e-18);
        assert!((f.predict(100.0) - 298.0).abs() < 1e-8);
    }

    #[test]
    fn line_rejects_degenerate() {
        assert!(fit_line(&[(1.0, 2.0)]).is_err());
        assert!(fit_line(&[(1.0, 2.0), (1.0, 3.0), (1.0, 4.0)]).is_err());
    }

    #[test]
    fn plane_recovers_planted_coefficients() {
        let mut rng = Rng::new(2);
        let pts: Vec<(f64, f64, f64)> = (0..5000)
            .map(|_| {
                let x = rng.uniform(1.0, 64.0);
                let y = rng.uniform(1.0, 64.0);
                (x, y, 0.0017 * x + 0.0092 * y + 0.031 + rng.normal_ms(0.0, 0.002))
            })
            .collect();
        let f = fit_plane(&pts).unwrap();
        assert!((f.a - 0.0017).abs() < 2e-4, "a {}", f.a);
        assert!((f.b - 0.0092).abs() < 2e-4, "b {}", f.b);
        assert!((f.c - 0.031).abs() < 5e-4, "c {}", f.c);
        assert!(f.r2 > 0.95, "r2 {}", f.r2);
    }

    #[test]
    fn plane_handles_zero_coefficient() {
        // Transformer-like: T independent of N.
        let mut rng = Rng::new(3);
        let pts: Vec<(f64, f64, f64)> = (0..3000)
            .map(|_| {
                let x = rng.uniform(1.0, 64.0);
                let y = rng.uniform(1.0, 64.0);
                (x, y, 0.012 * y + 0.05 + rng.normal_ms(0.0, 0.001))
            })
            .collect();
        let f = fit_plane(&pts).unwrap();
        assert!(f.a.abs() < 5e-5, "a {}", f.a);
        assert!((f.b - 0.012).abs() < 1e-4);
    }

    #[test]
    fn plane_rejects_degenerate() {
        assert!(fit_plane(&[(1.0, 1.0, 1.0), (2.0, 2.0, 2.0)]).is_err());
        // Collinear x = y.
        let pts: Vec<(f64, f64, f64)> =
            (0..50).map(|i| (i as f64, i as f64, i as f64)).collect();
        assert!(fit_plane(&pts).is_err());
    }

    #[test]
    fn solve3_identity() {
        let m = [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]];
        let v = [4.0, 5.0, 6.0];
        assert_eq!(solve3(m, v).unwrap(), [4.0, 5.0, 6.0]);
    }

    #[test]
    fn solve3_needs_pivoting() {
        // Zero on the diagonal forces a row swap.
        let m = [[0.0, 1.0, 0.0], [1.0, 0.0, 0.0], [0.0, 0.0, 2.0]];
        let v = [3.0, 7.0, 8.0];
        let x = solve3(m, v).unwrap();
        assert!((x[0] - 7.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
        assert!((x[2] - 4.0).abs() < 1e-12);
    }
}
