//! Online transmission-time estimation (paper §II-C).
//!
//! "As in [11], we attach timestamps to each inference request/response
//! sent to/from the cloud to obtain a recent estimate of T_tx." The
//! estimator keeps an exponentially-weighted moving average of observed
//! round-trip samples, with an explicit notion of *staleness*: if no
//! offload happened recently the estimate decays toward a configurable
//! prior weight — this models the paper's remark that sporadic traffic
//! renders the timestamp mechanism ineffective on end-nodes (and why the
//! gateway, which aggregates many end-nodes, works).

/// Payload-size-aware transmission-time law: `T̂_tx = a·size + b`
/// (size in tokens transferred — source out, translation back).
///
/// The plain EWMA ([`TtxEstimator`]) collapses every transfer to one
/// scalar, so a burst of long offloads inflates the estimate short
/// requests then pay. This line keeps the size dependence (bandwidth
/// term `a`, latency floor `b`); the adaptive scheduler refits it
/// online from observed transfers via [`crate::predictor::RlsLine`] —
/// the same machinery that refits the T_exe planes — and installs it on
/// the router ([`crate::coordinator::Router::set_ttx_line`]), replacing
/// the EWMA once warmed up.
#[derive(Debug, Clone, Copy)]
pub struct TtxLine {
    /// Seconds per transferred token (inverse bandwidth).
    pub slope: f64,
    /// Fixed per-transfer cost (propagation + protocol floor), seconds.
    pub intercept: f64,
}

impl TtxLine {
    /// Estimated transfer seconds for a payload of `size_tokens`
    /// (clamped at 0 like every other latency estimate).
    pub fn estimate(&self, size_tokens: f64) -> f64 {
        (self.slope * size_tokens + self.intercept).max(0.0)
    }
}

/// EWMA-based T_tx estimator.
#[derive(Debug, Clone)]
pub struct TtxEstimator {
    /// Smoothing factor per observation (0 < alpha <= 1).
    alpha: f64,
    /// Current estimate (seconds); None until first observation.
    estimate: Option<f64>,
    /// Time of the most recent observation.
    last_obs_time: f64,
    /// Observations seen.
    count: u64,
}

impl TtxEstimator {
    /// EWMA estimator with smoothing factor `alpha` ∈ (0, 1].
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0);
        TtxEstimator { alpha, estimate: None, last_obs_time: f64::NEG_INFINITY, count: 0 }
    }

    /// Default smoothing used by the paper-analogous setup.
    pub fn default_paper() -> Self {
        TtxEstimator::new(0.3)
    }

    /// Record a measured round-trip `rtt_s` observed at time `now_s`
    /// (derived from request/response timestamps).
    pub fn observe(&mut self, now_s: f64, rtt_s: f64) {
        let rtt_s = rtt_s.max(0.0);
        self.estimate = Some(match self.estimate {
            None => rtt_s,
            Some(e) => e + self.alpha * (rtt_s - e),
        });
        self.last_obs_time = now_s;
        self.count += 1;
    }

    /// Current T_tx estimate. `fallback` is used before any observation
    /// (e.g. a configured prior RTT).
    pub fn estimate_or(&self, fallback: f64) -> f64 {
        self.estimate.unwrap_or(fallback)
    }

    /// Whether the newest observation is older than `max_age_s` at `now_s`.
    pub fn is_stale(&self, now_s: f64, max_age_s: f64) -> bool {
        self.count == 0 || now_s - self.last_obs_time > max_age_s
    }

    /// Observations absorbed so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Clock time of the last observation (−∞ before any).
    pub fn last_observation_time(&self) -> f64 {
        self.last_obs_time
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_observation_sets_estimate() {
        let mut e = TtxEstimator::new(0.3);
        assert_eq!(e.estimate_or(0.5), 0.5);
        e.observe(0.0, 0.1);
        assert!((e.estimate_or(0.5) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges_to_step_change() {
        let mut e = TtxEstimator::new(0.3);
        for i in 0..50 {
            e.observe(i as f64, 0.04);
        }
        assert!((e.estimate_or(0.0) - 0.04).abs() < 1e-6);
        // RTT jumps to 0.4; estimate should move most of the way within
        // ~10 observations (1 - 0.7^10 ≈ 0.97).
        for i in 50..60 {
            e.observe(i as f64, 0.4);
        }
        let est = e.estimate_or(0.0);
        assert!(est > 0.35 && est < 0.41, "est {est}");
    }

    #[test]
    fn tracks_but_smooths_noise() {
        // Alternating 0.1/0.3 should hover near 0.2, not bounce to rails.
        let mut e = TtxEstimator::new(0.2);
        for i in 0..200 {
            e.observe(i as f64, if i % 2 == 0 { 0.1 } else { 0.3 });
        }
        let est = e.estimate_or(0.0);
        assert!((est - 0.2).abs() < 0.05, "est {est}");
    }

    #[test]
    fn staleness() {
        let mut e = TtxEstimator::new(0.3);
        assert!(e.is_stale(0.0, 10.0));
        e.observe(100.0, 0.05);
        assert!(!e.is_stale(105.0, 10.0));
        assert!(e.is_stale(111.0, 10.0));
        assert_eq!(e.count(), 1);
    }

    #[test]
    fn negative_samples_clamped() {
        let mut e = TtxEstimator::new(1.0);
        e.observe(0.0, -5.0);
        assert_eq!(e.estimate_or(1.0), 0.0);
    }

    #[test]
    fn line_is_affine_in_size_and_clamped() {
        let l = TtxLine { slope: 1e-4, intercept: 0.03 };
        assert!((l.estimate(0.0) - 0.03).abs() < 1e-15);
        assert!((l.estimate(100.0) - 0.04).abs() < 1e-15);
        // A (transiently mis-fit) negative line never yields a negative
        // transfer time.
        let bad = TtxLine { slope: -1.0, intercept: 0.01 };
        assert_eq!(bad.estimate(10.0), 0.0);
    }
}
