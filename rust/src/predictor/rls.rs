//! Recursive-least-squares online refit of the T_exe planes.
//!
//! The paper fits `T_exe = αN·N + αM·M + β` **once, offline** (eq. 2,
//! "once-for-all characterisation"). Under drift — thermal throttling, a
//! noisy neighbour stealing the edge GPU, a cloud autoscaler swap — the
//! offline plane goes stale, and every estimate built on it (the eq. 1
//! comparison *and* the scheduler's expected-wait backlog) misroutes.
//!
//! [`RlsPlane`] wraps a [`TexeModel`] with exponentially-forgetting
//! recursive least squares over the regressor `x = [n, m, 1]`: each
//! observed completion `(n, m, t)` updates the coefficient estimate in
//! O(1) (a 3×3 covariance update — no refit over history), and a
//! forgetting factor λ < 1 discounts old samples with time constant
//! ≈ 1/(1−λ) observations, so the plane tracks drifting hardware. With
//! λ = 1 and a diffuse prior it converges to the ordinary
//! least-squares fit ([`crate::predictor::fit::fit_plane`]).
//!
//! Update equations (standard RLS; `P` is the scaled parameter
//! covariance, kept symmetric by construction):
//!
//! ```text
//! k = P·x / (λ + xᵀ·P·x)
//! w ← w + k·(t − xᵀ·w)
//! P ← (P − k·(P·x)ᵀ) / λ
//! ```
//!
//! # Example
//!
//! ```
//! use cnmt::predictor::{RlsPlane, TexeModel};
//!
//! // Start from an offline fit, then observe a device that is exactly
//! // 2x slower than the prior believes.
//! let prior = TexeModel::from_coeffs(0.001, 0.003, 0.006);
//! let truth = TexeModel::from_coeffs(0.002, 0.006, 0.012);
//! let mut rls = RlsPlane::new(prior, 0.99, 1.0).unwrap();
//! for i in 0..400usize {
//!     let (n, m) = (1 + i % 40, 1 + (i * 7) % 40);
//!     rls.observe(n as f64, m as f64, truth.estimate(n, m as f64));
//! }
//! let refit = rls.model();
//! assert!((refit.alpha_m - truth.alpha_m).abs() < 1e-4);
//! ```

use crate::util::Json;
use crate::{Error, Result};

use super::texe::TexeModel;
use super::ttx::TtxLine;

/// Online (n, m) → T_exe plane: a [`TexeModel`] kept fresh by
/// exponentially-forgetting recursive least squares.
#[derive(Debug, Clone, Copy)]
pub struct RlsPlane {
    /// Coefficients `[alpha_n, alpha_m, beta]`.
    w: [f64; 3],
    /// Scaled parameter covariance (symmetric 3×3).
    p: [[f64; 3]; 3],
    lambda: f64,
    count: u64,
}

impl RlsPlane {
    /// Start from an offline-fitted plane. `lambda` ∈ (0, 1] is the
    /// forgetting factor (1 = never forget); `prior_var` > 0 scales the
    /// initial covariance — small keeps the offline fit sticky, large
    /// lets the first observations dominate.
    pub fn new(init: TexeModel, lambda: f64, prior_var: f64) -> Result<Self> {
        if !(lambda > 0.0 && lambda <= 1.0) {
            return Err(Error::Fit(format!(
                "RLS forgetting factor {lambda} outside (0, 1]"
            )));
        }
        if !(prior_var > 0.0) || !prior_var.is_finite() {
            return Err(Error::Fit(format!(
                "RLS prior variance {prior_var} must be finite and > 0"
            )));
        }
        let mut p = [[0.0f64; 3]; 3];
        p[0][0] = prior_var;
        p[1][1] = prior_var;
        p[2][2] = prior_var;
        Ok(RlsPlane {
            w: [init.alpha_n, init.alpha_m, init.beta],
            p,
            lambda,
            count: 0,
        })
    }

    /// Feed one observed completion: input length `n`, realised output
    /// length `m`, measured execution seconds `t_s`. O(1).
    pub fn observe(&mut self, n: f64, m: f64, t_s: f64) {
        if !(n.is_finite() && m.is_finite() && t_s.is_finite()) {
            return; // never poison the covariance with NaN/inf
        }
        let x = [n, m, 1.0];
        // px = P·x
        let mut px = [0.0f64; 3];
        for i in 0..3 {
            px[i] = self.p[i][0] * x[0] + self.p[i][1] * x[1] + self.p[i][2] * x[2];
        }
        let denom = self.lambda + x[0] * px[0] + x[1] * px[1] + x[2] * px[2];
        let k = [px[0] / denom, px[1] / denom, px[2] / denom];
        let err = t_s - (x[0] * self.w[0] + x[1] * self.w[1] + x[2] * self.w[2]);
        for i in 0..3 {
            self.w[i] += k[i] * err;
        }
        // P ← (P − k·pxᵀ) / λ  (symmetric since k ∝ px).
        for i in 0..3 {
            for j in 0..3 {
                self.p[i][j] = (self.p[i][j] - k[i] * px[j]) / self.lambda;
            }
        }
        self.count += 1;
    }

    /// Current coefficient estimate as a [`TexeModel`] (fit-quality
    /// fields are NaN — RLS tracks coefficients, not residuals).
    pub fn model(&self) -> TexeModel {
        TexeModel::from_coeffs(self.w[0], self.w[1], self.w[2])
    }

    /// Estimate T_exe at (n, m) from the current coefficients (clamped
    /// at 0 like [`TexeModel::estimate`]).
    pub fn estimate(&self, n: usize, m: f64) -> f64 {
        self.model().estimate(n, m)
    }

    /// Observations absorbed so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// The configured forgetting factor.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Serialise the current coefficients (for refit reporting).
    pub fn to_json(&self) -> Json {
        let mut o = self.model().to_json();
        o.set("lambda", Json::Num(self.lambda))
            .set("observations", Json::Num(self.count as f64));
        o
    }
}

/// Online scalar line `x → t` (regressor `[x, 1]`): the 2×2 analogue of
/// [`RlsPlane`], used to refit the payload-size → T_tx law
/// ([`TtxLine`]) from observed transfers — the ROADMAP follow-on that
/// retires the plain EWMA once enough offloads have been timed.
///
/// Same update equations as the plane (standard forgetting-factor RLS),
/// O(1) per observation, `Copy`, never poisoned by non-finite samples.
#[derive(Debug, Clone, Copy)]
pub struct RlsLine {
    /// Coefficients `[slope, intercept]`.
    w: [f64; 2],
    /// Scaled parameter covariance (symmetric 2×2).
    p: [[f64; 2]; 2],
    lambda: f64,
    count: u64,
}

impl RlsLine {
    /// Start from a prior line. `lambda` ∈ (0, 1] is the forgetting
    /// factor; `prior_var` > 0 scales the initial covariance (small =
    /// sticky prior, large = data-dominated).
    pub fn new(init: TtxLine, lambda: f64, prior_var: f64) -> Result<Self> {
        if !(lambda > 0.0 && lambda <= 1.0) {
            return Err(Error::Fit(format!(
                "RLS forgetting factor {lambda} outside (0, 1]"
            )));
        }
        if !(prior_var > 0.0) || !prior_var.is_finite() {
            return Err(Error::Fit(format!(
                "RLS prior variance {prior_var} must be finite and > 0"
            )));
        }
        Ok(RlsLine {
            w: [init.slope, init.intercept],
            p: [[prior_var, 0.0], [0.0, prior_var]],
            lambda,
            count: 0,
        })
    }

    /// Feed one observed transfer: payload size `x` (tokens moved) and
    /// measured transfer seconds `t_s`. O(1).
    pub fn observe(&mut self, x: f64, t_s: f64) {
        if !(x.is_finite() && t_s.is_finite()) {
            return; // never poison the covariance with NaN/inf
        }
        let xv = [x, 1.0];
        let px = [
            self.p[0][0] * xv[0] + self.p[0][1] * xv[1],
            self.p[1][0] * xv[0] + self.p[1][1] * xv[1],
        ];
        let denom = self.lambda + xv[0] * px[0] + xv[1] * px[1];
        let k = [px[0] / denom, px[1] / denom];
        let err = t_s - (xv[0] * self.w[0] + xv[1] * self.w[1]);
        self.w[0] += k[0] * err;
        self.w[1] += k[1] * err;
        for i in 0..2 {
            for j in 0..2 {
                self.p[i][j] = (self.p[i][j] - k[i] * px[j]) / self.lambda;
            }
        }
        self.count += 1;
    }

    /// Current coefficient estimate as a [`TtxLine`].
    pub fn line(&self) -> TtxLine {
        TtxLine { slope: self.w[0], intercept: self.w[1] }
    }

    /// Estimated transfer seconds for payload size `x` (clamped at 0).
    pub fn estimate(&self, x: f64) -> f64 {
        self.line().estimate(x)
    }

    /// Observations absorbed so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// The configured forgetting factor.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// Serialise the current coefficients (for refit reporting).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("slope", Json::Num(self.w[0]))
            .set("intercept", Json::Num(self.w[1]))
            .set("lambda", Json::Num(self.lambda))
            .set("observations", Json::Num(self.count as f64));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn grid_samples(
        truth: &TexeModel,
        noise: f64,
        count: usize,
        seed: u64,
    ) -> Vec<(f64, f64, f64)> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| {
                let n = (1 + rng.usize(61)) as f64;
                let m = (1 + rng.usize(61)) as f64;
                let t = truth.estimate(n as usize, m) + rng.normal_ms(0.0, noise);
                (n, m, t.max(0.0))
            })
            .collect()
    }

    #[test]
    fn converges_to_planted_plane_under_stationary_noise() {
        let truth = TexeModel::from_coeffs(0.0012, 0.003, 0.006);
        let prior = TexeModel::from_coeffs(0.0, 0.0, 0.0);
        let mut rls = RlsPlane::new(prior, 1.0, 1e4).unwrap();
        for (n, m, t) in grid_samples(&truth, 1e-4, 4000, 11) {
            rls.observe(n, m, t);
        }
        let fit = rls.model();
        assert!((fit.alpha_n - truth.alpha_n).abs() < 2e-5, "alpha_n {}", fit.alpha_n);
        assert!((fit.alpha_m - truth.alpha_m).abs() < 2e-5, "alpha_m {}", fit.alpha_m);
        assert!((fit.beta - truth.beta).abs() < 1e-3, "beta {}", fit.beta);
        assert_eq!(rls.count(), 4000);
    }

    #[test]
    fn forgetting_tracks_a_step_change() {
        // Plane doubles mid-stream: with lambda < 1 the estimate must
        // land on the new plane; the prior plane must be forgotten.
        let before = TexeModel::from_coeffs(0.001, 0.003, 0.006);
        let after = TexeModel::from_coeffs(0.002, 0.006, 0.012);
        let mut rls = RlsPlane::new(before, 0.99, 1.0).unwrap();
        for (n, m, t) in grid_samples(&before, 1e-5, 500, 21) {
            rls.observe(n, m, t);
        }
        for (n, m, t) in grid_samples(&after, 1e-5, 1500, 22) {
            rls.observe(n, m, t);
        }
        let fit = rls.model();
        assert!(
            (fit.alpha_m - after.alpha_m).abs() < 2e-4,
            "alpha_m {} vs {}",
            fit.alpha_m,
            after.alpha_m
        );
        // Midpoint check: the estimate at a typical operating point is
        // much closer to the new plane than the old one.
        let est = rls.estimate(20, 20.0);
        let (t_new, t_old) = (after.estimate(20, 20.0), before.estimate(20, 20.0));
        assert!((est - t_new).abs() < 0.2 * (t_new - t_old).abs());
    }

    #[test]
    fn no_forgetting_matches_batch_ols_closely() {
        let truth = TexeModel::from_coeffs(0.0017, 0.0092, 0.031);
        let samples = grid_samples(&truth, 2e-3, 3000, 31);
        let mut rls = RlsPlane::new(TexeModel::from_coeffs(0.0, 0.0, 0.0), 1.0, 1e6).unwrap();
        for &(n, m, t) in &samples {
            rls.observe(n, m, t);
        }
        let ols = crate::predictor::fit::fit_plane(&samples).unwrap();
        let fit = rls.model();
        assert!((fit.alpha_n - ols.a).abs() < 1e-5, "{} vs {}", fit.alpha_n, ols.a);
        assert!((fit.alpha_m - ols.b).abs() < 1e-5, "{} vs {}", fit.alpha_m, ols.b);
        assert!((fit.beta - ols.c).abs() < 1e-3, "{} vs {}", fit.beta, ols.c);
    }

    #[test]
    fn sticky_prior_resists_single_outliers() {
        let prior = TexeModel::from_coeffs(0.001, 0.003, 0.006);
        let mut rls = RlsPlane::new(prior, 1.0, 1e-8).unwrap();
        rls.observe(30.0, 30.0, 100.0); // absurd outlier
        let fit = rls.model();
        assert!((fit.alpha_m - prior.alpha_m).abs() < 1e-3, "alpha_m {}", fit.alpha_m);
    }

    #[test]
    fn rejects_bad_configuration_and_ignores_non_finite_samples() {
        let t = TexeModel::from_coeffs(0.0, 0.0, 0.0);
        assert!(RlsPlane::new(t, 0.0, 1.0).is_err());
        assert!(RlsPlane::new(t, 1.1, 1.0).is_err());
        assert!(RlsPlane::new(t, 0.9, 0.0).is_err());
        assert!(RlsPlane::new(t, 0.9, f64::NAN).is_err());
        let mut rls = RlsPlane::new(t, 0.99, 1.0).unwrap();
        rls.observe(f64::NAN, 1.0, 1.0);
        rls.observe(1.0, f64::INFINITY, 1.0);
        assert_eq!(rls.count(), 0);
    }

    #[test]
    fn line_converges_to_planted_law_and_tracks_steps() {
        // Stationary: recover a planted bandwidth/latency pair from
        // noisy transfer timings.
        let truth = TtxLine { slope: 2e-4, intercept: 0.031 };
        let mut rls =
            RlsLine::new(TtxLine { slope: 0.0, intercept: 0.0 }, 1.0, 1e4).unwrap();
        let mut rng = Rng::new(0x77B1);
        for _ in 0..4000 {
            let size = (2 + rng.usize(123)) as f64;
            let t = (truth.estimate(size) + rng.normal_ms(0.0, 1e-4)).max(0.0);
            rls.observe(size, t);
        }
        let fit = rls.line();
        assert!((fit.slope - truth.slope).abs() < 1e-5, "slope {}", fit.slope);
        assert!(
            (fit.intercept - truth.intercept).abs() < 1e-3,
            "intercept {}",
            fit.intercept
        );
        // Step change (network degrades 3x): forgetting must re-learn.
        let after = TtxLine { slope: 6e-4, intercept: 0.093 };
        let mut rls =
            RlsLine::new(TtxLine { slope: 0.0, intercept: 0.0 }, 0.99, 1e4).unwrap();
        for _ in 0..500 {
            let size = (2 + rng.usize(123)) as f64;
            rls.observe(size, truth.estimate(size));
        }
        for _ in 0..1500 {
            let size = (2 + rng.usize(123)) as f64;
            rls.observe(size, after.estimate(size));
        }
        let est = rls.estimate(60.0);
        let (t_new, t_old) = (after.estimate(60.0), truth.estimate(60.0));
        assert!(
            (est - t_new).abs() < 0.1 * (t_new - t_old).abs(),
            "line stuck near the stale law: {est} vs new {t_new}"
        );
        assert_eq!(rls.count(), 2000);
    }

    #[test]
    fn line_rejects_bad_config_and_ignores_non_finite() {
        let l = TtxLine { slope: 0.0, intercept: 0.0 };
        assert!(RlsLine::new(l, 0.0, 1.0).is_err());
        assert!(RlsLine::new(l, 1.5, 1.0).is_err());
        assert!(RlsLine::new(l, 0.9, -1.0).is_err());
        let mut rls = RlsLine::new(l, 0.99, 1.0).unwrap();
        rls.observe(f64::NAN, 1.0);
        rls.observe(1.0, f64::INFINITY);
        assert_eq!(rls.count(), 0);
        let j = rls.to_json();
        assert!(j.get("slope").is_ok());
        assert!(j.get("observations").is_ok());
    }

    #[test]
    fn json_reports_coefficients_and_count() {
        let mut rls =
            RlsPlane::new(TexeModel::from_coeffs(0.001, 0.002, 0.003), 0.98, 1.0).unwrap();
        rls.observe(10.0, 10.0, 0.05);
        let j = rls.to_json();
        assert!((j.get("lambda").unwrap().as_f64().unwrap() - 0.98).abs() < 1e-12);
        assert!((j.get("observations").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-12);
        assert!(j.get("alpha_m").is_ok());
    }
}
