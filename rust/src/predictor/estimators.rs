//! Output-length estimator zoo — the paper's future work ("more advanced
//! output length estimation methods") implemented and ablated.
//!
//! Everything here maps `N → M̂` and can replace the linear regressor in
//! the C-NMT decision. `cnmt experiment ablation` compares them on the
//! Table-I harness (EXPERIMENTS.md §Ablations):
//!
//! * [`LengthEstimator::Constant`] — the Naive baseline's corpus mean.
//! * [`LengthEstimator::Linear`] — the paper's `γ·N + δ` (eq. 2).
//! * [`LengthEstimator::Bucket`] — per-N empirical conditional mean
//!   (non-parametric; falls back to linear outside observed support).
//! * [`LengthEstimator::Quantile`] — per-N empirical q-quantile:
//!   deliberately over-estimates M when the offload penalty is
//!   asymmetric (mis-keeping a long request at the edge costs more than
//!   mis-offloading a short one).
//! * [`LengthEstimator::Poly2`] — degree-2 least squares, tests whether
//!   any curvature in E[M|N] is worth modelling.

use crate::corpus::SentencePair;
use crate::{Error, Result};

use super::fit::fit_line;
use super::n2m::N2mRegressor;

/// A fitted N→M estimator.
#[derive(Debug, Clone)]
pub enum LengthEstimator {
    /// The Naive baseline: a single dataset-mean M̂.
    Constant {
        /// Mean output length of the fitting pairs.
        mean_m: f64,
    },
    /// The paper's linear regressor (γ·N + δ).
    Linear(N2mRegressor),
    /// Per-N empirical mean with a linear fallback.
    Bucket {
        /// Mean M for N = index + 1 (None where unobserved/sparse).
        means: Vec<Option<f64>>,
        /// Linear estimator used where the bucket is empty.
        fallback: N2mRegressor,
    },
    /// Per-N empirical quantile with a linear fallback.
    Quantile {
        /// q-quantile of M for N = index + 1.
        quantiles: Vec<Option<f64>>,
        /// The quantile fitted (0 = min, 0.5 = median, 1 = max).
        q: f64,
        /// Linear estimator used where the bucket is empty.
        fallback: N2mRegressor,
    },
    /// Quadratic fit M̂ = a·N² + b·N + c.
    Poly2 {
        /// Quadratic coefficient.
        a: f64,
        /// Linear coefficient.
        b: f64,
        /// Intercept.
        c: f64,
    },
}

/// Minimum samples per N bucket before trusting its empirical statistic.
const MIN_BUCKET: usize = 20;
const N_CAP: usize = 64;

impl LengthEstimator {
    /// Short identifier used in reports.
    pub fn id(&self) -> &'static str {
        match self {
            LengthEstimator::Constant { .. } => "constant",
            LengthEstimator::Linear(_) => "linear",
            LengthEstimator::Bucket { .. } => "bucket",
            LengthEstimator::Quantile { .. } => "quantile",
            LengthEstimator::Poly2 { .. } => "poly2",
        }
    }

    /// Predict the output length for input length `n` (≥ 1.0).
    pub fn predict(&self, n: usize) -> f64 {
        let v = match self {
            LengthEstimator::Constant { mean_m } => *mean_m,
            LengthEstimator::Linear(reg) => reg.predict(n),
            LengthEstimator::Bucket { means, fallback } => means
                .get(n.saturating_sub(1))
                .copied()
                .flatten()
                .unwrap_or_else(|| fallback.predict(n)),
            LengthEstimator::Quantile { quantiles, fallback, .. } => quantiles
                .get(n.saturating_sub(1))
                .copied()
                .flatten()
                .unwrap_or_else(|| fallback.predict(n)),
            LengthEstimator::Poly2 { a, b, c } => {
                let x = n as f64;
                a * x * x + b * x + c
            }
        };
        v.max(1.0)
    }

    // ------------------------------------------------------------ fitting

    /// Fit the constant (dataset mean) estimator.
    pub fn fit_constant(pairs: &[SentencePair]) -> Result<Self> {
        if pairs.is_empty() {
            return Err(Error::Fit("constant estimator: empty input".into()));
        }
        let mean_m =
            pairs.iter().map(|p| p.m_real as f64).sum::<f64>() / pairs.len() as f64;
        Ok(LengthEstimator::Constant { mean_m })
    }

    /// Fit the linear γ/δ estimator on raw pairs.
    pub fn fit_linear(pairs: &[SentencePair]) -> Result<Self> {
        Ok(LengthEstimator::Linear(N2mRegressor::fit_raw(pairs)?))
    }

    fn group_by_n(pairs: &[SentencePair]) -> Vec<Vec<f64>> {
        let mut buckets: Vec<Vec<f64>> = vec![Vec::new(); N_CAP];
        for p in pairs {
            if (1..=N_CAP).contains(&p.n()) {
                buckets[p.n() - 1].push(p.m_real as f64);
            }
        }
        buckets
    }

    /// Fit the per-N bucket-mean estimator.
    pub fn fit_bucket(pairs: &[SentencePair]) -> Result<Self> {
        let fallback = N2mRegressor::fit_raw(pairs)?;
        let means = Self::group_by_n(pairs)
            .into_iter()
            .map(|b| {
                if b.len() >= MIN_BUCKET {
                    Some(b.iter().sum::<f64>() / b.len() as f64)
                } else {
                    None
                }
            })
            .collect();
        Ok(LengthEstimator::Bucket { means, fallback })
    }

    /// Fit the per-N q-quantile estimator (0 ≤ q ≤ 1).
    pub fn fit_quantile(pairs: &[SentencePair], q: f64) -> Result<Self> {
        if !(0.0..=1.0).contains(&q) {
            return Err(Error::Fit(format!("quantile {q} out of [0,1]")));
        }
        let fallback = N2mRegressor::fit_raw(pairs)?;
        let quantiles = Self::group_by_n(pairs)
            .into_iter()
            .map(|mut b| {
                if b.len() >= MIN_BUCKET {
                    b.sort_by(|a, c| a.partial_cmp(c).unwrap());
                    let idx = ((b.len() - 1) as f64 * q).round() as usize;
                    Some(b[idx])
                } else {
                    None
                }
            })
            .collect();
        Ok(LengthEstimator::Quantile { quantiles, q, fallback })
    }

    /// Degree-2 polynomial least squares via the linear fit on a lifted
    /// basis (normal equations through [`super::fit::fit_plane`]).
    pub fn fit_poly2(pairs: &[SentencePair]) -> Result<Self> {
        let pts: Vec<(f64, f64, f64)> = pairs
            .iter()
            .map(|p| {
                let x = p.n() as f64;
                (x * x, x, p.m_real as f64)
            })
            .collect();
        let pf = super::fit::fit_plane(&pts)?;
        Ok(LengthEstimator::Poly2 { a: pf.a, b: pf.b, c: pf.c })
    }

    /// Fit the full zoo for an ablation run.
    pub fn fit_all(pairs: &[SentencePair]) -> Result<Vec<LengthEstimator>> {
        Ok(vec![
            Self::fit_constant(pairs)?,
            Self::fit_linear(pairs)?,
            Self::fit_bucket(pairs)?,
            Self::fit_quantile(pairs, 0.7)?,
            Self::fit_poly2(pairs)?,
        ])
    }

    /// Mean absolute error on a held-out set.
    pub fn mae(&self, pairs: &[SentencePair]) -> f64 {
        if pairs.is_empty() {
            return f64::NAN;
        }
        pairs
            .iter()
            .map(|p| (self.predict(p.n()) - p.m_real as f64).abs())
            .sum::<f64>()
            / pairs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{prefilter, CorpusGenerator, LangPair, PrefilterRules};

    fn corpus(pair: LangPair, n: usize, seed: u64) -> Vec<SentencePair> {
        let raw = CorpusGenerator::new(pair, seed).take(n);
        prefilter(&raw, &PrefilterRules::default()).0
    }

    #[test]
    fn all_estimators_fit_and_predict_in_range() {
        let pairs = corpus(LangPair::EnZh, 20_000, 1);
        for est in LengthEstimator::fit_all(&pairs).unwrap() {
            for n in [1usize, 5, 12, 30, 62, 64] {
                let m = est.predict(n);
                assert!(
                    (1.0..=80.0).contains(&m),
                    "{}: predict({n}) = {m}",
                    est.id()
                );
            }
        }
    }

    #[test]
    fn bucket_beats_constant_and_roughly_matches_linear() {
        let train = corpus(LangPair::FrEn, 30_000, 2);
        let test = corpus(LangPair::FrEn, 5_000, 3);
        let constant = LengthEstimator::fit_constant(&train).unwrap();
        let linear = LengthEstimator::fit_linear(&train).unwrap();
        let bucket = LengthEstimator::fit_bucket(&train).unwrap();
        let (mc, ml, mb) = (constant.mae(&test), linear.mae(&test), bucket.mae(&test));
        assert!(mb < mc * 0.6, "bucket {mb} vs constant {mc}");
        assert!(mb < ml * 1.15, "bucket {mb} much worse than linear {ml}");
    }

    #[test]
    fn quantile_overestimates_on_average() {
        let train = corpus(LangPair::DeEn, 30_000, 4);
        let q70 = LengthEstimator::fit_quantile(&train, 0.7).unwrap();
        let linear = LengthEstimator::fit_linear(&train).unwrap();
        // The 0.7-quantile should sit above the conditional mean.
        let mut above = 0;
        let mut total = 0;
        for n in 3..30 {
            total += 1;
            if q70.predict(n) > linear.predict(n) {
                above += 1;
            }
        }
        assert!(above * 10 >= total * 7, "q70 above mean only {above}/{total}");
    }

    #[test]
    fn poly2_close_to_linear_on_linear_data() {
        // The corpus is linear by construction; poly2's curvature term
        // should come out tiny.
        let train = corpus(LangPair::FrEn, 30_000, 5);
        if let LengthEstimator::Poly2 { a, .. } =
            LengthEstimator::fit_poly2(&train).unwrap()
        {
            assert!(a.abs() < 0.01, "curvature {a}");
        } else {
            panic!("wrong variant");
        }
    }

    #[test]
    fn sparse_buckets_fall_back_to_linear() {
        // Tiny corpus: most buckets under MIN_BUCKET, predictions must
        // still be sane everywhere.
        let train = corpus(LangPair::EnZh, 200, 6);
        let bucket = LengthEstimator::fit_bucket(&train).unwrap();
        for n in 1..=64 {
            assert!(bucket.predict(n) >= 1.0);
        }
    }

    #[test]
    fn fit_errors_on_degenerate_input() {
        assert!(LengthEstimator::fit_constant(&[]).is_err());
        let one = vec![SentencePair { src: vec![5; 4], m_real: 4, outlier: false }];
        assert!(LengthEstimator::fit_linear(&one).is_err());
        assert!(LengthEstimator::fit_quantile(&one, 1.5).is_err());
    }
}
