//! Per-device linear execution-time model (paper eq. 2).
//!
//! `T_exe,i = αN,i·N + αM,i·M + βi` for device `i ∈ {edge, cloud}` —
//! "these parameters can be computed with a once-for-all offline
//! characterisation". For RNNs αN and αM are both material (serial scans
//! on both sides); for Transformers on parallel hardware αN ≈ 0 (encoder
//! ~constant in N) and αM dominates (serial autoregressive decode).
//!
//! Combined with the N→M regressor this yields the paper's eq. 2:
//! `T_exe,i = αN·N + αM·(γ·N + δ) + β`.
//!
//! # Example
//!
//! ```
//! use cnmt::predictor::{N2mRegressor, TexeModel};
//!
//! let texe = TexeModel::from_coeffs(0.001, 0.003, 0.006);
//! let n2m = N2mRegressor::from_coeffs(0.9, 1.0);
//! // eq. 2: T̂ = αN·N + αM·(γ·N + δ) + β at N = 10.
//! let direct = 0.001 * 10.0 + 0.003 * (0.9 * 10.0 + 1.0) + 0.006;
//! assert!((texe.estimate_with_n2m(10, &n2m) - direct).abs() < 1e-12);
//! ```

use super::fit::{fit_plane, PlaneFit};
use super::n2m::N2mRegressor;
use crate::util::Json;
use crate::{Error, Result};

/// Fitted execution-time plane for one (device, model) combination.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TexeModel {
    /// Seconds per input token.
    pub alpha_n: f64,
    /// Seconds per output token.
    pub alpha_m: f64,
    /// Fixed cost (seconds).
    pub beta: f64,
    /// Fit R² on the characterisation data.
    pub r2: f64,
    /// Fit MSE on the characterisation data (s²).
    pub mse: f64,
}

impl TexeModel {
    /// Fit from profiled samples `(n, m, t_seconds)`.
    pub fn fit(samples: &[(f64, f64, f64)]) -> Result<Self> {
        let pf: PlaneFit = fit_plane(samples)?;
        Ok(TexeModel { alpha_n: pf.a, alpha_m: pf.b, beta: pf.c, r2: pf.r2, mse: pf.mse })
    }

    /// Construct from known coefficients.
    pub fn from_coeffs(alpha_n: f64, alpha_m: f64, beta: f64) -> Self {
        TexeModel { alpha_n, alpha_m, beta, r2: f64::NAN, mse: f64::NAN }
    }

    /// Estimate T_exe for known (n, m) — paper's linear model.
    pub fn estimate(&self, n: usize, m: f64) -> f64 {
        (self.alpha_n * n as f64 + self.alpha_m * m + self.beta).max(0.0)
    }

    /// Paper eq. 2: estimate with the N→M regressor filling in M.
    pub fn estimate_with_n2m(&self, n: usize, n2m: &N2mRegressor) -> f64 {
        self.estimate(n, n2m.predict(n))
    }

    /// Serialise the plane (calibration files, reports).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("alpha_n", Json::Num(self.alpha_n))
            .set("alpha_m", Json::Num(self.alpha_m))
            .set("beta", Json::Num(self.beta))
            .set("r2", Json::Num(self.r2))
            .set("mse", Json::Num(self.mse));
        o
    }

    /// Parse a plane serialised by [`TexeModel::to_json`].
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(TexeModel {
            alpha_n: j.get("alpha_n")?.as_f64()?,
            alpha_m: j.get("alpha_m")?.as_f64()?,
            beta: j.get("beta")?.as_f64()?,
            r2: j.get_opt("r2")?.map_or(Ok(f64::NAN), |v| v.as_f64())?,
            mse: j.get_opt("mse")?.map_or(Ok(f64::NAN), |v| v.as_f64())?,
        })
    }

    /// Sanity-check the coefficients (finite, decode cost ≥ 0).
    pub fn validate(&self) -> Result<()> {
        if !self.alpha_n.is_finite() || !self.alpha_m.is_finite() || !self.beta.is_finite() {
            return Err(Error::Fit("non-finite T_exe coefficients".into()));
        }
        if self.alpha_m < 0.0 {
            // A negative per-output-token cost is always a fitting bug.
            return Err(Error::Fit(format!(
                "negative alpha_m {} (decode cannot get cheaper with longer output)",
                self.alpha_m
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fit_recovers_rnn_like_plane() {
        let mut rng = Rng::new(4);
        let truth = TexeModel::from_coeffs(0.0031, 0.0087, 0.012);
        let samples: Vec<(f64, f64, f64)> = (0..4000)
            .map(|_| {
                let n = rng.range_i64(1, 62) as f64;
                let m = (0.9 * n + rng.normal_ms(0.0, 2.0)).clamp(1.0, 62.0);
                let t = truth.estimate(n as usize, m) + rng.normal_ms(0.0, 0.0015);
                (n, m, t.max(0.0))
            })
            .collect();
        let fit = TexeModel::fit(&samples).unwrap();
        assert!((fit.alpha_n - truth.alpha_n).abs() < 4e-4, "alpha_n {}", fit.alpha_n);
        assert!((fit.alpha_m - truth.alpha_m).abs() < 4e-4, "alpha_m {}", fit.alpha_m);
        assert!((fit.beta - truth.beta).abs() < 2e-3, "beta {}", fit.beta);
        assert!(fit.r2 > 0.97, "r2 {}", fit.r2);
        fit.validate().unwrap();
    }

    #[test]
    fn eq2_composition() {
        // estimate_with_n2m must equal estimate(n, gamma*n + delta).
        let texe = TexeModel::from_coeffs(0.001, 0.010, 0.02);
        let n2m = N2mRegressor::from_coeffs(0.62, 0.9);
        for n in [1usize, 10, 30, 62] {
            let direct = texe.estimate(n, 0.62 * n as f64 + 0.9);
            assert!((texe.estimate_with_n2m(n, &n2m) - direct).abs() < 1e-12);
        }
    }

    #[test]
    fn estimate_clamps_at_zero() {
        let texe = TexeModel::from_coeffs(0.0, 0.001, -1.0);
        assert_eq!(texe.estimate(1, 1.0), 0.0);
    }

    #[test]
    fn json_roundtrip() {
        let t = TexeModel { alpha_n: 1e-3, alpha_m: 2e-3, beta: 0.5, r2: 0.99, mse: 1e-6 };
        let back = TexeModel::from_json(&t.to_json()).unwrap();
        assert!((back.alpha_n - t.alpha_n).abs() < 1e-15);
        assert!((back.alpha_m - t.alpha_m).abs() < 1e-15);
        assert!((back.beta - t.beta).abs() < 1e-15);
        assert!((back.r2 - t.r2).abs() < 1e-15);
    }

    #[test]
    fn validate_rejects_bad_models() {
        assert!(TexeModel::from_coeffs(f64::NAN, 0.0, 0.0).validate().is_err());
        assert!(TexeModel::from_coeffs(0.0, -0.1, 0.0).validate().is_err());
        assert!(TexeModel::from_coeffs(-1e-6, 0.1, 0.0).validate().is_ok());
    }
}
