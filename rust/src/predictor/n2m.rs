//! The linear N→M output-length regressor (paper §II-B, Fig. 3).
//!
//! "it is reasonable to assume that there is a correlation [...] between
//! the length of an input sentence and the one of its translation" — the
//! paper fits `M ≈ γ·N + δ` per language pair on *ground-truth* corpus
//! pairs, after ParaCrawl-style outlier removal, and reports R² ≈ 0.99.
//! γ and δ depend only on the language pair, not on the device or model.

use crate::corpus::{prefilter, PrefilterRules, SentencePair};
use crate::Result;

use super::fit::{fit_line, LineFit};

/// Fitted `M = γ·N + δ` regressor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct N2mRegressor {
    /// Slope: predicted output tokens per input token.
    pub gamma: f64,
    /// Intercept (tokens).
    pub delta: f64,
    /// Fit R² (for Fig. 3 reporting).
    pub r2: f64,
    /// Fit MSE (for Fig. 3 reporting).
    pub mse: f64,
    /// Number of (prefiltered) pairs fitted.
    pub n_samples: usize,
}

impl N2mRegressor {
    /// Fit on prefiltered corpus pairs (applies [`prefilter`] first,
    /// exactly as the paper does before computing γ and δ).
    pub fn fit(pairs: &[SentencePair], rules: &PrefilterRules) -> Result<Self> {
        let (kept, _stats) = prefilter(pairs, rules);
        Self::fit_raw(&kept)
    }

    /// Fit directly on (already clean) pairs.
    pub fn fit_raw(pairs: &[SentencePair]) -> Result<Self> {
        let pts: Vec<(f64, f64)> = pairs
            .iter()
            .map(|p| (p.n() as f64, p.m_real as f64))
            .collect();
        let lf: LineFit = fit_line(&pts)?;
        Ok(N2mRegressor {
            gamma: lf.slope,
            delta: lf.intercept,
            r2: lf.r2,
            mse: lf.mse,
            n_samples: lf.n_samples,
        })
    }

    /// Construct from known coefficients (tests / config override).
    pub fn from_coeffs(gamma: f64, delta: f64) -> Self {
        N2mRegressor { gamma, delta, r2: f64::NAN, mse: f64::NAN, n_samples: 0 }
    }

    /// Predicted output length for input length `n` (continuous; callers
    /// round only when they need a token count).
    pub fn predict(&self, n: usize) -> f64 {
        (self.gamma * n as f64 + self.delta).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{CorpusGenerator, LangPair, PrefilterRules};

    #[test]
    fn recovers_language_pair_verbosity() {
        // Paper Fig. 3: R²=0.99 for all three pairs after prefiltering.
        for lp in LangPair::ALL {
            let mut g = CorpusGenerator::new(lp, 21);
            let pairs = g.take(20_000);
            let reg =
                N2mRegressor::fit(&pairs, &PrefilterRules::default()).unwrap();
            let truth = lp.params();
            assert!(
                (reg.gamma - truth.gamma).abs() < 0.03,
                "{}: gamma {} vs {}",
                lp.id(),
                reg.gamma,
                truth.gamma
            );
            assert!(
                (reg.delta - truth.delta).abs() < 0.5,
                "{}: delta {} vs {}",
                lp.id(),
                reg.delta,
                truth.delta
            );
            // Per-pair R² (not the per-N-average R² the paper's Fig. 3
            // caption quotes — see experiments::fig3::r2_on_means).
            assert!(reg.r2 > 0.88, "{}: r2 {}", lp.id(), reg.r2);
        }
    }

    #[test]
    fn prefiltering_improves_fit() {
        // Without outlier removal the fit degrades — this is exactly why
        // the paper prefilters before computing gamma/delta.
        let mut g = CorpusGenerator::new(LangPair::EnZh, 22);
        let pairs = g.take(20_000);
        let with = N2mRegressor::fit(&pairs, &PrefilterRules::default()).unwrap();
        let without = N2mRegressor::fit_raw(&pairs).unwrap();
        assert!(with.r2 > without.r2, "with {} vs without {}", with.r2, without.r2);
        assert!(with.mse < without.mse);
    }

    #[test]
    fn predict_floors_at_one_token() {
        let reg = N2mRegressor::from_coeffs(0.5, -3.0);
        assert_eq!(reg.predict(1), 1.0);
        assert!((reg.predict(20) - 7.0).abs() < 1e-12);
    }
}
