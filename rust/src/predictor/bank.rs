//! Per-device banks of online-refit models — the fleet generalisation
//! of the pair's two [`RlsPlane`]s and single T_tx [`RlsLine`].
//!
//! The pair-scope adaptive scheduler (PR 2/3) keeps one refit plane per
//! *tier*. At fleet scope that sharing is exactly wrong: when one cloud
//! replica starts throttling, folding its completions into a tier-wide
//! plane poisons the estimate every healthy sibling is scored with —
//! the selector then mistrusts the whole tier instead of the one sick
//! device (the heterogeneity problem CoFormer/Galaxy call out; see
//! PAPERS.md). A [`PlaneBank`] holds one independently-warmed
//! [`RlsPlane`] per device, fed by that device's lane completions only,
//! so a drifting replica is re-learned without moving anyone else's
//! plane (the isolation test in `fleet::select` asserts other devices'
//! scores stay bit-identical).
//!
//! [`LineBank`] is the network-side twin: one payload-size → T_tx
//! [`RlsLine`] per *cloud* device, fed by that replica's observed
//! transfers (which already include its `link_scale` multiple), so a
//! replica behind a degrading route re-prices itself instead of
//! inflating the shared EWMA.
//!
//! Both banks start from the selector's per-device priors (tier plane ×
//! the device's slowdown), so on the 1×1 topology the bank's arithmetic
//! is bit-identical to the pair harness's two planes and one line — the
//! fleet ≡ pair differential holds with refit enabled on both sides.

use crate::{Error, Result};

use super::rls::{RlsLine, RlsPlane};
use super::texe::TexeModel;
use super::ttx::TtxLine;

/// One independently-refit T_exe plane per fleet device.
#[derive(Debug, Clone)]
pub struct PlaneBank {
    planes: Vec<RlsPlane>,
}

impl PlaneBank {
    /// One plane per prior, all with the same forgetting factor and
    /// prior covariance. `priors` are the devices' offline planes (tier
    /// plane × device slowdown), in device-id order.
    pub fn new(priors: &[TexeModel], lambda: f64, prior_var: f64) -> Result<PlaneBank> {
        if priors.is_empty() {
            return Err(Error::Fit("PlaneBank needs at least one device".into()));
        }
        let planes = priors
            .iter()
            .map(|&p| RlsPlane::new(p, lambda, prior_var))
            .collect::<Result<Vec<RlsPlane>>>()?;
        Ok(PlaneBank { planes })
    }

    /// Number of devices.
    pub fn len(&self) -> usize {
        self.planes.len()
    }

    /// True when the bank has no devices (rejected at construction).
    pub fn is_empty(&self) -> bool {
        self.planes.is_empty()
    }

    /// Feed one observed completion on device `d`. O(1); only device
    /// `d`'s plane moves.
    pub fn observe(&mut self, d: usize, n: f64, m: f64, t_s: f64) {
        self.planes[d].observe(n, m, t_s);
    }

    /// Observations absorbed by device `d`'s plane.
    pub fn count(&self, d: usize) -> u64 {
        self.planes[d].count()
    }

    /// Device `d`'s current coefficient estimate.
    pub fn model(&self, d: usize) -> TexeModel {
        self.planes[d].model()
    }

    /// Has device `d`'s plane absorbed at least `min_obs` observations
    /// (the install threshold, [`crate::sim::AdaptiveOpts::refit_min_obs`])?
    pub fn warmed(&self, d: usize, min_obs: u64) -> bool {
        self.planes[d].count() >= min_obs
    }
}

/// One payload-size → T_tx refit line per cloud device (`None` for edge
/// devices — they pay no network cost).
#[derive(Debug, Clone)]
pub struct LineBank {
    lines: Vec<Option<RlsLine>>,
}

impl LineBank {
    /// `is_cloud[d]` selects which devices carry a line. Lines start
    /// diffuse at zero, exactly like the pair harness's T_tx refit line
    /// — they are only consulted once warmed.
    pub fn new(is_cloud: &[bool], lambda: f64, prior_var: f64) -> Result<LineBank> {
        let lines = is_cloud
            .iter()
            .map(|&cloud| {
                if cloud {
                    RlsLine::new(TtxLine { slope: 0.0, intercept: 0.0 }, lambda, prior_var)
                        .map(Some)
                } else {
                    Ok(None)
                }
            })
            .collect::<Result<Vec<Option<RlsLine>>>>()?;
        Ok(LineBank { lines })
    }

    /// Number of devices (cloud and edge alike).
    pub fn len(&self) -> usize {
        self.lines.len()
    }

    /// True when the bank has no devices.
    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    /// Feed one observed transfer on device `d`: payload size in tokens
    /// and measured (link-scaled) transfer seconds. No-op for devices
    /// without a line.
    pub fn observe(&mut self, d: usize, size_tokens: f64, t_s: f64) {
        if let Some(line) = self.lines[d].as_mut() {
            line.observe(size_tokens, t_s);
        }
    }

    /// Transfers absorbed by device `d`'s line (0 for edge devices).
    pub fn count(&self, d: usize) -> u64 {
        self.lines[d].as_ref().map_or(0, |l| l.count())
    }

    /// Device `d`'s current law, if it carries one.
    pub fn line(&self, d: usize) -> Option<TtxLine> {
        self.lines[d].as_ref().map(|l| l.line())
    }

    /// Has device `d`'s line absorbed at least `min_obs` transfers?
    pub fn warmed(&self, d: usize, min_obs: u64) -> bool {
        self.lines[d].as_ref().is_some_and(|l| l.count() >= min_obs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn priors() -> Vec<TexeModel> {
        vec![
            TexeModel::from_coeffs(1.2e-3, 3.0e-3, 6.0e-3),
            TexeModel::from_coeffs(0.22e-3, 0.55e-3, 26.0e-3),
            TexeModel::from_coeffs(0.44e-3, 1.1e-3, 52.0e-3),
        ]
    }

    #[test]
    fn observations_move_only_the_fed_device() {
        // THE isolation property the fleet refit rests on: feeding one
        // device leaves every other plane bit-identical to its prior.
        let ps = priors();
        let mut bank = PlaneBank::new(&ps, 0.998, 1.0).unwrap();
        let truth = TexeModel::from_coeffs(1.1e-3, 2.75e-3, 130.0e-3); // 2.5x slower
        for i in 0..500usize {
            let (n, m) = (1 + i % 40, 1 + (i * 7) % 40);
            bank.observe(2, n as f64, m as f64, truth.estimate(n, m as f64));
        }
        assert_eq!(bank.count(2), 500);
        for d in [0usize, 1] {
            assert_eq!(bank.count(d), 0);
            let (got, prior) = (bank.model(d), ps[d]);
            assert_eq!(got.alpha_n.to_bits(), prior.alpha_n.to_bits());
            assert_eq!(got.alpha_m.to_bits(), prior.alpha_m.to_bits());
            assert_eq!(got.beta.to_bits(), prior.beta.to_bits());
        }
        // The fed device converged toward its drifted truth.
        let fit = bank.model(2);
        assert!((fit.alpha_m - truth.alpha_m).abs() < 2e-4, "alpha_m {}", fit.alpha_m);
        assert!(bank.warmed(2, 64));
        assert!(!bank.warmed(0, 1));
    }

    #[test]
    fn line_bank_skips_edge_devices() {
        let mut lines = LineBank::new(&[false, true, true], 0.998, 1.0).unwrap();
        assert_eq!(lines.len(), 3);
        // Feeding an edge device is inert.
        lines.observe(0, 30.0, 0.05);
        assert_eq!(lines.count(0), 0);
        assert!(lines.line(0).is_none());
        // Cloud lines learn independently.
        for _ in 0..200 {
            lines.observe(1, 40.0, 0.2e-3 * 40.0 + 8e-3);
        }
        assert_eq!(lines.count(1), 200);
        assert_eq!(lines.count(2), 0);
        assert!(lines.warmed(1, 64));
        assert!(!lines.warmed(2, 1));
        let law = lines.line(1).unwrap();
        assert!((law.estimate(40.0) - (0.2e-3 * 40.0 + 8e-3)).abs() < 1e-4);
    }

    #[test]
    fn rejects_degenerate_construction() {
        assert!(PlaneBank::new(&[], 0.998, 1.0).is_err());
        assert!(PlaneBank::new(&priors(), 0.0, 1.0).is_err());
        assert!(LineBank::new(&[true], 1.5, 1.0).is_err());
        // An all-edge bank is legal — it just never observes anything.
        let lb = LineBank::new(&[false, false], 0.998, 1.0).unwrap();
        assert_eq!(lb.len(), 2);
        assert!(!lb.is_empty());
    }
}
