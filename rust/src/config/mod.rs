//! Configuration system.
//!
//! Every experiment / serving run is described by a JSON config (defaults
//! reproduce the paper's setup §III: 100k requests, 10k characterisation
//! inferences per device, 100 Mbps symmetric link, CP1/CP2 profiles, the
//! three model/dataset pairs). The `cnmt` CLI reads `--config <path>` and
//! applies flag overrides on top.

use std::path::{Path, PathBuf};

use crate::corpus::LangPair;
use crate::net::trace::ConnectionProfile;
use crate::util::Json;
use crate::{Error, Result};

/// Top-level configuration for experiments and the gateway.
#[derive(Debug, Clone)]
pub struct Config {
    /// Master seed: every stochastic component forks from this.
    pub seed: u64,
    /// Evaluation request count (paper: 100_000).
    pub requests: usize,
    /// Characterisation inferences per device (paper: 10_000).
    pub fit_inferences: usize,
    /// Corpus pairs generated for the eval pool.
    pub eval_pool: usize,
    /// Language pairs to evaluate (Table I rows).
    pub pairs: Vec<LangPair>,
    /// Connection profiles to evaluate (Table I column groups).
    pub profiles: Vec<ConnectionProfile>,
    /// Path to a calibration JSON; None = built-in paper defaults.
    pub calibration: Option<PathBuf>,
    /// EWMA smoothing for the online T_tx estimator.
    pub ttx_alpha: f64,
    /// T_tx prior before any observation (seconds).
    pub ttx_prior_s: f64,
    /// Mean request inter-arrival time (seconds) for spreading the
    /// request stream over the RTT trace timeline.
    pub mean_interarrival_s: f64,
    /// Link bandwidth (bits/second, paper: 100 Mbps symmetric).
    pub bandwidth_bps: f64,
    /// Artifacts directory (HLO + weights + manifest).
    pub artifacts_dir: PathBuf,
    /// Output directory for reports.
    pub out_dir: PathBuf,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            seed: 20220315,
            requests: 100_000,
            fit_inferences: 10_000,
            eval_pool: 50_000,
            pairs: LangPair::ALL.to_vec(),
            profiles: ConnectionProfile::ALL.to_vec(),
            calibration: None,
            ttx_alpha: 0.3,
            ttx_prior_s: 0.05,
            mean_interarrival_s: 0.14, // ~100k requests over a 4h trace
            bandwidth_bps: 100e6,
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("reports"),
        }
    }
}

impl Config {
    /// A scaled-down config for tests and smoke runs.
    pub fn smoke() -> Self {
        Config {
            requests: 2_000,
            fit_inferences: 1_000,
            eval_pool: 2_000,
            ..Default::default()
        }
    }

    /// Check the configuration for internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.requests == 0 {
            return Err(Error::Config("requests must be > 0".into()));
        }
        if self.fit_inferences < 10 {
            return Err(Error::Config("fit_inferences must be >= 10".into()));
        }
        if self.eval_pool == 0 {
            return Err(Error::Config("eval_pool must be > 0".into()));
        }
        if self.pairs.is_empty() || self.profiles.is_empty() {
            return Err(Error::Config("pairs/profiles must be non-empty".into()));
        }
        if !(0.0..=1.0).contains(&self.ttx_alpha) || self.ttx_alpha == 0.0 {
            return Err(Error::Config(format!("ttx_alpha {} out of (0,1]", self.ttx_alpha)));
        }
        if self.bandwidth_bps <= 0.0 || self.mean_interarrival_s <= 0.0 {
            return Err(Error::Config("bandwidth/interarrival must be positive".into()));
        }
        Ok(())
    }

    // ------------------------------------------------------------ JSON I/O

    /// Serialise the configuration.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("seed", Json::Num(self.seed as f64))
            .set("requests", Json::Num(self.requests as f64))
            .set("fit_inferences", Json::Num(self.fit_inferences as f64))
            .set("eval_pool", Json::Num(self.eval_pool as f64))
            .set(
                "pairs",
                Json::Array(
                    self.pairs.iter().map(|p| Json::Str(p.id().into())).collect(),
                ),
            )
            .set(
                "profiles",
                Json::Array(
                    self.profiles.iter().map(|p| Json::Str(p.id().into())).collect(),
                ),
            )
            .set(
                "calibration",
                self.calibration
                    .as_ref()
                    .map(|p| Json::Str(p.display().to_string()))
                    .unwrap_or(Json::Null),
            )
            .set("ttx_alpha", Json::Num(self.ttx_alpha))
            .set("ttx_prior_s", Json::Num(self.ttx_prior_s))
            .set("mean_interarrival_s", Json::Num(self.mean_interarrival_s))
            .set("bandwidth_bps", Json::Num(self.bandwidth_bps))
            .set(
                "artifacts_dir",
                Json::Str(self.artifacts_dir.display().to_string()),
            )
            .set("out_dir", Json::Str(self.out_dir.display().to_string()));
        o
    }

    /// Parse a configuration serialised by [`Config::to_json`].
    pub fn from_json(j: &Json) -> Result<Config> {
        let mut c = Config::default();
        if let Some(v) = j.get_opt("seed")? {
            c.seed = v.as_i64()? as u64;
        }
        if let Some(v) = j.get_opt("requests")? {
            c.requests = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("fit_inferences")? {
            c.fit_inferences = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("eval_pool")? {
            c.eval_pool = v.as_usize()?;
        }
        if let Some(v) = j.get_opt("pairs")? {
            c.pairs = v
                .as_array()?
                .iter()
                .map(|s| {
                    let id = s.as_str()?;
                    LangPair::from_id(id)
                        .ok_or_else(|| Error::Config(format!("unknown pair `{id}`")))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = j.get_opt("profiles")? {
            c.profiles = v
                .as_array()?
                .iter()
                .map(|s| {
                    let id = s.as_str()?;
                    ConnectionProfile::from_id(id)
                        .ok_or_else(|| Error::Config(format!("unknown profile `{id}`")))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(v) = j.get_opt("calibration")? {
            c.calibration = Some(PathBuf::from(v.as_str()?));
        }
        if let Some(v) = j.get_opt("ttx_alpha")? {
            c.ttx_alpha = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("ttx_prior_s")? {
            c.ttx_prior_s = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("mean_interarrival_s")? {
            c.mean_interarrival_s = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("bandwidth_bps")? {
            c.bandwidth_bps = v.as_f64()?;
        }
        if let Some(v) = j.get_opt("artifacts_dir")? {
            c.artifacts_dir = PathBuf::from(v.as_str()?);
        }
        if let Some(v) = j.get_opt("out_dir")? {
            c.out_dir = PathBuf::from(v.as_str()?);
        }
        c.validate()?;
        Ok(c)
    }

    /// Load a configuration from a JSON file.
    pub fn load(path: &Path) -> Result<Config> {
        Config::from_json(&Json::parse_file(path)?)
    }

    /// Write the configuration to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_setup() {
        let c = Config::default();
        c.validate().unwrap();
        assert_eq!(c.requests, 100_000);
        assert_eq!(c.fit_inferences, 10_000);
        assert_eq!(c.pairs.len(), 3);
        assert_eq!(c.profiles.len(), 2);
        assert!((c.bandwidth_bps - 100e6).abs() < 1.0);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = Config::smoke();
        c.calibration = Some(PathBuf::from("cal.json"));
        c.pairs = vec![LangPair::EnZh];
        let j = c.to_json();
        let back = Config::from_json(&j).unwrap();
        assert_eq!(back.requests, c.requests);
        assert_eq!(back.pairs, c.pairs);
        assert_eq!(back.calibration, c.calibration);
        assert_eq!(back.seed, c.seed);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"requests": 500}"#).unwrap();
        let c = Config::from_json(&j).unwrap();
        assert_eq!(c.requests, 500);
        assert_eq!(c.fit_inferences, 10_000);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = Config::default();
        c.requests = 0;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.ttx_alpha = 1.5;
        assert!(c.validate().is_err());
        let mut c = Config::default();
        c.pairs.clear();
        assert!(c.validate().is_err());
        let j = Json::parse(r#"{"pairs": ["xx_yy"]}"#).unwrap();
        assert!(Config::from_json(&j).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("cnmt_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let c = Config::smoke();
        c.save(&path).unwrap();
        let back = Config::load(&path).unwrap();
        assert_eq!(back.requests, c.requests);
        std::fs::remove_file(&path).ok();
    }
}
