//! Simulated inference devices with calibrated latency ground truth.

use std::collections::BTreeMap;

use crate::util::Rng;
use crate::{Error, Result};

use super::calibration::DeviceTimeModel;

/// Which side of the edge/cloud split a device sits on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// The edge gateway (paper: Jetson TX2). Local: no network cost.
    Edge,
    /// The cloud server (paper: Titan XP). Remote: requests pay T_tx.
    Cloud,
}

impl DeviceKind {
    /// Both devices, in report order.
    pub const ALL: [DeviceKind; 2] = [DeviceKind::Edge, DeviceKind::Cloud];

    /// Stable string id (`edge` / `cloud`).
    pub fn id(&self) -> &'static str {
        match self {
            DeviceKind::Edge => "edge",
            DeviceKind::Cloud => "cloud",
        }
    }

    /// Parse an id produced by [`DeviceKind::id`].
    pub fn from_id(s: &str) -> Option<DeviceKind> {
        match s {
            "edge" => Some(DeviceKind::Edge),
            "cloud" => Some(DeviceKind::Cloud),
            _ => None,
        }
    }
}

/// A simulated device: per-model ground-truth latency models.
///
/// `exec_time(model, n, m)` draws the *actual* time a request would take —
/// linear trend plus noise — which the experiment harness charges, and
/// which differs from what the router's fitted [`crate::predictor::TexeModel`]
/// predicts (that mismatch is one of the paper's sources of C-NMT
/// sub-optimality vs the Oracle).
#[derive(Debug, Clone)]
pub struct SimDevice {
    /// Which device this simulates.
    pub kind: DeviceKind,
    models: BTreeMap<String, DeviceTimeModel>,
    rng: Rng,
}

impl SimDevice {
    /// Device with the built-in paper-shaped time models.
    pub fn new(kind: DeviceKind, seed: u64) -> Self {
        SimDevice {
            kind,
            models: BTreeMap::new(),
            rng: Rng::new(seed ^ (kind as u64 + 1).wrapping_mul(0xDE71CE)),
        }
    }

    /// Register the ground-truth time model for `model_name`.
    pub fn with_model(mut self, model_name: &str, m: DeviceTimeModel) -> Self {
        self.models.insert(model_name.to_string(), m);
        self
    }

    /// Is a time model registered for `model_name`?
    pub fn has_model(&self, model_name: &str) -> bool {
        self.models.contains_key(model_name)
    }

    /// The ground-truth time model for `model_name`.
    pub fn time_model(&self, model_name: &str) -> Result<&DeviceTimeModel> {
        self.models.get(model_name).ok_or_else(|| {
            Error::Sim(format!(
                "device {} has no time model for `{model_name}`",
                self.kind.id()
            ))
        })
    }

    /// Deterministic trend component (used by the Oracle-without-noise
    /// ablation and by tests).
    pub fn mean_time(&self, model_name: &str, n: usize, m: usize) -> Result<f64> {
        Ok(self.time_model(model_name)?.mean(n, m))
    }

    /// Sample the ground-truth execution time for one request.
    pub fn exec_time(&mut self, model_name: &str, n: usize, m: usize) -> Result<f64> {
        let tm = *self.time_model(model_name)?;
        Ok(tm.sample(n, m, &mut self.rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::calibration::Calibration;
    use crate::metrics::OnlineStats;

    #[test]
    fn exec_time_tracks_trend() {
        let cal = Calibration::default_paper();
        let mut dev = cal.build_device(DeviceKind::Edge, 1).unwrap();
        let mut s = OnlineStats::new();
        for _ in 0..3000 {
            s.push(dev.exec_time("gru_fr_en", 20, 18).unwrap());
        }
        let trend = dev.mean_time("gru_fr_en", 20, 18).unwrap();
        assert!(
            (s.mean() - trend).abs() / trend < 0.02,
            "mean {} vs trend {trend}",
            s.mean()
        );
        assert!(s.std() > 0.0, "noise must be present");
        assert!(s.min() > 0.0, "times must be positive");
    }

    #[test]
    fn cloud_faster_than_edge_for_long_requests() {
        // The calibration geometry: the cloud's *slopes* are far below
        // the edge's, but its fixed cost is higher — so it wins clearly
        // on medium/long requests while very short ones can favour the
        // edge even before network costs (paper Fig. 2b edge region).
        let cal = Calibration::default_paper();
        let mut edge = cal.build_device(DeviceKind::Edge, 2).unwrap();
        let mut cloud = cal.build_device(DeviceKind::Cloud, 2).unwrap();
        for model in ["bilstm_de_en", "gru_fr_en", "transformer_en_zh"] {
            for (n, m) in [(30, 25), (60, 55)] {
                let te = edge.mean_time(model, n, m).unwrap();
                let tc = cloud.mean_time(model, n, m).unwrap();
                assert!(
                    tc < te,
                    "{model} ({n},{m}): cloud {tc} not faster than edge {te}"
                );
            }
            // Per-token slopes strictly lower on the cloud.
            let e = cal.get(DeviceKind::Edge, model).unwrap().texe;
            let c = cal.get(DeviceKind::Cloud, model).unwrap().texe;
            assert!(c.alpha_m < e.alpha_m);
            assert!(c.alpha_n <= e.alpha_n);
        }
    }

    #[test]
    fn missing_model_is_error() {
        let dev = SimDevice::new(DeviceKind::Edge, 3);
        assert!(dev.mean_time("nope", 1, 1).is_err());
    }

    #[test]
    fn kind_ids_roundtrip() {
        for k in DeviceKind::ALL {
            assert_eq!(DeviceKind::from_id(k.id()), Some(k));
        }
        assert_eq!(DeviceKind::from_id("tpu"), None);
    }
}
