//! Offline device characterisation (the paper's "once-for-all offline
//! characterisation" that produces the αN, αM, β of eq. 2).
//!
//! Two sources of coefficients:
//!
//! 1. **Measured** — the `cnmt calibrate` CLI runs real PJRT inferences
//!    over an (N, M) sweep, measures wall time, fits
//!    [`crate::predictor::TexeModel`] planes and writes them here; edge
//!    and cloud are derived from the measured CPU numbers by per-device
//!    speed scaling (DESIGN.md §4: the edge:cloud ratio is the quantity
//!    that matters for routing geometry, not the absolute scale).
//! 2. **Built-in defaults** ([`Calibration::default_paper`]) — paper-shaped
//!    coefficients (Jetson-TX2-vs-Titan-XP-like ratios, Fig. 2a slopes)
//!    so every experiment runs out of the box and reproducibly.

use std::collections::BTreeMap;
use std::path::Path;

use crate::devices::sim::{DeviceKind, SimDevice};
use crate::predictor::TexeModel;
use crate::util::{Json, Rng};
use crate::{Error, Result};

/// Ground-truth latency model for one (device, NMT model) pair: linear
/// trend + heteroscedastic noise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceTimeModel {
    /// Linear trend (the "real" plane the router tries to learn).
    pub texe: TexeModel,
    /// Multiplicative noise: std = `noise_frac`·mean.
    pub noise_frac: f64,
    /// Additive noise floor (seconds).
    pub noise_floor_s: f64,
}

impl DeviceTimeModel {
    /// Mean (noise-free) execution time at (n, m), in seconds.
    pub fn mean(&self, n: usize, m: usize) -> f64 {
        self.texe.estimate(n, m as f64)
    }

    /// Sample an execution time (trend + truncated Gaussian noise).
    pub fn sample(&self, n: usize, m: usize, rng: &mut Rng) -> f64 {
        let mean = self.mean(n, m);
        let std = self.noise_frac * mean + self.noise_floor_s;
        (mean + rng.normal_ms(0.0, std)).max(mean * 0.2).max(1e-6)
    }

    /// Serialise one device/model time model.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("texe", self.texe.to_json())
            .set("noise_frac", Json::Num(self.noise_frac))
            .set("noise_floor_s", Json::Num(self.noise_floor_s));
        o
    }

    /// Parse a model serialised by [`DeviceTimeModel::to_json`].
    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(DeviceTimeModel {
            texe: TexeModel::from_json(j.get("texe")?)?,
            noise_frac: j.get("noise_frac")?.as_f64()?,
            noise_floor_s: j.get("noise_floor_s")?.as_f64()?,
        })
    }
}

/// Full calibration: (device, model) → ground-truth time model.
#[derive(Debug, Clone, Default)]
pub struct Calibration {
    /// Keyed by `"<device_id>/<model_name>"`.
    entries: BTreeMap<String, DeviceTimeModel>,
}

fn key(device: DeviceKind, model: &str) -> String {
    format!("{}/{model}", device.id())
}

impl Calibration {
    /// Empty calibration (fill via [`Calibration::set`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert/replace the time model for (device, model).
    pub fn set(&mut self, device: DeviceKind, model: &str, tm: DeviceTimeModel) {
        self.entries.insert(key(device, model), tm);
    }

    /// Look up the time model for (device, model).
    pub fn get(&self, device: DeviceKind, model: &str) -> Result<&DeviceTimeModel> {
        self.entries.get(&key(device, model)).ok_or_else(|| {
            Error::Sim(format!("no calibration for {}/{model}", device.id()))
        })
    }

    /// Distinct model names present (sorted).
    pub fn models(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .entries
            .keys()
            .filter_map(|k| k.split_once('/').map(|(_, m)| m.to_string()))
            .collect();
        out.sort();
        out.dedup();
        out
    }

    /// Instantiate a [`SimDevice`] with every model calibrated for `kind`.
    pub fn build_device(&self, kind: DeviceKind, seed: u64) -> Result<SimDevice> {
        let mut dev = SimDevice::new(kind, seed);
        let mut any = false;
        for (k, tm) in &self.entries {
            if let Some((d, model)) = k.split_once('/') {
                if d == kind.id() {
                    dev = dev.with_model(model, *tm);
                    any = true;
                }
            }
        }
        if !any {
            return Err(Error::Sim(format!("no calibration entries for {}", kind.id())));
        }
        Ok(dev)
    }

    /// Built-in paper-shaped coefficients (seconds). Ratios follow the
    /// paper's observations: cloud ~4-6× faster; Transformer αN ≈ 0
    /// (encoder parallel ⇒ ~constant in N) while decode dominates; RNNs
    /// linear in both N and M; cloud relatively noisier (paper Fig. 2a:
    /// Titan R²=0.85 vs Jetson 0.99).
    pub fn default_paper() -> Calibration {
        let mut c = Calibration::new();
        let e = DeviceKind::Edge;
        let cl = DeviceKind::Cloud;
        let dm = |an: f64, am: f64, b: f64, nf: f64, floor: f64| DeviceTimeModel {
            texe: TexeModel::from_coeffs(an, am, b),
            noise_frac: nf,
            noise_floor_s: floor,
        };
        // Absolute scales follow the paper's testbed regime: Jetson-TX2
        // edge times are comparable to (or above) the WAN RTT, and the
        // Titan-class server is ~6-10x faster, so the edge/cloud
        // crossover falls inside the corpus length range under both
        // connection profiles (paper Fig. 2b).
        //
        // The cloud carries a noticeable *fixed* cost (RPC deserialise,
        // scheduler, kernel-launch train-up — visible as the non-zero
        // intercept of the Titan series in the paper's Fig. 2a), while
        // its per-token slopes are ~6-8x below the edge's. That geometry
        // puts the edge/cloud crossover inside the corpus length range
        // under both connection profiles (paper Fig. 2b).
        //
        // 2-layer BiLSTM (IWSLT'14 DE-EN).
        c.set(e, "bilstm_de_en", dm(1.80e-3, 4.80e-3, 8.0e-3, 0.04, 0.5e-3));
        c.set(cl, "bilstm_de_en", dm(0.30e-3, 0.80e-3, 33.0e-3, 0.08, 0.8e-3));
        // 1-layer GRU (OPUS-100 FR-EN) — lightest model: edge-favoured.
        c.set(e, "gru_fr_en", dm(1.20e-3, 3.00e-3, 6.0e-3, 0.04, 0.4e-3));
        c.set(cl, "gru_fr_en", dm(0.22e-3, 0.55e-3, 26.0e-3, 0.08, 0.6e-3));
        // MarianMT-style Transformer (OPUS-100 EN-ZH): encoder ~free,
        // serial masked decode dominates — cloud-favoured.
        c.set(e, "transformer_en_zh", dm(0.15e-3, 11.0e-3, 12.0e-3, 0.04, 0.5e-3));
        c.set(cl, "transformer_en_zh", dm(0.03e-3, 1.60e-3, 28.0e-3, 0.08, 0.8e-3));
        c
    }

    /// Derive edge/cloud calibrations from *measured* samples on the local
    /// PJRT backend: fit a plane per model, then scale by per-device speed
    /// factors (edge ≈ local CPU, cloud ≈ `cloud_speedup`× faster).
    pub fn from_measurements(
        samples_per_model: &BTreeMap<String, Vec<(f64, f64, f64)>>,
        edge_slowdown: f64,
        cloud_speedup: f64,
    ) -> Result<Calibration> {
        if edge_slowdown <= 0.0 || cloud_speedup <= 0.0 {
            return Err(Error::Config("speed factors must be positive".into()));
        }
        let mut c = Calibration::new();
        for (model, samples) in samples_per_model {
            let base = TexeModel::fit(samples)?;
            base.validate()?;
            let scaled = |f: f64| TexeModel {
                alpha_n: base.alpha_n * f,
                alpha_m: base.alpha_m * f,
                beta: base.beta * f,
                r2: base.r2,
                mse: base.mse * f * f,
            };
            // Residual noise from the fit, carried into the simulation.
            let resid_std = base.mse.sqrt();
            let mean_t = samples.iter().map(|s| s.2).sum::<f64>() / samples.len() as f64;
            let noise_frac = (resid_std / mean_t).clamp(0.01, 0.25);
            c.set(DeviceKind::Edge, model, DeviceTimeModel {
                texe: scaled(edge_slowdown),
                noise_frac,
                noise_floor_s: 0.2e-3,
            });
            c.set(DeviceKind::Cloud, model, DeviceTimeModel {
                texe: scaled(1.0 / cloud_speedup),
                // Cloud relatively noisier (shared machine, paper Fig 2a).
                noise_frac: (noise_frac * 1.8).clamp(0.01, 0.3),
                noise_floor_s: 0.4e-3,
            });
        }
        Ok(c)
    }

    // ------------------------------------------------------------ JSON I/O

    /// Serialise the full calibration table.
    pub fn to_json(&self) -> Json {
        let mut entries = Json::object();
        for (k, v) in &self.entries {
            entries.set(k, v.to_json());
        }
        let mut root = Json::object();
        root.set("version", Json::Num(1.0)).set("entries", entries);
        root
    }

    /// Parse a table serialised by [`Calibration::to_json`].
    pub fn from_json(j: &Json) -> Result<Calibration> {
        let mut c = Calibration::new();
        for (k, v) in j.get("entries")?.as_object()? {
            let (dev, model) = k.split_once('/').ok_or_else(|| {
                Error::Config(format!("bad calibration key `{k}`"))
            })?;
            let kind = DeviceKind::from_id(dev).ok_or_else(|| {
                Error::Config(format!("bad device id `{dev}`"))
            })?;
            c.set(kind, model, DeviceTimeModel::from_json(v)?);
        }
        Ok(c)
    }

    /// Write the calibration to a JSON file.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    /// Load a calibration from a JSON file.
    pub fn load(path: &Path) -> Result<Calibration> {
        Calibration::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn default_paper_covers_all_pairs() {
        let c = Calibration::default_paper();
        for dev in DeviceKind::ALL {
            for model in ["bilstm_de_en", "gru_fr_en", "transformer_en_zh"] {
                let tm = c.get(dev, model).unwrap();
                tm.texe.validate().unwrap();
                assert!(tm.mean(10, 10) > 0.0);
            }
        }
        assert_eq!(c.models().len(), 3);
    }

    #[test]
    fn transformer_edge_is_decode_dominated() {
        // Paper §III: "decoding dominates the total latency of
        // Transformer-based NMT".
        let c = Calibration::default_paper();
        let tm = c.get(DeviceKind::Edge, "transformer_en_zh").unwrap();
        assert!(tm.texe.alpha_m > 10.0 * tm.texe.alpha_n.max(1e-9));
    }

    #[test]
    fn json_roundtrip_via_file() {
        let c = Calibration::default_paper();
        let dir = std::env::temp_dir().join("cnmt_cal_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cal.json");
        c.save(&path).unwrap();
        let back = Calibration::load(&path).unwrap();
        for dev in DeviceKind::ALL {
            for model in c.models() {
                let a = c.get(dev, &model).unwrap();
                let b = back.get(dev, &model).unwrap();
                assert!((a.texe.alpha_m - b.texe.alpha_m).abs() < 1e-15);
                assert!((a.noise_frac - b.noise_frac).abs() < 1e-15);
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn from_measurements_scales_devices() {
        let mut rng = Rng::new(5);
        let truth = TexeModel::from_coeffs(2e-3, 6e-3, 20e-3);
        let mut samples = BTreeMap::new();
        samples.insert(
            "gru_fr_en".to_string(),
            (0..2000)
                .map(|_| {
                    let n = rng.range_i64(1, 62) as f64;
                    let m = rng.range_i64(1, 62) as f64;
                    (n, m, truth.estimate(n as usize, m) + rng.normal_ms(0.0, 1e-3))
                })
                .collect::<Vec<_>>(),
        );
        let c = Calibration::from_measurements(&samples, 1.0, 5.0).unwrap();
        let edge = c.get(DeviceKind::Edge, "gru_fr_en").unwrap();
        let cloud = c.get(DeviceKind::Cloud, "gru_fr_en").unwrap();
        assert!((edge.texe.alpha_m / cloud.texe.alpha_m - 5.0).abs() < 0.01);
        assert!((edge.texe.alpha_m - truth.alpha_m).abs() < 4e-4);
    }

    #[test]
    fn from_measurements_rejects_bad_factors() {
        let samples = BTreeMap::new();
        assert!(Calibration::from_measurements(&samples, 0.0, 5.0).is_err());
        assert!(Calibration::from_measurements(&samples, 1.0, -1.0).is_err());
    }
}
