//! Gateway-side energy model — the second objective of the CI literature
//! the paper builds on (its intro: CI "optimizes the latency and energy
//! consumption"; Neurosurgeon [4] switches between latency and energy
//! targets). The paper evaluates latency only; this module adds the
//! energy view as a first-class extension (`cnmt experiment energy`).
//!
//! Perspective: the **edge gateway's battery/thermal budget** (the
//! quantity an embedded deployment cares about). A request costs
//!
//! * executed locally:  `E = P_busy · T_exe,edge`
//! * offloaded:         `E = P_radio · T_tx` (radio active for the round
//!   trip; the cloud's energy is not the gateway's problem)
//!
//! Defaults approximate a Jetson-TX2-class board (≈9 W busy GPU+SoC) and
//! an active WiFi/LTE radio (≈1.5 W).

/// Edge-gateway power model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyModel {
    /// Power while running inference locally (W).
    pub p_busy_w: f64,
    /// Radio power while a transfer/round trip is in flight (W).
    pub p_radio_w: f64,
    /// Idle floor (W) — charged for the request duration regardless of
    /// placement (board is on either way); included so energy *savings*
    /// are not overstated.
    pub p_idle_w: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Jetson TX2: ~7.5-15 W under GPU load, ~1.9 W idle;
        // WiFi/LTE active radio ~1-2 W.
        EnergyModel { p_busy_w: 9.0, p_radio_w: 1.5, p_idle_w: 1.9 }
    }
}

impl EnergyModel {
    /// Gateway energy (J) for a locally-executed request.
    pub fn local_energy(&self, t_exe_s: f64) -> f64 {
        (self.p_busy_w + self.p_idle_w) * t_exe_s
    }

    /// Gateway energy (J) for an offloaded request: radio for the round
    /// trip, idle while the cloud computes.
    pub fn offload_energy(&self, t_tx_s: f64, t_cloud_s: f64) -> f64 {
        (self.p_radio_w + self.p_idle_w) * t_tx_s + self.p_idle_w * t_cloud_s
    }

    /// Energy-aware placement (the extension policy): offload when the
    /// gateway-side energy of offloading undercuts local execution,
    /// using the same estimated quantities as the latency rule.
    pub fn prefer_offload(
        &self,
        t_edge_est: f64,
        t_cloud_est: f64,
        t_tx_est: f64,
    ) -> bool {
        self.offload_energy(t_tx_est, t_cloud_est) < self.local_energy(t_edge_est)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_scales_with_exec_time() {
        let e = EnergyModel::default();
        assert!((e.local_energy(1.0) - 10.9).abs() < 1e-12);
        assert!(e.local_energy(2.0) > e.local_energy(1.0));
    }

    #[test]
    fn offload_cheaper_for_long_requests() {
        // Long local execution burns busy power; offloading the same
        // request costs only radio+idle — energy favours the cloud more
        // aggressively than latency does.
        let e = EnergyModel::default();
        let local = e.local_energy(0.5); // 0.5 s on the edge GPU
        let off = e.offload_energy(0.1, 0.1); // 100 ms RTT, 100 ms cloud
        assert!(off < local, "offload {off} J vs local {local} J");
        assert!(e.prefer_offload(0.5, 0.1, 0.1));
    }

    #[test]
    fn offload_wasteful_for_tiny_requests_on_slow_net() {
        let e = EnergyModel::default();
        // 5 ms local vs a 300 ms round trip: radio energy dominates.
        assert!(!e.prefer_offload(0.005, 0.001, 0.3));
    }
}
