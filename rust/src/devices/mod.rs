//! Device substrate: the edge gateway and cloud server.
//!
//! The paper's testbed is an NVIDIA Jetson TX2 (edge GW) and a Xeon +
//! Titan XP server (cloud), both running PyTorch. This environment has
//! a single CPU PJRT backend, so (DESIGN.md §4) devices appear in two
//! forms:
//!
//! * [`sim::SimDevice`] — ground-truth execution-time models (linear in
//!   N and M with heteroscedastic noise), with coefficients either from
//!   [`calibration`] (fitted on real PJRT runs, scaled per device) or
//!   from the built-in paper-shaped defaults. Used by the 100k-request
//!   experiment harness.
//! * `runtime::Seq2SeqEngine` (see `crate::runtime`, behind the `pjrt`
//!   cargo feature) — real PJRT
//!   execution, used by the examples, the calibration pass and the
//!   end-to-end gateway.

pub mod calibration;
pub mod energy;
pub mod sim;

pub use calibration::{Calibration, DeviceTimeModel};
pub use energy::EnergyModel;
pub use sim::{DeviceKind, SimDevice};
