//! The C-NMT decision engine (paper eq. 1 + eq. 2).
//!
//! Per request the router evaluates, in O(1):
//!
//! ```text
//! M̂        = γ·N + δ                       (N→M regressor, per lang pair)
//! T̂_exe,e  = αN,e·N + αM,e·M̂ + βe          (edge T_exe plane)
//! T̂_exe,c  = αN,c·N + αM,c·M̂ + βc          (cloud T_exe plane)
//! d        = edge  if  T̂_exe,e ≤ T̂_tx + T̂_exe,c  else cloud
//! ```
//!
//! with `T̂_tx` maintained online from timestamped request/response pairs
//! ([`crate::predictor::TtxEstimator`]). The Naive baseline replaces `M̂`
//! with the dataset's constant mean; the static policies skip estimation.

use crate::devices::DeviceKind;
use crate::predictor::{N2mRegressor, TexeModel, TtxEstimator, TtxLine};
use crate::{Error, Result};

use super::policy::PolicyKind;

/// Everything the router computed for one decision (reported by the
/// experiment drivers; also useful for debugging the boundary).
#[derive(Debug, Clone, Copy)]
pub struct DecisionTrace {
    /// Device the policy picked.
    pub device: DeviceKind,
    /// M̂ used (NaN for non-predictive policies).
    pub m_est: f64,
    /// Estimated edge execution time (s).
    pub t_edge_est: f64,
    /// Estimated cloud execution time, excluding network (s).
    pub t_cloud_est: f64,
    /// T_tx estimate used (s).
    pub ttx_est: f64,
}

impl DecisionTrace {
    /// Signed expected-latency gap between the two sides of the loaded
    /// eq. 1 — `(T̂_exe,e + Ŵ_e) − (T̂_tx + T̂_exe,c + Ŵ_c)` — with the
    /// same wait terms that produced this decision. Negative means the
    /// edge looked faster. NaN for non-predictive policies.
    ///
    /// A small `|margin|` means the decision sits inside the model's
    /// error bar: committing to either device is a coin flip, which is
    /// exactly when hedged dispatch
    /// ([`crate::scheduler::Dispatcher::submit_hedged`]) pays off.
    pub fn loaded_margin_s(&self, edge_wait_s: f64, cloud_wait_s: f64) -> f64 {
        (self.t_edge_est + edge_wait_s) - (self.ttx_est + self.t_cloud_est + cloud_wait_s)
    }
}

/// The per-(model, language-pair) decision engine.
#[derive(Debug, Clone)]
pub struct Router {
    policy: PolicyKind,
    texe_edge: TexeModel,
    texe_cloud: TexeModel,
    n2m: N2mRegressor,
    ttx: TtxEstimator,
    /// Refit payload-size → T_tx law; overrides the EWMA for decisions
    /// once installed ([`Router::set_ttx_line`]).
    ttx_line: Option<TtxLine>,
    ttx_prior_s: f64,
    decisions: u64,
}

/// Builder — makes the wiring explicit at call sites.
#[derive(Debug, Clone)]
pub struct RouterBuilder {
    policy: PolicyKind,
    texe_edge: Option<TexeModel>,
    texe_cloud: Option<TexeModel>,
    n2m: Option<N2mRegressor>,
    ttx_alpha: f64,
    ttx_prior_s: f64,
}

impl RouterBuilder {
    /// Builder for `policy` with default T_tx settings.
    pub fn new(policy: PolicyKind) -> Self {
        RouterBuilder {
            policy,
            texe_edge: None,
            texe_cloud: None,
            n2m: None,
            ttx_alpha: 0.3,
            ttx_prior_s: 0.05,
        }
    }

    /// Set both execution-time planes.
    pub fn texe(mut self, edge: TexeModel, cloud: TexeModel) -> Self {
        self.texe_edge = Some(edge);
        self.texe_cloud = Some(cloud);
        self
    }

    /// Set the N→M regressor.
    pub fn n2m(mut self, reg: N2mRegressor) -> Self {
        self.n2m = Some(reg);
        self
    }

    /// Set the T_tx EWMA smoothing factor and prior.
    pub fn ttx(mut self, alpha: f64, prior_s: f64) -> Self {
        self.ttx_alpha = alpha;
        self.ttx_prior_s = prior_s;
        self
    }

    /// Validate and build the router.
    pub fn build(self) -> Result<Router> {
        let needs_models = !matches!(
            self.policy,
            PolicyKind::EdgeOnly | PolicyKind::CloudOnly
        );
        let texe_edge = match (needs_models, self.texe_edge) {
            (true, None) => {
                return Err(Error::Config(format!(
                    "policy {} needs T_exe models",
                    self.policy.id()
                )))
            }
            (_, t) => t.unwrap_or_else(|| TexeModel::from_coeffs(0.0, 0.0, 0.0)),
        };
        let texe_cloud = self
            .texe_cloud
            .unwrap_or_else(|| TexeModel::from_coeffs(0.0, 0.0, 0.0));
        if matches!(self.policy, PolicyKind::Cnmt) && self.n2m.is_none() {
            return Err(Error::Config("C-NMT policy needs the N→M regressor".into()));
        }
        Ok(Router {
            policy: self.policy,
            texe_edge,
            texe_cloud,
            n2m: self.n2m.unwrap_or_else(|| N2mRegressor::from_coeffs(1.0, 0.0)),
            ttx: TtxEstimator::new(self.ttx_alpha),
            ttx_line: None,
            ttx_prior_s: self.ttx_prior_s,
            decisions: 0,
        })
    }
}

impl Router {
    /// The policy this router implements.
    pub fn policy(&self) -> PolicyKind {
        self.policy
    }

    /// Decisions made so far.
    pub fn decisions(&self) -> u64 {
        self.decisions
    }

    /// The N→M regressor in use.
    pub fn n2m(&self) -> &N2mRegressor {
        &self.n2m
    }

    /// Feed a timestamped network observation (from an offloaded
    /// request's request/response timestamps, or a gateway heartbeat).
    pub fn observe_ttx(&mut self, now_s: f64, rtt_s: f64) {
        self.ttx.observe(now_s, rtt_s);
    }

    /// Replace both execution-time planes — the online-refit hook. An
    /// adaptive harness feeds observed completions to a pair of
    /// [`crate::predictor::RlsPlane`]s and installs their current
    /// coefficients here, so subsequent decisions use planes that track
    /// the hardware instead of the offline characterisation.
    pub fn set_texe(&mut self, edge: TexeModel, cloud: TexeModel) {
        self.texe_edge = edge;
        self.texe_cloud = cloud;
    }

    /// Install (or clear) the refit payload-size → T_tx law — the
    /// network-side twin of [`Router::set_texe`]. While installed,
    /// predictive decisions estimate `T̂_tx = a·(N + M̂) + b` per request
    /// instead of reading the size-blind EWMA; an adaptive harness feeds
    /// observed transfers to a [`crate::predictor::RlsLine`] and keeps
    /// the law current here once warmed up
    /// ([`crate::sim::AdaptiveOpts::refit_min_obs`]).
    pub fn set_ttx_line(&mut self, line: Option<TtxLine>) {
        self.ttx_line = line;
    }

    /// The refit T_tx law currently installed, if any.
    pub fn ttx_line(&self) -> Option<TtxLine> {
        self.ttx_line
    }

    /// The execution-time planes currently used for decisions
    /// (`(edge, cloud)`).
    pub fn texe(&self) -> (&TexeModel, &TexeModel) {
        (&self.texe_edge, &self.texe_cloud)
    }

    /// Is the T_tx estimate stale at `now_s`?
    pub fn ttx_stale(&self, now_s: f64, max_age_s: f64) -> bool {
        self.ttx.is_stale(now_s, max_age_s)
    }

    /// Current T_tx estimate (prior until observations arrive).
    pub fn ttx_estimate(&self) -> f64 {
        self.ttx.estimate_or(self.ttx_prior_s)
    }

    /// Decide the target device for a request with source length `n`,
    /// assuming both devices are idle (the paper's setting).
    ///
    /// This is the paper's entire runtime overhead: two plane evaluations
    /// and a comparison (`cnmt bench bench_decision` measures it).
    pub fn decide(&mut self, n: usize) -> DecisionTrace {
        self.decide_loaded(n, 0.0, 0.0)
    }

    /// Queue-aware decision: eq. 1 with an expected queueing-delay term
    /// on each side (supplied by
    /// [`crate::scheduler::Dispatcher::expected_wait_s`]):
    ///
    /// ```text
    /// d = edge  if  T̂_exe,e + Ŵ_e ≤ T̂_tx + T̂_exe,c + Ŵ_c  else cloud
    /// ```
    ///
    /// With both waits zero this is exactly [`Router::decide`]. Still
    /// O(1): the wait estimates are maintained incrementally by the
    /// scheduler, not computed here.
    pub fn decide_loaded(
        &mut self,
        n: usize,
        edge_wait_s: f64,
        cloud_wait_s: f64,
    ) -> DecisionTrace {
        self.decisions += 1;
        let ttx_est = self.ttx.estimate_or(self.ttx_prior_s);
        match self.policy {
            PolicyKind::EdgeOnly => DecisionTrace {
                device: DeviceKind::Edge,
                m_est: f64::NAN,
                t_edge_est: f64::NAN,
                t_cloud_est: f64::NAN,
                ttx_est,
            },
            PolicyKind::CloudOnly => DecisionTrace {
                device: DeviceKind::Cloud,
                m_est: f64::NAN,
                t_edge_est: f64::NAN,
                t_cloud_est: f64::NAN,
                ttx_est,
            },
            PolicyKind::Oracle => {
                // The Oracle is resolved by the harness (it needs ground
                // truth); the router defers.
                DecisionTrace {
                    device: DeviceKind::Edge,
                    m_est: f64::NAN,
                    t_edge_est: f64::NAN,
                    t_cloud_est: f64::NAN,
                    ttx_est,
                }
            }
            PolicyKind::Naive { mean_m } => {
                self.decide_with_m(n, mean_m, ttx_est, edge_wait_s, cloud_wait_s)
            }
            PolicyKind::Cnmt => {
                let m_est = self.n2m.predict(n);
                self.decide_with_m(n, m_est, ttx_est, edge_wait_s, cloud_wait_s)
            }
        }
    }

    /// Decide with an externally-supplied output-length estimate — the
    /// hook the estimator-ablation harness uses to swap in alternative
    /// N→M estimators ([`crate::predictor::LengthEstimator`]).
    pub fn decide_given_m(&mut self, n: usize, m_est: f64) -> DecisionTrace {
        self.decisions += 1;
        let ttx_est = self.ttx.estimate_or(self.ttx_prior_s);
        self.decide_with_m(n, m_est, ttx_est, 0.0, 0.0)
    }

    fn decide_with_m(
        &self,
        n: usize,
        m_est: f64,
        ttx_est: f64,
        edge_wait_s: f64,
        cloud_wait_s: f64,
    ) -> DecisionTrace {
        // Refit T_tx law (when installed) knows the payload size the
        // EWMA collapses away: N source tokens out, M̂ translation back.
        let ttx_est = match &self.ttx_line {
            Some(line) => line.estimate(n as f64 + m_est),
            None => ttx_est,
        };
        let t_edge_est = self.texe_edge.estimate(n, m_est);
        let t_cloud_est = self.texe_cloud.estimate(n, m_est);
        // Paper eq. 1, plus the expected-wait term on each side.
        let device = if t_edge_est + edge_wait_s <= ttx_est + t_cloud_est + cloud_wait_s {
            DeviceKind::Edge
        } else {
            DeviceKind::Cloud
        };
        DecisionTrace { device, m_est, t_edge_est, t_cloud_est, ttx_est }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::{N2mRegressor, TexeModel};

    fn mk_router(policy: PolicyKind) -> Router {
        RouterBuilder::new(policy)
            // edge 4x slower than cloud
            .texe(
                TexeModel::from_coeffs(1e-3, 2e-3, 5e-3),
                TexeModel::from_coeffs(0.25e-3, 0.5e-3, 2e-3),
            )
            .n2m(N2mRegressor::from_coeffs(0.8, 0.5))
            .ttx(0.3, 0.05)
            .build()
            .unwrap()
    }

    #[test]
    fn short_inputs_stay_on_edge_long_go_to_cloud() {
        let mut r = mk_router(PolicyKind::Cnmt);
        r.observe_ttx(0.0, 0.040);
        let short = r.decide(3);
        assert_eq!(short.device, DeviceKind::Edge, "{short:?}");
        let long = r.decide(60);
        assert_eq!(long.device, DeviceKind::Cloud, "{long:?}");
        assert_eq!(r.decisions(), 2);
    }

    #[test]
    fn higher_rtt_expands_edge_region() {
        // The same request flips to edge when the network degrades.
        let mut r = mk_router(PolicyKind::Cnmt);
        r.observe_ttx(0.0, 0.010);
        let n = 30;
        let fast_net = r.decide(n);
        assert_eq!(fast_net.device, DeviceKind::Cloud);
        for i in 0..60 {
            r.observe_ttx(i as f64, 0.500);
        }
        let slow_net = r.decide(n);
        assert_eq!(slow_net.device, DeviceKind::Edge);
    }

    #[test]
    fn cnmt_uses_n2m_naive_uses_mean() {
        let mut c = mk_router(PolicyKind::Cnmt);
        let tr = c.decide(20);
        assert!((tr.m_est - (0.8 * 20.0 + 0.5)).abs() < 1e-12);
        let mut n = RouterBuilder::new(PolicyKind::Naive { mean_m: 11.5 })
            .texe(
                TexeModel::from_coeffs(1e-3, 2e-3, 5e-3),
                TexeModel::from_coeffs(0.25e-3, 0.5e-3, 2e-3),
            )
            .build()
            .unwrap();
        let tn = n.decide(20);
        assert!((tn.m_est - 11.5).abs() < 1e-12);
    }

    #[test]
    fn static_policies_never_consult_models() {
        let mut e = RouterBuilder::new(PolicyKind::EdgeOnly).build().unwrap();
        let mut c = RouterBuilder::new(PolicyKind::CloudOnly).build().unwrap();
        for n in [1, 10, 62] {
            assert_eq!(e.decide(n).device, DeviceKind::Edge);
            assert_eq!(c.decide(n).device, DeviceKind::Cloud);
        }
    }

    #[test]
    fn builder_rejects_missing_models() {
        assert!(RouterBuilder::new(PolicyKind::Cnmt).build().is_err());
        let only_texe = RouterBuilder::new(PolicyKind::Cnmt).texe(
            TexeModel::from_coeffs(0.0, 0.0, 0.0),
            TexeModel::from_coeffs(0.0, 0.0, 0.0),
        );
        assert!(only_texe.build().is_err()); // still no n2m
        assert!(RouterBuilder::new(PolicyKind::EdgeOnly).build().is_ok());
    }

    #[test]
    fn ttx_prior_used_before_observations() {
        let r = mk_router(PolicyKind::Cnmt);
        assert!((r.ttx_estimate() - 0.05).abs() < 1e-12);
        assert!(r.ttx_stale(100.0, 10.0));
    }

    #[test]
    fn loaded_decision_reduces_to_eq1_when_idle() {
        let mut a = mk_router(PolicyKind::Cnmt);
        let mut b = mk_router(PolicyKind::Cnmt);
        a.observe_ttx(0.0, 0.040);
        b.observe_ttx(0.0, 0.040);
        for n in [1usize, 10, 30, 62] {
            assert_eq!(a.decide(n).device, b.decide_loaded(n, 0.0, 0.0).device);
        }
    }

    #[test]
    fn edge_backlog_diverts_to_cloud_and_back() {
        let mut r = mk_router(PolicyKind::Cnmt);
        r.observe_ttx(0.0, 0.040);
        let n = 3; // firmly edge when idle
        assert_eq!(r.decide_loaded(n, 0.0, 0.0).device, DeviceKind::Edge);
        // A big edge backlog flips it to the cloud...
        assert_eq!(r.decide_loaded(n, 5.0, 0.0).device, DeviceKind::Cloud);
        // ...and a symmetric cloud backlog flips it back.
        assert_eq!(r.decide_loaded(n, 5.0, 5.1).device, DeviceKind::Edge);
    }

    #[test]
    fn margin_is_signed_gap_and_zero_at_the_boundary() {
        let mut r = mk_router(PolicyKind::Cnmt);
        r.observe_ttx(0.0, 0.040);
        let tr = r.decide_loaded(10, 0.3, 0.1);
        let direct = (tr.t_edge_est + 0.3) - (tr.ttx_est + tr.t_cloud_est + 0.1);
        assert!((tr.loaded_margin_s(0.3, 0.1) - direct).abs() < 1e-15);
        // The decision agrees with the margin's sign.
        let edge_picked = tr.device == DeviceKind::Edge;
        assert_eq!(edge_picked, tr.loaded_margin_s(0.3, 0.1) <= 0.0);
        // Non-predictive policies expose no margin.
        let mut e = RouterBuilder::new(PolicyKind::EdgeOnly).build().unwrap();
        assert!(e.decide(10).loaded_margin_s(0.0, 0.0).is_nan());
    }

    #[test]
    fn set_texe_refits_the_decision() {
        let mut r = mk_router(PolicyKind::Cnmt);
        r.observe_ttx(0.0, 0.040);
        let n = 3; // firmly edge under the offline planes
        assert_eq!(r.decide(n).device, DeviceKind::Edge);
        // Edge degrades 100x (thermal throttling): refit flips the call.
        let (edge, cloud) = {
            let (e, c) = r.texe();
            (*e, *c)
        };
        let slow_edge = TexeModel::from_coeffs(
            edge.alpha_n * 100.0,
            edge.alpha_m * 100.0,
            edge.beta * 100.0,
        );
        r.set_texe(slow_edge, cloud);
        assert_eq!(r.decide(n).device, DeviceKind::Cloud);
    }

    #[test]
    fn ttx_line_overrides_ewma_and_is_size_aware() {
        use crate::predictor::TtxLine;
        let mut r = mk_router(PolicyKind::Cnmt);
        r.observe_ttx(0.0, 0.040);
        let n = 30;
        // EWMA path first.
        let before = r.decide(n);
        assert!((before.ttx_est - 0.040).abs() < 1e-12);
        // Install a law that matches the EWMA at this size: decision
        // identical, provenance different.
        let m_est = 0.8 * n as f64 + 0.5;
        let size = n as f64 + m_est;
        r.set_ttx_line(Some(TtxLine { slope: 0.0, intercept: 0.040 }));
        let flat = r.decide(n);
        assert_eq!(flat.device, before.device);
        assert!((flat.ttx_est - 0.040).abs() < 1e-12);
        // A steep size term must raise the estimate for long requests —
        // and push the long request toward the edge.
        r.set_ttx_line(Some(TtxLine { slope: 0.010, intercept: 0.040 }));
        let steep = r.decide(n);
        assert!((steep.ttx_est - (0.040 + 0.010 * size)).abs() < 1e-12);
        assert_eq!(steep.device, DeviceKind::Edge, "expensive network ⇒ stay local");
        // Clearing the law restores the EWMA.
        r.set_ttx_line(None);
        assert!(r.ttx_line().is_none());
        assert!((r.decide(n).ttx_est - 0.040).abs() < 1e-12);
    }

    #[test]
    fn static_policies_ignore_waits() {
        let mut e = RouterBuilder::new(PolicyKind::EdgeOnly).build().unwrap();
        let mut c = RouterBuilder::new(PolicyKind::CloudOnly).build().unwrap();
        assert_eq!(e.decide_loaded(10, 99.0, 0.0).device, DeviceKind::Edge);
        assert_eq!(c.decide_loaded(10, 0.0, 99.0).device, DeviceKind::Cloud);
    }

    #[test]
    fn boundary_monotone_in_n() {
        // With both planes increasing in N but edge steeper, once the
        // decision flips to cloud it stays cloud for larger N.
        let mut r = mk_router(PolicyKind::Cnmt);
        r.observe_ttx(0.0, 0.030);
        let mut seen_cloud = false;
        for n in 1..=62 {
            let d = r.decide(n).device;
            if seen_cloud {
                assert_eq!(d, DeviceKind::Cloud, "flip-back at n={n}");
            }
            if d == DeviceKind::Cloud {
                seen_cloud = true;
            }
        }
        assert!(seen_cloud, "boundary never crossed");
    }
}
