//! Multi-level collaborative inference (extension).
//!
//! The paper's related work extends CI "to more than two offloading
//! levels (e.g. end-device, edge gateway and cloud)" (DeePar [8]; CRIME
//! [11] for RNNs). This module generalises the C-NMT decision rule from
//! the 2-device eq. 1 to an N-tier hierarchy:
//!
//! ```text
//! d = argmin_i  Σ_{j ≤ i} T̂_tx,j  +  T̂_exe,i (N, M̂)
//! ```
//!
//! where tier 0 is where the request originates (end device) and each
//! hop `j` pays that link's online-estimated round-trip cost. With two
//! tiers and zero first-hop cost this reduces exactly to eq. 1 (tested).

use crate::devices::DeviceTimeModel;
use crate::predictor::{N2mRegressor, TexeModel, TtxEstimator};
use crate::util::Rng;
use crate::{Error, Result};

/// One tier of the hierarchy.
#[derive(Debug, Clone)]
pub struct Tier {
    /// Tier name (reports).
    pub name: String,
    /// Fitted execution-time plane for this tier's hardware.
    pub texe: TexeModel,
    /// Ground-truth time model (simulation only).
    pub truth: DeviceTimeModel,
    /// Estimator for the link *into* this tier (tier 0: unused/zero).
    pub ttx: TtxEstimator,
    /// Prior for that link before any observation (seconds).
    pub ttx_prior_s: f64,
}

/// The multi-level router.
#[derive(Debug, Clone)]
pub struct MultiRouter {
    tiers: Vec<Tier>,
    n2m: N2mRegressor,
    decisions: u64,
}

/// One decision's estimated totals per tier.
#[derive(Debug, Clone)]
pub struct MultiDecision {
    /// Chosen tier index.
    pub tier: usize,
    /// Estimated total latency per tier (seconds).
    pub totals: Vec<f64>,
    /// M̂ used for the decision.
    pub m_est: f64,
}

impl MultiRouter {
    /// Router over ≥ 2 tiers sharing one N→M regressor.
    pub fn new(tiers: Vec<Tier>, n2m: N2mRegressor) -> Result<MultiRouter> {
        if tiers.len() < 2 {
            return Err(Error::Config("multi-level router needs >= 2 tiers".into()));
        }
        Ok(MultiRouter { tiers, n2m, decisions: 0 })
    }

    /// The configured tiers.
    pub fn tiers(&self) -> &[Tier] {
        &self.tiers
    }

    /// Routing decisions made so far.
    pub fn decisions(&self) -> u64 {
        self.decisions
    }

    /// Feed a link observation (request/response timestamps on the hop
    /// into `tier`).
    pub fn observe_link(&mut self, tier: usize, now_s: f64, rtt_s: f64) {
        if tier > 0 && tier < self.tiers.len() {
            self.tiers[tier].ttx.observe(now_s, rtt_s);
        }
    }

    /// Generalised eq. 1: argmin over tiers of cumulative-tx + exec.
    pub fn decide(&mut self, n: usize) -> MultiDecision {
        self.decisions += 1;
        let m_est = self.n2m.predict(n);
        let mut totals = Vec::with_capacity(self.tiers.len());
        let mut cum_tx = 0.0;
        for (i, t) in self.tiers.iter().enumerate() {
            if i > 0 {
                cum_tx += t.ttx.estimate_or(t.ttx_prior_s);
            }
            totals.push(cum_tx + t.texe.estimate(n, m_est));
        }
        let tier = totals
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        MultiDecision { tier, totals, m_est }
    }

    /// Ground-truth cost of running at `tier` (simulation): sampled exec
    /// time + the true per-hop link costs.
    pub fn true_cost(
        &mut self,
        tier: usize,
        n: usize,
        m: usize,
        link_rtts: &[f64],
        rng: &mut Rng,
    ) -> f64 {
        let mut cost = 0.0;
        for (i, _) in self.tiers.iter().enumerate().take(tier + 1).skip(1) {
            cost += link_rtts[i - 1];
        }
        cost + self.tiers[tier].truth.sample(n, m, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::TexeModel;

    fn tier(name: &str, an: f64, am: f64, b: f64, prior: f64) -> Tier {
        let texe = TexeModel::from_coeffs(an, am, b);
        Tier {
            name: name.into(),
            texe,
            truth: DeviceTimeModel { texe, noise_frac: 0.0, noise_floor_s: 0.0 },
            ttx: TtxEstimator::new(0.3),
            ttx_prior_s: prior,
        }
    }

    fn three_tiers() -> MultiRouter {
        MultiRouter::new(
            vec![
                // end device: slow silicon but zero fixed/link cost.
                tier("end", 4e-3, 10e-3, 1e-3, 0.0),
                // gateway: 3x faster; cheap WLAN hop.
                tier("gw", 1.3e-3, 3.3e-3, 8e-3, 0.008),
                // cloud: 12x faster than end; WAN hop.
                tier("cloud", 0.3e-3, 0.8e-3, 30e-3, 0.060),
            ],
            N2mRegressor::from_coeffs(0.9, 0.5),
        )
        .unwrap()
    }

    #[test]
    fn short_stays_on_device_medium_gateway_long_cloud() {
        let mut r = three_tiers();
        assert_eq!(r.decide(1).tier, 0, "{:?}", r.decide(1));
        assert_eq!(r.decide(12).tier, 1, "{:?}", r.decide(12));
        assert_eq!(r.decide(60).tier, 2, "{:?}", r.decide(60));
    }

    #[test]
    fn reduces_to_eq1_with_two_tiers() {
        // 2-tier multi-router must agree with the pairwise rule.
        let mut r = MultiRouter::new(
            vec![
                tier("edge", 1.8e-3, 4.8e-3, 8e-3, 0.0),
                tier("cloud", 0.3e-3, 0.8e-3, 33e-3, 0.050),
            ],
            N2mRegressor::from_coeffs(1.05, 0.4),
        )
        .unwrap();
        for n in 1..=62 {
            let d = r.decide(n);
            let m = 1.05 * n as f64 + 0.4;
            let te = 1.8e-3 * n as f64 + 4.8e-3 * m + 8e-3;
            let tc = 0.050 + 0.3e-3 * n as f64 + 0.8e-3 * m + 33e-3;
            let want = if te <= tc { 0 } else { 1 };
            assert_eq!(d.tier, want, "n={n}");
        }
    }

    #[test]
    fn link_observations_move_the_boundary() {
        let mut r = three_tiers();
        let n = 30;
        let before = r.decide(n).tier;
        // WAN degrades badly: cloud should lose its region.
        for i in 0..60 {
            r.observe_link(2, i as f64, 1.0);
        }
        let after = r.decide(n);
        assert!(after.tier < 2 || before != 2, "{after:?}");
        assert!(after.totals[2] > after.totals[after.tier]);
    }

    #[test]
    fn true_cost_accumulates_hops() {
        let mut r = three_tiers();
        let mut rng = Rng::new(1);
        let links = [0.01, 0.05];
        let c0 = r.true_cost(0, 10, 10, &links, &mut rng);
        let c2 = r.true_cost(2, 10, 10, &links, &mut rng);
        // Tier-2 cost includes both hops.
        assert!(c2 > 0.06, "c2 {c2}");
        assert!(c0 < 0.2);
    }

    #[test]
    fn rejects_single_tier() {
        assert!(MultiRouter::new(
            vec![tier("x", 1e-3, 1e-3, 0.0, 0.0)],
            N2mRegressor::from_coeffs(1.0, 0.0)
        )
        .is_err());
    }
}
