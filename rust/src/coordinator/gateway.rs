//! The serving gateway: real PJRT execution behind the C-NMT router.
//!
//! Topology mirrors the paper's §II-C deployment: end-nodes send
//! translation requests to an **edge gateway**, which either serves them
//! locally or offloads to a **cloud server**. Here both devices are
//! backed by the same CPU PJRT runtime (DESIGN.md §4), so the physics of
//! the paper's testbed are reproduced with two knobs:
//!
//! * `edge_slowdown` — stretches edge execution time (Jetson-vs-server
//!   silicon gap) by sleeping the residual after the real execution;
//! * an [`RttTrace`] replayed against the gateway clock — offloaded
//!   requests pay the simulated network round trip, and their
//!   request/response timestamps feed the router's T_tx estimator
//!   exactly as in the paper.
//!
//! Engines are not `Send` (PJRT client is `Rc`-based), so each device is
//! an **actor**: a dedicated OS thread that owns its engine and serves
//! jobs from an mpsc queue — one serial execution stream per device, the
//! same serving discipline the paper's latency model assumes.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::request::Outcome;
use crate::coordinator::router::Router;
use crate::devices::DeviceKind;
use crate::metrics::LatencyRecorder;
use crate::net::RttTrace;
use crate::runtime::{Seq2SeqEngine, TranslateOptions};
use crate::{Error, Result};

/// Gateway configuration.
#[derive(Debug, Clone)]
pub struct GatewayConfig {
    /// Directory holding compiled model artifacts.
    pub artifacts_dir: PathBuf,
    /// Model name served (must exist in the manifest).
    pub model: String,
    /// Multiplier stretching edge execution (1.0 = no stretch).
    pub edge_slowdown: f64,
    /// RTT trace replayed for offloaded requests (None = zero-RTT).
    pub trace: Option<RttTrace>,
    /// Cap on decode steps (None = artifact M_MAX).
    pub max_steps: Option<usize>,
}

struct Job {
    src: Vec<u16>,
    force_steps: Option<usize>,
    max_steps: Option<usize>,
    respond: mpsc::Sender<Result<(f64, usize)>>, // (exec_s, steps)
}

struct DeviceActor {
    tx: mpsc::Sender<Job>,
    handle: Option<JoinHandle<()>>,
}

impl DeviceActor {
    /// Spawn an executor thread owning its own engine.
    fn spawn(
        kind: DeviceKind,
        cfg: &GatewayConfig,
    ) -> Result<DeviceActor> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let artifacts = cfg.artifacts_dir.clone();
        let model = cfg.model.clone();
        let slowdown = if kind == DeviceKind::Edge { cfg.edge_slowdown } else { 1.0 };
        let handle = std::thread::Builder::new()
            .name(format!("cnmt-{}", kind.id()))
            .spawn(move || {
                let engine = match Seq2SeqEngine::load(&artifacts, &model) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(job) = rx.recv() {
                    let t0 = Instant::now();
                    let res = engine.translate(
                        &job.src,
                        TranslateOptions {
                            force_steps: job.force_steps,
                            max_steps: job.max_steps,
                        },
                    );
                    let reply = res.map(|tr| {
                        let mut exec_s = t0.elapsed().as_secs_f64();
                        if slowdown > 1.0 {
                            let extra = exec_s * (slowdown - 1.0);
                            std::thread::sleep(std::time::Duration::from_secs_f64(
                                extra,
                            ));
                            exec_s *= slowdown;
                        }
                        (exec_s, tr.steps)
                    });
                    let _ = job.respond.send(reply);
                }
            })
            .map_err(|e| Error::Serve(format!("spawn {}: {e}", kind.id())))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Serve(format!("{} actor died at startup", kind.id())))??;
        Ok(DeviceActor { tx, handle: Some(handle) })
    }
}

/// The gateway: router + two device actors + metrics.
pub struct Gateway {
    router: Mutex<Router>,
    edge: DeviceActor,
    cloud: DeviceActor,
    trace: Option<RttTrace>,
    start: Instant,
    recorder: Arc<Mutex<LatencyRecorder>>,
    max_steps: Option<usize>,
}

impl Gateway {
    /// Start both device actors (loads the model twice: one engine per
    /// device, as in the real two-machine deployment).
    pub fn start(cfg: GatewayConfig, router: Router) -> Result<Gateway> {
        let edge = DeviceActor::spawn(DeviceKind::Edge, &cfg)?;
        let cloud = DeviceActor::spawn(DeviceKind::Cloud, &cfg)?;
        Ok(Gateway {
            router: Mutex::new(router),
            edge,
            cloud,
            trace: cfg.trace,
            start: Instant::now(),
            recorder: Arc::new(Mutex::new(LatencyRecorder::new())),
            max_steps: cfg.max_steps,
        })
    }

    /// Gateway clock (seconds since start) — also the trace replay time.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn rtt_now(&self) -> f64 {
        match &self.trace {
            Some(t) => t.rtt_at(self.now()),
            None => 0.0,
        }
    }

    /// Submit one translation request and wait for its outcome.
    ///
    /// `force_steps` pins the decode length (characterisation runs);
    /// normal requests pass `None` and decode greedily to EOS.
    pub fn submit(&self, id: u64, src: &[u16], force_steps: Option<usize>) -> Result<Outcome> {
        let n = src.len();
        let decision = {
            let mut r = self.router.lock().unwrap();
            r.decide(n)
        };
        let (actor, device) = match decision.device {
            DeviceKind::Edge => (&self.edge, DeviceKind::Edge),
            DeviceKind::Cloud => (&self.cloud, DeviceKind::Cloud),
        };

        // Offloads pay the simulated network round trip, timestamped.
        let (tx_s, sent_at) = if device == DeviceKind::Cloud {
            let rtt = self.rtt_now();
            std::thread::sleep(std::time::Duration::from_secs_f64(rtt));
            (rtt, self.now())
        } else {
            (0.0, self.now())
        };

        let (resp_tx, resp_rx) = mpsc::channel();
        actor
            .tx
            .send(Job {
                src: src.to_vec(),
                force_steps,
                max_steps: self.max_steps,
                respond: resp_tx,
            })
            .map_err(|_| Error::Serve(format!("{} actor gone", device.id())))?;
        let (exec_s, steps) = resp_rx
            .recv()
            .map_err(|_| Error::Serve(format!("{} actor dropped reply", device.id())))??;

        if device == DeviceKind::Cloud {
            // Response timestamp closes the loop for the T_tx estimator.
            let mut r = self.router.lock().unwrap();
            r.observe_ttx(sent_at, tx_s);
        }

        let latency_s = exec_s + tx_s;
        {
            let mut rec = self.recorder.lock().unwrap();
            rec.record(device.id(), latency_s);
            rec.record("all", latency_s);
        }
        Ok(Outcome { id, device, latency_s, exec_s, tx_s, steps })
    }

    /// Metrics snapshot as JSON.
    pub fn metrics(&self) -> crate::util::Json {
        self.recorder.lock().unwrap().to_json()
    }

    /// Routing decisions made so far.
    pub fn decisions(&self) -> u64 {
        self.router.lock().unwrap().decisions()
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        // Close the queues; actors exit their recv loops and join.
        let (t, _r) = mpsc::channel();
        let _ = std::mem::replace(&mut self.edge.tx, t);
        let (t, _r) = mpsc::channel();
        let _ = std::mem::replace(&mut self.cloud.tx, t);
        if let Some(h) = self.edge.handle.take() {
            let _ = h.join();
        }
        if let Some(h) = self.cloud.handle.take() {
            let _ = h.join();
        }
    }
}

// Integration tests live in rust/tests/integration_runtime.rs (they need
// built artifacts).
