//! Request/outcome types shared by the gateway and the simulator.

use crate::corpus::LangPair;
use crate::devices::DeviceKind;

/// One translation request as seen by the coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    /// Caller-assigned request id.
    pub id: u64,
    /// Language pair (selects model + regressor).
    pub pair: LangPair,
    /// Source token ids (content only; runtime appends EOS).
    pub src: Vec<u16>,
    /// Ground-truth output length from the corpus — drives the decode
    /// step count in simulation/characterisation; *never* visible to the
    /// router (it only sees N).
    pub m_real: usize,
    /// Arrival time on the simulation/serving clock (seconds).
    pub arrival_s: f64,
}

impl Request {
    /// Source length (tokens).
    pub fn n(&self) -> usize {
        self.src.len()
    }
}

/// What happened to a request.
#[derive(Debug, Clone)]
pub struct Outcome {
    /// Id of the request this outcome belongs to.
    pub id: u64,
    /// Where the router sent it.
    pub device: DeviceKind,
    /// Total latency charged (exec + network if offloaded), seconds.
    pub latency_s: f64,
    /// Execution-only component (seconds).
    pub exec_s: f64,
    /// Network component (0 for edge), seconds.
    pub tx_s: f64,
    /// Decode steps actually executed (M).
    pub steps: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_n_is_src_len() {
        let r = Request {
            id: 1,
            pair: LangPair::DeEn,
            src: vec![5, 6, 7],
            m_real: 4,
            arrival_s: 0.0,
        };
        assert_eq!(r.n(), 3);
    }
}
