//! The mapping policies evaluated in Table I.

/// Which strategy decides the target device for each request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PolicyKind {
    /// Everything runs on the edge gateway (paper baseline "GW").
    EdgeOnly,
    /// Everything is offloaded to the server (paper baseline "Server").
    CloudOnly,
    /// Ideal lower bound: always picks the device that *will* be faster,
    /// including the true (future) network cost — unaffected by any of
    /// C-NMT's approximations. Only realisable in simulation.
    Oracle,
    /// CI with the paper's eq. 1 but a constant output-length estimate
    /// `M = mean M of the reference dataset` (paper baseline "Naive").
    Naive {
        /// Mean output length of the fit split.
        mean_m: f64,
    },
    /// The paper's C-NMT: eq. 1 with eq. 2's `M̂ = γ·N + δ`.
    Cnmt,
}

impl PolicyKind {
    /// Display id used in reports and CLI flags.
    pub fn id(&self) -> &'static str {
        match self {
            PolicyKind::EdgeOnly => "edge_only",
            PolicyKind::CloudOnly => "cloud_only",
            PolicyKind::Oracle => "oracle",
            PolicyKind::Naive { .. } => "naive",
            PolicyKind::Cnmt => "cnmt",
        }
    }

    /// Parse a CLI id (Naive takes its mean separately).
    pub fn from_id(s: &str, mean_m: f64) -> Option<PolicyKind> {
        match s {
            "edge_only" => Some(PolicyKind::EdgeOnly),
            "cloud_only" => Some(PolicyKind::CloudOnly),
            "oracle" => Some(PolicyKind::Oracle),
            "naive" => Some(PolicyKind::Naive { mean_m }),
            "cnmt" => Some(PolicyKind::Cnmt),
            _ => None,
        }
    }

    /// Does this policy need the router's predictive models?
    pub fn is_predictive(&self) -> bool {
        matches!(self, PolicyKind::Naive { .. } | PolicyKind::Cnmt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_roundtrip() {
        for p in [
            PolicyKind::EdgeOnly,
            PolicyKind::CloudOnly,
            PolicyKind::Oracle,
            PolicyKind::Naive { mean_m: 12.0 },
            PolicyKind::Cnmt,
        ] {
            let back = PolicyKind::from_id(p.id(), 12.0).unwrap();
            assert_eq!(back.id(), p.id());
        }
        assert!(PolicyKind::from_id("nope", 0.0).is_none());
    }

    #[test]
    fn predictive_flags() {
        assert!(PolicyKind::Cnmt.is_predictive());
        assert!(PolicyKind::Naive { mean_m: 1.0 }.is_predictive());
        assert!(!PolicyKind::Oracle.is_predictive());
        assert!(!PolicyKind::EdgeOnly.is_predictive());
    }
}
