//! The C-NMT coordinator — the paper's contribution (L3).
//!
//! * [`request`] — request/outcome types shared by the gateway and the
//!   experiment harness.
//! * [`policy`] — the mapping policies of Table I: C-NMT (eq. 1/2), the
//!   Naive CI baseline (constant mean-M estimate), the Oracle lower
//!   bound, and the two static mappings (GW-only / Server-only).
//! * [`router`] — the decision engine: per-model T_exe planes + the
//!   per-language-pair N→M regressor + the online T_tx estimator,
//!   evaluated per request in O(1) (the paper: "the C-NMT decision has
//!   negligible overheads").
//! * [`gateway`] — a thread-per-device serving gateway over the real PJRT
//!   runtime: end-nodes submit translation requests; the router maps each
//!   to the edge or cloud executor.

#[cfg(feature = "pjrt")]
pub mod gateway;
pub mod multilevel;
pub mod policy;
pub mod request;
pub mod router;

pub use policy::PolicyKind;
pub use request::{Outcome, Request};
pub use router::{DecisionTrace, Router, RouterBuilder};
