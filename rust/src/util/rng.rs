//! Deterministic pseudo-random generation + the distributions the
//! simulation needs (no `rand`/`rand_distr` in the offline crate set).
//!
//! Generator: **xoshiro256\*\*** (Blackman & Vigna) seeded via splitmix64 —
//! fast, high quality, and trivially reproducible across runs, which the
//! experiment harness relies on (every experiment records its seed).

/// Deterministic per-cell seed for sharded sweeps: cell `cell` of a
/// sweep seeded with `master` gets the independent stream seeded by
/// `master ^ (cell+1)·φ64` (splitmix64's golden-ratio increment — the
/// same derivation the load sweep has always used per load point).
///
/// Because every sweep cell re-seeds from this pure function instead of
/// drawing from a shared generator, the parallel runner
/// (`crate::experiments::runner`) produces bit-identical results at any
/// thread count: no cell's stream depends on which thread ran it or in
/// what order.
pub fn cell_seed(master: u64, cell: u64) -> u64 {
    master ^ cell.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15)
}

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the last Box-Muller draw.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-component rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    /// Next raw 64-bit output of the generator.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift, debiased).
    pub fn usize(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::usize(0)");
        let n64 = n as u64;
        let threshold = n64.wrapping_neg() % n64;
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n64 as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.usize((hi - lo + 1) as usize) as i64
    }

    /// Bernoulli trial.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson (Knuth for small mean, normal approximation for large).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal_ms(mean, mean.sqrt());
            z.max(0.0).round() as u64
        }
    }

    /// Sample an index from unnormalised weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted: all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_unbiased_coverage() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.usize(7)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn poisson_mean_matches() {
        let mut r = Rng::new(4);
        for lam in [0.5, 3.0, 12.0, 80.0] {
            let n = 20_000;
            let total: u64 = (0..n).map(|_| r.poisson(lam)).sum();
            let mean = total as f64 / n as f64;
            assert!(
                (mean - lam).abs() < lam.sqrt() * 0.15 + 0.05,
                "lambda {lam}: mean {mean}"
            );
        }
    }

    #[test]
    fn exponential_mean_matches() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let total: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        assert!((total / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(6);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn cell_seed_is_pure_and_spreads() {
        // Purity (the parallel-runner determinism argument) and basic
        // stream separation between neighbouring cells.
        assert_eq!(cell_seed(7, 3), cell_seed(7, 3));
        assert_eq!(
            cell_seed(20220315, 0),
            20220315 ^ 0x9E3779B97F4A7C15,
            "cell 0 must match the sweep's historical per-point seed"
        );
        let mut seen = std::collections::BTreeSet::new();
        for cell in 0..64 {
            assert!(seen.insert(cell_seed(42, cell)), "cell seed collision");
        }
        let mut a = Rng::new(cell_seed(42, 0));
        let mut b = Rng::new(cell_seed(42, 1));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(8);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }
}
