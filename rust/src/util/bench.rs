//! Micro-benchmark harness (criterion is not in the offline crate set).
//!
//! Used by every target under `rust/benches/`: warmup, timed iterations
//! with per-iteration sampling, robust summary (mean/p50/p95/min) and an
//! aligned report table. Deterministic workloads + enough samples give
//! run-to-run variation of a few percent, which is all the perf pass
//! needs to rank bottlenecks (EXPERIMENTS.md §Perf).

use std::time::Instant;

use super::json::Json;

/// One benchmark's summary statistics.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Bench label (reported verbatim).
    pub name: String,
    /// Samples measured.
    pub samples: usize,
    /// Mean nanoseconds per iteration.
    pub mean_ns: f64,
    /// Median nanoseconds per iteration.
    pub p50_ns: f64,
    /// 95th-percentile nanoseconds per iteration.
    pub p95_ns: f64,
    /// Fastest observed iteration (ns).
    pub min_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: f64,
}

impl BenchResult {
    /// Iterations (× items) per second at the mean.
    pub fn throughput_per_s(&self) -> f64 {
        if self.mean_ns == 0.0 {
            f64::NAN
        } else {
            self.items_per_iter * 1e9 / self.mean_ns
        }
    }

    /// Serialise for machine-readable bench reports
    /// (`cnmt bench sched --json` → `BENCH_sched.json`).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("name", Json::Str(self.name.clone()))
            .set("samples", Json::Num(self.samples as f64))
            .set("mean_ns", Json::Num(self.mean_ns))
            .set("p50_ns", Json::Num(self.p50_ns))
            .set("p95_ns", Json::Num(self.p95_ns))
            .set("min_ns", Json::Num(self.min_ns))
            .set("items_per_iter", Json::Num(self.items_per_iter))
            .set("throughput_per_s", Json::Num(self.throughput_per_s()));
        o
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Iterations discarded before measuring.
    pub warmup_iters: usize,
    /// Samples collected.
    pub samples: usize,
    /// Iterations batched per sample (for very fast functions).
    pub iters_per_sample: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 20, samples: 60, iters_per_sample: 1 }
    }
}

impl BenchConfig {
    /// For sub-microsecond functions: batch many iterations per sample.
    pub fn fast() -> Self {
        BenchConfig { warmup_iters: 1000, samples: 50, iters_per_sample: 10_000 }
    }

    /// For expensive (>100 ms) end-to-end runs.
    pub fn slow() -> Self {
        BenchConfig { warmup_iters: 1, samples: 8, iters_per_sample: 1 }
    }
}

/// Run a benchmark. `f` is called `warmup + samples*iters_per_sample`
/// times; its return value is passed through `std::hint::black_box` so
/// the compiler cannot elide the work.
pub fn bench<T, F: FnMut() -> T>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        std::hint::black_box(f());
    }
    let mut samples_ns = Vec::with_capacity(cfg.samples);
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..cfg.iters_per_sample {
            std::hint::black_box(f());
        }
        let dt = t0.elapsed().as_nanos() as f64 / cfg.iters_per_sample as f64;
        samples_ns.push(dt);
    }
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    BenchResult {
        name: name.to_string(),
        samples: n,
        mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
        p50_ns: samples_ns[n / 2],
        p95_ns: samples_ns[((n as f64 * 0.95) as usize).min(n - 1)],
        min_ns: samples_ns[0],
        items_per_iter: 1.0,
    }
}

/// Like [`bench`] but records an items/iteration count for throughput.
pub fn bench_throughput<T, F: FnMut() -> T>(
    name: &str,
    cfg: BenchConfig,
    items_per_iter: f64,
    f: F,
) -> BenchResult {
    let mut r = bench(name, cfg, f);
    r.items_per_iter = items_per_iter;
    r
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print a criterion-style report table for a group of results.
pub fn report(group: &str, results: &[BenchResult]) {
    println!("\n== bench group: {group}");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>14}",
        "benchmark", "mean", "p50", "p95", "throughput"
    );
    println!("{}", "-".repeat(98));
    for r in results {
        let thr = if r.items_per_iter > 1.0 {
            format!("{:.0}/s", r.throughput_per_s())
        } else {
            String::from("-")
        };
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>14}",
            r.name,
            fmt_ns(r.mean_ns),
            fmt_ns(r.p50_ns),
            fmt_ns(r.p95_ns),
            thr
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleeps_roughly() {
        let r = bench(
            "sleep100us",
            BenchConfig { warmup_iters: 1, samples: 10, iters_per_sample: 1 },
            || std::thread::sleep(std::time::Duration::from_micros(100)),
        );
        assert!(r.mean_ns > 80_000.0, "mean {}", r.mean_ns);
        assert!(r.p50_ns <= r.p95_ns);
        assert!(r.min_ns <= r.p50_ns);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            samples: 1,
            mean_ns: 1e6, // 1 ms per iter
            p50_ns: 1e6,
            p95_ns: 1e6,
            min_ns: 1e6,
            items_per_iter: 100.0,
        };
        assert!((r.throughput_per_s() - 100_000.0).abs() < 1e-6);
    }

    #[test]
    fn json_carries_all_fields() {
        let r = BenchResult {
            name: "x".into(),
            samples: 5,
            mean_ns: 200.0,
            p50_ns: 150.0,
            p95_ns: 400.0,
            min_ns: 100.0,
            items_per_iter: 10.0,
        };
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap(), &Json::Str("x".into()));
        assert!((j.get("mean_ns").unwrap().as_f64().unwrap() - 200.0).abs() < 1e-12);
        let thr = j.get("throughput_per_s").unwrap().as_f64().unwrap();
        assert!((thr - 5e7).abs() < 1e-3);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2500.0), "2.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(2.5e9), "2.500 s");
    }
}
