//! Minimal-but-complete JSON parser and writer.
//!
//! The offline build environment has no `serde`/`serde_json`, so this
//! module implements the subset of JSON handling the framework needs
//! (which is in fact all of RFC 8259 minus `\u` surrogate pairs being
//! validated pedantically): the artifact `manifest.json` written by the
//! python AOT path, experiment configuration files, and experiment report
//! output.
//!
//! The API is a simple value tree ([`Json`]) with typed accessors that
//! return [`crate::Error::Config`] on mismatch, so call sites read like
//! `m.get("params")?.as_array()?`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always stored as f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Array(Vec<Json>),
    /// Object keys are kept sorted (BTreeMap) so output is deterministic.
    Object(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- typed accessors

    /// The boolean value, or a type error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(type_err("bool", other)),
        }
    }

    /// The numeric value, or a type error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(type_err("number", other)),
        }
    }

    /// The number as i64 (must be integral), or a type error.
    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 || n.abs() > 9.0e15 {
            return Err(Error::Config(format!("expected integer, got {n}")));
        }
        Ok(n as i64)
    }

    /// The number as usize (must be integral ≥ 0), or a type error.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_i64()?;
        usize::try_from(n)
            .map_err(|_| Error::Config(format!("expected usize, got {n}")))
    }

    /// The string value, or a type error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(type_err("string", other)),
        }
    }

    /// The array elements, or a type error.
    pub fn as_array(&self) -> Result<&[Json]> {
        match self {
            Json::Array(a) => Ok(a),
            other => Err(type_err("array", other)),
        }
    }

    /// The object map, or a type error.
    pub fn as_object(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Ok(o),
            other => Err(type_err("object", other)),
        }
    }

    /// Object field access with a descriptive error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| Error::Config(format!("missing field `{key}`")))
    }

    /// Optional field: `Ok(None)` if absent or null.
    pub fn get_opt(&self, key: &str) -> Result<Option<&Json>> {
        Ok(match self.as_object()?.get(key) {
            None | Some(Json::Null) => None,
            Some(v) => Some(v),
        })
    }

    /// `[usize]` helper for shape arrays.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_array()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---------------------------------------------------------------- constructors

    /// New empty object.
    pub fn object() -> Json {
        Json::Object(BTreeMap::new())
    }

    /// Insert into an object (panics if self is not an object — builder use
    /// only).
    pub fn set(&mut self, key: &str, v: Json) -> &mut Self {
        match self {
            Json::Object(o) => {
                o.insert(key.to_string(), v);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Array of numbers from a slice.
    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Array(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // ---------------------------------------------------------------- serialisation

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialisation with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Array(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Object(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---------------------------------------------------------------- parsing

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Parse the contents of a file.
    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!("cannot read {}: {e}", path.display()))
        })?;
        Json::parse(&text)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))
    }
}

fn type_err(want: &str, got: &Json) -> Error {
    let kind = match got {
        Json::Null => "null",
        Json::Bool(_) => "bool",
        Json::Num(_) => "number",
        Json::Str(_) => "string",
        Json::Array(_) => "array",
        Json::Object(_) => "object",
    };
    Error::Config(format!("expected {want}, got {kind}"))
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; emit null (readers treat null as absent).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Object(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Array(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Array(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| {
                                self.err("invalid unicode escape")
                            })?);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character (may be multi-byte).
                    let rest = &self.b[self.i..];
                    let n = utf8_len(rest[0]);
                    let chunk = rest
                        .get(..n)
                        .and_then(|c| std::str::from_utf8(c).ok())
                        .ok_or_else(|| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i += n;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let chunk = self
            .b
            .get(self.i..self.i + 4)
            .and_then(|c| std::str::from_utf8(c).ok())
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let v = u32::from_str_radix(chunk, 16)
            .map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------- streaming writer

/// Emit-as-you-go pretty-JSON writer for reports too large to build as
/// an in-memory [`Json`] tree.
///
/// Produces output byte-identical to [`Json::to_string_pretty`] on the
/// same logical document: 2-space indent, `{}`/`[]` for empty
/// containers, identical number/string formatting (subtrees are
/// rendered by the same serializer). Misuse (closing an unopened
/// container, finishing with open containers) panics — builder use
/// only, like [`Json::set`]. IO errors are latched so the builder
/// calls stay infallible; [`JsonStream::finish`] surfaces the first
/// one.
pub struct JsonStream<W: std::io::Write> {
    w: W,
    err: Option<std::io::Error>,
    counts: Vec<usize>,
    pending_key: bool,
}

impl<W: std::io::Write> JsonStream<W> {
    /// Wrap a writer; emit exactly one root value before `finish`.
    pub fn new(w: W) -> Self {
        JsonStream { w, err: None, counts: Vec::new(), pending_key: false }
    }

    fn out(&mut self, bytes: &[u8]) {
        if self.err.is_some() {
            return;
        }
        if let Err(e) = self.w.write_all(bytes) {
            self.err = Some(e);
        }
    }

    /// Comma/newline/indent before an element, unless it is the value
    /// of a key that already wrote them.
    fn prelude(&mut self) {
        if self.pending_key {
            self.pending_key = false;
            return;
        }
        if let Some(count) = self.counts.last_mut() {
            let n = *count;
            *count += 1;
            let depth = self.counts.len();
            let mut s = String::with_capacity(2 + 2 * depth);
            if n > 0 {
                s.push(',');
            }
            s.push('\n');
            for _ in 0..2 * depth {
                s.push(' ');
            }
            self.out(s.as_bytes());
        }
    }

    /// Open an object.
    pub fn begin_object(&mut self) {
        self.prelude();
        self.out(b"{");
        self.counts.push(0);
    }

    /// Open an array.
    pub fn begin_array(&mut self) {
        self.prelude();
        self.out(b"[");
        self.counts.push(0);
    }

    fn close(&mut self, bracket: u8) {
        let count = self.counts.pop().expect("JsonStream: close without open");
        if count == 0 {
            self.out(&[bracket]);
            return;
        }
        let depth = self.counts.len();
        let mut s = String::with_capacity(2 + 2 * depth);
        s.push('\n');
        for _ in 0..2 * depth {
            s.push(' ');
        }
        s.push(bracket as char);
        self.out(s.as_bytes());
    }

    /// Close the innermost object.
    pub fn end_object(&mut self) {
        self.close(b'}');
    }

    /// Close the innermost array.
    pub fn end_array(&mut self) {
        self.close(b']');
    }

    /// Write an object key; the next `value`/`begin_*` call becomes
    /// its value.
    pub fn key(&mut self, k: &str) {
        self.prelude();
        let mut s = String::new();
        write_escaped(&mut s, k);
        s.push_str(": ");
        self.out(s.as_bytes());
        self.pending_key = true;
    }

    /// Write a complete [`Json`] subtree in place.
    pub fn value(&mut self, v: &Json) {
        self.prelude();
        let depth = self.counts.len();
        let mut s = String::new();
        v.write(&mut s, Some(2), depth);
        self.out(s.as_bytes());
    }

    /// Surface any latched IO error, flush, and return the writer.
    pub fn finish(mut self) -> std::io::Result<W> {
        assert!(self.counts.is_empty(), "JsonStream: unclosed container");
        if let Some(e) = self.err {
            return Err(e);
        }
        self.w.flush()?;
        Ok(self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\"").unwrap(),
            Json::Str("hi".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[1].as_i64().unwrap(), 2);
        assert_eq!(*a[2].get("b").unwrap(), Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn parse_unicode_raw() {
        let v = Json::parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"nums":[1,2.5,-3],"s":"a\"b","t":true,"u":null}"#;
        let v = Json::parse(src).unwrap();
        for text in [v.to_string(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\x\"",
                    "[1] tail"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse("{\"a\": 1.5}").unwrap();
        assert!(v.get("a").unwrap().as_i64().is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.get_opt("missing").unwrap().is_none());
        assert!(v.get_opt("a").unwrap().is_some());
    }

    #[test]
    fn shape_helper() {
        let v = Json::parse("[2, 64, 256]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![2, 64, 256]);
        assert!(Json::parse("[2, -1]").unwrap().as_shape().is_err());
    }

    #[test]
    fn builder_roundtrip() {
        let mut o = Json::object();
        o.set("x", Json::Num(1.0))
            .set("arr", Json::from_f64_slice(&[1.0, 2.0]));
        let parsed = Json::parse(&o.to_string()).unwrap();
        assert_eq!(parsed, o);
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"b":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn stream_matches_pretty_tree() {
        // Build the same document as a tree and through the streaming
        // writer; the bytes must be identical.
        let mut inner = Json::object();
        inner
            .set("s", Json::Str("a\"b\nc".into()))
            .set("neg", Json::Num(-3.5))
            .set("int", Json::Num(42.0));
        let mut tree = Json::object();
        tree.set("configs", Json::Array(vec![inner.clone(), Json::Null]))
            .set("empty_arr", Json::Array(vec![]))
            .set("empty_obj", Json::object())
            .set("n", Json::Num(1.0));

        let mut s = JsonStream::new(Vec::new());
        s.begin_object();
        s.key("configs");
        s.begin_array();
        s.value(&inner);
        s.value(&Json::Null);
        s.end_array();
        s.key("empty_arr");
        s.begin_array();
        s.end_array();
        s.key("empty_obj");
        s.begin_object();
        s.end_object();
        s.key("n");
        s.value(&Json::Num(1.0));
        s.end_object();
        let bytes = s.finish().unwrap();

        assert_eq!(String::from_utf8(bytes).unwrap(), tree.to_string_pretty());
    }

    #[test]
    fn stream_root_scalar_and_array() {
        let mut s = JsonStream::new(Vec::new());
        s.value(&Json::Num(7.0));
        assert_eq!(s.finish().unwrap(), b"7");

        let v = Json::Array(vec![Json::Num(1.0), Json::Bool(true)]);
        let mut s = JsonStream::new(Vec::new());
        s.begin_array();
        s.value(&Json::Num(1.0));
        s.value(&Json::Bool(true));
        s.end_array();
        assert_eq!(
            String::from_utf8(s.finish().unwrap()).unwrap(),
            v.to_string_pretty()
        );
    }
}
