//! Generational slab arena: stable integer keys, O(1) insert/remove,
//! zero steady-state allocation.
//!
//! The scheduler's hot path used to key its in-flight hedge table on
//! request ids through a `HashMap` and carry a parallel `HashSet` of
//! cancel tokens — every hedged request paid two hashes on submit, one
//! to three on every completion, and the map churned heap nodes under
//! sustained load. The slab replaces both: entries live in a flat
//! `Vec`, freed slots are recycled through an in-place free list, and a
//! per-slot **generation counter** makes recycled slots unforgeable — a
//! stale [`SlabKey`] held after its entry was removed can never alias a
//! newer occupant, because the generation embedded in the key no longer
//! matches the slot's (checked on every access, property-tested in
//! `tests/proptest_invariants.rs`).
//!
//! In steady state (peak population reached once) the slab performs no
//! heap allocation at all: inserts pop the free list, removals push it.
//! This is what the counting-allocator test
//! (`tests/alloc_steady_state.rs`) asserts for the whole dispatch path.

/// Key into a [`Slab`]: slot index plus the generation the slot had
/// when the entry was inserted. `Copy` and 8 bytes — cheap to embed in
/// queued-request records.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlabKey {
    index: u32,
    generation: u32,
}

impl SlabKey {
    /// Slot index (for debugging/telemetry; not a stable identity on
    /// its own — only the full key is).
    pub fn index(&self) -> usize {
        self.index as usize
    }

    /// Generation of the slot at insertion time.
    pub fn generation(&self) -> u32 {
        self.generation
    }
}

/// One arena slot: either an occupant (tagged with its generation) or a
/// vacancy holding the generation its *next* occupant will get.
#[derive(Debug, Clone)]
enum Slot<T> {
    Vacant { next_generation: u32 },
    Occupied { generation: u32, value: T },
}

/// Generational slab arena (see the module docs).
#[derive(Debug, Clone)]
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T> Slab<T> {
    /// Empty slab (no allocation until the first insert).
    pub fn new() -> Self {
        Slab { slots: Vec::new(), free: Vec::new(), len: 0 }
    }

    /// Empty slab with room for `capacity` entries before reallocating.
    pub fn with_capacity(capacity: usize) -> Self {
        Slab {
            slots: Vec::with_capacity(capacity),
            free: Vec::with_capacity(capacity),
            len: 0,
        }
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the slab empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Physical slots (live + vacant) — the high-water mark of the
    /// population.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Insert a value, recycling a vacant slot when one exists
    /// (allocation-free in steady state). Returns the entry's key.
    pub fn insert(&mut self, value: T) -> SlabKey {
        match self.free.pop() {
            Some(index) => {
                let slot = &mut self.slots[index as usize];
                let generation = match *slot {
                    Slot::Vacant { next_generation } => next_generation,
                    Slot::Occupied { .. } => unreachable!("free list held a live slot"),
                };
                *slot = Slot::Occupied { generation, value };
                self.len += 1;
                SlabKey { index, generation }
            }
            None => {
                let index = u32::try_from(self.slots.len())
                    .expect("slab exceeded u32::MAX slots");
                self.slots.push(Slot::Occupied { generation: 0, value });
                self.len += 1;
                SlabKey { index, generation: 0 }
            }
        }
    }

    /// Shared access; `None` when the key is stale (entry removed, slot
    /// possibly recycled — the generation check catches both).
    pub fn get(&self, key: SlabKey) -> Option<&T> {
        match self.slots.get(key.index as usize) {
            Some(Slot::Occupied { generation, value }) if *generation == key.generation => {
                Some(value)
            }
            _ => None,
        }
    }

    /// Exclusive access; `None` when the key is stale.
    pub fn get_mut(&mut self, key: SlabKey) -> Option<&mut T> {
        match self.slots.get_mut(key.index as usize) {
            Some(Slot::Occupied { generation, value }) if *generation == key.generation => {
                Some(value)
            }
            _ => None,
        }
    }

    /// Remove and return the entry, bumping the slot's generation so
    /// every outstanding key to it goes stale. `None` when the key
    /// already is. The slot joins the free list (no deallocation).
    pub fn remove(&mut self, key: SlabKey) -> Option<T> {
        let slot = self.slots.get_mut(key.index as usize)?;
        match slot {
            Slot::Occupied { generation, .. } if *generation == key.generation => {
                let vacant =
                    Slot::Vacant { next_generation: key.generation.wrapping_add(1) };
                match std::mem::replace(slot, vacant) {
                    Slot::Occupied { value, .. } => {
                        self.free.push(key.index);
                        self.len -= 1;
                        Some(value)
                    }
                    Slot::Vacant { .. } => unreachable!("matched occupied above"),
                }
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.get(b), Some(&"b"));
        assert_eq!(s.remove(a), Some("a"));
        assert_eq!(s.get(a), None);
        assert_eq!(s.len(), 1);
        assert_eq!(s.remove(a), None, "double remove is a no-op");
        assert_eq!(s.get(b), Some(&"b"));
    }

    #[test]
    fn recycled_slot_rejects_stale_key() {
        let mut s = Slab::new();
        let old = s.insert(1u64);
        s.remove(old);
        let new = s.insert(2u64);
        // Same physical slot, different generation.
        assert_eq!(new.index(), old.index());
        assert_ne!(new.generation(), old.generation());
        assert_eq!(s.get(old), None, "stale key aliased a recycled slot");
        assert_eq!(s.get_mut(old), None);
        assert_eq!(s.remove(old), None);
        assert_eq!(s.get(new), Some(&2));
    }

    #[test]
    fn steady_state_reuses_slots_without_growing() {
        let mut s: Slab<usize> = Slab::with_capacity(4);
        for round in 0..100usize {
            let fresh: Vec<SlabKey> = (0..4).map(|i| s.insert(round * 4 + i)).collect();
            assert_eq!(s.len(), 4);
            for (i, &k) in fresh.iter().enumerate() {
                assert_eq!(s.get(k), Some(&(round * 4 + i)));
                assert_eq!(s.remove(k), Some(round * 4 + i));
            }
            // Population peaked at 4: the arena never grows past it.
            assert_eq!(s.capacity(), 4);
            assert!(s.is_empty());
        }
    }

    #[test]
    fn get_mut_mutates_in_place() {
        let mut s = Slab::new();
        let k = s.insert(vec![1, 2]);
        s.get_mut(k).unwrap().push(3);
        assert_eq!(s.get(k), Some(&vec![1, 2, 3]));
        assert_eq!(s.remove(k), Some(vec![1, 2, 3]));
        assert!(s.is_empty());
    }

    #[test]
    fn out_of_range_key_is_stale() {
        let mut s = Slab::new();
        let k = s.insert(7);
        let bogus = SlabKey { index: 999, generation: 0 };
        assert_eq!(s.get(bogus), None);
        assert_eq!(s.remove(bogus), None);
        assert_eq!(s.get(k), Some(&7));
    }
}
