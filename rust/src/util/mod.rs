//! In-crate utility substrates.
//!
//! The offline build environment ships no `serde`, `rand`, or `clap`;
//! per the project's build-every-substrate rule these live here:
//!
//! * [`json`] — RFC 8259 parser + writer (manifest, configs, reports),
//!   plus a streaming emit-as-you-go pretty writer for reports too
//!   large to materialize as a tree.
//! * [`rng`] — xoshiro256** + the distributions the simulator needs,
//!   plus the per-cell seed splitting the parallel sweep runner uses.
//! * [`cli`] — subcommand + `--flag` argument parsing.
//! * [`slab`] — generational slab arena (the scheduler's zero-churn
//!   hedge table).
//! * [`ring`] — growable ring buffer (the admission queues' storage).

pub mod bench;
pub mod cli;
pub mod json;
pub mod ring;
pub mod rng;
pub mod slab;

pub use cli::Args;
pub use json::{Json, JsonStream};
pub use ring::RingBuffer;
pub use rng::Rng;
pub use slab::{Slab, SlabKey};
