//! In-crate utility substrates.
//!
//! The offline build environment ships no `serde`, `rand`, or `clap`;
//! per the project's build-every-substrate rule these live here:
//!
//! * [`json`] — RFC 8259 parser + writer (manifest, configs, reports).
//! * [`rng`] — xoshiro256** + the distributions the simulator needs.
//! * [`cli`] — subcommand + `--flag` argument parsing.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
