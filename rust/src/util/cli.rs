//! Tiny command-line argument parser (no `clap` in the offline crate set).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` style used by the `cnmt` binary and the examples. Unknown
//! flags are an error (catches typos in experiment sweeps).

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line: a positional subcommand list plus flag map.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Positional arguments (e.g. `["experiment", "table1"]`).
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Flags that were consumed by a typed accessor (for unknown-flag
    /// detection at the end of parsing).
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err(Error::Config("bare `--` not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // Lookahead: a value unless next is another flag / end.
                    match it.peek() {
                        Some(v) if !v.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(name.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(name.to_string(), "true".into());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    /// First positional argument, if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    fn raw(&self, name: &str) -> Option<&str> {
        let v = self.flags.get(name).map(|s| s.as_str());
        if v.is_some() {
            self.seen.borrow_mut().insert(name.to_string());
        }
        v
    }

    /// String flag with default.
    pub fn str(&self, name: &str, default: &str) -> String {
        self.raw(name).unwrap_or(default).to_string()
    }

    /// Optional string flag.
    pub fn str_opt(&self, name: &str) -> Option<String> {
        self.raw(name).map(|s| s.to_string())
    }

    /// Required string flag.
    pub fn str_req(&self, name: &str) -> Result<String> {
        self.raw(name)
            .map(|s| s.to_string())
            .ok_or_else(|| Error::Config(format!("missing required --{name}")))
    }

    /// u64 flag with default.
    pub fn u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.raw(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("--{name}: `{v}` is not an integer"))
            }),
        }
    }

    /// usize flag with default.
    pub fn usize(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.u64(name, default as u64)? as usize)
    }

    /// f64 flag with default.
    pub fn f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.raw(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("--{name}: `{v}` is not a number"))
            }),
        }
    }

    /// Boolean flag (present, `=true`, or `=1`).
    pub fn bool(&self, name: &str) -> bool {
        matches!(self.raw(name), Some("true") | Some("1"))
    }

    /// Error if any flag was never consumed by an accessor — call last.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !seen.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(Error::Config(format!("unknown flags: {unknown:?}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("experiment table1 --requests 1000 --profile=cp1 --fast");
        assert_eq!(a.subcommand(), Some("experiment"));
        assert_eq!(a.positional, vec!["experiment", "table1"]);
        assert_eq!(a.u64("requests", 0).unwrap(), 1000);
        assert_eq!(a.str("profile", "x"), "cp1");
        assert!(a.bool("fast"));
        assert!(!a.bool("slow"));
    }

    #[test]
    fn defaults_and_required() {
        let a = parse("run");
        assert_eq!(a.str("out", "default.json"), "default.json");
        assert_eq!(a.f64("ratio", 1.5).unwrap(), 1.5);
        assert!(a.str_req("model").is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("x --n abc");
        assert!(a.u64("n", 0).is_err());
    }

    #[test]
    fn equals_form_and_flag_before_flag() {
        let a = parse("cmd --a --b=2 --c 3");
        assert!(a.bool("a"));
        assert_eq!(a.u64("b", 0).unwrap(), 2);
        assert_eq!(a.u64("c", 0).unwrap(), 3);
    }

    #[test]
    fn reject_unknown_flags() {
        let a = parse("cmd --known 1 --typo 2");
        let _ = a.u64("known", 0);
        assert!(a.reject_unknown().is_err());
        let b = parse("cmd --known 1");
        let _ = b.u64("known", 0);
        assert!(b.reject_unknown().is_ok());
    }

    #[test]
    fn negative_number_as_value() {
        // `--x -3` : `-3` does not start with `--` so it is a value.
        let a = parse("cmd --x -3");
        assert_eq!(a.f64("x", 0.0).unwrap(), -3.0);
    }
}
