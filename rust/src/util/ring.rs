//! Growable ring buffer backing the admission queues.
//!
//! A FIFO over a power-of-two slot array with head/length indices:
//! `push_back`/`pop_front`/`front` are O(1) with no per-operation
//! allocation (the array only reallocates when the population exceeds
//! every previous peak — so in steady state, never). `get`/`remove`
//! support the batcher's bounded lookahead: `remove(i)` is O(i), closing
//! the hole by shifting the (short, lookahead-bounded) prefix toward the
//! back and advancing the head.
//!
//! Slots hold `Option<T>` so the buffer is 100% safe code; `take()` on a
//! slot moves values without cloning.

/// Growable ring buffer (see the module docs).
#[derive(Debug, Clone)]
pub struct RingBuffer<T> {
    /// Slot array; length is always a power of two.
    slots: Vec<Option<T>>,
    head: usize,
    len: usize,
}

impl<T> RingBuffer<T> {
    /// Ring with room for at least `capacity` elements before the first
    /// reallocation (rounded up to a power of two, minimum 4).
    pub fn with_capacity(capacity: usize) -> Self {
        let cap = capacity.max(4).next_power_of_two();
        RingBuffer {
            slots: (0..cap).map(|_| None).collect(),
            head: 0,
            len: 0,
        }
    }

    #[inline]
    fn mask(&self) -> usize {
        self.slots.len() - 1
    }

    #[inline]
    fn physical(&self, logical: usize) -> usize {
        (self.head + logical) & self.mask()
    }

    /// Elements currently buffered.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Is the buffer empty?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Physical slot count (the high-water capacity).
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Append at the back; amortised O(1), allocation-free unless the
    /// population exceeds its previous peak.
    pub fn push_back(&mut self, value: T) {
        if self.len == self.slots.len() {
            self.grow();
        }
        let i = self.physical(self.len);
        debug_assert!(self.slots[i].is_none());
        self.slots[i] = Some(value);
        self.len += 1;
    }

    /// Double the slot array, compacting the live range to the front.
    fn grow(&mut self) {
        let old_cap = self.slots.len();
        let mut slots: Vec<Option<T>> = (0..old_cap * 2).map(|_| None).collect();
        for (i, slot) in slots.iter_mut().take(self.len).enumerate() {
            *slot = self.slots[(self.head + i) & (old_cap - 1)].take();
        }
        self.slots = slots;
        self.head = 0;
    }

    /// The front element, if any.
    #[inline]
    pub fn front(&self) -> Option<&T> {
        if self.len == 0 {
            None
        } else {
            self.slots[self.head].as_ref()
        }
    }

    /// Remove and return the front element.
    #[inline]
    pub fn pop_front(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let value = self.slots[self.head].take();
        debug_assert!(value.is_some());
        self.head = (self.head + 1) & self.mask();
        self.len -= 1;
        value
    }

    /// Element at logical position `i` from the front.
    #[inline]
    pub fn get(&self, i: usize) -> Option<&T> {
        if i >= self.len {
            None
        } else {
            self.slots[self.physical(i)].as_ref()
        }
    }

    /// Remove the element at logical position `i`, preserving the order
    /// of the rest. O(i): the prefix `[0, i)` shifts one slot toward the
    /// back and the head advances — callers (the batcher) keep `i`
    /// bounded by their lookahead window.
    pub fn remove(&mut self, i: usize) -> Option<T> {
        if i >= self.len {
            return None;
        }
        let removed = self.slots[self.physical(i)].take();
        debug_assert!(removed.is_some());
        let mut j = i;
        while j > 0 {
            let src = self.physical(j - 1);
            let dst = self.physical(j);
            self.slots[dst] = self.slots[src].take();
            j -= 1;
        }
        self.head = (self.head + 1) & self.mask();
        self.len -= 1;
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::VecDeque;

    #[test]
    fn fifo_roundtrip_and_wraparound() {
        let mut r = RingBuffer::with_capacity(4);
        // Force the head around the physical array several times.
        for round in 0..10u64 {
            for i in 0..3 {
                r.push_back(round * 10 + i);
            }
            assert_eq!(r.len(), 3);
            assert_eq!(r.front(), Some(&(round * 10)));
            for i in 0..3 {
                assert_eq!(r.pop_front(), Some(round * 10 + i));
            }
            assert!(r.is_empty());
            assert_eq!(r.pop_front(), None);
        }
        assert_eq!(r.capacity(), 4, "peak population 3 never forced growth");
    }

    #[test]
    fn growth_preserves_order_across_the_seam() {
        let mut r = RingBuffer::with_capacity(4);
        // Wrap the head, then overfill so growth must re-linearise a
        // buffer whose live range straddles the physical seam.
        for i in 0..3u32 {
            r.push_back(i);
        }
        r.pop_front();
        r.pop_front();
        for i in 3..12u32 {
            r.push_back(i);
        }
        let drained: Vec<u32> = std::iter::from_fn(|| r.pop_front()).collect();
        assert_eq!(drained, (2..12).collect::<Vec<u32>>());
    }

    #[test]
    fn get_indexes_from_the_front() {
        let mut r = RingBuffer::with_capacity(4);
        for i in 0..5u32 {
            r.push_back(i);
        }
        r.pop_front();
        for (i, want) in (1..5u32).enumerate() {
            assert_eq!(r.get(i), Some(&want));
        }
        assert_eq!(r.get(4), None);
    }

    #[test]
    fn remove_mid_preserves_relative_order() {
        let mut r = RingBuffer::with_capacity(4);
        for i in 0..6u32 {
            r.push_back(i);
        }
        assert_eq!(r.remove(2), Some(2));
        assert_eq!(r.remove(0), Some(0));
        assert_eq!(r.remove(99), None);
        let rest: Vec<u32> = std::iter::from_fn(|| r.pop_front()).collect();
        assert_eq!(rest, vec![1, 3, 4, 5]);
    }

    #[test]
    fn model_check_against_vecdeque() {
        // Random op sequences must agree with the std VecDeque model,
        // including around wrap/growth boundaries.
        let mut rng = Rng::new(0x51B);
        for trial in 0..200u64 {
            let mut ring: RingBuffer<u64> = RingBuffer::with_capacity(1 + rng.usize(8));
            let mut model: VecDeque<u64> = VecDeque::new();
            for step in 0..300u64 {
                match rng.usize(5) {
                    0 | 1 => {
                        let v = trial * 1_000 + step;
                        ring.push_back(v);
                        model.push_back(v);
                    }
                    2 => assert_eq!(ring.pop_front(), model.pop_front()),
                    3 => {
                        if !model.is_empty() {
                            let i = rng.usize(model.len() + 2);
                            assert_eq!(ring.remove(i), model.remove(i));
                        }
                    }
                    _ => {
                        let i = rng.usize(model.len().max(1) + 1);
                        assert_eq!(ring.get(i), model.get(i));
                    }
                }
                assert_eq!(ring.len(), model.len());
                assert_eq!(ring.front(), model.front());
            }
            let a: Vec<u64> = std::iter::from_fn(|| ring.pop_front()).collect();
            let b: Vec<u64> = std::iter::from_fn(|| model.pop_front()).collect();
            assert_eq!(a, b, "trial {trial} diverged");
        }
    }
}
