//! Detection-quality evaluation: score the online anomaly detector
//! against injected ground truth.
//!
//! The outage sweep ([`super::outage`]) proves the *scheduler* survives
//! a fault; this experiment proves the *detector* notices it, names the
//! right root cause, and stays silent when nothing is wrong. Five
//! scenarios replay the outage pool on the `hetero` fleet, failover
//! armed, telemetry sampling on, a [`Detector`] attached
//! ([`crate::sim::run_fleet_outage_detect`]):
//!
//! * `twin`  — fault-free. The false-positive control: **zero** alerts
//!   is the acceptance bar, enforced by [`run`] itself.
//! * `crash` — the checked-in outage fault (lead edge gateway down for
//!   30 s). Expected: one `device_crash` raise on the faulted lane,
//!   within seconds of onset (the first failover reroute is the
//!   evidence).
//! * `slow`  — the same lane fail-slows ×[`SLOW_FACTOR`]. Expected:
//!   `device_slowdown` from the lane's execution-residual CUSUM chart.
//! * `link`  — the first cloud replica's transfer cost degrades
//!   ×[`LINK_FACTOR`]. Expected: `link_degradation` from the per-token
//!   transfer chart, with the execution chart in control.
//! * `surge` — no device fault at all: arrivals after the onset instant
//!   are compressed ×[`SURGE_RATE`] (offered load jumps accordingly).
//!   Expected: `load_surge` from the multi-lane gauge breach, blamed on
//!   no single device.
//!
//! Each scenario is scored against its injected spec
//! ([`score_alerts`]): detection latency, lane attribution, and false
//! alerts (every raise in the twin is false by definition). Every
//! completed request chain's blame decomposition is re-proven exact by
//! [`verify_blame`] before the report is written — `detect_eval.json`
//! never contains an unverified partition.
//!
//! The cells shard over [`super::runner::run_cells`] and the report is
//! byte-identical at any thread count; the no-toolchain mirror is
//! `python/tools/detect_mirror.py`.

use crate::fleet::Topology;
use crate::obs::{
    score_alerts, verify_blame, AlertKind, AlertRec, AlertScore, BlameChain, DetectCfg,
    Detector, TelemetryCfg,
};
use crate::scheduler::RetryPolicy;
use crate::sim::harness::GOODPUT_WINDOW_S;
use crate::sim::{
    run_fleet_outage_detect, DetectRunOut, FaultMode, FaultSpec, FleetOpts,
};
use crate::util::Json;
use crate::{Error, Result};

use super::outage::{outage_fault_spec, outage_pool, OutageConfig};
use super::runner;

/// Fail-slow multiplier of the `slow` scenario.
pub const SLOW_FACTOR: f64 = 4.0;
/// Transfer-cost multiplier of the `link` scenario.
pub const LINK_FACTOR: f64 = 8.0;
/// Arrival-compression factor of the `surge` scenario: inter-arrival
/// gaps after onset shrink by this factor (offered load rises by it).
/// Sized so the gauge charts breach on several lanes at the full-scale
/// operating point while the residual charts stay inside the CUSUM
/// slack — the surge must be detected *as* a surge.
pub const SURGE_RATE: f64 = 2.5;
/// Scenario labels, in cell order (mirror order).
pub const SCENARIOS: [&str; 5] = ["twin", "crash", "slow", "link", "surge"];

/// Evaluation configuration: the outage sweep's workload/topology knobs
/// plus the detector's.
#[derive(Debug, Clone)]
pub struct DetectConfig {
    /// Workload, topology, retry and thread knobs (shared with the
    /// outage sweep so the `crash` scenario replays its exact fault).
    pub base: OutageConfig,
    /// Detector tuning shared by every scenario.
    pub detect: DetectCfg,
    /// Gauge-sampling cadence feeding the surge charts.
    pub telemetry: TelemetryCfg,
}

impl Default for DetectConfig {
    fn default() -> Self {
        DetectConfig {
            base: OutageConfig::default(),
            detect: DetectCfg::default(),
            telemetry: TelemetryCfg::default(),
        }
    }
}

/// One scored scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Scenario label (see [`SCENARIOS`]).
    pub name: String,
    /// The injected fault (`None` for `twin` and `surge`).
    pub fault: Option<FaultSpec>,
    /// The alert the detector is expected to raise (`None` for the
    /// fault-free twin).
    pub expect: Option<(AlertKind, u32)>,
    /// Whether the expected alert names one culpable lane (`false` for
    /// a load surge, which blames no single device).
    pub lane_attributable: bool,
    /// Fault onset (seconds; 0 for the twin).
    pub onset_s: f64,
    /// The replay under detection.
    pub out: DetectRunOut,
    /// The alert stream scored against the spec.
    pub score: AlertScore,
}

/// The full evaluation: every scenario plus its shared configuration.
#[derive(Debug, Clone)]
pub struct DetectEval {
    /// Scenarios in [`SCENARIOS`] order.
    pub scenarios: Vec<Scenario>,
    /// The fleet evaluated.
    pub topo: Topology,
    /// Detector tuning.
    pub detect: DetectCfg,
    /// Failover retry policy (shared with the outage sweep).
    pub retry: RetryPolicy,
    /// Requests per scenario.
    pub requests_per_point: usize,
    /// Master seed.
    pub seed: u64,
    /// Offered load before any surge compression (r/s).
    pub offered_rps: f64,
    /// Gauge cadence (seconds).
    pub telemetry_interval_s: f64,
}

impl DetectEval {
    /// Scenario by label (panics when absent — report bug).
    pub fn get(&self, name: &str) -> &Scenario {
        self.scenarios
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing detect scenario {name}"))
    }

    /// Faulted scenarios whose expected alert was raised in-window.
    pub fn detected(&self) -> usize {
        self.scenarios.iter().filter(|s| s.expect.is_some() && s.score.detected).count()
    }

    /// False alerts summed over every scenario (twin raises included).
    pub fn false_alerts(&self) -> u32 {
        self.scenarios.iter().map(|s| s.score.false_alerts).sum()
    }

    /// Worst detection latency over the detected scenarios (NaN when
    /// nothing was detected).
    pub fn max_detection_latency_s(&self) -> f64 {
        self.scenarios
            .iter()
            .filter(|s| s.score.detected)
            .map(|s| s.score.detection_latency_s)
            .fold(f64::NAN, f64::max)
    }

    /// Fraction of faulted scenarios detected with the right kind and —
    /// where one lane is culpable — the right lane.
    pub fn attribution_accuracy(&self) -> f64 {
        let faulted: Vec<_> = self.scenarios.iter().filter(|s| s.expect.is_some()).collect();
        if faulted.is_empty() {
            return f64::NAN;
        }
        let good = faulted
            .iter()
            .filter(|s| s.score.detected && (!s.lane_attributable || s.score.correct_lane))
            .count();
        good as f64 / faulted.len() as f64
    }
}

/// Compress the arrival stream after `onset_s` by `rate`: the gap
/// between successive post-onset arrivals shrinks ×`rate`, modelling an
/// offered-load surge with the same request bodies.
pub fn compress_arrivals(pool: &[crate::sim::RequestTruth], onset_s: f64, rate: f64) -> Vec<crate::sim::RequestTruth> {
    pool.iter()
        .map(|r| {
            let mut r = r.clone();
            if r.arrival_s > onset_s {
                r.arrival_s = onset_s + (r.arrival_s - onset_s) / rate;
            }
            r
        })
        .collect()
}

/// Run the five-scenario evaluation. Fails when any blame partition
/// does not re-verify bit-exactly, and when the fault-free twin raises
/// any alert — quiescence is an invariant here, not a score.
pub fn run(cfg: &DetectConfig) -> Result<DetectEval> {
    let base = &cfg.base;
    if base.requests_per_point == 0 {
        return Err(Error::Config("detect eval needs requests_per_point > 0".into()));
    }
    base.topo.validate()?;
    if base.topo.edge_ids().is_empty() || base.topo.cloud_ids().is_empty() {
        return Err(Error::Config(format!(
            "detect eval needs both tiers in topology {} (a lane to fault \
             per scenario kind)",
            base.topo.name
        )));
    }
    base.retry.validate()?;
    let crash = outage_fault_spec(&base.topo, base.requests_per_point, base.offered_rps);
    let onset_s = crash.start_s;
    let slow = FaultSpec {
        lane: crash.lane,
        mode: FaultMode::Slow { factor: SLOW_FACTOR },
        start_s: crash.start_s,
        recover_s: crash.recover_s,
    };
    let link = FaultSpec {
        lane: base.topo.cloud_ids()[0],
        mode: FaultMode::Link { factor: LINK_FACTOR },
        start_s: crash.start_s,
        recover_s: crash.recover_s,
    };
    let (pool, ch) = outage_pool(base);
    let surge_pool = compress_arrivals(&pool, onset_s, SURGE_RATE);
    let tiers: Vec<_> = base.topo.devices.iter().map(|d| d.tier).collect();
    let opts = FleetOpts { telemetry: Some(cfg.telemetry), ..base.opts.clone() };
    let faults: [Option<&FaultSpec>; 5] = [None, Some(&crash), Some(&slow), Some(&link), None];
    let outcomes = runner::run_cells(base.threads, SCENARIOS.len(), |cell| {
        let requests = if SCENARIOS[cell] == "surge" { &surge_pool } else { &pool };
        let det = Detector::new(&tiers, cfg.detect);
        let (out, _rec) = run_fleet_outage_detect(
            requests,
            &ch,
            &base.topo,
            &opts,
            faults[cell],
            &base.retry,
            det,
            None,
        )?;
        Ok(out)
    });
    let outs = outcomes.into_iter().collect::<Result<Vec<_>>>()?;
    let mut scenarios = Vec::with_capacity(SCENARIOS.len());
    for (cell, out) in outs.into_iter().enumerate() {
        let name = SCENARIOS[cell];
        verify_blame(&out.blame)
            .map_err(|e| Error::Config(format!("detect scenario {name}: {e}")))?;
        let (expect, lane_attributable, onset) = match name {
            "twin" => (None, false, 0.0),
            "crash" => (Some((AlertKind::DeviceCrash, crash.lane as u32)), true, onset_s),
            "slow" => (Some((AlertKind::DeviceSlowdown, slow.lane as u32)), true, onset_s),
            "link" => (Some((AlertKind::LinkDegradation, link.lane as u32)), true, onset_s),
            "surge" => (Some((AlertKind::LoadSurge, 0)), false, onset_s),
            _ => unreachable!(),
        };
        let score = score_alerts(&out.alerts, expect, onset);
        scenarios.push(Scenario {
            name: name.to_string(),
            fault: faults[cell].copied(),
            expect,
            lane_attributable,
            onset_s: onset,
            out,
            score,
        });
    }
    let twin = &scenarios[0];
    if twin.out.raised != 0 {
        return Err(Error::Config(format!(
            "detect eval: fault-free twin raised {} alert(s) — the detector \
             is mistuned for this operating point",
            twin.out.raised
        )));
    }
    Ok(DetectEval {
        scenarios,
        topo: base.topo.clone(),
        detect: cfg.detect,
        retry: base.retry,
        requests_per_point: base.requests_per_point,
        seed: base.seed,
        offered_rps: base.offered_rps,
        telemetry_interval_s: cfg.telemetry.interval_s,
    })
}

fn alert_to_json(a: &AlertRec) -> Json {
    let mut o = Json::object();
    o.set("t_s", Json::Num(a.t_s))
        .set("lane", Json::Num(a.lane as f64))
        .set("kind", Json::Str(a.kind.tag().to_string()))
        .set("raised", Json::Bool(a.raised))
        .set("score", Json::Num(a.score));
    o
}

fn chain_to_json(c: &BlameChain) -> Json {
    let mut o = Json::object();
    o.set("id", Json::Num(c.id as f64))
        .set("attempts", Json::Num(c.attempts as f64))
        .set("timeout_kills", Json::Num(c.timeout_kills as f64))
        .set("crash_kills", Json::Num(c.crash_kills as f64))
        .set("queue_wasted_s", Json::Num(c.queue_wasted_s))
        .set("retry_wait_s", Json::Num(c.retry_wait_s))
        .set("queue_s", Json::Num(c.queue_s))
        .set("batch_wait_s", Json::Num(c.batch_wait_s))
        .set("exec_s", Json::Num(c.exec_s))
        .set("tx_s", Json::Num(c.tx_s))
        .set("total_s", Json::Num(c.total_s));
    o
}

/// Aggregate a scenario's blame ledger: per-segment sums accumulated in
/// completion order (the mirror replicates the fold order), plus the
/// retried chains in full — the interesting ones, and few enough to
/// check in.
fn blame_to_json(chains: &[BlameChain]) -> Json {
    let mut sums = [0.0f64; 7];
    let (mut attempts, mut timeout_kills, mut crash_kills) = (0u64, 0u64, 0u64);
    let mut retried = Vec::new();
    for c in chains {
        attempts += c.attempts as u64;
        timeout_kills += c.timeout_kills as u64;
        crash_kills += c.crash_kills as u64;
        for (slot, v) in sums.iter_mut().zip([
            c.queue_wasted_s,
            c.retry_wait_s,
            c.queue_s,
            c.batch_wait_s,
            c.exec_s,
            c.tx_s,
            c.total_s,
        ]) {
            *slot += v;
        }
        if c.attempts > 1 {
            retried.push(chain_to_json(c));
        }
    }
    let mut o = Json::object();
    o.set("chains", Json::Num(chains.len() as f64))
        .set("attempts", Json::Num(attempts as f64))
        .set("timeout_kills", Json::Num(timeout_kills as f64))
        .set("crash_kills", Json::Num(crash_kills as f64))
        .set("queue_wasted_s", Json::Num(sums[0]))
        .set("retry_wait_s", Json::Num(sums[1]))
        .set("queue_s", Json::Num(sums[2]))
        .set("batch_wait_s", Json::Num(sums[3]))
        .set("exec_s", Json::Num(sums[4]))
        .set("tx_s", Json::Num(sums[5]))
        .set("total_s", Json::Num(sums[6]))
        .set("retried", Json::Array(retried));
    o
}

fn score_to_json(s: &AlertScore) -> Json {
    let mut o = Json::object();
    o.set("detected", Json::Bool(s.detected))
        .set(
            "detection_latency_s",
            if s.detection_latency_s.is_nan() {
                Json::Null
            } else {
                Json::Num(s.detection_latency_s)
            },
        )
        .set("correct_lane", Json::Bool(s.correct_lane))
        .set("false_alerts", Json::Num(s.false_alerts as f64));
    o
}

/// Render the evaluation as an aligned scenario table plus the
/// quiescence/attribution headline (mirror of the python `summarize`).
pub fn render_text(e: &DetectEval) -> String {
    let hdr = format!(
        "{:<8} {:>16} {:>7} {:>7} {:>9} {:>5} {:>6} {:>7}",
        "scenario", "expected", "raised", "clears", "latency_s", "lane", "false", "chains"
    );
    let mut out = String::new();
    out.push_str(&hdr);
    out.push('\n');
    out.push_str(&"-".repeat(hdr.len()));
    out.push('\n');
    for s in &e.scenarios {
        let expected = match s.expect {
            Some((kind, _)) => kind.tag().to_string(),
            None => "-".to_string(),
        };
        let latency = if s.score.detected {
            format!("{:.3}", s.score.detection_latency_s)
        } else {
            "-".to_string()
        };
        let lane = match (s.score.detected, s.lane_attributable) {
            (true, true) if s.score.correct_lane => "ok".to_string(),
            (true, true) => "WRONG".to_string(),
            _ => "-".to_string(),
        };
        out.push_str(&format!(
            "{:<8} {:>16} {:>7} {:>7} {:>9} {:>5} {:>6} {:>7}\n",
            s.name,
            expected,
            s.out.raised,
            s.out.cleared,
            latency,
            lane,
            s.score.false_alerts,
            s.out.blame.len(),
        ));
    }
    out.push_str(&format!(
        "\nheadline: {}/{} faults detected (worst latency {:.3}s), \
         attribution accuracy {:.0}%, {} false alert(s), twin quiescent\n",
        e.detected(),
        e.scenarios.iter().filter(|s| s.expect.is_some()).count(),
        e.max_detection_latency_s(),
        e.attribution_accuracy() * 100.0,
        e.false_alerts(),
    ));
    out
}

/// JSON report (`detect_eval.json`, written through
/// [`super::report::write_report`]) — key order mirrored by
/// `python/tools/detect_mirror.py`'s `detect_to_json`.
pub fn to_json(e: &DetectEval) -> Json {
    let mut detect = Json::object();
    detect
        .set("warmup", Json::Num(e.detect.warmup as f64))
        .set("cusum_k", Json::Num(e.detect.cusum_k))
        .set("cusum_h", Json::Num(e.detect.cusum_h))
        .set("sigma_floor", Json::Num(e.detect.sigma_floor))
        .set("clear_after", Json::Num(e.detect.clear_after as f64))
        .set("gauge_warmup", Json::Num(e.detect.gauge_warmup as f64))
        .set("gauge_lambda", Json::Num(e.detect.gauge_lambda))
        .set("gauge_l", Json::Num(e.detect.gauge_l))
        .set("surge_lanes", Json::Num(e.detect.surge_lanes as f64))
        .set("surge_clear", Json::Num(e.detect.surge_clear as f64));
    let mut retry = Json::object();
    retry
        .set("timeout_mult", Json::Num(e.retry.timeout_mult))
        .set("min_timeout_s", Json::Num(e.retry.min_timeout_s))
        .set("backoff_base_s", Json::Num(e.retry.backoff_base_s))
        .set("backoff_mult", Json::Num(e.retry.backoff_mult))
        .set("max_retries", Json::Num(e.retry.max_retries as f64));
    let mut scenarios = Json::object();
    for s in &e.scenarios {
        let mut o = Json::object();
        o.set("fault", s.fault.as_ref().map_or(Json::Null, |f| f.to_json()))
            .set(
                "expect",
                match s.expect {
                    Some((kind, lane)) => {
                        let mut ex = Json::object();
                        ex.set("kind", Json::Str(kind.tag().to_string()))
                            .set("lane", Json::Num(lane as f64));
                        ex
                    }
                    None => Json::Null,
                },
            )
            .set("lane_attributable", Json::Bool(s.lane_attributable))
            .set("onset_s", Json::Num(s.onset_s))
            .set("result", s.out.result.to_json())
            .set("alerts", Json::Array(s.out.alerts.iter().map(alert_to_json).collect()))
            .set("score", score_to_json(&s.score))
            .set("blame", blame_to_json(&s.out.blame));
        scenarios.set(&s.name, o);
    }
    let mut root = Json::object();
    root.set("seed", Json::Num(e.seed as f64))
        .set("requests_per_point", Json::Num(e.requests_per_point as f64))
        .set("offered_rps", Json::Num(e.offered_rps))
        .set("topology", e.topo.to_json())
        .set("detect", detect)
        .set("retry", retry)
        .set("telemetry_interval_s", Json::Num(e.telemetry_interval_s))
        .set("slow_factor", Json::Num(SLOW_FACTOR))
        .set("link_factor", Json::Num(LINK_FACTOR))
        .set("surge_rate", Json::Num(SURGE_RATE))
        .set("goodput_window_s", Json::Num(GOODPUT_WINDOW_S))
        .set("scenarios", scenarios)
        .set("headline_detected", Json::Num(e.detected() as f64))
        .set("headline_false_alerts", Json::Num(e.false_alerts() as f64))
        .set(
            "headline_max_detection_latency_s",
            Json::Num(e.max_detection_latency_s()),
        )
        .set(
            "headline_attribution_accuracy",
            Json::Num(e.attribution_accuracy()),
        );
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_cfg() -> DetectConfig {
        let mut cfg = DetectConfig::default();
        cfg.base.requests_per_point = 2_000;
        cfg
    }

    #[test]
    fn five_scenarios_twin_quiescent_crash_attributed() {
        let eval = run(&smoke_cfg()).unwrap();
        assert_eq!(eval.scenarios.len(), 5);
        for (s, want) in eval.scenarios.iter().zip(SCENARIOS) {
            assert_eq!(s.name, want);
        }
        // Quiescence is enforced by run() itself; double-check the twin
        // stream really is empty.
        let twin = eval.get("twin");
        assert!(twin.out.alerts.is_empty());
        assert_eq!(twin.score.false_alerts, 0);
        // The crash evidence (a failover reroute) is unambiguous even at
        // smoke scale: detected fast, on the right lane.
        let crash = eval.get("crash");
        assert!(crash.score.detected, "{:?}", crash.out.alerts);
        assert!(crash.score.correct_lane);
        assert!(crash.score.detection_latency_s < 5.0);
        assert_eq!(crash.score.false_alerts, 0, "{:?}", crash.out.alerts);
        // Detection is observation-only: the crash replay's scheduling
        // outcome matches the plain outage harness bit-for-bit.
        let plain = crate::sim::run_fleet_outage(
            &outage_pool(&eval_cfg_base()).0,
            &outage_pool(&eval_cfg_base()).1,
            &eval.topo,
            &FleetOpts { telemetry: Some(TelemetryCfg::default()), ..Default::default() },
            &crash.fault.unwrap(),
            &eval.retry,
            true,
        )
        .unwrap();
        assert_eq!(plain.completed, crash.out.result.completed);
        assert_eq!(plain.p99_s.to_bits(), crash.out.result.p99_s.to_bits());
    }

    fn eval_cfg_base() -> OutageConfig {
        OutageConfig { requests_per_point: 2_000, ..Default::default() }
    }

    #[test]
    fn eval_is_bit_identical_across_thread_counts() {
        let mut cfg = smoke_cfg();
        cfg.base.requests_per_point = 800;
        let serial = to_json(&run(&cfg).unwrap()).to_string_pretty();
        for threads in [2, 4] {
            cfg.base.threads = threads;
            let parallel = to_json(&run(&cfg).unwrap()).to_string_pretty();
            assert_eq!(parallel, serial, "{threads}-thread detect eval diverged");
        }
    }

    #[test]
    fn json_covers_the_schema() {
        let eval = run(&smoke_cfg()).unwrap();
        let j = to_json(&eval);
        assert!(j.get("topology").unwrap().get("devices").is_ok());
        assert_eq!(
            j.get("detect").unwrap().get("cusum_h").unwrap().as_f64().unwrap(),
            25.0
        );
        for name in SCENARIOS {
            let s = j.get("scenarios").unwrap().get(name).unwrap();
            assert!(s.get("result").unwrap().get("goodput_curve").is_ok(), "{name}");
            assert!(s.get("result").unwrap().get("telemetry").is_ok(), "{name}");
            assert!(s.get("blame").unwrap().get("total_s").is_ok(), "{name}");
            assert!(s.get("score").is_ok(), "{name}");
        }
        let twin = j.get("scenarios").unwrap().get("twin").unwrap();
        assert_eq!(twin.get("fault").unwrap(), &Json::Null);
        assert_eq!(
            j.get("headline_false_alerts").unwrap().as_f64().unwrap(),
            0.0
        );
    }

    #[test]
    fn surge_compression_preserves_order_and_prefix() {
        let (pool, _) = outage_pool(&eval_cfg_base());
        let onset = 5.0;
        let surged = compress_arrivals(&pool, onset, SURGE_RATE);
        assert_eq!(surged.len(), pool.len());
        for (a, b) in pool.iter().zip(&surged) {
            if a.arrival_s <= onset {
                assert_eq!(a.arrival_s.to_bits(), b.arrival_s.to_bits());
            } else {
                assert!(b.arrival_s < a.arrival_s);
            }
            assert_eq!(a.n, b.n);
        }
        for w in surged.windows(2) {
            assert!(w[0].arrival_s <= w[1].arrival_s);
        }
    }

    #[test]
    fn rejects_degenerate_configs() {
        let mut cfg = smoke_cfg();
        cfg.base.requests_per_point = 0;
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.base.topo = Topology {
            name: "edge-only".into(),
            devices: vec![crate::fleet::DeviceSpec::edge("e0", 1.0)],
        };
        assert!(run(&cfg).is_err());
    }
}
