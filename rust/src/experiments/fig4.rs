//! Fig. 4: the two connection profiles (RTT vs simulation time).
//!
//! Generates the CP1/CP2 traces used by Table I, writes them as CSV
//! (re-plottable) and reports summary statistics. The paper's traces are
//! RIPE Atlas measurement 1437285 / probe 6222 (2018-05-03, 3-7 p.m. and
//! 7:30-12:30 a.m.); ours are synthetic with the same qualitative
//! structure (DESIGN.md §4) — CP1 slower on average and burstier.

use std::path::Path;

use crate::metrics::stats::percentile_sorted;
use crate::net::trace::{ConnectionProfile, RttTrace, TraceGenerator};
use crate::util::Json;
use crate::Result;

use super::report::text_table;

/// Stats for one profile.
#[derive(Debug, Clone)]
pub struct ProfileStats {
    /// Profile the trace was generated from.
    pub profile: ConnectionProfile,
    /// RTT samples in the trace.
    pub samples: usize,
    /// Trace duration (seconds).
    pub duration_s: f64,
    /// Mean RTT (ms).
    pub mean_ms: f64,
    /// Median RTT (ms).
    pub p50_ms: f64,
    /// 95th-percentile RTT (ms).
    pub p95_ms: f64,
    /// Maximum RTT (ms).
    pub max_ms: f64,
}

/// Fig. 4 result: stats + the traces themselves.
pub struct Fig4 {
    /// Summary stats per profile.
    pub stats: Vec<ProfileStats>,
    /// The generated traces (for CSV export).
    pub traces: Vec<(ConnectionProfile, RttTrace)>,
}

/// Generate both profiles.
pub fn run(seed: u64) -> Result<Fig4> {
    let mut stats = Vec::new();
    let mut traces = Vec::new();
    for profile in ConnectionProfile::ALL {
        let trace = TraceGenerator::new(seed ^ 0x4E7).profile(profile);
        let mut sorted = trace.rtt.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        stats.push(ProfileStats {
            profile,
            samples: trace.len(),
            duration_s: trace.duration(),
            mean_ms: trace.mean() * 1e3,
            p50_ms: percentile_sorted(&sorted, 50.0) * 1e3,
            p95_ms: percentile_sorted(&sorted, 95.0) * 1e3,
            max_ms: trace.max() * 1e3,
        });
        traces.push((profile, trace));
    }
    Ok(Fig4 { stats, traces })
}

/// Write the trace CSVs next to the JSON report.
pub fn write_traces(f: &Fig4, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    for (profile, trace) in &f.traces {
        trace.save_csv(&out_dir.join(format!("fig4_{}.csv", profile.id())))?;
    }
    Ok(())
}

/// Text rendering.
pub fn render_text(f: &Fig4) -> String {
    let mut out = "Fig. 4 — connection profiles (synthetic RIPE-Atlas analogs)\n".to_string();
    let mut rows = vec![vec![
        "profile".to_string(),
        "samples".to_string(),
        "duration_h".to_string(),
        "mean ms".to_string(),
        "p50 ms".to_string(),
        "p95 ms".to_string(),
        "max ms".to_string(),
    ]];
    for s in &f.stats {
        rows.push(vec![
            s.profile.id().to_string(),
            s.samples.to_string(),
            format!("{:.1}", s.duration_s / 3600.0),
            format!("{:.1}", s.mean_ms),
            format!("{:.1}", s.p50_ms),
            format!("{:.1}", s.p95_ms),
            format!("{:.1}", s.max_ms),
        ]);
    }
    out.push_str(&text_table(&rows));
    out.push_str("paper: CP1 = 3-7 p.m. (slower), CP2 = 7:30-12:30 a.m.\n");
    out
}

/// JSON report.
pub fn to_json(f: &Fig4) -> Json {
    let mut arr = Vec::new();
    for s in &f.stats {
        let mut o = Json::object();
        o.set("profile", Json::Str(s.profile.id().into()))
            .set("samples", Json::Num(s.samples as f64))
            .set("duration_s", Json::Num(s.duration_s))
            .set("mean_ms", Json::Num(s.mean_ms))
            .set("p50_ms", Json::Num(s.p50_ms))
            .set("p95_ms", Json::Num(s.p95_ms))
            .set("max_ms", Json::Num(s.max_ms));
        arr.push(o);
    }
    let mut root = Json::object();
    root.set("profiles", Json::Array(arr));
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cp1_slower_and_burstier() {
        let f = run(1).unwrap();
        let cp1 = &f.stats[0];
        let cp2 = &f.stats[1];
        assert_eq!(cp1.profile, ConnectionProfile::Cp1);
        assert!(cp1.mean_ms > cp2.mean_ms);
        assert!(cp1.max_ms > cp2.max_ms);
        assert!(cp1.p95_ms > cp2.p95_ms);
        // Spikes: p95 well above p50 for CP1.
        assert!(cp1.p95_ms > 1.15 * cp1.p50_ms);
    }

    #[test]
    fn csv_written() {
        let f = run(2).unwrap();
        let dir = std::env::temp_dir().join("cnmt_fig4_test");
        write_traces(&f, &dir).unwrap();
        assert!(dir.join("fig4_cp1.csv").exists());
        assert!(dir.join("fig4_cp2.csv").exists());
        let loaded = RttTrace::load_csv(&dir.join("fig4_cp1.csv")).unwrap();
        assert_eq!(loaded.len(), f.traces[0].1.len());
        std::fs::remove_dir_all(&dir).ok();
    }
}
