//! Deterministic multi-threaded sweep runner.
//!
//! The full-parameter load sweep is embarrassingly parallel — every
//! (offered load × configuration × scenario) **cell** replays its own
//! workload through its own dispatcher — yet it ran strictly serially,
//! so CI's report regeneration and any million-request study were
//! bottlenecked by the harness, not the modelled hardware. This module
//! shards cells across OS threads while keeping the output **bit-
//! identical at any thread count**:
//!
//! * every cell derives its RNG stream from a pure per-cell seed split
//!   ([`crate::util::rng::cell_seed`] — master seed ⊕ (cell+1)·φ64 into
//!   splitmix64/xoshiro256**), so no cell's randomness depends on which
//!   thread ran it or in what order;
//! * cells write results into their own index slot, so assembly order
//!   is the cell order, not completion order;
//! * no shared mutable simulation state exists — each cell builds its
//!   own workload, router, dispatcher and accounting from the seed.
//!
//! Scheduling is work-stealing-lite: one shared atomic cursor, each
//! thread claims the next unclaimed cell when it finishes its current
//! one. Long cells (high-load points) therefore never convoy behind a
//! static block partition. The python mirror stays serial and remains
//! the lockstep cross-check — `--threads N` must (and does) reproduce
//! its bytes exactly; CI diffs `--threads 1` against `--threads 4`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Threads to use when the caller asks for "all cores".
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a `--threads` flag: 0 means "all cores", anything else is
/// taken literally.
pub fn resolve_threads(requested: usize) -> usize {
    if requested == 0 {
        default_threads()
    } else {
        requested
    }
}

/// Run `cells` independent cells on up to `threads` OS threads and
/// return their results **in cell order** (index `i` holds `run(i)`).
///
/// `run` must be a pure function of the cell index (derive all
/// randomness from a per-cell seed — see the module docs); under that
/// contract the result vector is identical for every `threads` value.
/// A panicking cell propagates the panic to the caller once all threads
/// have joined (no result is silently dropped).
pub fn run_cells<T, F>(threads: usize, cells: usize, run: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.clamp(1, cells.max(1));
    if threads <= 1 {
        return (0..cells).map(run).collect();
    }
    let cursor = AtomicUsize::new(0);
    // One slot per cell; each slot is written by exactly one thread
    // (whichever claimed the cell), so the per-slot mutexes never
    // contend beyond their two lock sites.
    let slots: Vec<Mutex<Option<T>>> = (0..cells).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let cell = cursor.fetch_add(1, Ordering::Relaxed);
                if cell >= cells {
                    break;
                }
                let result = run(cell);
                *slots[cell].lock().expect("cell slot poisoned") = Some(result);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("cell slot poisoned")
                .expect("every cell below the cursor ran")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::cell_seed;
    use crate::util::Rng;

    #[test]
    fn results_arrive_in_cell_order() {
        // Cell i sleeps inversely to i, so completion order is roughly
        // reversed — results must still land in cell order.
        let out = run_cells(4, 16, |i| {
            std::thread::sleep(std::time::Duration::from_millis((16 - i) as u64));
            i * 10
        });
        assert_eq!(out, (0..16).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn thread_count_does_not_change_results() {
        // The load-bearing property: a seeded per-cell computation is
        // bit-identical at 1, 2, 3, 8 threads (and with more threads
        // than cells).
        let cell = |i: usize| -> Vec<u64> {
            let mut rng = Rng::new(cell_seed(0xC0FFEE, i as u64));
            (0..50).map(|_| rng.next_u64()).collect()
        };
        let serial = run_cells(1, 11, cell);
        for threads in [2, 3, 8, 64] {
            assert_eq!(run_cells(threads, 11, cell), serial, "{threads} threads");
        }
    }

    #[test]
    fn zero_and_tiny_cell_counts() {
        assert!(run_cells(8, 0, |i| i).is_empty());
        assert_eq!(run_cells(8, 1, |i| i + 7), vec![7]);
        assert_eq!(run_cells(0, 3, |i| i), vec![0, 1, 2], "0 threads = serial");
    }

    #[test]
    fn resolve_threads_maps_zero_to_all_cores() {
        assert_eq!(resolve_threads(3), 3);
        let auto = resolve_threads(0);
        assert!(auto >= 1);
        assert_eq!(auto, default_threads());
    }

    // std::thread::scope re-panics with its own payload ("a scoped
    // thread panicked"), so match on that rather than the cell's text.
    #[test]
    #[should_panic(expected = "panicked")]
    fn cell_panics_propagate() {
        run_cells(4, 8, |i| {
            if i == 5 {
                panic!("cell 5 exploded");
            }
            i
        });
    }
}
