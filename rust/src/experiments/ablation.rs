//! Estimator ablation — the paper's future work ("more advanced output
//! length estimation methods") made concrete: swap the N→M estimator in
//! the C-NMT decision and measure the impact on total execution time and
//! on the gap to the Oracle, per dataset × profile.

use crate::config::Config;
use crate::coordinator::PolicyKind;
use crate::corpus::{prefilter, LangPair, PrefilterRules};
use crate::devices::Calibration;
use crate::net::trace::ConnectionProfile;
use crate::predictor::LengthEstimator;
use crate::sim::{run_policy, run_with_estimator, TruthTable};
use crate::util::Json;
use crate::Result;

use super::report::text_table;

/// One (pair, profile) row of the ablation.
#[derive(Debug, Clone)]
pub struct AblationRow {
    /// Language pair of this row.
    pub pair: LangPair,
    /// Connection profile of this row.
    pub profile: ConnectionProfile,
    /// (estimator id, total_s, % vs oracle, held-out MAE).
    pub entries: Vec<(String, f64, f64, f64)>,
}

/// Full ablation result.
#[derive(Debug, Clone)]
pub struct Ablation {
    /// One row per (pair, profile) grid cell.
    pub rows: Vec<AblationRow>,
}

/// Run the ablation over the configured grid.
pub fn run(cfg: &Config, calibration: &Calibration) -> Result<Ablation> {
    let mut rows = Vec::new();
    for &pair in &cfg.pairs {
        for &profile in &cfg.profiles {
            let table = TruthTable::build(cfg, pair, profile, calibration)?;
            let oracle = run_policy(&table, PolicyKind::Oracle)?;

            // Fit the zoo on the same (prefiltered) fit split the linear
            // regressor was characterised on.
            let dataset = crate::corpus::Dataset::generate(
                pair,
                cfg.fit_inferences,
                64,
                cfg.seed
                    ^ (pair as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ (profile as u64 + 1).wrapping_mul(0xBF58476D1CE4E5B9),
            );
            let (fit_pairs, _) = prefilter(&dataset.fit, &PrefilterRules::default());
            let holdout = crate::corpus::CorpusGenerator::new(pair, cfg.seed ^ 0x0A)
                .take(5_000);
            let (holdout, _) = prefilter(&holdout, &PrefilterRules::default());

            let mut entries = Vec::new();
            for est in LengthEstimator::fit_all(&fit_pairs)? {
                let r = run_with_estimator(&table, &est)?;
                let vs_oracle = (r.total_s - oracle.total_s) / oracle.total_s * 100.0;
                entries.push((est.id().to_string(), r.total_s, vs_oracle, est.mae(&holdout)));
            }
            rows.push(AblationRow { pair, profile, entries });
        }
    }
    Ok(Ablation { rows })
}

/// Text rendering.
pub fn render_text(a: &Ablation) -> String {
    let mut out = String::from(
        "Estimator ablation — C-NMT with alternative N→M estimators\n\
         (% vs Oracle: lower is better; MAE: held-out |M̂−M| tokens)\n",
    );
    let mut rows = vec![vec![
        "cell".to_string(),
        "estimator".to_string(),
        "total_s".to_string(),
        "vs Oracle %".to_string(),
        "MAE".to_string(),
    ]];
    for r in &a.rows {
        for (id, total, vs, mae) in &r.entries {
            rows.push(vec![
                format!("{}/{}", r.pair.id(), r.profile.id()),
                id.clone(),
                format!("{total:.1}"),
                format!("{vs:+.2}"),
                format!("{mae:.2}"),
            ]);
        }
    }
    out.push_str(&text_table(&rows));
    out
}

/// JSON report.
pub fn to_json(a: &Ablation) -> Json {
    let mut rows = Vec::new();
    for r in &a.rows {
        let mut o = Json::object();
        o.set("pair", Json::Str(r.pair.id().into()))
            .set("profile", Json::Str(r.profile.id().into()));
        let mut ests = Json::object();
        for (id, total, vs, mae) in &r.entries {
            let mut e = Json::object();
            e.set("total_s", Json::Num(*total))
                .set("vs_oracle_pct", Json::Num(*vs))
                .set("mae_tokens", Json::Num(*mae));
            ests.set(id, e);
        }
        o.set("estimators", ests);
        rows.push(o);
    }
    let mut root = Json::object();
    root.set("rows", Json::Array(rows));
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_family_beats_constant() {
        let mut cfg = Config::smoke();
        cfg.requests = 3_000;
        cfg.pairs = vec![LangPair::EnZh];
        cfg.profiles = vec![ConnectionProfile::Cp1];
        let a = run(&cfg, &Calibration::default_paper()).unwrap();
        assert_eq!(a.rows.len(), 1);
        let entries = &a.rows[0].entries;
        assert_eq!(entries.len(), 5);
        let total = |id: &str| {
            entries.iter().find(|e| e.0 == id).unwrap().1
        };
        // The estimators that model the N→M relation must beat the
        // constant (Naive-like) estimate on the decode-dominated pair.
        assert!(total("linear") <= total("constant"));
        assert!(total("bucket") <= total("constant") * 1.005);
        // MAE ordering: linear-family below constant.
        let mae = |id: &str| entries.iter().find(|e| e.0 == id).unwrap().3;
        assert!(mae("linear") < mae("constant"));
    }

    #[test]
    fn render_and_json_shape() {
        let mut cfg = Config::smoke();
        cfg.requests = 1_000;
        cfg.pairs = vec![LangPair::FrEn];
        cfg.profiles = vec![ConnectionProfile::Cp2];
        let a = run(&cfg, &Calibration::default_paper()).unwrap();
        let txt = render_text(&a);
        assert!(txt.contains("quantile"));
        let j = to_json(&a);
        assert_eq!(j.get("rows").unwrap().as_array().unwrap().len(), 1);
    }
}
