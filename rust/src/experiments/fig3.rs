//! Fig. 3: the N→M regressions for the three language pairs (paper
//! caption: IWSLT'14 DE-EN R²=0.99 MSE=0.57; OPUS-100 FR-EN R²=0.99
//! MSE=0.15; OPUS-100 EN-ZH R²=0.99 MSE=0.73).
//!
//! For each pair: generate the corpus, prefilter (ParaCrawl rules), plot
//! mean M ± std per N, fit the linear regressor, report γ/δ/R²/MSE.

use std::collections::BTreeMap;

use crate::corpus::{prefilter, CorpusGenerator, LangPair, PrefilterRules};
use crate::metrics::OnlineStats;
use crate::predictor::N2mRegressor;
use crate::util::Json;
use crate::Result;

use super::report::text_table;

/// One panel of Fig. 3.
#[derive(Debug, Clone)]
pub struct Fig3Panel {
    /// Language pair of this panel.
    pub pair: LangPair,
    /// Fitted N→M regressor (the panel's line).
    pub reg: N2mRegressor,
    /// N → (mean M, std M, count) after prefiltering.
    pub by_n: BTreeMap<usize, (f64, f64, u64)>,
    /// Percentage of pairs removed by prefiltering.
    pub dropped_pct: f64,
}

/// Full Fig. 3.
#[derive(Debug, Clone)]
pub struct Fig3 {
    /// One panel per language pair.
    pub panels: Vec<Fig3Panel>,
    /// Corpus pairs sampled per panel.
    pub samples: usize,
}

/// Run the experiment.
pub fn run(samples: usize, seed: u64) -> Result<Fig3> {
    let mut panels = Vec::new();
    for pair in LangPair::ALL {
        let mut gen = CorpusGenerator::new(pair, seed ^ 0xF16_3 ^ pair as u64);
        let pairs = gen.take(samples);
        let rules = PrefilterRules::default();
        let (kept, stats) = prefilter(&pairs, &rules);
        let reg = N2mRegressor::fit_raw(&kept)?;
        let mut by_n: BTreeMap<usize, OnlineStats> = BTreeMap::new();
        for p in &kept {
            by_n.entry(p.n()).or_insert_with(OnlineStats::new).push(p.m_real as f64);
        }
        panels.push(Fig3Panel {
            pair,
            reg,
            by_n: by_n
                .iter()
                .map(|(&n, s)| (n, (s.mean(), s.std(), s.count())))
                .collect(),
            dropped_pct: stats.drop_rate() * 100.0,
        });
    }
    Ok(Fig3 { panels, samples })
}

/// Text rendering.
pub fn render_text(f: &Fig3) -> String {
    let mut out = format!("Fig. 3 — N→M linear regressions ({} pairs/corpus)\n", f.samples);
    let mut rows = vec![vec![
        "pair".to_string(),
        "gamma".to_string(),
        "delta".to_string(),
        "R^2".to_string(),
        "MSE".to_string(),
        "dropped%".to_string(),
    ]];
    for p in &f.panels {
        rows.push(vec![
            p.pair.id().to_string(),
            format!("{:.3}", p.reg.gamma),
            format!("{:.3}", p.reg.delta),
            format!("{:.3}", p.reg.r2),
            format!("{:.3}", p.reg.mse),
            format!("{:.1}", p.dropped_pct),
        ]);
    }
    out.push_str(&text_table(&rows));
    out.push_str(
        "paper: DE-EN R^2=0.99 MSE=0.57; FR-EN R^2=0.99 MSE=0.15; \
         EN-ZH R^2=0.99 MSE=0.73 (on per-N averages)\n",
    );
    out
}

/// JSON report.
pub fn to_json(f: &Fig3) -> Json {
    let mut panels = Vec::new();
    for p in &f.panels {
        let mut o = Json::object();
        o.set("pair", Json::Str(p.pair.id().into()))
            .set("gamma", Json::Num(p.reg.gamma))
            .set("delta", Json::Num(p.reg.delta))
            .set("r2", Json::Num(p.reg.r2))
            .set("mse", Json::Num(p.reg.mse))
            .set("dropped_pct", Json::Num(p.dropped_pct));
        let mut pts = Vec::new();
        for (&n, &(mean, std, count)) in &p.by_n {
            let mut q = Json::object();
            q.set("n", Json::Num(n as f64))
                .set("mean_m", Json::Num(mean))
                .set("std_m", Json::Num(std))
                .set("count", Json::Num(count as f64));
            pts.push(q);
        }
        o.set("points", Json::Array(pts));
        panels.push(o);
    }
    let mut root = Json::object();
    root.set("samples", Json::Num(f.samples as f64))
        .set("panels", Json::Array(panels));
    root
}

/// R² of the regressor evaluated on the *per-N mean* points — this is
/// what the paper's Fig. 3 caption scores (the plotted averages), and it
/// is much higher than the per-pair R² because per-pair noise averages
/// out.
pub fn r2_on_means(panel: &Fig3Panel) -> f64 {
    let pts: Vec<(f64, f64)> = panel
        .by_n
        .iter()
        .filter(|(_, &(_, _, c))| c >= 30)
        .map(|(&n, &(mean, _, _))| (n as f64, mean))
        .collect();
    if pts.len() < 3 {
        return f64::NAN;
    }
    let my = pts.iter().map(|p| p.1).sum::<f64>() / pts.len() as f64;
    let (mut ss_res, mut ss_tot) = (0.0, 0.0);
    for &(n, m) in &pts {
        let e = m - panel.reg.predict(n as usize);
        ss_res += e * e;
        ss_tot += (m - my) * (m - my);
    }
    1.0 - ss_res / ss_tot
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_pairs_fit_well_on_means() {
        // Paper Fig. 3: R² = 0.99 on the per-N averages, for all pairs.
        let f = run(30_000, 11).unwrap();
        assert_eq!(f.panels.len(), 3);
        for p in &f.panels {
            let r2m = r2_on_means(p);
            assert!(r2m > 0.97, "{}: R² on means {}", p.pair.id(), r2m);
            assert!(p.dropped_pct < 10.0);
        }
    }

    #[test]
    fn gamma_ordering_matches_verbosity() {
        // DE-EN ≈ 1, FR-EN < 1, EN-ZH smallest (paper's Fig. 3 narrative).
        let f = run(20_000, 12).unwrap();
        let g = |pair: LangPair| {
            f.panels.iter().find(|p| p.pair == pair).unwrap().reg.gamma
        };
        assert!(g(LangPair::DeEn) > g(LangPair::FrEn));
        assert!(g(LangPair::FrEn) > g(LangPair::EnZh));
        assert!(g(LangPair::EnZh) < 0.75);
    }

    #[test]
    fn render_and_json() {
        let f = run(5_000, 13).unwrap();
        assert!(render_text(&f).contains("gamma"));
        assert_eq!(to_json(&f).get("panels").unwrap().as_array().unwrap().len(), 3);
    }
}
