//! Energy-view experiment (extension): gateway-side energy consumption
//! of every Table-I policy, plus an **energy-aware** C-NMT variant that
//! uses the same predictive stack (eq. 2's T̂ estimates) but places
//! requests by the gateway energy rule ([`EnergyModel::prefer_offload`]).
//!
//! Headline question: how much latency does the energy-optimal placement
//! give up, and vice versa — the latency/energy tradeoff the CI
//! literature (Neurosurgeon etc.) navigates and the paper leaves to
//! future work.

use crate::config::Config;
use crate::coordinator::{PolicyKind, RouterBuilder};
use crate::corpus::LangPair;
use crate::devices::energy::EnergyModel;
use crate::devices::{Calibration, DeviceKind};
use crate::net::trace::ConnectionProfile;
use crate::sim::TruthTable;
use crate::util::Json;
use crate::Result;

use super::report::text_table;

/// Per-policy latency+energy totals for one cell.
#[derive(Debug, Clone)]
pub struct EnergyEntry {
    /// Policy id.
    pub policy: String,
    /// Total latency over the stream (seconds).
    pub total_time_s: f64,
    /// Total gateway energy over the stream (joules).
    pub total_energy_j: f64,
    /// Requests served at the edge.
    pub edge_count: usize,
    /// Requests offloaded to the cloud.
    pub cloud_count: usize,
}

/// One (pair, profile) cell.
#[derive(Debug, Clone)]
pub struct EnergyCell {
    /// Language pair of this cell.
    pub pair: LangPair,
    /// Connection profile of this cell.
    pub profile: ConnectionProfile,
    /// One entry per policy.
    pub entries: Vec<EnergyEntry>,
}

/// Full experiment result.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    /// One cell per (pair, profile).
    pub cells: Vec<EnergyCell>,
    /// The gateway energy model used.
    pub model: EnergyModel,
}

fn eval(
    table: &TruthTable,
    policy_id: &str,
    energy: &EnergyModel,
    mut decide: impl FnMut(&crate::sim::harness::RequestTruth) -> DeviceKind,
) -> EnergyEntry {
    let mut time = 0.0;
    let mut joules = 0.0;
    let (mut edge_count, mut cloud_count) = (0, 0);
    for rq in &table.requests {
        match decide(rq) {
            DeviceKind::Edge => {
                edge_count += 1;
                time += rq.t_edge;
                joules += energy.local_energy(rq.t_edge);
            }
            DeviceKind::Cloud => {
                cloud_count += 1;
                time += rq.t_tx + rq.t_cloud;
                joules += energy.offload_energy(rq.t_tx, rq.t_cloud);
            }
        }
    }
    EnergyEntry {
        policy: policy_id.to_string(),
        total_time_s: time,
        total_energy_j: joules,
        edge_count,
        cloud_count,
    }
}

/// Run the experiment over the configured grid.
pub fn run(
    cfg: &Config,
    calibration: &Calibration,
    energy: EnergyModel,
) -> Result<EnergyReport> {
    let mut cells = Vec::new();
    for &pair in &cfg.pairs {
        for &profile in &cfg.profiles {
            let table = TruthTable::build(cfg, pair, profile, calibration)?;
            let ch = table.characterization.clone();
            let mut entries = Vec::new();

            entries.push(eval(&table, "edge_only", &energy, |_| DeviceKind::Edge));
            entries.push(eval(&table, "cloud_only", &energy, |_| DeviceKind::Cloud));
            entries.push(eval(&table, "oracle_latency", &energy, |rq| {
                if rq.t_edge <= rq.t_tx + rq.t_cloud {
                    DeviceKind::Edge
                } else {
                    DeviceKind::Cloud
                }
            }));

            // C-NMT (latency objective), with the online T_tx estimator.
            let mut router = RouterBuilder::new(PolicyKind::Cnmt)
                .texe(ch.texe_edge, ch.texe_cloud)
                .n2m(ch.n2m)
                .build()?;
            entries.push(eval(&table, "cnmt_latency", &energy, |rq| {
                if router.ttx_stale(rq.arrival_s, 60.0) {
                    router.observe_ttx(rq.arrival_s, rq.rtt);
                }
                let d = router.decide(rq.n).device;
                if d == DeviceKind::Cloud {
                    router.observe_ttx(rq.arrival_s, rq.rtt);
                }
                d
            }));

            // Energy-aware C-NMT: same predictive stack, energy rule.
            let mut router_e = RouterBuilder::new(PolicyKind::Cnmt)
                .texe(ch.texe_edge, ch.texe_cloud)
                .n2m(ch.n2m)
                .build()?;
            entries.push(eval(&table, "cnmt_energy", &energy, |rq| {
                if router_e.ttx_stale(rq.arrival_s, 60.0) {
                    router_e.observe_ttx(rq.arrival_s, rq.rtt);
                }
                let tr = router_e.decide(rq.n); // estimates
                let d = if energy.prefer_offload(tr.t_edge_est, tr.t_cloud_est, tr.ttx_est)
                {
                    DeviceKind::Cloud
                } else {
                    DeviceKind::Edge
                };
                if d == DeviceKind::Cloud {
                    router_e.observe_ttx(rq.arrival_s, rq.rtt);
                }
                d
            }));

            // Energy oracle (lower bound on gateway energy).
            entries.push(eval(&table, "oracle_energy", &energy, |rq| {
                if energy.local_energy(rq.t_edge)
                    <= energy.offload_energy(rq.t_tx, rq.t_cloud)
                {
                    DeviceKind::Edge
                } else {
                    DeviceKind::Cloud
                }
            }));

            cells.push(EnergyCell { pair, profile, entries });
        }
    }
    Ok(EnergyReport { cells, model: energy })
}

/// Text rendering.
pub fn render_text(r: &EnergyReport) -> String {
    let mut out = format!(
        "Energy view (gateway perspective: busy {:.1} W, radio {:.1} W, idle {:.1} W)\n",
        r.model.p_busy_w, r.model.p_radio_w, r.model.p_idle_w
    );
    let mut rows = vec![vec![
        "cell".to_string(),
        "policy".to_string(),
        "time_s".to_string(),
        "energy_J".to_string(),
        "edge/cloud".to_string(),
    ]];
    for c in &r.cells {
        for e in &c.entries {
            rows.push(vec![
                format!("{}/{}", c.pair.id(), c.profile.id()),
                e.policy.clone(),
                format!("{:.1}", e.total_time_s),
                format!("{:.1}", e.total_energy_j),
                format!("{}/{}", e.edge_count, e.cloud_count),
            ]);
        }
    }
    out.push_str(&text_table(&rows));
    out
}

/// JSON report.
pub fn to_json(r: &EnergyReport) -> Json {
    let mut cells = Vec::new();
    for c in &r.cells {
        let mut o = Json::object();
        o.set("pair", Json::Str(c.pair.id().into()))
            .set("profile", Json::Str(c.profile.id().into()));
        let mut policies = Json::object();
        for e in &c.entries {
            let mut p = Json::object();
            p.set("total_time_s", Json::Num(e.total_time_s))
                .set("total_energy_j", Json::Num(e.total_energy_j))
                .set("edge_count", Json::Num(e.edge_count as f64))
                .set("cloud_count", Json::Num(e.cloud_count as f64));
            policies.set(&e.policy, p);
        }
        o.set("policies", policies);
        cells.push(o);
    }
    let mut root = Json::object();
    root.set("cells", Json::Array(cells));
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke() -> EnergyReport {
        let mut cfg = Config::smoke();
        cfg.requests = 3_000;
        cfg.pairs = vec![LangPair::EnZh];
        run(&cfg, &Calibration::default_paper(), EnergyModel::default()).unwrap()
    }

    #[test]
    fn energy_oracle_lower_bounds_energy() {
        let r = smoke();
        for c in &r.cells {
            let oe = c
                .entries
                .iter()
                .find(|e| e.policy == "oracle_energy")
                .unwrap()
                .total_energy_j;
            for e in &c.entries {
                assert!(
                    oe <= e.total_energy_j + 1e-9,
                    "{}: energy oracle beaten by {}",
                    c.pair.id(),
                    e.policy
                );
            }
        }
    }

    #[test]
    fn energy_rule_saves_energy_vs_latency_rule() {
        // The energy-aware variant must consume no more gateway energy
        // than latency-C-NMT (it optimises exactly that).
        let r = smoke();
        for c in &r.cells {
            let get = |id: &str| c.entries.iter().find(|e| e.policy == id).unwrap();
            assert!(
                get("cnmt_energy").total_energy_j
                    <= get("cnmt_latency").total_energy_j * 1.02,
                "energy rule didn't save energy"
            );
        }
    }

    #[test]
    fn edge_only_burns_most_energy_under_load() {
        // With a 9 W busy GPU vs 1.5 W radio, keeping everything local
        // must cost more energy than full offload in these workloads.
        let r = smoke();
        for c in &r.cells {
            let get = |id: &str| c.entries.iter().find(|e| e.policy == id).unwrap();
            assert!(
                get("edge_only").total_energy_j > get("cloud_only").total_energy_j
            );
        }
    }
}
