//! Scenario experiment: one declarative [`ScenarioSpec`] replayed under
//! the class-blind FIFO baseline and the EDF + class-aware-hedging
//! treatment, on the identical shaped workload (`cnmt experiment
//! scenario`).
//!
//! This is the report-facing driver over
//! [`crate::sim::run_scenario_engine`]: the spec (JSON-loadable like a
//! [`crate::fleet::Topology`]) names a topology preset, a time-varying
//! [`LoadShape`] (diurnal sinusoid + flash-crowd spikes), SLO service
//! classes, a hedge shape, and a drift/fault timeline. The driver
//! generates the workload once
//! ([`super::load::synth_shaped_workload`] — a non-homogeneous Poisson
//! arrival process over the classic per-request draws) and replays it
//! twice:
//!
//! * **fifo** — class-blind: arrival-order lane queues, the hedge bar
//!   (if any) applied uniformly. What a scheduler that cannot see
//!   service classes does under the same storm.
//! * **edf** — the treatment: earliest-deadline-first within per-class
//!   quotas of the fair front-end, and the hedge waste budget spent
//!   class-aware (interactive first).
//!
//! The headline is per-class SLO attainment on the **offered** basis
//! (shed requests count as misses): EDF + class-aware hedging holds the
//! interactive class's attainment under a flash crowd + fault window
//! where FIFO misses a multiple of it, at equal-or-better goodput.
//!
//! Both cells run on the deterministic parallel runner
//! ([`crate::experiments::runner`]); the report JSON is byte-identical
//! at any thread count, and `python/tools/scenario_mirror.py`
//! regenerates `reports/scenario_sweep.json` float-exactly with no rust
//! toolchain.

use crate::devices::DeviceKind;
use crate::sim::{
    run_scenario_engine, ClassSpec, DriftSpec, FaultMode, FaultSpec, FleetOpts, HedgeShape,
    LoadShape, ScenarioResult, ScenarioSpec, Scheduling, Spike,
};
use crate::util::Json;
use crate::Result;

use super::load::synth_shaped_workload;
use super::report::text_table;
use super::runner;

/// Scenario experiment configuration.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// The declarative scenario (defaults to
    /// [`default_scenario_spec`], the checked-in
    /// `examples/scenarios/slo_mix.json`).
    pub spec: ScenarioSpec,
    /// Fleet sizing shared by both disciplines (strategy must stay
    /// `Select`; hedging comes from the spec).
    pub opts: FleetOpts,
    /// OS threads to shard the two discipline cells across; results
    /// are bit-identical at any value. 1 = serial (the mirror's mode).
    pub threads: usize,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            spec: default_scenario_spec(),
            opts: FleetOpts::default(),
            threads: 1,
        }
    }
}

/// The default scenario — kept in lockstep with
/// `examples/scenarios/slo_mix.json` (a unit test diffs the two): the
/// hetero fleet under a diurnal sinusoid, a 2.8x flash crowd, a
/// correlated cloud-tier drift, and a fail-slow fault on the fast edge
/// that lands while the crowd's backlog is still draining, carrying
/// three SLO classes. A background-heavy mix (55% of traffic with a
/// 30 s SLO) is what gives EDF room to protect the 0.5 s interactive
/// class where FIFO cannot.
pub fn default_scenario_spec() -> ScenarioSpec {
    ScenarioSpec {
        name: "slo_mix".to_string(),
        topology: "hetero".to_string(),
        seed: 20220315,
        requests: 20_000,
        load: LoadShape {
            base_rps: 260.0,
            period_s: 30.0,
            amplitude: 0.4,
            spikes: vec![Spike { start_s: 25.0, duration_s: 12.0, factor: 2.8 }],
        },
        classes: vec![
            ClassSpec {
                name: "interactive".to_string(),
                deadline_s: 0.5,
                share: 0.2,
                weight: 12.0,
                quota: 512,
                hedge_scale: 2.0,
            },
            ClassSpec {
                name: "batch".to_string(),
                deadline_s: 2.0,
                share: 0.25,
                weight: 3.0,
                quota: 512,
                hedge_scale: 1.0,
            },
            ClassSpec {
                name: "background".to_string(),
                deadline_s: 30.0,
                share: 0.55,
                weight: 1.0,
                quota: 512,
                hedge_scale: 0.0,
            },
        ],
        scheduling: Scheduling::Edf,
        hedge: Some(HedgeShape {
            margin_s: 0.012,
            waste_budget: 0.08,
            class_aware: true,
        }),
        drifts: vec![DriftSpec {
            device: DeviceKind::Cloud,
            lane: None,
            start_s: 40.0,
            ramp_s: 15.0,
            factor: 1.5,
        }],
        faults: vec![FaultSpec {
            lane: 0,
            mode: FaultMode::Slow { factor: 2.5 },
            start_s: 30.0,
            recover_s: 45.0,
        }],
        batch_aware_wait: true,
    }
}

/// The class-blind baseline variant of a spec: FIFO lane queues and a
/// uniform hedge bar (class-aware scaling off) — everything else, and
/// the workload, identical.
fn baseline_variant(spec: &ScenarioSpec) -> ScenarioSpec {
    let mut s = spec.clone();
    s.scheduling = Scheduling::Fifo;
    s.hedge = s.hedge.map(|h| HedgeShape { class_aware: false, ..h });
    s
}

/// The treatment variant: EDF-within-quota plus the spec's hedge shape
/// as written (class-aware when the spec says so).
fn treatment_variant(spec: &ScenarioSpec) -> ScenarioSpec {
    let mut s = spec.clone();
    s.scheduling = Scheduling::Edf;
    s
}

/// Index of the spec's most latency-sensitive class (smallest SLO,
/// lowest index on ties) — the headline class.
fn interactive_class(spec: &ScenarioSpec) -> usize {
    let mut best = 0usize;
    for (k, c) in spec.classes.iter().enumerate() {
        if c.deadline_s < spec.classes[best].deadline_s {
            best = k;
        }
    }
    best
}

/// Full scenario sweep: one result per discipline over one workload.
#[derive(Debug, Clone)]
pub struct ScenarioSweep {
    /// The scenario as configured (before per-cell discipline
    /// overrides).
    pub spec: ScenarioSpec,
    /// `[fifo baseline, edf treatment]` results.
    pub results: Vec<ScenarioResult>,
}

impl ScenarioSweep {
    /// Result for a discipline tag (panics when absent — report bug).
    pub fn get(&self, scheduling: &str) -> &ScenarioResult {
        self.results
            .iter()
            .find(|r| r.scheduling == scheduling)
            .unwrap_or_else(|| panic!("missing discipline {scheduling}"))
    }

    /// The headline class's label.
    pub fn interactive_name(&self) -> &str {
        &self.spec.classes[interactive_class(&self.spec)].name
    }

    /// Interactive SLO attainment under EDF + class-aware hedging
    /// (offered basis).
    pub fn headline_interactive_attainment(&self) -> f64 {
        self.get("edf").classes[interactive_class(&self.spec)].attainment()
    }

    /// Interactive SLO attainment under the class-blind FIFO baseline.
    pub fn headline_fifo_attainment(&self) -> f64 {
        self.get("fifo").classes[interactive_class(&self.spec)].attainment()
    }

    /// Interactive miss ratio (FIFO misses / EDF misses, offered
    /// basis) — the headline "class-awareness misses Nx less". The
    /// denominator is floored at one miss so a perfect EDF run reports
    /// a finite ratio.
    pub fn headline_miss_ratio(&self) -> f64 {
        let k = interactive_class(&self.spec);
        let fifo = &self.get("fifo").classes[k];
        let edf = &self.get("edf").classes[k];
        let fifo_missed = fifo.offered - fifo.within_deadline;
        let edf_missed = edf.offered - edf.within_deadline;
        fifo_missed as f64 / edf_missed.max(1) as f64
    }

    /// Goodput ratio (EDF / FIFO) — the "at equal-or-better goodput"
    /// half of the headline.
    pub fn headline_goodput_ratio(&self) -> f64 {
        self.get("edf").throughput_rps / self.get("fifo").throughput_rps
    }
}

/// Run the scenario experiment: generate the shaped workload once from
/// the spec, then replay it under both disciplines, one runner cell
/// each.
pub fn run(cfg: &ScenarioConfig) -> Result<ScenarioSweep> {
    let topo = cfg.spec.topology()?;
    cfg.spec.validate_for(&topo)?;
    let (requests, ch) =
        synth_shaped_workload(cfg.spec.seed, cfg.spec.requests, &cfg.spec.load);
    let variants = [baseline_variant(&cfg.spec), treatment_variant(&cfg.spec)];
    let outcomes = runner::run_cells(cfg.threads, variants.len(), |cell| {
        run_scenario_engine(&requests, &ch, &topo, &cfg.opts, &variants[cell], None)
            .map(|(result, _rec)| result)
    });
    let mut results = Vec::with_capacity(variants.len());
    for outcome in outcomes {
        results.push(outcome?);
    }
    Ok(ScenarioSweep { spec: cfg.spec.clone(), results })
}

/// Render the sweep as aligned text tables plus the headline.
pub fn render_text(s: &ScenarioSweep) -> String {
    let mut out = format!(
        "scenario `{}` on {}: {} requests, base {:.0} r/s (amplitude {:.2}, \
         {} spike(s)), {} drift(s), {} fault(s)\n\n",
        s.spec.name,
        s.spec.topology,
        s.spec.requests,
        s.spec.load.base_rps,
        s.spec.load.amplitude,
        s.spec.load.spikes.len(),
        s.spec.drifts.len(),
        s.spec.faults.len(),
    );
    let mut rows = vec![[
        "discipline",
        "class",
        "offered",
        "shed",
        "attain %",
        "mean ms",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "hedged",
    ]
    .iter()
    .map(|h| h.to_string())
    .collect::<Vec<_>>()];
    for r in &s.results {
        for c in &r.classes {
            rows.push(vec![
                r.scheduling.clone(),
                c.name.clone(),
                format!("{}", c.offered),
                format!("{}", c.shed),
                format!("{:.1}", c.attainment() * 100.0),
                format!("{:.1}", c.mean_latency_s * 1e3),
                format!("{:.1}", c.p50_s * 1e3),
                format!("{:.1}", c.p95_s * 1e3),
                format!("{:.1}", c.p99_s * 1e3),
                format!("{}", c.hedged),
            ]);
        }
    }
    out.push_str(&text_table(&rows));
    let mut totals = vec![[
        "discipline",
        "goodput r/s",
        "completed",
        "rejected",
        "p50 ms",
        "p99 ms",
        "batch",
        "hedged",
        "waste s",
    ]
    .iter()
    .map(|h| h.to_string())
    .collect::<Vec<_>>()];
    for r in &s.results {
        totals.push(vec![
            r.scheduling.clone(),
            format!("{:.1}", r.throughput_rps),
            format!("{}", r.completed),
            format!("{}", r.rejected),
            format!("{:.1}", r.p50_s * 1e3),
            format!("{:.1}", r.p99_s * 1e3),
            format!("{:.2}", r.mean_batch),
            format!("{}", r.hedged),
            format!("{:.2}", r.wasted_work_s),
        ]);
    }
    out.push('\n');
    out.push_str(&text_table(&totals));
    out.push_str(&format!(
        "\nheadline: EDF + class-aware hedging holds `{}` SLO attainment at \
         {:.1}% vs FIFO's {:.1}% ({:.1}x fewer misses) at {:.2}x goodput\n",
        s.interactive_name(),
        s.headline_interactive_attainment() * 100.0,
        s.headline_fifo_attainment() * 100.0,
        s.headline_miss_ratio(),
        s.headline_goodput_ratio(),
    ));
    out
}

/// JSON report (written through [`super::report::write_report`] as
/// `scenario_sweep.json`).
pub fn to_json(s: &ScenarioSweep) -> Json {
    let mut disciplines = Json::object();
    for r in &s.results {
        disciplines.set(&r.scheduling, r.to_json());
    }
    let mut root = Json::object();
    root.set("spec", s.spec.to_json())
        .set(
            "interactive_class",
            Json::Str(s.interactive_name().to_string()),
        )
        .set("disciplines", disciplines)
        .set(
            "headline_interactive_attainment",
            Json::Num(s.headline_interactive_attainment()),
        )
        .set(
            "headline_fifo_attainment",
            Json::Num(s.headline_fifo_attainment()),
        )
        .set("headline_miss_ratio", Json::Num(s.headline_miss_ratio()))
        .set(
            "headline_goodput_ratio",
            Json::Num(s.headline_goodput_ratio()),
        );
    root
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::FleetStrategy;
    use std::path::Path;

    /// A compressed storm: the same structure as the default spec with
    /// times shrunk so a few-thousand-request smoke run still crosses
    /// the spike, the drift ramp, and the fault window.
    fn smoke_spec() -> ScenarioSpec {
        ScenarioSpec {
            name: "smoke".to_string(),
            topology: "hetero".to_string(),
            seed: 42,
            requests: 2_500,
            load: LoadShape {
                base_rps: 170.0,
                period_s: 8.0,
                amplitude: 0.4,
                spikes: vec![Spike { start_s: 4.0, duration_s: 3.0, factor: 2.5 }],
            },
            classes: vec![
                ClassSpec {
                    name: "interactive".to_string(),
                    deadline_s: 0.3,
                    share: 0.5,
                    weight: 4.0,
                    quota: 96,
                    hedge_scale: 2.0,
                },
                ClassSpec {
                    name: "batch".to_string(),
                    deadline_s: 1.5,
                    share: 0.3,
                    weight: 2.0,
                    quota: 96,
                    hedge_scale: 1.0,
                },
                ClassSpec {
                    name: "background".to_string(),
                    deadline_s: 6.0,
                    share: 0.2,
                    weight: 1.0,
                    quota: 96,
                    hedge_scale: 0.0,
                },
            ],
            scheduling: Scheduling::Edf,
            hedge: Some(HedgeShape {
                margin_s: 0.012,
                waste_budget: 0.08,
                class_aware: true,
            }),
            drifts: vec![DriftSpec {
                device: DeviceKind::Cloud,
                lane: None,
                start_s: 6.0,
                ramp_s: 3.0,
                factor: 1.5,
            }],
            faults: vec![FaultSpec {
                lane: 0,
                mode: FaultMode::Slow { factor: 3.0 },
                start_s: 8.0,
                recover_s: 12.0,
            }],
            batch_aware_wait: true,
        }
    }

    fn smoke_cfg() -> ScenarioConfig {
        ScenarioConfig { spec: smoke_spec(), ..Default::default() }
    }

    #[test]
    fn default_spec_matches_the_checked_in_asset() {
        // The JSON asset is the public face of the default scenario;
        // the rust constructor must never drift from it.
        let asset = ScenarioSpec::load(Path::new("../examples/scenarios/slo_mix.json"))
            .expect("examples/scenarios/slo_mix.json loads");
        assert_eq!(
            asset.to_json().to_string_pretty(),
            default_scenario_spec().to_json().to_string_pretty(),
            "examples/scenarios/slo_mix.json drifted from default_scenario_spec()"
        );
    }

    #[test]
    fn sweep_structure_and_conservation() {
        let sweep = run(&smoke_cfg()).unwrap();
        assert_eq!(sweep.results.len(), 2);
        assert_eq!(sweep.results[0].scheduling, "fifo");
        assert_eq!(sweep.results[1].scheduling, "edf");
        for r in &sweep.results {
            assert_eq!(r.offered, 2_500);
            assert_eq!(r.completed + r.rejected, r.offered);
            assert_eq!(r.edge_count + r.cloud_count, r.completed);
            assert_eq!(r.device_results.iter().sum::<usize>(), r.completed);
            assert_eq!(r.classes.len(), 3);
            let mut offered = 0usize;
            for c in &r.classes {
                assert_eq!(c.offered, c.shed + c.completed);
                assert!(c.within_deadline <= c.completed);
                offered += c.offered;
            }
            assert_eq!(offered, r.offered);
        }
    }

    #[test]
    fn edf_holds_the_interactive_class_at_least_as_well_as_fifo() {
        // The acceptance property at smoke scale: under the compressed
        // storm, class-aware scheduling can only help the tightest SLO,
        // and it must not buy that help with goodput.
        let sweep = run(&smoke_cfg()).unwrap();
        assert_eq!(sweep.interactive_name(), "interactive");
        let edf = sweep.headline_interactive_attainment();
        let fifo = sweep.headline_fifo_attainment();
        assert!(
            edf >= fifo,
            "EDF interactive attainment {edf} below FIFO {fifo}"
        );
        assert!(
            sweep.headline_goodput_ratio() >= 0.98,
            "EDF goodput fell {}x below FIFO",
            sweep.headline_goodput_ratio()
        );
    }

    #[test]
    fn sweep_is_bit_identical_across_thread_counts() {
        let mut cfg = smoke_cfg();
        cfg.spec.requests = 1_200;
        let serial = to_json(&run(&cfg).unwrap()).to_string_pretty();
        for threads in [2, 4, 7] {
            cfg.threads = threads;
            let parallel = to_json(&run(&cfg).unwrap()).to_string_pretty();
            assert_eq!(parallel, serial, "{threads}-thread sweep diverged");
        }
    }

    #[test]
    fn rejects_degenerate_configs() {
        let mut cfg = smoke_cfg();
        cfg.spec.topology = "not-a-preset".to_string();
        assert!(run(&cfg).is_err());

        let mut cfg = smoke_cfg();
        cfg.spec.faults[0].lane = 99;
        assert!(run(&cfg).is_err());

        let mut cfg = smoke_cfg();
        cfg.opts.strategy = FleetStrategy::Hedged { margin_s: 0.01 };
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn render_and_json_cover_both_disciplines() {
        let sweep = run(&smoke_cfg()).unwrap();
        let txt = render_text(&sweep);
        assert!(txt.contains("fifo"));
        assert!(txt.contains("edf"));
        assert!(txt.contains("interactive"));
        assert!(txt.contains("headline"));
        let j = to_json(&sweep);
        assert!(j.get("spec").is_ok());
        let d = j.get("disciplines").unwrap();
        for tag in ["fifo", "edf"] {
            let r = d.get(tag).unwrap();
            assert!(r.get("classes").is_ok());
            assert!(r.get("throughput_rps").is_ok());
        }
        assert!(j.get("headline_interactive_attainment").is_ok());
        assert!(j.get("headline_miss_ratio").is_ok());
        assert!(j.get("headline_goodput_ratio").is_ok());
    }
}
