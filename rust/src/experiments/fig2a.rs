//! Fig. 2a: total translation time vs output length M is linear, for the
//! Transformer on both devices (paper caption: Jetson R²=0.99,
//! MSE=0.13 ms; Titan R²=0.85, MSE=1.2 ms).
//!
//! Procedure mirrors the paper: run many translations, group by M, plot
//! the per-M mean ± std, and report the scores of a 1-D linear fit of
//! T on M. Two modes:
//!
//! * simulated devices (default; any model, both devices, fast), and
//! * `--measured` real PJRT runs through `crate::runtime::Seq2SeqEngine`
//!   (edge == local CPU), which is what the calibration CLI wraps.

use std::collections::BTreeMap;

use crate::corpus::{CorpusGenerator, LangPair};
use crate::devices::{Calibration, DeviceKind};
use crate::metrics::OnlineStats;
use crate::predictor::fit::fit_line;
use crate::util::Json;
use crate::Result;

use super::report::text_table;

/// Per-M statistics for one device.
#[derive(Debug, Clone)]
pub struct DeviceSeries {
    /// Device this series was measured on.
    pub device: DeviceKind,
    /// M → (mean T, std T, count), in seconds.
    pub by_m: BTreeMap<usize, (f64, f64, u64)>,
    /// R² of the linear T(M) fit.
    pub r2: f64,
    /// MSE of the fit (ms²).
    pub mse_ms: f64,
    /// Fitted decode cost per output token (ms).
    pub slope_ms_per_token: f64,
}

/// Fig. 2a result: one series per device.
#[derive(Debug, Clone)]
pub struct Fig2a {
    /// Language pair profiled.
    pub pair: LangPair,
    /// Inferences profiled per device.
    pub samples: usize,
    /// One series per device.
    pub series: Vec<DeviceSeries>,
}

/// Run with simulated devices.
pub fn run(
    pair: LangPair,
    calibration: &Calibration,
    samples: usize,
    seed: u64,
) -> Result<Fig2a> {
    let model = pair.model_name();
    let mut gen = CorpusGenerator::new(pair, seed ^ 0xF26A);
    let pairs = gen.take(samples);
    let mut series = Vec::new();
    for kind in DeviceKind::ALL {
        let mut dev = calibration.build_device(kind, seed ^ kind as u64)?;
        let mut stats: BTreeMap<usize, OnlineStats> = BTreeMap::new();
        let mut points = Vec::with_capacity(samples);
        for p in &pairs {
            let t = dev.exec_time(model, p.n(), p.m_real)?;
            stats
                .entry(p.m_real)
                .or_insert_with(OnlineStats::new)
                .push(t);
            points.push((p.m_real as f64, t));
        }
        let lf = fit_line(&points)?;
        series.push(DeviceSeries {
            device: kind,
            by_m: stats
                .iter()
                .map(|(&m, s)| (m, (s.mean(), s.std(), s.count())))
                .collect(),
            r2: lf.r2,
            mse_ms: lf.mse * 1e6, // s² → ms² ... see note below
            slope_ms_per_token: lf.slope * 1e3,
        });
    }
    // Note: the paper quotes "MSE" in ms; we report RMSE in ms for
    // comparability (sqrt of mean squared error).
    for s in &mut series {
        s.mse_ms = s.mse_ms.sqrt();
    }
    Ok(Fig2a { pair, samples, series })
}

/// Text rendering.
pub fn render_text(f: &Fig2a) -> String {
    let mut out = format!(
        "Fig. 2a — T_exe vs output length M ({}, {} samples)\n",
        f.pair.model_name(),
        f.samples
    );
    let mut rows = vec![vec![
        "device".to_string(),
        "slope ms/token".to_string(),
        "R^2".to_string(),
        "RMSE ms".to_string(),
    ]];
    for s in &f.series {
        rows.push(vec![
            s.device.id().to_string(),
            format!("{:.3}", s.slope_ms_per_token),
            format!("{:.3}", s.r2),
            format!("{:.3}", s.mse_ms),
        ]);
    }
    out.push_str(&text_table(&rows));
    out.push_str("paper: Jetson R^2=0.99 MSE=0.13ms, Titan R^2=0.85 MSE=1.2ms\n");
    out
}

/// JSON report (series suitable for re-plotting).
pub fn to_json(f: &Fig2a) -> Json {
    let mut series = Vec::new();
    for s in &f.series {
        let mut o = Json::object();
        o.set("device", Json::Str(s.device.id().into()))
            .set("r2", Json::Num(s.r2))
            .set("rmse_ms", Json::Num(s.mse_ms))
            .set("slope_ms_per_token", Json::Num(s.slope_ms_per_token));
        let mut pts = Vec::new();
        for (&m, &(mean, std, count)) in &s.by_m {
            let mut p = Json::object();
            p.set("m", Json::Num(m as f64))
                .set("mean_s", Json::Num(mean))
                .set("std_s", Json::Num(std))
                .set("count", Json::Num(count as f64));
            pts.push(p);
        }
        o.set("points", Json::Array(pts));
        series.push(o);
    }
    let mut root = Json::object();
    root.set("pair", Json::Str(f.pair.id().into()))
        .set("samples", Json::Num(f.samples as f64))
        .set("series", Json::Array(series));
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transformer_time_is_linear_in_m() {
        let f = run(LangPair::EnZh, &Calibration::default_paper(), 8_000, 3).unwrap();
        assert_eq!(f.series.len(), 2);
        for s in &f.series {
            // Paper: strong linearity on the edge device; cloud noisier.
            match s.device {
                DeviceKind::Edge => assert!(s.r2 > 0.95, "edge r2 {}", s.r2),
                DeviceKind::Cloud => assert!(s.r2 > 0.6, "cloud r2 {}", s.r2),
            }
            assert!(s.slope_ms_per_token > 0.0);
        }
        // Edge slope steeper than cloud slope (slower device).
        assert!(f.series[0].slope_ms_per_token > f.series[1].slope_ms_per_token);
    }

    #[test]
    fn cloud_relatively_noisier_matches_paper() {
        // Titan's R² (0.85) < Jetson's (0.99) in the paper.
        let f = run(LangPair::EnZh, &Calibration::default_paper(), 8_000, 4).unwrap();
        let edge = f.series.iter().find(|s| s.device == DeviceKind::Edge).unwrap();
        let cloud = f.series.iter().find(|s| s.device == DeviceKind::Cloud).unwrap();
        assert!(edge.r2 > cloud.r2, "edge {} cloud {}", edge.r2, cloud.r2);
    }

    #[test]
    fn render_and_json() {
        let f = run(LangPair::EnZh, &Calibration::default_paper(), 1_000, 5).unwrap();
        let txt = render_text(&f);
        assert!(txt.contains("edge"));
        let j = to_json(&f);
        assert_eq!(j.get("series").unwrap().as_array().unwrap().len(), 2);
    }
}
