//! Report output helpers (text tables + JSON files).

use std::path::Path;

use crate::util::Json;
use crate::Result;

/// Write a JSON report to `<out_dir>/<name>.json`, creating the
/// directory if needed. Returns the path written.
pub fn write_report(out_dir: &Path, name: &str, body: &Json) -> Result<std::path::PathBuf> {
    std::fs::create_dir_all(out_dir)?;
    let path = out_dir.join(format!("{name}.json"));
    std::fs::write(&path, body.to_string_pretty())?;
    Ok(path)
}

/// Render an aligned text table. `rows` include the header as row 0.
pub fn text_table(rows: &[Vec<String>]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let cols = rows.iter().map(|r| r.len()).max().unwrap();
    let mut widths = vec![0usize; cols];
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    for (ri, row) in rows.iter().enumerate() {
        for (i, cell) in row.iter().enumerate() {
            if i > 0 {
                out.push_str("  ");
            }
            // Left-align first column, right-align the rest.
            if i == 0 {
                out.push_str(&format!("{cell:<width$}", width = widths[i]));
            } else {
                out.push_str(&format!("{cell:>width$}", width = widths[i]));
            }
        }
        out.push('\n');
        if ri == 0 {
            for (i, w) in widths.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&"-".repeat(*w));
            }
            out.push('\n');
        }
    }
    out
}

/// Format a percentage with sign, paper-style (`-13.55`, `+0.11`).
pub fn pct(x: f64) -> String {
    format!("{}{:.2}", if x >= 0.0 { "+" } else { "" }, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_alignment() {
        let rows = vec![
            vec!["name".into(), "x".into()],
            vec!["longer-name".into(), "12345".into()],
        ];
        let t = text_table(&rows);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("name"));
        assert!(lines[1].starts_with("----"));
        assert!(lines[2].contains("12345"));
    }

    #[test]
    fn pct_formatting() {
        assert_eq!(pct(-13.551), "-13.55");
        assert_eq!(pct(0.114), "+0.11");
        assert_eq!(pct(29.168), "+29.17");
    }

    #[test]
    fn write_report_creates_dirs() {
        let dir = std::env::temp_dir().join("cnmt_report_test/nested");
        let mut j = Json::object();
        j.set("x", Json::Num(1.0));
        let path = write_report(&dir, "t", &j).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(std::env::temp_dir().join("cnmt_report_test")).ok();
    }
}
