//! Multi-level CI experiment (extension): does a third offloading level
//! (end-device → gateway → cloud, as in DeePar [8] / CRIME [11]) help
//! NMT? Compares the static single-tier mappings, 2-level C-NMT
//! (gateway↔cloud, the paper's setup, with requests originating on the
//! end device), 3-level C-NMT, and the 3-level Oracle.

use crate::config::Config;
use crate::coordinator::multilevel::{MultiRouter, Tier};
use crate::corpus::{Dataset, LangPair, PrefilterRules};
use crate::devices::calibration::DeviceTimeModel;
use crate::net::trace::ConnectionProfile;
use crate::net::TraceGenerator;
use crate::predictor::{N2mRegressor, TexeModel, TtxEstimator};
use crate::util::{Json, Rng};
use crate::Result;

use super::report::text_table;

/// Per-strategy totals for one language pair.
#[derive(Debug, Clone)]
pub struct MlEntry {
    /// Routing strategy id.
    pub strategy: String,
    /// Total latency over the stream (seconds).
    pub total_s: f64,
    /// Requests per tier (end, gw, cloud).
    pub mix: [usize; 3],
}

/// Result over the configured pairs (CP1 WAN trace).
#[derive(Debug, Clone)]
pub struct Multilevel {
    /// Per-pair entries, one per strategy.
    pub rows: Vec<(LangPair, Vec<MlEntry>)>,
}

/// End-device hardware: slower than the gateway by this factor.
const END_SLOWDOWN: f64 = 3.0;
/// WLAN (end→gw) round trip: fast and stable.
const WLAN_RTT_S: f64 = 0.008;

fn tiers_for(pair: LangPair, cal: &crate::devices::Calibration) -> Result<Vec<Tier>> {
    let model = pair.model_name();
    let gw = *cal.get(crate::devices::DeviceKind::Edge, model)?;
    let cloud = *cal.get(crate::devices::DeviceKind::Cloud, model)?;
    let end_texe = TexeModel::from_coeffs(
        gw.texe.alpha_n * END_SLOWDOWN,
        gw.texe.alpha_m * END_SLOWDOWN,
        gw.texe.beta * END_SLOWDOWN,
    );
    let end = DeviceTimeModel { texe: end_texe, ..gw };
    let mk = |name: &str, truth: DeviceTimeModel, prior: f64| Tier {
        name: name.into(),
        texe: truth.texe, // idealised characterisation (fit ≈ truth)
        truth,
        ttx: TtxEstimator::new(0.3),
        ttx_prior_s: prior,
    };
    Ok(vec![
        mk("end", end, 0.0),
        mk("gw", gw, WLAN_RTT_S),
        mk("cloud", cloud, 0.06),
    ])
}

/// Run the experiment (CP1 trace for the WAN hop).
pub fn run(cfg: &Config, cal: &crate::devices::Calibration) -> Result<Multilevel> {
    let mut rows = Vec::new();
    for &pair in &cfg.pairs {
        let seed = cfg.seed ^ (pair as u64 + 1).wrapping_mul(0x3317);
        let dataset = Dataset::generate(pair, cfg.fit_inferences, cfg.eval_pool, seed);
        let n2m = N2mRegressor::fit(&dataset.fit, &PrefilterRules::default())?;
        let wan = TraceGenerator::new(seed ^ 0x4E7).profile(ConnectionProfile::Cp1);
        let stream = dataset.sample_eval(cfg.requests, seed ^ 0x5A);
        let mut rng = Rng::new(seed ^ 0x7A9);

        // Pre-sample ground truth once; all strategies share it.
        struct Truth {
            n: usize,
            costs: [f64; 3], // true total latency per tier
        }
        let mut router0 = MultiRouter::new(tiers_for(pair, cal)?, n2m)?;
        let mut t = 0.0f64;
        let truths: Vec<Truth> = stream
            .iter()
            .map(|p| {
                t += rng.exponential(1.0 / cfg.mean_interarrival_s);
                let links = [WLAN_RTT_S, wan.rtt_at(t)];
                let costs = [
                    router0.true_cost(0, p.n(), p.m_real, &links, &mut rng),
                    router0.true_cost(1, p.n(), p.m_real, &links, &mut rng),
                    router0.true_cost(2, p.n(), p.m_real, &links, &mut rng),
                ];
                Truth { n: p.n(), costs }
            })
            .collect();

        let eval = |name: &str, mut pick: Box<dyn FnMut(&Truth) -> usize>| -> MlEntry {
            let mut total = 0.0;
            let mut mix = [0usize; 3];
            for tr in &truths {
                let tier = pick(tr);
                mix[tier] += 1;
                total += tr.costs[tier];
            }
            MlEntry { strategy: name.into(), total_s: total, mix }
        };

        let mut entries = Vec::new();
        for (i, name) in ["end_only", "gw_only", "cloud_only"].iter().enumerate() {
            entries.push(eval(name, Box::new(move |_| i)));
        }
        // 2-level C-NMT: requests originate on the end device but may
        // only run there or in the cloud (no gateway tier).
        let mut r2 = MultiRouter::new(
            tiers_for(pair, cal)?.into_iter().enumerate()
                .filter(|(i, _)| *i != 1)
                .map(|(_, t)| t)
                .collect(),
            n2m,
        )?;
        entries.push(eval(
            "cnmt_2level",
            Box::new(move |tr| if r2.decide(tr.n).tier == 0 { 0 } else { 2 }),
        ));
        // 3-level C-NMT.
        let mut r3 = MultiRouter::new(tiers_for(pair, cal)?, n2m)?;
        entries.push(eval("cnmt_3level", Box::new(move |tr| r3.decide(tr.n).tier)));
        // Oracle over all three tiers.
        entries.push(eval(
            "oracle_3level",
            Box::new(|tr| {
                tr.costs
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            }),
        ));
        rows.push((pair, entries));
    }
    Ok(Multilevel { rows })
}

/// Text rendering.
pub fn render_text(m: &Multilevel) -> String {
    let mut out = String::from(
        "Multi-level CI (end-device / gateway / cloud, CP1 WAN) — extension\n",
    );
    let mut rows = vec![vec![
        "pair".to_string(),
        "strategy".to_string(),
        "total_s".to_string(),
        "end/gw/cloud".to_string(),
    ]];
    for (pair, entries) in &m.rows {
        for e in entries {
            rows.push(vec![
                pair.id().to_string(),
                e.strategy.clone(),
                format!("{:.1}", e.total_s),
                format!("{}/{}/{}", e.mix[0], e.mix[1], e.mix[2]),
            ]);
        }
    }
    out.push_str(&text_table(&rows));
    out
}

/// JSON report.
pub fn to_json(m: &Multilevel) -> Json {
    let mut rows = Vec::new();
    for (pair, entries) in &m.rows {
        let mut o = Json::object();
        o.set("pair", Json::Str(pair.id().into()));
        let mut es = Json::object();
        for e in entries {
            let mut j = Json::object();
            j.set("total_s", Json::Num(e.total_s)).set(
                "mix",
                Json::Array(e.mix.iter().map(|&x| Json::Num(x as f64)).collect()),
            );
            es.set(&e.strategy, j);
        }
        o.set("strategies", es);
        rows.push(o);
    }
    let mut root = Json::object();
    root.set("rows", Json::Array(rows));
    root
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devices::Calibration;

    fn smoke() -> Multilevel {
        let mut cfg = Config::smoke();
        cfg.requests = 4_000;
        cfg.pairs = vec![LangPair::DeEn, LangPair::EnZh];
        run(&cfg, &Calibration::default_paper()).unwrap()
    }

    #[test]
    fn three_levels_dominate_two() {
        let m = smoke();
        for (pair, entries) in &m.rows {
            let get = |id: &str| {
                entries.iter().find(|e| e.strategy == id).unwrap().total_s
            };
            assert!(
                get("cnmt_3level") <= get("cnmt_2level") * 1.001,
                "{}: 3-level {} vs 2-level {}",
                pair.id(),
                get("cnmt_3level"),
                get("cnmt_2level")
            );
            // And beats every static mapping.
            for s in ["end_only", "gw_only", "cloud_only"] {
                assert!(get("cnmt_3level") <= get(s) * 1.001, "{}: vs {s}", pair.id());
            }
            // Oracle lower-bounds everything.
            for e in entries {
                assert!(get("oracle_3level") <= e.total_s + 1e-9, "{}", e.strategy);
            }
        }
    }

    #[test]
    fn gateway_tier_actually_used() {
        let m = smoke();
        let (_, entries) = &m.rows[0];
        let three = entries.iter().find(|e| e.strategy == "cnmt_3level").unwrap();
        assert!(three.mix[1] > 0, "gateway tier unused: {:?}", three.mix);
    }
}
