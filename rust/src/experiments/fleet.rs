//! Fleet sweep: routing strategies compared across fleet shapes.
//!
//! The load sweep ([`super::load`]) stresses the paper's 1×1 pair; this
//! sweep generalises the question to fleets: given N edge devices and M
//! cloud replicas ([`crate::fleet::Topology`]), how much does
//! **fleet-wide queue-aware placement** buy over blind replica
//! assignment? Four strategies replay the identical workload per shape:
//!
//! * `fleet+static` — tier by idle eq. 1, replica by round-robin;
//! * `fleet+random` — tier by idle eq. 1, replica drawn uniformly
//!   (seeded, deterministic);
//! * `fleet+select` — the tentpole: every placement scored with eq. 1
//!   plus its expected wait, arg-min wins
//!   ([`crate::fleet::FleetSelector`]);
//! * `fleet+hedge` — `fleet+select` plus racing the best edge placement
//!   against the best cloud placement inside the error bar.
//!
//! Shapes swept by default: the `1x1` anchor (bit-identical to the pair
//! path — the differential tests in `sim::harness` prove it), uniform
//! `4x2` and `8x4` scale-ups, and a `hetero` mix of device speeds and
//! link qualities. Offered load scales with each shape's capacity so
//! every point sits in the contended regime where placement matters.
//!
//! Alongside the open-loop shape sweep, a **closed-loop drift sweep**
//! ([`run_closed`], `--closed-loop`) drives the `hetero` topology with
//! K bounded-outstanding clients while its lead edge gateway throttles
//! 2.5× mid-run (the classic thermal-throttling story, pinned to a
//! single device), comparing blind assignment, the tier-baseline
//! selector, per-device RLS refit ([`crate::predictor::PlaneBank`])
//! and budget-controlled hedging — `reports/fleet_closed_loop.json`.
//!
//! Cells (shape × strategy, or client count × configuration) are
//! sharded across threads by [`super::runner::run_cells`]; every cell
//! reseeds from the pure split [`cell_seed`], so both reports are
//! **byte-identical at any thread count**. The standalone mirror
//! `python/tools/fleet_sweep_mirror.py` regenerates the same bytes with
//! no rust toolchain — keep the two in lockstep when editing any
//! constant here.

use crate::devices::DeviceKind;
use crate::fleet::{FleetStrategy, Topology};
use crate::obs::TelemetryCfg;
use crate::sim::harness::RequestTruth;
use crate::sim::{
    run_fleet, run_fleet_closed, run_fleet_closed_streamed, run_fleet_streamed, AdaptiveOpts,
    Characterization, DriftSpec, FleetOpts, FleetResult,
};
use crate::util::rng::cell_seed;
use crate::util::Json;
use crate::{Error, Result};

use super::load::{synth_characterization, synth_stream, synth_workload};
use super::report::text_table;
use super::runner;

/// Hedge error bar of the `fleet+hedge` configuration (seconds) —
/// matches the pair sweep's [`crate::sim::AdaptiveOpts`] default.
pub const FLEET_HEDGE_MARGIN_S: f64 = 0.010;
/// Seed tag mixed into a shape's workload seed to derive the
/// `fleet+random` replica-pick stream.
const RANDOM_PICK_TAG: u64 = 0xF1E37;
/// Seed tag of the closed-loop fleet request pool.
const FLEET_CLOSED_SEED_TAG: u64 = 0xFC105ED;
/// Slowdown of the drifted replica in the closed-loop scenario.
pub const FLEET_CLOSED_DRIFT_FACTOR: f64 = 2.5;
/// Fraction of the nominal run duration (requests ÷ the shape's tuned
/// offered load) at which the drift starts.
pub const FLEET_CLOSED_DRIFT_START_FRAC: f64 = 0.25;
/// Seconds over which the drift ramps in.
pub const FLEET_CLOSED_DRIFT_RAMP_S: f64 = 10.0;

/// One swept fleet shape: a topology plus the offered load it is
/// stressed at.
#[derive(Debug, Clone)]
pub struct ShapeSpec {
    /// The fleet topology.
    pub topo: Topology,
    /// Open-loop offered load (r/s), scaled to the shape's capacity.
    pub offered_rps: f64,
}

/// Default offered load for a shape: tuned values for the standard
/// presets (the pair saturates near 100 r/s in the load sweep; the
/// scale-ups multiply that), a capacity-proportional heuristic for
/// anything else (an edge worker sustains ~16 r/s batched, a 4-worker
/// baseline replica ~112 r/s).
pub fn default_offered_rps(topo: &Topology) -> f64 {
    match topo.name.as_str() {
        "1x1" => 96.0,
        "4x2" => 288.0,
        "8x4" => 576.0,
        "hetero" => 224.0,
        _ => {
            let (e, c) = topo.shape();
            e as f64 * 16.0 + c as f64 * 112.0
        }
    }
}

/// The default shape grid: the 1×1 anchor, uniform scale-ups and a
/// heterogeneous mix, each at its tuned offered load.
pub fn default_shapes() -> Vec<ShapeSpec> {
    ["1x1", "4x2", "8x4", "hetero"]
        .iter()
        .map(|n| {
            let topo = Topology::preset(n).expect("built-in preset resolves");
            let offered_rps = default_offered_rps(&topo);
            ShapeSpec { topo, offered_rps }
        })
        .collect()
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Master seed of the sweep.
    pub seed: u64,
    /// Requests simulated at each (shape × strategy) cell.
    pub requests_per_point: usize,
    /// Shapes to sweep.
    pub shapes: Vec<ShapeSpec>,
    /// Scheduler sizing shared by every cell (`strategy` is overridden
    /// per cell).
    pub opts: FleetOpts,
    /// Hedge error bar for the `fleet+hedge` cells (seconds).
    pub hedge_margin_s: f64,
    /// OS threads to shard cells across ([`super::runner`]); results
    /// are bit-identical at any value. 1 = serial (the mirror's mode).
    pub threads: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            seed: 20220315,
            requests_per_point: 20_000,
            shapes: default_shapes(),
            opts: FleetOpts::default(),
            hedge_margin_s: FLEET_HEDGE_MARGIN_S,
            threads: 1,
        }
    }
}

/// The four strategies evaluated at one shape. `workload_seed` is the
/// shape's [`cell_seed`] split; the random baseline's replica stream is
/// derived from it so every cell stays a pure function of the master
/// seed.
fn strategies(workload_seed: u64, hedge_margin_s: f64) -> [FleetStrategy; 4] {
    [
        FleetStrategy::Static,
        FleetStrategy::Random { seed: workload_seed ^ RANDOM_PICK_TAG },
        FleetStrategy::Select,
        FleetStrategy::Hedged { margin_s: hedge_margin_s },
    ]
}

/// All strategies evaluated on one shape.
#[derive(Debug, Clone)]
pub struct ShapeCell {
    /// The swept shape.
    pub shape: ShapeSpec,
    /// One result per strategy.
    pub results: Vec<FleetResult>,
}

impl ShapeCell {
    /// Result for a strategy label (panics when absent — report bug).
    pub fn get(&self, policy: &str) -> &FleetResult {
        self.results
            .iter()
            .find(|r| r.policy == policy)
            .unwrap_or_else(|| panic!("missing fleet policy {policy}"))
    }

    /// p99 ratio (random / select) — the shape's headline: how much
    /// tail the queue-aware arg-min buys over blind random assignment.
    pub fn p99_vs_random(&self) -> f64 {
        self.get("fleet+random").p99_s / self.get("fleet+select").p99_s
    }

    /// p99 ratio (static round-robin / select).
    pub fn p99_vs_static(&self) -> f64 {
        self.get("fleet+static").p99_s / self.get("fleet+select").p99_s
    }
}

/// Full fleet sweep result.
#[derive(Debug, Clone)]
pub struct FleetSweep {
    /// One cell per shape.
    pub cells: Vec<ShapeCell>,
    /// Requests simulated per cell.
    pub requests_per_point: usize,
    /// Master seed of the sweep.
    pub seed: u64,
    /// Hedge error bar of the `fleet+hedge` cells (seconds).
    pub hedge_margin_s: f64,
}

impl FleetSweep {
    /// The headline shape: `8x4` when swept, else the last shape.
    fn headline_cell(&self) -> Option<&ShapeCell> {
        self.cells
            .iter()
            .find(|c| c.shape.topo.name == "8x4")
            .or_else(|| self.cells.last())
    }

    /// Headline: random / select p99 ratio on the headline shape.
    pub fn headline_p99_ratio(&self) -> f64 {
        self.headline_cell().map_or(f64::NAN, |c| c.p99_vs_random())
    }
}

/// Run the fleet sweep: every (shape × strategy) cell on the
/// deterministic parallel runner, each shape replaying one shared
/// workload seeded from the pure per-shape split of the master seed.
pub fn run(cfg: &FleetConfig) -> Result<FleetSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("fleet sweep needs requests_per_point > 0".into()));
    }
    if cfg.shapes.is_empty() {
        return Err(Error::Config("fleet sweep needs at least one shape".into()));
    }
    if !(cfg.hedge_margin_s.is_finite() && cfg.hedge_margin_s >= 0.0) {
        return Err(Error::Config(format!(
            "fleet hedge margin {} must be finite and >= 0",
            cfg.hedge_margin_s
        )));
    }
    for s in &cfg.shapes {
        s.topo.validate()?;
        if !s.offered_rps.is_finite() || s.offered_rps <= 0.0 {
            return Err(Error::Config(format!(
                "shape {}: offered load {} r/s must be finite and > 0",
                s.topo.name, s.offered_rps
            )));
        }
    }
    let n_strat = strategies(0, cfg.hedge_margin_s).len();
    // Workloads are generated once per shape (pure functions of the
    // per-shape seed split) and shared read-only by that shape's
    // strategy cells — the same precompute-serially pattern the load
    // sweep uses to keep the runner's determinism argument intact.
    let workloads: Vec<(Vec<RequestTruth>, Characterization)> = cfg
        .shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            synth_workload(cell_seed(cfg.seed, i as u64), cfg.requests_per_point, s.offered_rps)
        })
        .collect();
    let outcomes = runner::run_cells(cfg.threads, cfg.shapes.len() * n_strat, |cell| {
        let si = cell / n_strat;
        let strategy = strategies(cell_seed(cfg.seed, si as u64), cfg.hedge_margin_s)
            [cell % n_strat];
        let (requests, ch) = &workloads[si];
        run_fleet(
            requests,
            ch,
            &cfg.shapes[si].topo,
            &FleetOpts { strategy, ..cfg.opts },
        )
    });
    let mut outcomes = outcomes.into_iter();
    let mut cells = Vec::with_capacity(cfg.shapes.len());
    for shape in &cfg.shapes {
        let mut results = Vec::with_capacity(n_strat);
        for _ in 0..n_strat {
            results.push(outcomes.next().expect("one outcome per fleet cell")?);
        }
        cells.push(ShapeCell { shape: shape.clone(), results });
    }
    Ok(FleetSweep {
        cells,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
        hedge_margin_s: cfg.hedge_margin_s,
    })
}

/// Streaming twin of [`run`]: every cell regenerates its shape's
/// workload lazily through [`synth_stream`] and replays it with
/// [`run_fleet_streamed`] — bit-identical report JSON (the
/// differential tests assert it) in O(outstanding) memory per cell.
pub fn run_streamed(cfg: &FleetConfig) -> Result<FleetSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("fleet sweep needs requests_per_point > 0".into()));
    }
    if cfg.shapes.is_empty() {
        return Err(Error::Config("fleet sweep needs at least one shape".into()));
    }
    if !(cfg.hedge_margin_s.is_finite() && cfg.hedge_margin_s >= 0.0) {
        return Err(Error::Config(format!(
            "fleet hedge margin {} must be finite and >= 0",
            cfg.hedge_margin_s
        )));
    }
    for s in &cfg.shapes {
        s.topo.validate()?;
        if !s.offered_rps.is_finite() || s.offered_rps <= 0.0 {
            return Err(Error::Config(format!(
                "shape {}: offered load {} r/s must be finite and > 0",
                s.topo.name, s.offered_rps
            )));
        }
    }
    let n_strat = strategies(0, cfg.hedge_margin_s).len();
    let chs: Vec<Characterization> = cfg
        .shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            synth_characterization(
                cell_seed(cfg.seed, i as u64),
                cfg.requests_per_point,
                s.offered_rps,
            )
        })
        .collect();
    let outcomes = runner::run_cells(cfg.threads, cfg.shapes.len() * n_strat, |cell| {
        let si = cell / n_strat;
        let strategy = strategies(cell_seed(cfg.seed, si as u64), cfg.hedge_margin_s)
            [cell % n_strat];
        let arrivals = synth_stream(
            cell_seed(cfg.seed, si as u64),
            cfg.requests_per_point,
            cfg.shapes[si].offered_rps,
        )
        .map(Ok);
        run_fleet_streamed(
            arrivals,
            &chs[si],
            &cfg.shapes[si].topo,
            &FleetOpts { strategy, ..cfg.opts },
        )
    });
    let mut outcomes = outcomes.into_iter();
    let mut cells = Vec::with_capacity(cfg.shapes.len());
    for shape in &cfg.shapes {
        let mut results = Vec::with_capacity(n_strat);
        for _ in 0..n_strat {
            results.push(outcomes.next().expect("one outcome per fleet cell")?);
        }
        cells.push(ShapeCell { shape: shape.clone(), results });
    }
    Ok(FleetSweep {
        cells,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
        hedge_margin_s: cfg.hedge_margin_s,
    })
}

/// Render the sweep as an aligned text table plus per-shape headlines.
pub fn render_text(s: &FleetSweep) -> String {
    let mut rows = vec![[
        "shape",
        "policy",
        "goodput r/s",
        "shed %",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "batch",
        "hedge %",
        "waste %",
        "edge/cloud",
    ]
    .iter()
    .map(|c| c.to_string())
    .collect::<Vec<String>>()];
    for c in &s.cells {
        for r in &c.results {
            rows.push(vec![
                c.shape.topo.name.clone(),
                r.policy.clone(),
                format!("{:.1}", r.throughput_rps),
                format!("{:.1}", r.shed_rate() * 100.0),
                format!("{:.1}", r.p50_s * 1e3),
                format!("{:.1}", r.p95_s * 1e3),
                format!("{:.1}", r.p99_s * 1e3),
                format!("{:.2}", r.mean_batch),
                format!("{:.1}", r.hedge_rate() * 100.0),
                format!("{:.1}", r.wasted_frac() * 100.0),
                format!("{}/{}", r.edge_count, r.cloud_count),
            ]);
        }
    }
    let mut out = text_table(&rows);
    for c in &s.cells {
        out.push_str(&format!(
            "\n{} @ {:.0} r/s: select p99 is {:.1}x shorter than random, {:.1}x \
             shorter than static round-robin\n",
            c.shape.topo.name,
            c.shape.offered_rps,
            c.p99_vs_random(),
            c.p99_vs_static()
        ));
    }
    out.push_str(&format!(
        "\nheadline: fleet-wide queue-aware selection beats random replica \
         assignment {:.1}x on p99 at equal goodput\n",
        s.headline_p99_ratio()
    ));
    out
}

/// JSON report (`fleet_sweep.json`, written through
/// [`super::report::write_report`]).
pub fn to_json(s: &FleetSweep) -> Json {
    let mut shapes = Vec::new();
    for c in &s.cells {
        let (edges, clouds) = c.shape.topo.shape();
        let mut policies = Json::object();
        for r in &c.results {
            policies.set(&r.policy, r.to_json());
        }
        let mut o = Json::object();
        o.set("name", Json::Str(c.shape.topo.name.clone()))
            .set("offered_rps", Json::Num(c.shape.offered_rps))
            .set("edges", Json::Num(edges as f64))
            .set("clouds", Json::Num(clouds as f64))
            .set("topology", c.shape.topo.to_json())
            .set("policies", policies)
            .set("p99_ratio_vs_random", Json::Num(c.p99_vs_random()))
            .set("p99_ratio_vs_static", Json::Num(c.p99_vs_static()));
        shapes.push(o);
    }
    let mut root = Json::object();
    root.set("seed", Json::Num(s.seed as f64))
        .set("requests_per_point", Json::Num(s.requests_per_point as f64))
        .set("hedge_margin_s", Json::Num(s.hedge_margin_s))
        .set("shapes", Json::Array(shapes))
        .set("headline_p99_ratio", Json::Num(s.headline_p99_ratio()));
    root
}

// ------------------------------------------------------------ closed loop

/// Closed-loop fleet sweep configuration
/// (`cnmt experiment fleet --closed-loop`): K bounded-outstanding
/// clients drive one topology while one device — its lead edge
/// gateway — drifts slower mid-run: the adaptation story at fleet
/// scope. Four configurations replay the identical pool per client
/// count:
///
/// * `fleet+static` — blind round-robin replica assignment;
/// * `fleet+select` — queue-aware arg-min on the **tier-baseline**
///   planes (adaptation off: the drifted replica keeps its stale
///   estimate);
/// * `fleet+select+refit` — per-device RLS refit
///   ([`crate::predictor::PlaneBank`]): only the throttled replica's
///   plane is re-learned, its siblings stay warm;
/// * `fleet+hedge+refit` — plus best-edge vs best-cloud hedging under
///   the waste-budget margin controller
///   ([`crate::scheduler::HedgeBudget`]).
#[derive(Debug, Clone)]
pub struct FleetClosedConfig {
    /// Master seed of the sweep.
    pub seed: u64,
    /// Request bodies submitted per (client count × configuration) cell.
    pub requests_per_point: usize,
    /// Client counts to sweep (each = max outstanding requests).
    pub clients: Vec<usize>,
    /// Per-client think time between result and next submission (s).
    pub think_s: f64,
    /// The fleet under test (drift pins its first edge gateway).
    pub topo: Topology,
    /// Scheduler sizing shared by every cell (`strategy`, `adaptive`
    /// and `drift` are overridden per cell).
    pub opts: FleetOpts,
    /// Hedge error bar (initial margin of the budget controller) for
    /// the hedged configuration (seconds).
    pub hedge_margin_s: f64,
    /// Adaptive knobs of the refit configurations (budget included).
    pub adaptive: AdaptiveOpts,
    /// OS threads to shard cells across; results are bit-identical at
    /// any value. 1 = serial (the mirror's mode).
    pub threads: usize,
}

impl Default for FleetClosedConfig {
    fn default() -> Self {
        FleetClosedConfig {
            seed: 20220315,
            requests_per_point: 20_000,
            clients: vec![8, 16, 32, 64],
            think_s: 0.0,
            topo: Topology::hetero(),
            opts: FleetOpts::default(),
            hedge_margin_s: FLEET_HEDGE_MARGIN_S,
            adaptive: AdaptiveOpts::default(),
            threads: 1,
        }
    }
}

/// The drift injected into every closed-loop cell: the topology's lead
/// edge gateway (`hetero`'s fast desktop-class edge0 — the thermal-
/// throttling scenario of the pair drift study, now pinned to a single
/// device) slows by [`FLEET_CLOSED_DRIFT_FACTOR`] a quarter of the way
/// into the nominal run, ramping over [`FLEET_CLOSED_DRIFT_RAMP_S`]
/// seconds. The tier-baseline selector keeps believing it is the
/// fastest edge (and keeps under-pricing its backlog); per-device refit
/// re-learns exactly that one plane.
pub fn closed_drift_spec(topo: &Topology, requests_per_point: usize) -> DriftSpec {
    let lane = topo.edge_ids()[0];
    let nominal_rps = default_offered_rps(topo);
    DriftSpec {
        device: DeviceKind::Edge,
        lane: Some(lane),
        start_s: (requests_per_point as f64 / nominal_rps) * FLEET_CLOSED_DRIFT_START_FRAC,
        ramp_s: FLEET_CLOSED_DRIFT_RAMP_S,
        factor: FLEET_CLOSED_DRIFT_FACTOR,
    }
}

/// The four configurations evaluated at each client count.
fn closed_configurations(cfg: &FleetClosedConfig) -> [(FleetStrategy, Option<AdaptiveOpts>); 4] {
    [
        (FleetStrategy::Static, None),
        (FleetStrategy::Select, None),
        (FleetStrategy::Select, Some(cfg.adaptive)),
        (
            FleetStrategy::Hedged { margin_s: cfg.hedge_margin_s },
            Some(cfg.adaptive),
        ),
    ]
}

/// All configurations evaluated at one client count.
#[derive(Debug, Clone)]
pub struct FleetClosedCell {
    /// Concurrent clients at this point.
    pub clients: usize,
    /// One result per configuration.
    pub results: Vec<FleetResult>,
}

impl FleetClosedCell {
    /// Result for a policy label (panics when absent — report bug).
    pub fn get(&self, policy: &str) -> &FleetResult {
        self.results
            .iter()
            .find(|r| r.policy == policy)
            .unwrap_or_else(|| panic!("missing fleet policy {policy}"))
    }

    /// p99 ratio (tier-baseline select / per-device refit select) — the
    /// cell's headline: what re-learning the one throttled replica buys.
    pub fn p99_vs_baseline(&self) -> f64 {
        self.get("fleet+select").p99_s / self.get("fleet+select+refit").p99_s
    }
}

/// Full closed-loop fleet sweep.
#[derive(Debug, Clone)]
pub struct FleetClosedSweep {
    /// One cell per client count.
    pub cells: Vec<FleetClosedCell>,
    /// The swept topology.
    pub topo: Topology,
    /// The drift every cell replayed under.
    pub drift: DriftSpec,
    /// Request bodies per cell.
    pub requests_per_point: usize,
    /// Master seed.
    pub seed: u64,
    /// Per-client think time (s).
    pub think_s: f64,
    /// Hedge error bar (initial controller margin, seconds).
    pub hedge_margin_s: f64,
    /// Configured hedge waste budget (fraction of executed work).
    pub waste_budget: f64,
}

impl FleetClosedSweep {
    /// Headline: baseline-select / refit-select p99 ratio at the
    /// largest client count (the saturated end of the curve).
    pub fn headline_p99_ratio(&self) -> f64 {
        self.cells.last().map_or(f64::NAN, |c| c.p99_vs_baseline())
    }

    /// Worst wasted-work fraction any hedged cell reported — the number
    /// the budget acceptance criterion gates (≤ budget + 2 pts).
    pub fn max_hedge_wasted_frac(&self) -> f64 {
        self.cells
            .iter()
            .map(|c| c.get("fleet+hedge+refit").wasted_frac())
            .fold(0.0, f64::max)
    }
}

/// Run the closed-loop fleet sweep: every (client count ×
/// configuration) cell on the deterministic parallel runner, all cells
/// replaying one shared drift scenario over one shared pool.
pub fn run_closed(cfg: &FleetClosedConfig) -> Result<FleetClosedSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("fleet closed loop needs requests_per_point > 0".into()));
    }
    if cfg.clients.is_empty() {
        return Err(Error::Config("fleet closed loop needs at least one client count".into()));
    }
    if cfg.clients.iter().any(|&k| k == 0) {
        return Err(Error::Config("client counts must be > 0".into()));
    }
    if !(cfg.hedge_margin_s.is_finite() && cfg.hedge_margin_s >= 0.0) {
        return Err(Error::Config(format!(
            "fleet hedge margin {} must be finite and >= 0",
            cfg.hedge_margin_s
        )));
    }
    cfg.topo.validate()?;
    let drift = closed_drift_spec(&cfg.topo, cfg.requests_per_point);
    // Arrival times in the pool are ignored (completions drive
    // arrivals); one pool shared read-only by every cell.
    let (pool, ch) = synth_workload(
        cfg.seed ^ FLEET_CLOSED_SEED_TAG,
        cfg.requests_per_point,
        1.0,
    );
    let n_cfg = closed_configurations(cfg).len();
    let outcomes = runner::run_cells(cfg.threads, cfg.clients.len() * n_cfg, |cell| {
        let clients = cfg.clients[cell / n_cfg];
        let (strategy, adaptive) = closed_configurations(cfg)[cell % n_cfg];
        let opts = FleetOpts {
            strategy,
            adaptive,
            drift: Some(drift),
            ..cfg.opts
        };
        run_fleet_closed(&pool, &ch, &cfg.topo, &opts, clients, cfg.think_s)
    });
    let mut outcomes = outcomes.into_iter();
    let mut cells = Vec::with_capacity(cfg.clients.len());
    for &clients in &cfg.clients {
        let mut results = Vec::with_capacity(n_cfg);
        for _ in 0..n_cfg {
            results.push(outcomes.next().expect("one outcome per fleet closed cell")?);
        }
        cells.push(FleetClosedCell { clients, results });
    }
    Ok(FleetClosedSweep {
        cells,
        topo: cfg.topo.clone(),
        drift,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
        think_s: cfg.think_s,
        hedge_margin_s: cfg.hedge_margin_s,
        waste_budget: cfg.adaptive.waste_budget,
    })
}

/// Streaming twin of [`run_closed`]: bodies are pulled lazily from
/// [`synth_stream`] and replayed with [`run_fleet_closed_streamed`] —
/// bit-identical report JSON in O(clients) memory per cell.
pub fn run_closed_streamed(cfg: &FleetClosedConfig) -> Result<FleetClosedSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("fleet closed loop needs requests_per_point > 0".into()));
    }
    if cfg.clients.is_empty() {
        return Err(Error::Config("fleet closed loop needs at least one client count".into()));
    }
    if cfg.clients.iter().any(|&k| k == 0) {
        return Err(Error::Config("client counts must be > 0".into()));
    }
    if !(cfg.hedge_margin_s.is_finite() && cfg.hedge_margin_s >= 0.0) {
        return Err(Error::Config(format!(
            "fleet hedge margin {} must be finite and >= 0",
            cfg.hedge_margin_s
        )));
    }
    cfg.topo.validate()?;
    let drift = closed_drift_spec(&cfg.topo, cfg.requests_per_point);
    let ch = synth_characterization(
        cfg.seed ^ FLEET_CLOSED_SEED_TAG,
        cfg.requests_per_point,
        1.0,
    );
    let n_cfg = closed_configurations(cfg).len();
    let outcomes = runner::run_cells(cfg.threads, cfg.clients.len() * n_cfg, |cell| {
        let clients = cfg.clients[cell / n_cfg];
        let (strategy, adaptive) = closed_configurations(cfg)[cell % n_cfg];
        let opts = FleetOpts {
            strategy,
            adaptive,
            drift: Some(drift),
            ..cfg.opts
        };
        let bodies = synth_stream(
            cfg.seed ^ FLEET_CLOSED_SEED_TAG,
            cfg.requests_per_point,
            1.0,
        )
        .map(Ok);
        run_fleet_closed_streamed(bodies, &ch, &cfg.topo, &opts, clients, cfg.think_s)
    });
    let mut outcomes = outcomes.into_iter();
    let mut cells = Vec::with_capacity(cfg.clients.len());
    for &clients in &cfg.clients {
        let mut results = Vec::with_capacity(n_cfg);
        for _ in 0..n_cfg {
            results.push(outcomes.next().expect("one outcome per fleet closed cell")?);
        }
        cells.push(FleetClosedCell { clients, results });
    }
    Ok(FleetClosedSweep {
        cells,
        topo: cfg.topo.clone(),
        drift,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
        think_s: cfg.think_s,
        hedge_margin_s: cfg.hedge_margin_s,
        waste_budget: cfg.adaptive.waste_budget,
    })
}

/// Render the closed-loop fleet sweep as an aligned text table plus the
/// drift/budget headlines.
pub fn render_closed_text(s: &FleetClosedSweep) -> String {
    let mut rows = vec![[
        "clients",
        "policy",
        "goodput r/s",
        "mean ms",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "batch",
        "hedge %",
        "waste %",
        "edge/cloud",
    ]
    .iter()
    .map(|c| c.to_string())
    .collect::<Vec<String>>()];
    for c in &s.cells {
        for r in &c.results {
            rows.push(vec![
                format!("{}", c.clients),
                r.policy.clone(),
                format!("{:.1}", r.throughput_rps),
                format!("{:.1}", r.mean_latency_s * 1e3),
                format!("{:.1}", r.p50_s * 1e3),
                format!("{:.1}", r.p95_s * 1e3),
                format!("{:.1}", r.p99_s * 1e3),
                format!("{:.2}", r.mean_batch),
                format!("{:.1}", r.hedge_rate() * 100.0),
                format!("{:.1}", r.wasted_frac() * 100.0),
                format!("{}/{}", r.edge_count, r.cloud_count),
            ]);
        }
    }
    let mut out = text_table(&rows);
    out.push_str(&format!(
        "\ndrift: {} (device {}) slows {:.1}x from t={:.0}s (ramp {:.0}s)\n",
        s.topo.devices[s.drift.lane.unwrap_or(0)].name,
        s.drift.lane.unwrap_or(0),
        s.drift.factor,
        s.drift.start_s,
        s.drift.ramp_s
    ));
    for c in &s.cells {
        out.push_str(&format!(
            "K={}: per-device refit p99 is {:.1}x shorter than the tier-baseline \
             selector\n",
            c.clients,
            c.p99_vs_baseline()
        ));
    }
    out.push_str(&format!(
        "\nheadline: with one replica drifted {:.1}x slower, per-device refit \
         cuts fleet+select p99 {:.1}x at K={}; hedge waste peaks at {:.1}% \
         against a {:.0}% budget\n",
        s.drift.factor,
        s.headline_p99_ratio(),
        s.cells.last().map_or(0, |c| c.clients),
        s.max_hedge_wasted_frac() * 100.0,
        s.waste_budget * 100.0
    ));
    out
}

/// JSON report (`fleet_closed_loop.json`, written through
/// [`super::report::write_report`]).
pub fn closed_to_json(s: &FleetClosedSweep) -> Json {
    let mut points = Vec::new();
    for c in &s.cells {
        let mut policies = Json::object();
        for r in &c.results {
            policies.set(&r.policy, r.to_json());
        }
        let mut o = Json::object();
        o.set("clients", Json::Num(c.clients as f64))
            .set("policies", policies)
            .set("p99_ratio_vs_baseline", Json::Num(c.p99_vs_baseline()));
        points.push(o);
    }
    let mut root = Json::object();
    root.set("seed", Json::Num(s.seed as f64))
        .set("requests_per_point", Json::Num(s.requests_per_point as f64))
        .set("think_s", Json::Num(s.think_s))
        .set("topology", s.topo.to_json())
        .set("drift", s.drift.to_json())
        .set("hedge_margin_s", Json::Num(s.hedge_margin_s))
        .set("waste_budget", Json::Num(s.waste_budget))
        .set("points", Json::Array(points))
        .set("headline_p99_ratio", Json::Num(s.headline_p99_ratio()))
        .set("max_hedge_wasted_frac", Json::Num(s.max_hedge_wasted_frac()));
    root
}

// ------------------------------------------------------ drift telemetry

/// Telemetry sampling cadence of `telemetry_drift.json` (seconds).
pub const TELEMETRY_INTERVAL_S: f64 = 2.0;
/// Telemetry window capacity of `telemetry_drift.json` (samples).
pub const TELEMETRY_CAPACITY: usize = 64;
/// The single client count the telemetry report runs at — the contended
/// mid-point of the closed-loop curve, where the drift story is
/// sharpest without the static baseline outliving the window by much.
pub const TELEMETRY_CLIENTS: usize = 32;

/// The closed-loop drift sweep with the control-loop telemetry sampler
/// switched on (`cnmt experiment fleet --closed-loop --telemetry`):
/// identical scenario, topology and seed discipline to
/// [`FleetClosedConfig::default`], but pinned to K =
/// [`TELEMETRY_CLIENTS`] and carrying a
/// [`TelemetryCfg`] so every cell's [`FleetResult`] gains the phase
/// decomposition and per-device gauge series. Telemetry only observes:
/// every aggregate in the report is bit-identical to the untelemetered
/// run.
pub fn telemetry_config(seed: u64) -> FleetClosedConfig {
    FleetClosedConfig {
        seed,
        clients: vec![TELEMETRY_CLIENTS],
        opts: FleetOpts {
            telemetry: Some(TelemetryCfg {
                interval_s: TELEMETRY_INTERVAL_S,
                capacity: TELEMETRY_CAPACITY,
            }),
            ..FleetOpts::default()
        },
        ..Default::default()
    }
}

/// First, peak and last element of one gauge series (NaNs when empty).
fn series_story(xs: &[f64]) -> (f64, f64, f64) {
    let first = xs.first().copied().unwrap_or(f64::NAN);
    let peak = xs.iter().copied().fold(f64::NAN, f64::max);
    let last = xs.last().copied().unwrap_or(f64::NAN);
    (first, peak, last)
}

/// The compressed drift-story diagnostics of the telemetry report: does
/// the time-series actually show the scenario? The throttled device's
/// backlog rising under the tier-baseline selector, the refit plane
/// coefficients stepping toward the drifted ground truth, and the hedge
/// margin controller converging with its windowed waste near the
/// budget. Mirrored element-for-element by
/// `python/tools/telemetry_mirror.py`.
pub fn telemetry_story(s: &FleetClosedSweep) -> Json {
    let mut o = Json::object();
    let lane = s.drift.lane.unwrap_or(0);
    o.set("drift_lane", Json::Num(lane as f64));
    let Some(cell) = s.cells.last() else { return o };
    // Tier-baseline selector: the stale plane keeps under-pricing the
    // throttled device, so its sampled backlog climbs.
    if let Some(tel) = &cell.get("fleet+select").telemetry {
        let (first, peak, last) = series_story(&tel.devices[lane].expected_wait_s);
        o.set("baseline_backlog_first_s", Json::Num(first))
            .set("baseline_backlog_peak_s", Json::Num(peak))
            .set("baseline_backlog_last_s", Json::Num(last));
    }
    // Per-device refit: the throttled replica's installed plane steps
    // toward the drifted ground truth (≈ drift.factor × the baseline).
    if let Some(tel) = &cell.get("fleet+select+refit").telemetry {
        if let Some(plane) = &tel.devices[lane].plane {
            let (first, _, last) = series_story(&plane[0]);
            o.set("refit_plane_an_first", Json::Num(first))
                .set("refit_plane_an_last", Json::Num(last))
                .set("refit_plane_an_ratio", Json::Num(last / first));
        }
    }
    // Budget-controlled hedging: margin settles, windowed waste pins
    // near the configured budget.
    if let Some(tel) = &cell.get("fleet+hedge+refit").telemetry {
        if let Some(m) = &tel.hedge_margin_s {
            let (_, _, last) = series_story(m);
            o.set("hedge_margin_last_s", Json::Num(last));
        }
        if let Some(w) = &tel.wasted_frac {
            let (_, _, last) = series_story(w);
            o.set("wasted_frac_last", Json::Num(last));
        }
    }
    o
}

/// JSON report (`telemetry_drift.json`): the closed-loop drift report
/// augmented with the sampler parameters and the drift-story
/// diagnostics. Per-policy blocks carry the `phases` and `telemetry`
/// series (present because the run had telemetry on).
pub fn telemetry_to_json(s: &FleetClosedSweep) -> Json {
    let mut root = closed_to_json(s);
    root.set("telemetry_interval_s", Json::Num(TELEMETRY_INTERVAL_S))
        .set("telemetry_capacity", Json::Num(TELEMETRY_CAPACITY as f64))
        .set("drift_story", telemetry_story(s));
    root
}

/// Render the telemetry sweep: the closed-loop table plus the
/// drift-story lines the acceptance criteria gate on.
pub fn render_telemetry_text(s: &FleetClosedSweep) -> String {
    let mut out = render_closed_text(s);
    let story = telemetry_story(s);
    let get = |k: &str| story.get_opt(k).and_then(|v| v.as_f64().ok());
    if let (Some(first), Some(peak)) = (
        get("baseline_backlog_first_s"),
        get("baseline_backlog_peak_s"),
    ) {
        out.push_str(&format!(
            "\ntelemetry: throttled device backlog {:.1} ms → {:.1} ms peak \
             under the tier-baseline selector\n",
            first * 1e3,
            peak * 1e3
        ));
    }
    if let Some(ratio) = get("refit_plane_an_ratio") {
        out.push_str(&format!(
            "telemetry: refit stepped the throttled plane a_N {:.2}x toward \
             the {:.1}x drifted truth\n",
            ratio, s.drift.factor
        ));
    }
    if let (Some(m), Some(w)) = (get("hedge_margin_last_s"), get("wasted_frac_last")) {
        out.push_str(&format!(
            "telemetry: hedge margin settled at {:.2} ms with windowed waste \
             {:.1}% against the {:.0}% budget\n",
            m * 1e3,
            w * 100.0,
            s.waste_budget * 100.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_cfg() -> FleetConfig {
        FleetConfig {
            requests_per_point: 2_000,
            shapes: vec![
                ShapeSpec { topo: Topology::pair(), offered_rps: 96.0 },
                ShapeSpec { topo: Topology::uniform(4, 2), offered_rps: 288.0 },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn structure_and_conservation() {
        let sweep = run(&smoke_cfg()).unwrap();
        assert_eq!(sweep.cells.len(), 2);
        for cell in &sweep.cells {
            assert_eq!(cell.results.len(), 4);
            for r in &cell.results {
                assert_eq!(r.offered, 2_000, "{}", r.policy);
                assert_eq!(r.completed + r.rejected, r.offered, "{}", r.policy);
                assert_eq!(
                    r.device_results.iter().sum::<usize>(),
                    r.completed,
                    "{}",
                    r.policy
                );
                assert_eq!(r.device_results.len(), cell.shape.topo.len());
                assert!(r.p50_s <= r.p99_s + 1e-12, "{}", r.policy);
                if r.policy != "fleet+hedge" {
                    assert_eq!(r.hedged, 0, "{}", r.policy);
                }
            }
            // Every strategy label present exactly once.
            for label in ["fleet+static", "fleet+random", "fleet+select", "fleet+hedge"] {
                assert_eq!(
                    cell.results.iter().filter(|r| r.policy == label).count(),
                    1,
                    "{label}"
                );
            }
        }
    }

    #[test]
    fn sweep_is_bit_identical_across_thread_counts() {
        // The determinism acceptance property: the JSON bytes CI diffs
        // must not depend on the thread count.
        let mut cfg = smoke_cfg();
        cfg.requests_per_point = 800;
        let serial = to_json(&run(&cfg).unwrap()).to_string_pretty();
        for threads in [2, 4, 7] {
            cfg.threads = threads;
            let parallel = to_json(&run(&cfg).unwrap()).to_string_pretty();
            assert_eq!(parallel, serial, "{threads}-thread fleet sweep diverged");
        }
    }

    #[test]
    fn select_beats_blind_assignment_on_the_scaled_shapes() {
        // Smoke-scale version of the acceptance criterion: on 4x2 the
        // queue-aware arg-min beats both blind baselines on p99 at
        // equal-or-better goodput.
        let sweep = run(&smoke_cfg()).unwrap();
        let cell = &sweep.cells[1];
        assert_eq!(cell.shape.topo.name, "4x2");
        let select = cell.get("fleet+select");
        for blind in [cell.get("fleet+random"), cell.get("fleet+static")] {
            assert!(
                select.p99_s < blind.p99_s,
                "select p99 {} not below {} p99 {}",
                select.p99_s,
                blind.policy,
                blind.p99_s
            );
            assert!(
                select.throughput_rps >= blind.throughput_rps * 0.999,
                "select goodput {} below {} {}",
                select.throughput_rps,
                blind.policy,
                blind.throughput_rps
            );
        }
        assert!(cell.p99_vs_random() > 1.0);
        assert!(cell.p99_vs_static() > 1.0);
    }

    #[test]
    fn render_and_json_cover_all_shapes() {
        let sweep = run(&smoke_cfg()).unwrap();
        let txt = render_text(&sweep);
        assert!(txt.contains("fleet+select"));
        assert!(txt.contains("fleet+hedge"));
        assert!(txt.contains("headline"));
        let j = to_json(&sweep);
        let shapes = j.get("shapes").unwrap().as_array().unwrap();
        assert_eq!(shapes.len(), 2);
        let s0 = &shapes[0];
        assert_eq!(s0.get("name").unwrap().as_str().unwrap(), "1x1");
        assert!(s0.get("policies").unwrap().get("fleet+select").is_ok());
        assert!(s0.get("topology").unwrap().get("devices").is_ok());
        assert!(s0.get("p99_ratio_vs_random").is_ok());
        assert!(j.get("headline_p99_ratio").is_ok());
    }

    #[test]
    fn rejects_degenerate_configs() {
        let mut cfg = smoke_cfg();
        cfg.requests_per_point = 0;
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.shapes.clear();
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.shapes[0].offered_rps = -1.0;
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.hedge_margin_s = f64::NAN;
        assert!(run(&cfg).is_err());
    }

    fn closed_smoke_cfg() -> FleetClosedConfig {
        FleetClosedConfig {
            requests_per_point: 1_200,
            clients: vec![4, 16],
            ..Default::default()
        }
    }

    #[test]
    fn closed_structure_labels_and_conservation() {
        let sweep = run_closed(&closed_smoke_cfg()).unwrap();
        assert_eq!(sweep.cells.len(), 2);
        assert_eq!(sweep.topo.name, "hetero");
        // The drift pins the topology's lead edge gateway.
        assert_eq!(sweep.drift.lane, Some(0));
        assert_eq!(sweep.drift.factor, FLEET_CLOSED_DRIFT_FACTOR);
        for cell in &sweep.cells {
            assert_eq!(cell.results.len(), 4);
            for label in [
                "fleet+static",
                "fleet+select",
                "fleet+select+refit",
                "fleet+hedge+refit",
            ] {
                let r = cell.get(label);
                assert_eq!(r.completed + r.rejected, r.offered, "{label}");
                assert_eq!(r.offered, 1_200, "{label}");
                assert_eq!(r.rejected, 0, "{label}: closed loop should not shed");
                assert_eq!(
                    r.device_results.iter().sum::<usize>(),
                    r.completed,
                    "{label}"
                );
            }
            // Only the hedged configuration hedges, and its controller
            // reports a final margin.
            assert_eq!(cell.get("fleet+select+refit").hedged, 0);
            assert!(cell.get("fleet+hedge+refit").hedge_final_margin_s.is_finite());
            assert!(cell.get("fleet+select").hedge_final_margin_s.is_nan());
        }
        let j = closed_to_json(&sweep);
        assert_eq!(j.get("points").unwrap().as_array().unwrap().len(), 2);
        assert!(j.get("drift").unwrap().get("lane").is_ok());
        assert!(j.get("waste_budget").is_ok());
        assert!(j.get("max_hedge_wasted_frac").is_ok());
        let p0 = &j.get("points").unwrap().as_array().unwrap()[0];
        assert!(p0.get("policies").unwrap().get("fleet+select+refit").is_ok());
        let hedge = p0.get("policies").unwrap().get("fleet+hedge+refit").unwrap();
        assert!(hedge.get("hedge_final_margin_s").is_ok());
        let txt = render_closed_text(&sweep);
        assert!(txt.contains("fleet+select+refit"));
        assert!(txt.contains("headline"));
    }

    #[test]
    fn closed_sweep_is_bit_identical_across_thread_counts() {
        let mut cfg = closed_smoke_cfg();
        cfg.requests_per_point = 600;
        let serial = closed_to_json(&run_closed(&cfg).unwrap()).to_string_pretty();
        for threads in [2, 4, 7] {
            cfg.threads = threads;
            let parallel = closed_to_json(&run_closed(&cfg).unwrap()).to_string_pretty();
            assert_eq!(parallel, serial, "{threads}-thread fleet closed sweep diverged");
        }
    }

    #[test]
    fn telemetry_rides_along_without_changing_dynamics() {
        // The off-by-default guarantee's inverse: switching the sampler
        // ON must not perturb a single aggregate — recording only
        // observes.
        let mut base_cfg = closed_smoke_cfg();
        base_cfg.requests_per_point = 600;
        base_cfg.clients = vec![8];
        let base = run_closed(&base_cfg).unwrap();
        let mut tel_cfg = base_cfg.clone();
        tel_cfg.opts.telemetry =
            Some(TelemetryCfg { interval_s: 0.5, capacity: 256 });
        let tel = run_closed(&tel_cfg).unwrap();
        for (a, b) in base.cells[0].results.iter().zip(&tel.cells[0].results) {
            assert_eq!(a.policy, b.policy);
            assert!(a.telemetry.is_none() && a.phases.is_none(), "{}", a.policy);
            assert!(b.telemetry.is_some() && b.phases.is_some(), "{}", b.policy);
            assert_eq!(a.completed, b.completed, "{}", a.policy);
            assert_eq!(a.p99_s.to_bits(), b.p99_s.to_bits(), "{}", a.policy);
            assert_eq!(
                a.mean_latency_s.to_bits(),
                b.mean_latency_s.to_bits(),
                "{}",
                a.policy
            );
            assert_eq!(a.hedged, b.hedged, "{}", a.policy);
            assert_eq!(
                a.wasted_work_s.to_bits(),
                b.wasted_work_s.to_bits(),
                "{}",
                a.policy
            );
            // The decomposition partitions every result's latency: the
            // phase sums reassemble the total latency mass exactly.
            let p = b.phases.as_ref().unwrap();
            assert_eq!(p.count(), b.completed as u64, "{}", b.policy);
            let got = p.queue_wait.sum() + p.batch_wait.sum() + p.exec.sum() + p.tx.sum();
            let want = b.mean_latency_s * b.completed as f64;
            assert!(
                (got - want).abs() <= 1e-6 * want.max(1.0),
                "{}: phase mass {got} vs latency mass {want}",
                b.policy
            );
            // Gauge series all align with the sample clock.
            let t = b.telemetry.as_ref().unwrap();
            assert!(t.samples() > 0, "{}", b.policy);
            for d in &t.devices {
                assert_eq!(d.queue_depth.len(), t.samples());
                assert_eq!(d.expected_wait_s.len(), t.samples());
                assert_eq!(d.in_flight.len(), t.samples());
            }
        }
    }

    #[test]
    fn telemetry_report_carries_series_and_story() {
        let mut cfg = telemetry_config(20220315);
        cfg.requests_per_point = 1_200;
        cfg.clients = vec![8];
        let sweep = run_closed(&cfg).unwrap();
        let j = telemetry_to_json(&sweep);
        assert_eq!(
            j.get("telemetry_interval_s").unwrap().as_f64().unwrap(),
            TELEMETRY_INTERVAL_S
        );
        let p0 = &j.get("points").unwrap().as_array().unwrap()[0];
        for label in ["fleet+select", "fleet+hedge+refit"] {
            let pol = p0.get("policies").unwrap().get(label).unwrap();
            assert!(pol.get("phases").is_ok(), "{label}");
            let tel = pol.get("telemetry").unwrap();
            assert!(tel.get("t_s").is_ok(), "{label}");
            assert!(tel.get("devices").is_ok(), "{label}");
        }
        // Adaptive cells carry plane series; the hedged cell carries the
        // controller series.
        let refit = p0.get("policies").unwrap().get("fleet+select+refit").unwrap();
        let dev0 = &refit.get("telemetry").unwrap().get("devices").unwrap().as_array().unwrap()[0];
        assert!(dev0.get("plane_an").is_ok());
        let hedge = p0.get("policies").unwrap().get("fleet+hedge+refit").unwrap();
        assert!(hedge.get("telemetry").unwrap().get("hedge_margin_s").is_ok());
        assert!(hedge.get("telemetry").unwrap().get("wasted_frac").is_ok());
        let story = j.get("drift_story").unwrap();
        assert!(story.get("baseline_backlog_peak_s").is_ok());
        assert!(story.get("refit_plane_an_ratio").is_ok());
        assert!(story.get("wasted_frac_last").is_ok());
        let txt = render_telemetry_text(&sweep);
        assert!(txt.contains("telemetry:"), "{txt}");
    }

    #[test]
    fn closed_rejects_degenerate_configs() {
        let mut cfg = closed_smoke_cfg();
        cfg.requests_per_point = 0;
        assert!(run_closed(&cfg).is_err());
        let mut cfg = closed_smoke_cfg();
        cfg.clients.clear();
        assert!(run_closed(&cfg).is_err());
        let mut cfg = closed_smoke_cfg();
        cfg.clients = vec![0];
        assert!(run_closed(&cfg).is_err());
        let mut cfg = closed_smoke_cfg();
        cfg.hedge_margin_s = f64::NAN;
        assert!(run_closed(&cfg).is_err());
    }
}
