//! Fleet sweep: routing strategies compared across fleet shapes.
//!
//! The load sweep ([`super::load`]) stresses the paper's 1×1 pair; this
//! sweep generalises the question to fleets: given N edge devices and M
//! cloud replicas ([`crate::fleet::Topology`]), how much does
//! **fleet-wide queue-aware placement** buy over blind replica
//! assignment? Four strategies replay the identical workload per shape:
//!
//! * `fleet+static` — tier by idle eq. 1, replica by round-robin;
//! * `fleet+random` — tier by idle eq. 1, replica drawn uniformly
//!   (seeded, deterministic);
//! * `fleet+select` — the tentpole: every placement scored with eq. 1
//!   plus its expected wait, arg-min wins
//!   ([`crate::fleet::FleetSelector`]);
//! * `fleet+hedge` — `fleet+select` plus racing the best edge placement
//!   against the best cloud placement inside the error bar.
//!
//! Shapes swept by default: the `1x1` anchor (bit-identical to the pair
//! path — the differential tests in `sim::harness` prove it), uniform
//! `4x2` and `8x4` scale-ups, and a `hetero` mix of device speeds and
//! link qualities. Offered load scales with each shape's capacity so
//! every point sits in the contended regime where placement matters.
//!
//! Cells (shape × strategy) are sharded across threads by
//! [`super::runner::run_cells`]; every cell reseeds from the pure split
//! [`cell_seed`], so `reports/fleet_sweep.json` is **byte-identical at
//! any thread count**. The standalone mirror
//! `python/tools/fleet_sweep_mirror.py` regenerates the same bytes with
//! no rust toolchain — keep the two in lockstep when editing any
//! constant here.

use crate::fleet::{FleetStrategy, Topology};
use crate::sim::harness::RequestTruth;
use crate::sim::{run_fleet, Characterization, FleetOpts, FleetResult};
use crate::util::rng::cell_seed;
use crate::util::Json;
use crate::{Error, Result};

use super::load::synth_workload;
use super::report::text_table;
use super::runner;

/// Hedge error bar of the `fleet+hedge` configuration (seconds) —
/// matches the pair sweep's [`crate::sim::AdaptiveOpts`] default.
pub const FLEET_HEDGE_MARGIN_S: f64 = 0.010;
/// Seed tag mixed into a shape's workload seed to derive the
/// `fleet+random` replica-pick stream.
const RANDOM_PICK_TAG: u64 = 0xF1E37;

/// One swept fleet shape: a topology plus the offered load it is
/// stressed at.
#[derive(Debug, Clone)]
pub struct ShapeSpec {
    /// The fleet topology.
    pub topo: Topology,
    /// Open-loop offered load (r/s), scaled to the shape's capacity.
    pub offered_rps: f64,
}

/// Default offered load for a shape: tuned values for the standard
/// presets (the pair saturates near 100 r/s in the load sweep; the
/// scale-ups multiply that), a capacity-proportional heuristic for
/// anything else (an edge worker sustains ~16 r/s batched, a 4-worker
/// baseline replica ~112 r/s).
pub fn default_offered_rps(topo: &Topology) -> f64 {
    match topo.name.as_str() {
        "1x1" => 96.0,
        "4x2" => 288.0,
        "8x4" => 576.0,
        "hetero" => 224.0,
        _ => {
            let (e, c) = topo.shape();
            e as f64 * 16.0 + c as f64 * 112.0
        }
    }
}

/// The default shape grid: the 1×1 anchor, uniform scale-ups and a
/// heterogeneous mix, each at its tuned offered load.
pub fn default_shapes() -> Vec<ShapeSpec> {
    ["1x1", "4x2", "8x4", "hetero"]
        .iter()
        .map(|n| {
            let topo = Topology::preset(n).expect("built-in preset resolves");
            let offered_rps = default_offered_rps(&topo);
            ShapeSpec { topo, offered_rps }
        })
        .collect()
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Master seed of the sweep.
    pub seed: u64,
    /// Requests simulated at each (shape × strategy) cell.
    pub requests_per_point: usize,
    /// Shapes to sweep.
    pub shapes: Vec<ShapeSpec>,
    /// Scheduler sizing shared by every cell (`strategy` is overridden
    /// per cell).
    pub opts: FleetOpts,
    /// Hedge error bar for the `fleet+hedge` cells (seconds).
    pub hedge_margin_s: f64,
    /// OS threads to shard cells across ([`super::runner`]); results
    /// are bit-identical at any value. 1 = serial (the mirror's mode).
    pub threads: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            seed: 20220315,
            requests_per_point: 20_000,
            shapes: default_shapes(),
            opts: FleetOpts::default(),
            hedge_margin_s: FLEET_HEDGE_MARGIN_S,
            threads: 1,
        }
    }
}

/// The four strategies evaluated at one shape. `workload_seed` is the
/// shape's [`cell_seed`] split; the random baseline's replica stream is
/// derived from it so every cell stays a pure function of the master
/// seed.
fn strategies(workload_seed: u64, hedge_margin_s: f64) -> [FleetStrategy; 4] {
    [
        FleetStrategy::Static,
        FleetStrategy::Random { seed: workload_seed ^ RANDOM_PICK_TAG },
        FleetStrategy::Select,
        FleetStrategy::Hedged { margin_s: hedge_margin_s },
    ]
}

/// All strategies evaluated on one shape.
#[derive(Debug, Clone)]
pub struct ShapeCell {
    /// The swept shape.
    pub shape: ShapeSpec,
    /// One result per strategy.
    pub results: Vec<FleetResult>,
}

impl ShapeCell {
    /// Result for a strategy label (panics when absent — report bug).
    pub fn get(&self, policy: &str) -> &FleetResult {
        self.results
            .iter()
            .find(|r| r.policy == policy)
            .unwrap_or_else(|| panic!("missing fleet policy {policy}"))
    }

    /// p99 ratio (random / select) — the shape's headline: how much
    /// tail the queue-aware arg-min buys over blind random assignment.
    pub fn p99_vs_random(&self) -> f64 {
        self.get("fleet+random").p99_s / self.get("fleet+select").p99_s
    }

    /// p99 ratio (static round-robin / select).
    pub fn p99_vs_static(&self) -> f64 {
        self.get("fleet+static").p99_s / self.get("fleet+select").p99_s
    }
}

/// Full fleet sweep result.
#[derive(Debug, Clone)]
pub struct FleetSweep {
    /// One cell per shape.
    pub cells: Vec<ShapeCell>,
    /// Requests simulated per cell.
    pub requests_per_point: usize,
    /// Master seed of the sweep.
    pub seed: u64,
    /// Hedge error bar of the `fleet+hedge` cells (seconds).
    pub hedge_margin_s: f64,
}

impl FleetSweep {
    /// The headline shape: `8x4` when swept, else the last shape.
    fn headline_cell(&self) -> Option<&ShapeCell> {
        self.cells
            .iter()
            .find(|c| c.shape.topo.name == "8x4")
            .or_else(|| self.cells.last())
    }

    /// Headline: random / select p99 ratio on the headline shape.
    pub fn headline_p99_ratio(&self) -> f64 {
        self.headline_cell().map_or(f64::NAN, |c| c.p99_vs_random())
    }
}

/// Run the fleet sweep: every (shape × strategy) cell on the
/// deterministic parallel runner, each shape replaying one shared
/// workload seeded from the pure per-shape split of the master seed.
pub fn run(cfg: &FleetConfig) -> Result<FleetSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("fleet sweep needs requests_per_point > 0".into()));
    }
    if cfg.shapes.is_empty() {
        return Err(Error::Config("fleet sweep needs at least one shape".into()));
    }
    if !(cfg.hedge_margin_s >= 0.0) || !cfg.hedge_margin_s.is_finite() {
        return Err(Error::Config(format!(
            "fleet hedge margin {} must be finite and >= 0",
            cfg.hedge_margin_s
        )));
    }
    for s in &cfg.shapes {
        s.topo.validate()?;
        if !s.offered_rps.is_finite() || s.offered_rps <= 0.0 {
            return Err(Error::Config(format!(
                "shape {}: offered load {} r/s must be finite and > 0",
                s.topo.name, s.offered_rps
            )));
        }
    }
    let n_strat = strategies(0, cfg.hedge_margin_s).len();
    // Workloads are generated once per shape (pure functions of the
    // per-shape seed split) and shared read-only by that shape's
    // strategy cells — the same precompute-serially pattern the load
    // sweep uses to keep the runner's determinism argument intact.
    let workloads: Vec<(Vec<RequestTruth>, Characterization)> = cfg
        .shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            synth_workload(cell_seed(cfg.seed, i as u64), cfg.requests_per_point, s.offered_rps)
        })
        .collect();
    let outcomes = runner::run_cells(cfg.threads, cfg.shapes.len() * n_strat, |cell| {
        let si = cell / n_strat;
        let strategy = strategies(cell_seed(cfg.seed, si as u64), cfg.hedge_margin_s)
            [cell % n_strat];
        let (requests, ch) = &workloads[si];
        run_fleet(
            requests,
            ch,
            &cfg.shapes[si].topo,
            &FleetOpts { strategy, ..cfg.opts },
        )
    });
    let mut outcomes = outcomes.into_iter();
    let mut cells = Vec::with_capacity(cfg.shapes.len());
    for shape in &cfg.shapes {
        let mut results = Vec::with_capacity(n_strat);
        for _ in 0..n_strat {
            results.push(outcomes.next().expect("one outcome per fleet cell")?);
        }
        cells.push(ShapeCell { shape: shape.clone(), results });
    }
    Ok(FleetSweep {
        cells,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
        hedge_margin_s: cfg.hedge_margin_s,
    })
}

/// Render the sweep as an aligned text table plus per-shape headlines.
pub fn render_text(s: &FleetSweep) -> String {
    let mut rows = vec![[
        "shape",
        "policy",
        "goodput r/s",
        "shed %",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "batch",
        "hedge %",
        "waste %",
        "edge/cloud",
    ]
    .iter()
    .map(|c| c.to_string())
    .collect::<Vec<String>>()];
    for c in &s.cells {
        for r in &c.results {
            rows.push(vec![
                c.shape.topo.name.clone(),
                r.policy.clone(),
                format!("{:.1}", r.throughput_rps),
                format!("{:.1}", r.shed_rate() * 100.0),
                format!("{:.1}", r.p50_s * 1e3),
                format!("{:.1}", r.p95_s * 1e3),
                format!("{:.1}", r.p99_s * 1e3),
                format!("{:.2}", r.mean_batch),
                format!("{:.1}", r.hedge_rate() * 100.0),
                format!("{:.1}", r.wasted_frac() * 100.0),
                format!("{}/{}", r.edge_count, r.cloud_count),
            ]);
        }
    }
    let mut out = text_table(&rows);
    for c in &s.cells {
        out.push_str(&format!(
            "\n{} @ {:.0} r/s: select p99 is {:.1}x shorter than random, {:.1}x \
             shorter than static round-robin\n",
            c.shape.topo.name,
            c.shape.offered_rps,
            c.p99_vs_random(),
            c.p99_vs_static()
        ));
    }
    out.push_str(&format!(
        "\nheadline: fleet-wide queue-aware selection beats random replica \
         assignment {:.1}x on p99 at equal goodput\n",
        s.headline_p99_ratio()
    ));
    out
}

/// JSON report (`fleet_sweep.json`, written through
/// [`super::report::write_report`]).
pub fn to_json(s: &FleetSweep) -> Json {
    let mut shapes = Vec::new();
    for c in &s.cells {
        let (edges, clouds) = c.shape.topo.shape();
        let mut policies = Json::object();
        for r in &c.results {
            policies.set(&r.policy, r.to_json());
        }
        let mut o = Json::object();
        o.set("name", Json::Str(c.shape.topo.name.clone()))
            .set("offered_rps", Json::Num(c.shape.offered_rps))
            .set("edges", Json::Num(edges as f64))
            .set("clouds", Json::Num(clouds as f64))
            .set("topology", c.shape.topo.to_json())
            .set("policies", policies)
            .set("p99_ratio_vs_random", Json::Num(c.p99_vs_random()))
            .set("p99_ratio_vs_static", Json::Num(c.p99_vs_static()));
        shapes.push(o);
    }
    let mut root = Json::object();
    root.set("seed", Json::Num(s.seed as f64))
        .set("requests_per_point", Json::Num(s.requests_per_point as f64))
        .set("hedge_margin_s", Json::Num(s.hedge_margin_s))
        .set("shapes", Json::Array(shapes))
        .set("headline_p99_ratio", Json::Num(s.headline_p99_ratio()));
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_cfg() -> FleetConfig {
        FleetConfig {
            requests_per_point: 2_000,
            shapes: vec![
                ShapeSpec { topo: Topology::pair(), offered_rps: 96.0 },
                ShapeSpec { topo: Topology::uniform(4, 2), offered_rps: 288.0 },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn structure_and_conservation() {
        let sweep = run(&smoke_cfg()).unwrap();
        assert_eq!(sweep.cells.len(), 2);
        for cell in &sweep.cells {
            assert_eq!(cell.results.len(), 4);
            for r in &cell.results {
                assert_eq!(r.offered, 2_000, "{}", r.policy);
                assert_eq!(r.completed + r.rejected, r.offered, "{}", r.policy);
                assert_eq!(
                    r.device_results.iter().sum::<usize>(),
                    r.completed,
                    "{}",
                    r.policy
                );
                assert_eq!(r.device_results.len(), cell.shape.topo.len());
                assert!(r.p50_s <= r.p99_s + 1e-12, "{}", r.policy);
                if r.policy != "fleet+hedge" {
                    assert_eq!(r.hedged, 0, "{}", r.policy);
                }
            }
            // Every strategy label present exactly once.
            for label in ["fleet+static", "fleet+random", "fleet+select", "fleet+hedge"] {
                assert_eq!(
                    cell.results.iter().filter(|r| r.policy == label).count(),
                    1,
                    "{label}"
                );
            }
        }
    }

    #[test]
    fn sweep_is_bit_identical_across_thread_counts() {
        // The determinism acceptance property: the JSON bytes CI diffs
        // must not depend on the thread count.
        let mut cfg = smoke_cfg();
        cfg.requests_per_point = 800;
        let serial = to_json(&run(&cfg).unwrap()).to_string_pretty();
        for threads in [2, 4, 7] {
            cfg.threads = threads;
            let parallel = to_json(&run(&cfg).unwrap()).to_string_pretty();
            assert_eq!(parallel, serial, "{threads}-thread fleet sweep diverged");
        }
    }

    #[test]
    fn select_beats_blind_assignment_on_the_scaled_shapes() {
        // Smoke-scale version of the acceptance criterion: on 4x2 the
        // queue-aware arg-min beats both blind baselines on p99 at
        // equal-or-better goodput.
        let sweep = run(&smoke_cfg()).unwrap();
        let cell = &sweep.cells[1];
        assert_eq!(cell.shape.topo.name, "4x2");
        let select = cell.get("fleet+select");
        for blind in [cell.get("fleet+random"), cell.get("fleet+static")] {
            assert!(
                select.p99_s < blind.p99_s,
                "select p99 {} not below {} p99 {}",
                select.p99_s,
                blind.policy,
                blind.p99_s
            );
            assert!(
                select.throughput_rps >= blind.throughput_rps * 0.999,
                "select goodput {} below {} {}",
                select.throughput_rps,
                blind.policy,
                blind.throughput_rps
            );
        }
        assert!(cell.p99_vs_random() > 1.0);
        assert!(cell.p99_vs_static() > 1.0);
    }

    #[test]
    fn render_and_json_cover_all_shapes() {
        let sweep = run(&smoke_cfg()).unwrap();
        let txt = render_text(&sweep);
        assert!(txt.contains("fleet+select"));
        assert!(txt.contains("fleet+hedge"));
        assert!(txt.contains("headline"));
        let j = to_json(&sweep);
        let shapes = j.get("shapes").unwrap().as_array().unwrap();
        assert_eq!(shapes.len(), 2);
        let s0 = &shapes[0];
        assert_eq!(s0.get("name").unwrap().as_str().unwrap(), "1x1");
        assert!(s0.get("policies").unwrap().get("fleet+select").is_ok());
        assert!(s0.get("topology").unwrap().get("devices").is_ok());
        assert!(s0.get("p99_ratio_vs_random").is_ok());
        assert!(j.get("headline_p99_ratio").is_ok());
    }

    #[test]
    fn rejects_degenerate_configs() {
        let mut cfg = smoke_cfg();
        cfg.requests_per_point = 0;
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.shapes.clear();
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.shapes[0].offered_rps = -1.0;
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.hedge_margin_s = f64::NAN;
        assert!(run(&cfg).is_err());
    }
}
