//! Experiment drivers — one per table/figure in the paper's evaluation
//! (DESIGN.md §5 experiment index).
//!
//! | Paper artifact | Driver | CLI |
//! |---|---|---|
//! | Fig. 2a (T vs M linearity) | [`fig2a`] | `cnmt experiment fig2a` |
//! | Fig. 3 (N→M regressions) | [`fig3`] | `cnmt experiment fig3` |
//! | Fig. 4 (connection profiles) | [`fig4`] | `cnmt experiment fig4` |
//! | Table I (policy comparison) | [`table1`] | `cnmt experiment table1` |
//! | — (beyond paper: load sweep) | [`load`] | `cnmt experiment load` |
//! | — (beyond paper: fleet sweep) | [`fleet`] | `cnmt experiment fleet` |
//! | — (beyond paper: outage sweep) | [`outage`] | `cnmt experiment outage` |
//! | — (beyond paper: detection quality) | [`detect`] | `cnmt experiment detect` |
//! | — (beyond paper: SLO scenario) | [`scenario`] | `cnmt experiment scenario` |
//!
//! Every driver prints a human-readable table and writes a JSON report
//! through the one shared path ([`report::write_report`] over
//! [`crate::util::Json`]) under the configured `out_dir`, so
//! EXPERIMENTS.md can quote exact numbers.

pub mod ablation;
pub mod detect;
pub mod energy;
pub mod fig2a;
pub mod fig3;
pub mod fig4;
pub mod fleet;
pub mod load;
pub mod multilevel;
pub mod outage;
pub mod report;
pub mod runner;
pub mod scenario;
pub mod table1;

pub use report::write_report;
pub use runner::run_cells;
