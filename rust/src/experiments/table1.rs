//! Table I: execution-time variation (%) of Naive and C-NMT vs the three
//! baselines (GW-only, Server-only, Oracle), per dataset × connection
//! profile — the paper's headline experiment (100k requests each).

use crate::config::Config;
use crate::corpus::LangPair;
use crate::devices::Calibration;
use crate::net::trace::ConnectionProfile;
use crate::sim::{run_all_policies, PolicyResult, TruthTable};
use crate::util::Json;
use crate::Result;

use super::report::{pct, text_table};

/// One dataset×profile cell group of Table I.
#[derive(Debug, Clone)]
pub struct Table1Cell {
    /// Language pair of this cell.
    pub pair: LangPair,
    /// Connection profile of this cell.
    pub profile: ConnectionProfile,
    /// One result per policy.
    pub results: Vec<PolicyResult>,
}

impl Table1Cell {
    /// Result for a policy id (panics when absent — report bug).
    pub fn get(&self, id: &str) -> &PolicyResult {
        self.results
            .iter()
            .find(|r| r.policy == id)
            .unwrap_or_else(|| panic!("missing policy {id}"))
    }

    /// (% vs GW, % vs Server, % vs Oracle) for `policy`.
    pub fn vs_baselines(&self, policy: &str) -> (f64, f64, f64) {
        let p = self.get(policy);
        (
            p.vs(self.get("edge_only")),
            p.vs(self.get("cloud_only")),
            p.vs(self.get("oracle")),
        )
    }
}

/// Full Table-I result set.
#[derive(Debug, Clone)]
pub struct Table1 {
    /// One cell per (pair, profile).
    pub cells: Vec<Table1Cell>,
}

impl Table1 {
    /// The cell for (pair, profile) (panics when absent).
    pub fn cell(&self, pair: LangPair, profile: ConnectionProfile) -> &Table1Cell {
        self.cells
            .iter()
            .find(|c| c.pair == pair && c.profile == profile)
            .unwrap_or_else(|| panic!("missing cell {}/{}", pair.id(), profile.id()))
    }

    /// Paper headline: the largest total-time reduction C-NMT achieves
    /// vs any static mapping (the "up to 44%" claim), as a positive %.
    pub fn headline_vs_static(&self) -> f64 {
        self.cells
            .iter()
            .flat_map(|c| {
                let (gw, srv, _) = c.vs_baselines("cnmt");
                [gw, srv]
            })
            .fold(0.0, |acc, x| acc.max(-x))
    }

    /// Largest margin of C-NMT over Naive (the "up to 21%" claim).
    pub fn headline_vs_naive(&self) -> f64 {
        self.cells
            .iter()
            .map(|c| {
                let naive = c.get("naive").total_s;
                let cnmt = c.get("cnmt").total_s;
                (naive - cnmt) / naive * 100.0
            })
            .fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Run the Table-I experiment.
pub fn run(cfg: &Config, calibration: &Calibration) -> Result<Table1> {
    let mut cells = Vec::new();
    for &pair in &cfg.pairs {
        for &profile in &cfg.profiles {
            let table = TruthTable::build(cfg, pair, profile, calibration)?;
            let results = run_all_policies(&table)?;
            cells.push(Table1Cell { pair, profile, results });
        }
    }
    Ok(Table1 { cells })
}

/// Render the paper-style text table.
pub fn render_text(t: &Table1) -> String {
    let mut rows = vec![vec![
        "Dataset".to_string(),
        "Strategy".to_string(),
        "CP1 vs GW".to_string(),
        "CP1 vs Server".to_string(),
        "CP1 vs Oracle".to_string(),
        "CP2 vs GW".to_string(),
        "CP2 vs Server".to_string(),
        "CP2 vs Oracle".to_string(),
    ]];
    for pair in LangPair::ALL {
        for strategy in ["naive", "cnmt"] {
            let mut row = vec![
                pair.id().to_uppercase().replace('_', "-"),
                if strategy == "naive" { "Naive" } else { "C-NMT" }.to_string(),
            ];
            for profile in ConnectionProfile::ALL {
                let has = t
                    .cells
                    .iter()
                    .any(|c| c.pair == pair && c.profile == profile);
                if !has {
                    row.extend(["-".to_string(), "-".to_string(), "-".to_string()]);
                    continue;
                }
                let (gw, srv, or) = t.cell(pair, profile).vs_baselines(strategy);
                row.push(pct(gw));
                row.push(pct(srv));
                row.push(pct(or));
            }
            rows.push(row);
        }
    }
    let mut out = text_table(&rows);
    out.push_str(&format!(
        "\nheadline: C-NMT vs best static mapping: up to {:.1}% reduction \
         (paper: up to 44%)\n",
        t.headline_vs_static()
    ));
    out.push_str(&format!(
        "headline: C-NMT vs Naive:               up to {:.1}% reduction \
         (paper: up to 21%)\n",
        t.headline_vs_naive()
    ));
    out
}

/// JSON report (per cell: all policies' raw totals + the derived %s).
pub fn to_json(t: &Table1) -> Json {
    let mut cells = Vec::new();
    for c in &t.cells {
        let mut o = Json::object();
        o.set("pair", Json::Str(c.pair.id().into()))
            .set("profile", Json::Str(c.profile.id().into()));
        let mut policies = Json::object();
        for r in &c.results {
            policies.set(&r.policy, r.to_json());
        }
        o.set("policies", policies);
        let mut derived = Json::object();
        for strategy in ["naive", "cnmt"] {
            let (gw, srv, or) = c.vs_baselines(strategy);
            let mut d = Json::object();
            d.set("vs_gw_pct", Json::Num(gw))
                .set("vs_server_pct", Json::Num(srv))
                .set("vs_oracle_pct", Json::Num(or));
            derived.set(strategy, d);
        }
        o.set("derived", derived);
        cells.push(o);
    }
    let mut root = Json::object();
    root.set("cells", Json::Array(cells))
        .set("headline_vs_static_pct", Json::Num(t.headline_vs_static()))
        .set("headline_vs_naive_pct", Json::Num(t.headline_vs_naive()));
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_table1() -> Table1 {
        let mut cfg = Config::smoke();
        cfg.requests = 3_000;
        run(&cfg, &Calibration::default_paper()).unwrap()
    }

    #[test]
    fn full_grid_produced() {
        let t = smoke_table1();
        assert_eq!(t.cells.len(), 6); // 3 pairs x 2 profiles
        for c in &t.cells {
            assert_eq!(c.results.len(), 5);
        }
    }

    #[test]
    fn paper_shape_cnmt_beats_or_ties_static_everywhere() {
        let t = smoke_table1();
        for c in &t.cells {
            let (gw, srv, or) = c.vs_baselines("cnmt");
            assert!(gw <= 0.5, "{}/{} vs GW {gw}", c.pair.id(), c.profile.id());
            assert!(srv <= 0.5, "{}/{} vs Server {srv}", c.pair.id(), c.profile.id());
            assert!(or >= -1e-9, "{}/{} vs Oracle {or}", c.pair.id(), c.profile.id());
        }
    }

    #[test]
    fn paper_shape_cloud_gains_bigger_under_slow_cp1() {
        // vs-Server reduction should be at least as strong under CP1
        // (slow net) as the vs-GW reduction is under CP2, qualitatively:
        // check the specific ordering the paper calls out — C-NMT's
        // vs-Server margin under CP1 exceeds its vs-Server margin under
        // CP2 ... for the RNN pairs where the effect is clean.
        let t = smoke_table1();
        for pair in [LangPair::DeEn, LangPair::FrEn] {
            let cp1 = t.cell(pair, ConnectionProfile::Cp1).vs_baselines("cnmt").1;
            let cp2 = t.cell(pair, ConnectionProfile::Cp2).vs_baselines("cnmt").1;
            assert!(
                cp1 <= cp2 + 2.0,
                "{}: CP1 vs server {cp1} not stronger than CP2 {cp2}",
                pair.id()
            );
        }
    }

    #[test]
    fn render_has_all_rows() {
        let t = smoke_table1();
        let txt = render_text(&t);
        assert!(txt.contains("DE-EN"));
        assert!(txt.contains("C-NMT"));
        assert!(txt.contains("headline"));
        let j = to_json(&t);
        assert_eq!(j.get("cells").unwrap().as_array().unwrap().len(), 6);
    }
}
