//! Outage sweep: graceful degradation under a mid-run device crash.
//!
//! The fleet sweep ([`super::fleet`]) asks what queue-aware placement
//! buys when every device is healthy; this experiment asks what happens
//! when one is **not**. The `hetero` fleet takes a crash of its lead
//! edge gateway (device 0, the fastest edge) a quarter of the way into
//! the run — down for [`OUTAGE_DURATION_S`] seconds, queue and
//! in-flight batches destroyed, then recovered empty — under two
//! configurations replaying identical fault physics:
//!
//! * `fleet+select` — today's health-blind arg-min placement. The
//!   wiped requests are **stranded** forever, and while the device is
//!   down the blind selector keeps scoring it best (empty queue,
//!   fastest plane), so a large slice of the offered load sheds at
//!   admission for the whole outage window.
//! * `fleet+select+failover` — the same placement with the robustness
//!   machinery on ([`crate::sim::run_fleet_outage`] with `failover`):
//!   health-aware selection, failover re-routing of every wiped
//!   request, queue-wait deadline timers and a bounded retry budget
//!   ([`RetryPolicy`]). The headline: zero admitted requests lost,
//!   bounded p99, goodput recovering after re-admission.
//!
//! The two cells are sharded by [`super::runner::run_cells`] and reseed
//! from the pure split [`cell_seed`], so `outage_sweep.json` is
//! **byte-identical at any thread count**. The standalone mirror
//! `python/tools/outage_mirror.py` regenerates the same bytes with no
//! rust toolchain — keep the two in lockstep when editing any constant
//! here.

use crate::fleet::Topology;
use crate::scheduler::RetryPolicy;
use crate::sim::harness::{RequestTruth, GOODPUT_WINDOW_S};
use crate::sim::{
    run_fleet_outage, Characterization, FaultMode, FaultSpec, FleetOpts, OutageResult,
};
use crate::util::rng::cell_seed;
use crate::util::Json;
use crate::{Error, Result};

use super::load::synth_workload;
use super::runner;

/// Requests replayed per cell at full parameters.
pub const OUTAGE_REQUESTS: usize = 20_000;
/// Offered load of the outage scenario (r/s) — the `hetero` shape's
/// tuned contended operating point ([`super::fleet::default_offered_rps`]).
pub const OUTAGE_OFFERED_RPS: f64 = 224.0;
/// Seed tag mixed into the sweep's workload seed split.
pub const OUTAGE_SEED_TAG: u64 = 0xFA117;
/// Fraction of the nominal run duration (requests ÷ offered load) at
/// which the crash strikes.
pub const OUTAGE_START_FRAC: f64 = 0.25;
/// Seconds the crashed device stays dark before recovering.
pub const OUTAGE_DURATION_S: f64 = 30.0;

/// The injected fault: the topology's lead edge gateway (its first
/// edge device — `hetero`'s fast desktop-class edge0) crashes a
/// quarter into the nominal run and recovers [`OUTAGE_DURATION_S`]
/// seconds later, queue and in-flight work destroyed.
pub fn outage_fault_spec(topo: &Topology, requests: usize, offered_rps: f64) -> FaultSpec {
    let lane = topo.edge_ids()[0];
    let start_s = (requests as f64 / offered_rps) * OUTAGE_START_FRAC;
    FaultSpec {
        lane,
        mode: FaultMode::Crash,
        start_s,
        recover_s: start_s + OUTAGE_DURATION_S,
    }
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct OutageConfig {
    /// Master seed of the sweep.
    pub seed: u64,
    /// Requests replayed per cell.
    pub requests_per_point: usize,
    /// Offered load (r/s).
    pub offered_rps: f64,
    /// The fleet under test (the fault strikes its first edge device).
    pub topo: Topology,
    /// Scheduler sizing shared by both cells.
    pub opts: FleetOpts,
    /// Deadline/backoff/budget knobs of the failover cell.
    pub retry: RetryPolicy,
    /// OS threads to shard the two cells across; results are
    /// bit-identical at any value. 1 = serial (the mirror's mode).
    pub threads: usize,
}

impl Default for OutageConfig {
    fn default() -> Self {
        OutageConfig {
            seed: 20220315,
            requests_per_point: OUTAGE_REQUESTS,
            offered_rps: OUTAGE_OFFERED_RPS,
            topo: Topology::hetero(),
            opts: FleetOpts::default(),
            retry: RetryPolicy::default(),
            threads: 1,
        }
    }
}

/// The full outage sweep: both configurations replayed over one shared
/// pool under one shared fault.
#[derive(Debug, Clone)]
pub struct OutageSweep {
    /// Blind baseline first, failover second (mirror cell order).
    pub cells: Vec<OutageResult>,
    /// The fleet swept.
    pub topo: Topology,
    /// The fault both cells replayed under.
    pub fault: FaultSpec,
    /// The failover cell's retry policy.
    pub retry: RetryPolicy,
    /// Requests per cell.
    pub requests_per_point: usize,
    /// Master seed.
    pub seed: u64,
    /// Offered load (r/s).
    pub offered_rps: f64,
}

impl OutageSweep {
    /// Result for a policy label (panics when absent — report bug).
    pub fn get(&self, policy: &str) -> &OutageResult {
        self.cells
            .iter()
            .find(|r| r.policy == policy)
            .unwrap_or_else(|| panic!("missing outage policy {policy}"))
    }

    /// The health-blind baseline cell.
    pub fn baseline(&self) -> &OutageResult {
        self.get("fleet+select")
    }

    /// The failover cell.
    pub fn failover(&self) -> &OutageResult {
        self.get("fleet+select+failover")
    }

    /// Headline ratio: failover completions per baseline completion
    /// (NaN when the baseline completed nothing).
    pub fn completed_ratio(&self) -> f64 {
        let base = self.baseline().completed as f64;
        if base > 0.0 {
            self.failover().completed as f64 / base
        } else {
            f64::NAN
        }
    }
}

/// Build the shared request pool of the sweep (also used by the CLI's
/// `--trace` leg so the traced replay sees the exact report workload).
pub fn outage_pool(cfg: &OutageConfig) -> (Vec<RequestTruth>, Characterization) {
    synth_workload(
        cell_seed(cfg.seed, 0) ^ OUTAGE_SEED_TAG,
        cfg.requests_per_point,
        cfg.offered_rps,
    )
}

/// Run the outage sweep: baseline and failover cells on the
/// deterministic parallel runner, both replaying one shared fault over
/// one shared pool.
pub fn run(cfg: &OutageConfig) -> Result<OutageSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("outage sweep needs requests_per_point > 0".into()));
    }
    if !(cfg.offered_rps.is_finite() && cfg.offered_rps > 0.0) {
        return Err(Error::Config(format!(
            "outage offered load {} r/s must be finite and > 0",
            cfg.offered_rps
        )));
    }
    cfg.topo.validate()?;
    if cfg.topo.edge_ids().is_empty() {
        return Err(Error::Config(format!(
            "outage sweep needs an edge device to crash in topology {}",
            cfg.topo.name
        )));
    }
    cfg.retry.validate()?;
    let fault = outage_fault_spec(&cfg.topo, cfg.requests_per_point, cfg.offered_rps);
    let (pool, ch) = outage_pool(cfg);
    let outcomes = runner::run_cells(cfg.threads, 2, |cell| {
        run_fleet_outage(
            &pool,
            &ch,
            &cfg.topo,
            &cfg.opts,
            &fault,
            &cfg.retry,
            cell == 1,
        )
    });
    let cells = outcomes.into_iter().collect::<Result<Vec<_>>>()?;
    Ok(OutageSweep {
        cells,
        topo: cfg.topo.clone(),
        fault,
        retry: cfg.retry,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
        offered_rps: cfg.offered_rps,
    })
}

/// Render the sweep as an aligned text summary plus the fault line and
/// the graceful-degradation headline (mirror of the python
/// `summarize`).
pub fn render_text(s: &OutageSweep) -> String {
    let hdr = format!(
        "{:<22} {:>8} {:>7} {:>7} {:>6} {:>5} {:>8} {:>5} {:>8} {:>9}",
        "policy", "offered", "admit", "done", "shed%", "lost", "retries", "t/o", "p50ms", "p99ms"
    );
    let mut out = String::new();
    out.push_str(&hdr);
    out.push('\n');
    out.push_str(&"-".repeat(hdr.len()));
    out.push('\n');
    for label in ["fleet+select", "fleet+select+failover"] {
        let r = s.get(label);
        out.push_str(&format!(
            "{:<22} {:>8} {:>7} {:>7} {:>6.1} {:>5} {:>8} {:>5} {:>8.1} {:>9.1}\n",
            label,
            r.offered,
            r.admitted,
            r.completed,
            r.shed_rate() * 100.0,
            r.lost(),
            r.retry_dispatches,
            r.timeouts_fired,
            r.p50_s * 1e3,
            r.p99_s * 1e3,
        ));
    }
    let (base, fo) = (s.baseline(), s.failover());
    out.push_str(&format!(
        "\nfault: {} (device {}) crashes at t={:.1}s, recovers at t={:.1}s \
         (queue + in-flight wiped)\n",
        s.topo.devices[s.fault.lane].name, s.fault.lane, s.fault.start_s, s.fault.recover_s
    ));
    out.push_str(&format!(
        "headline: failover loses {} of {} admitted requests (p99 {:.0} ms) \
         while the blind baseline strands {} and sheds {} at admission \
         during the outage\n",
        fo.lost(),
        fo.admitted,
        fo.p99_s * 1e3,
        base.stranded,
        base.rejected
    ));
    out
}

/// JSON report (`outage_sweep.json`, written through
/// [`super::report::write_report`]) — key order mirrored by
/// `python/tools/outage_mirror.py`'s `outage_to_json`.
pub fn to_json(s: &OutageSweep) -> Json {
    let mut retry = Json::object();
    retry
        .set("timeout_mult", Json::Num(s.retry.timeout_mult))
        .set("min_timeout_s", Json::Num(s.retry.min_timeout_s))
        .set("backoff_base_s", Json::Num(s.retry.backoff_base_s))
        .set("backoff_mult", Json::Num(s.retry.backoff_mult))
        .set("max_retries", Json::Num(s.retry.max_retries as f64));
    let mut policies = Json::object();
    for r in &s.cells {
        policies.set(&r.policy, r.to_json());
    }
    let (base, fo) = (s.baseline(), s.failover());
    let mut root = Json::object();
    root.set("seed", Json::Num(s.seed as f64))
        .set("requests_per_point", Json::Num(s.requests_per_point as f64))
        .set("offered_rps", Json::Num(s.offered_rps))
        .set("topology", s.topo.to_json())
        .set("fault", s.fault.to_json())
        .set("retry", retry)
        .set("goodput_window_s", Json::Num(GOODPUT_WINDOW_S))
        .set("policies", policies)
        .set("headline_baseline_lost", Json::Num(base.lost() as f64))
        .set(
            "headline_baseline_unserved",
            Json::Num(base.offered as f64 - base.completed as f64),
        )
        .set("headline_failover_lost", Json::Num(fo.lost() as f64))
        .set("headline_failover_p99_s", Json::Num(fo.p99_s))
        .set("headline_completed_ratio", Json::Num(s.completed_ratio()));
    root
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_cfg() -> OutageConfig {
        OutageConfig { requests_per_point: 1_500, ..Default::default() }
    }

    #[test]
    fn structure_headlines_and_conservation() {
        let sweep = run(&smoke_cfg()).unwrap();
        assert_eq!(sweep.cells.len(), 2);
        assert_eq!(sweep.cells[0].policy, "fleet+select");
        assert_eq!(sweep.cells[1].policy, "fleet+select+failover");
        // The fault pins the hetero lead edge gateway, a quarter in.
        assert_eq!(sweep.fault.lane, 0);
        assert_eq!(sweep.fault.mode, FaultMode::Crash);
        let nominal = 1_500.0 / OUTAGE_OFFERED_RPS;
        assert!((sweep.fault.start_s - nominal * OUTAGE_START_FRAC).abs() < 1e-12);
        assert_eq!(sweep.fault.recover_s, sweep.fault.start_s + OUTAGE_DURATION_S);
        for r in &sweep.cells {
            assert_eq!(r.offered, 1_500, "{}", r.policy);
            assert_eq!(r.completed + r.lost(), r.admitted, "{}", r.policy);
            assert_eq!(
                r.device_results.iter().sum::<usize>(),
                r.completed,
                "{}",
                r.policy
            );
            assert_eq!(r.goodput_curve.iter().sum::<usize>(), r.completed, "{}", r.policy);
        }
        // The graceful-degradation headline at smoke scale: the blind
        // baseline loses work, failover loses none and serves more.
        let (base, fo) = (sweep.baseline(), sweep.failover());
        assert!(base.lost() > 0, "baseline lost nothing: {base:?}");
        assert_eq!(fo.lost(), 0, "failover lost requests: {fo:?}");
        assert!(fo.completed > base.completed);
        assert!(sweep.completed_ratio() > 1.0);
    }

    #[test]
    fn sweep_is_bit_identical_across_thread_counts() {
        // The determinism acceptance property: the JSON bytes CI diffs
        // must not depend on the thread count.
        let mut cfg = smoke_cfg();
        cfg.requests_per_point = 800;
        let serial = to_json(&run(&cfg).unwrap()).to_string_pretty();
        for threads in [2, 4, 7] {
            cfg.threads = threads;
            let parallel = to_json(&run(&cfg).unwrap()).to_string_pretty();
            assert_eq!(parallel, serial, "{threads}-thread outage sweep diverged");
        }
    }

    #[test]
    fn render_and_json_cover_the_schema() {
        let sweep = run(&smoke_cfg()).unwrap();
        let txt = render_text(&sweep);
        assert!(txt.contains("fleet+select+failover"));
        assert!(txt.contains("fault:"));
        assert!(txt.contains("headline:"));
        let j = to_json(&sweep);
        assert!(j.get("topology").unwrap().get("devices").is_ok());
        let fault = j.get("fault").unwrap();
        assert_eq!(fault.get("mode").unwrap().as_str().unwrap(), "crash");
        let retry = j.get("retry").unwrap();
        assert_eq!(retry.get("max_retries").unwrap().as_f64().unwrap(), 4.0);
        for label in ["fleet+select", "fleet+select+failover"] {
            let pol = j.get("policies").unwrap().get(label).unwrap();
            assert!(pol.get("goodput_curve").is_ok(), "{label}");
            assert!(pol.get("failover_reroutes").is_ok(), "{label}");
        }
        assert_eq!(j.get("headline_failover_lost").unwrap().as_f64().unwrap(), 0.0);
        assert!(j.get("headline_completed_ratio").unwrap().as_f64().unwrap() > 1.0);
        assert!(j.get("goodput_window_s").is_ok());
    }

    #[test]
    fn rejects_degenerate_configs() {
        let mut cfg = smoke_cfg();
        cfg.requests_per_point = 0;
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.offered_rps = f64::NAN;
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.retry = RetryPolicy { max_retries: 4, timeout_mult: -1.0, ..Default::default() };
        assert!(run(&cfg).is_err());
        let mut cfg = smoke_cfg();
        cfg.topo = Topology {
            name: "clouds-only".into(),
            devices: vec![crate::fleet::DeviceSpec::cloud("c0", 1.0, 1.0)],
        };
        assert!(run(&cfg).is_err());
    }
}
