//! Load sweep: throughput vs p50/p99 latency per routing policy, under
//! open-loop Poisson arrivals contending for device capacity.
//!
//! This is the first experiment beyond the paper's single-request
//! setting: it measures what happens when the ROADMAP's "heavy traffic"
//! regime meets the C-NMT decision. Four configurations are swept over
//! offered load:
//!
//! * `edge_only`, `cloud_only` — the static mappings;
//! * `cnmt` — the paper's queue-blind eq. 1;
//! * `cnmt+queue` — eq. 1 plus the scheduler's expected-wait term on
//!   each side ([`crate::coordinator::Router::decide_loaded`]).
//!
//! The expected shape: all four coincide at low load; as offered load
//! approaches the edge's capacity, the queue-blind router keeps sending
//! its short-request share to the edge, whose queue grows without bound
//! (shedding at the admission cap, p99 pinned to the queue drain time),
//! while the queue-aware router diverts the overflow to the cloud and
//! keeps the tail bounded — lower p99 at equal-or-better throughput.
//!
//! ## Workload
//!
//! The sweep uses a self-contained synthetic workload rather than the
//! corpus pipeline: request lengths are exponential (mean
//! [`MEAN_N`]), output lengths follow the FR-EN-like linear N→M law, and
//! ground-truth times are the `gru_fr_en` calibration planes with
//! multiplicative noise, under a fixed CP2-like RTT. Keeping the
//! workload closed-form makes every sweep point cheap, independent of
//! corpus changes, and exactly reproducible by the standalone mirror in
//! `python/tools/load_sweep_mirror.py` (which regenerates
//! `reports/load_sweep.json` byte-for-byte modulo libm rounding when no
//! rust toolchain is available — keep the two in sync when editing any
//! constant here).

use crate::coordinator::PolicyKind;
use crate::predictor::{N2mRegressor, TexeModel};
use crate::sim::harness::RequestTruth;
use crate::sim::{run_contended, Characterization, ContendedResult, ContentionOpts};
use crate::util::{Json, Rng};
use crate::{Error, Result};

use super::report::text_table;

/// Edge ground-truth plane (αN, αM, β) — `gru_fr_en` on the Jetson-like
/// edge ([`crate::devices::Calibration::default_paper`]).
pub const EDGE_PLANE: (f64, f64, f64) = (1.2e-3, 3.0e-3, 6.0e-3);
/// Cloud ground-truth plane (αN, αM, β) — `gru_fr_en` on the
/// Titan-class server.
pub const CLOUD_PLANE: (f64, f64, f64) = (0.22e-3, 0.55e-3, 26.0e-3);
/// FR-EN-like verbosity: M ≈ γ·N + δ.
pub const N2M_GAMMA: f64 = 0.95;
pub const N2M_DELTA: f64 = 0.8;
/// Fixed CP2-like round trip (seconds).
pub const RTT_S: f64 = 0.042;
/// Mean source length of the exponential length distribution (tokens).
pub const MEAN_N: f64 = 17.0;
/// Std of the additive noise on the N→M law (tokens).
const M_NOISE_STD: f64 = 2.0;
/// Std of the multiplicative execution-time noise.
const EXEC_NOISE_STD: f64 = 0.05;
/// Length cap (matches the corpus/token budget used elsewhere).
const N_MAX: usize = 62;

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub seed: u64,
    /// Requests simulated at each offered-load point.
    pub requests_per_point: usize,
    /// Offered loads to sweep (requests/second).
    pub loads_rps: Vec<f64>,
    /// Scheduler sizing shared by every configuration (`queue_aware` is
    /// overridden per configuration).
    pub opts: ContentionOpts,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            seed: 20220315,
            requests_per_point: 20_000,
            loads_rps: vec![4.0, 8.0, 16.0, 32.0, 64.0, 96.0, 128.0],
            opts: ContentionOpts::default(),
        }
    }
}

/// All configurations evaluated at one offered load.
#[derive(Debug, Clone)]
pub struct LoadCell {
    pub offered_rps: f64,
    pub results: Vec<ContendedResult>,
}

impl LoadCell {
    pub fn get(&self, policy: &str) -> &ContendedResult {
        self.results
            .iter()
            .find(|r| r.policy == policy)
            .unwrap_or_else(|| panic!("missing policy {policy}"))
    }
}

/// Full sweep result.
#[derive(Debug, Clone)]
pub struct LoadSweep {
    pub cells: Vec<LoadCell>,
    pub requests_per_point: usize,
    pub seed: u64,
}

impl LoadSweep {
    /// p99 ratio (blind / aware) at the highest swept load — the
    /// headline "queue-awareness buys an X× shorter tail".
    pub fn headline_p99_ratio(&self) -> f64 {
        match self.cells.last() {
            None => f64::NAN,
            Some(c) => c.get("cnmt").p99_s / c.get("cnmt+queue").p99_s,
        }
    }
}

/// Generate the synthetic open-loop workload for one sweep point.
/// Deterministic in `(seed, count, offered_rps)`; mirrored by
/// `python/tools/load_sweep_mirror.py` — keep the draw order stable.
pub fn synth_workload(
    seed: u64,
    count: usize,
    offered_rps: f64,
) -> (Vec<RequestTruth>, Characterization) {
    let texe_edge = TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2);
    let texe_cloud = TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2);
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(count);
    let mut t = 0.0f64;
    let mut sum_m = 0.0f64;
    for _ in 0..count {
        t += rng.exponential(offered_rps);
        let n = 1 + (rng.exponential(1.0 / MEAN_N) as usize).min(N_MAX - 1);
        let m_mean = N2M_GAMMA * n as f64 + N2M_DELTA;
        let m = (m_mean + rng.normal_ms(0.0, M_NOISE_STD))
            .round()
            .clamp(1.0, N_MAX as f64) as usize;
        let noise_e = (1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD)).max(0.2);
        let noise_c = (1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD)).max(0.2);
        requests.push(RequestTruth {
            n,
            m_real: m,
            arrival_s: t,
            t_edge: texe_edge.estimate(n, m as f64) * noise_e,
            t_cloud: texe_cloud.estimate(n, m as f64) * noise_c,
            t_tx: RTT_S,
            rtt: RTT_S,
        });
        sum_m += m as f64;
    }
    let ch = Characterization {
        texe_edge,
        texe_cloud,
        n2m: N2mRegressor::from_coeffs(N2M_GAMMA, N2M_DELTA),
        mean_m: sum_m / count.max(1) as f64,
    };
    (requests, ch)
}

/// The four configurations swept at each load point.
fn configurations() -> [(PolicyKind, bool); 4] {
    [
        (PolicyKind::EdgeOnly, false),
        (PolicyKind::CloudOnly, false),
        (PolicyKind::Cnmt, false),
        (PolicyKind::Cnmt, true),
    ]
}

/// Run the full sweep.
pub fn run(cfg: &LoadConfig) -> Result<LoadSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("load sweep needs requests_per_point > 0".into()));
    }
    if cfg.loads_rps.is_empty() {
        return Err(Error::Config("load sweep needs at least one offered load".into()));
    }
    for &load in &cfg.loads_rps {
        if !load.is_finite() || load <= 0.0 {
            return Err(Error::Config(format!(
                "offered load {load} r/s must be finite and > 0"
            )));
        }
    }
    let mut cells = Vec::with_capacity(cfg.loads_rps.len());
    for (i, &offered_rps) in cfg.loads_rps.iter().enumerate() {
        let seed = cfg.seed ^ (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
        let (requests, ch) = synth_workload(seed, cfg.requests_per_point, offered_rps);
        let mut results = Vec::new();
        for (policy, queue_aware) in configurations() {
            let opts = ContentionOpts { queue_aware, ..cfg.opts };
            results.push(run_contended(&requests, &ch, policy, &opts)?);
        }
        cells.push(LoadCell { offered_rps, results });
    }
    Ok(LoadSweep {
        cells,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
    })
}

/// Render the sweep as an aligned text table.
pub fn render_text(s: &LoadSweep) -> String {
    let mut rows = vec![vec![
        "load r/s".to_string(),
        "policy".to_string(),
        "goodput r/s".to_string(),
        "shed %".to_string(),
        "p50 ms".to_string(),
        "p95 ms".to_string(),
        "p99 ms".to_string(),
        "batch".to_string(),
        "edge/cloud".to_string(),
    ]];
    for c in &s.cells {
        for r in &c.results {
            rows.push(vec![
                format!("{:.0}", c.offered_rps),
                r.policy.clone(),
                format!("{:.1}", r.throughput_rps),
                format!("{:.1}", r.shed_rate() * 100.0),
                format!("{:.1}", r.p50_s * 1e3),
                format!("{:.1}", r.p95_s * 1e3),
                format!("{:.1}", r.p99_s * 1e3),
                format!("{:.2}", r.mean_batch),
                format!("{}/{}", r.edge_count, r.cloud_count),
            ]);
        }
    }
    let mut out = text_table(&rows);
    out.push_str(&format!(
        "\nheadline: at {:.0} r/s offered, queue-aware C-NMT's p99 is {:.1}x \
         shorter than queue-blind C-NMT's\n",
        s.cells.last().map_or(0.0, |c| c.offered_rps),
        s.headline_p99_ratio()
    ));
    out
}

/// JSON report (written through [`super::report::write_report`]).
pub fn to_json(s: &LoadSweep) -> Json {
    let mut workload = Json::object();
    let edge_plane = [EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2];
    let cloud_plane = [CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2];
    workload
        .set("edge_plane", Json::from_f64_slice(&edge_plane))
        .set("cloud_plane", Json::from_f64_slice(&cloud_plane))
        .set("n2m_gamma", Json::Num(N2M_GAMMA))
        .set("n2m_delta", Json::Num(N2M_DELTA))
        .set("rtt_s", Json::Num(RTT_S))
        .set("mean_n", Json::Num(MEAN_N));
    let mut points = Vec::new();
    for c in &s.cells {
        let mut o = Json::object();
        o.set("offered_rps", Json::Num(c.offered_rps));
        let mut policies = Json::object();
        for r in &c.results {
            policies.set(&r.policy, r.to_json());
        }
        o.set("policies", policies);
        points.push(o);
    }
    let mut root = Json::object();
    root.set("workload", workload)
        .set("seed", Json::Num(s.seed as f64))
        .set("requests_per_point", Json::Num(s.requests_per_point as f64))
        .set("points", Json::Array(points))
        .set("headline_p99_ratio", Json::Num(s.headline_p99_ratio()));
    root
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::BatchPolicy;

    fn smoke_cfg(loads: Vec<f64>) -> LoadConfig {
        LoadConfig {
            requests_per_point: 3_000,
            loads_rps: loads,
            ..Default::default()
        }
    }

    #[test]
    fn workload_is_deterministic_and_well_formed() {
        let (a, cha) = synth_workload(7, 500, 20.0);
        let (b, _chb) = synth_workload(7, 500, 20.0);
        assert_eq!(a.len(), 500);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.n, y.n);
            assert_eq!(x.m_real, y.m_real);
            assert!((x.arrival_s - y.arrival_s).abs() < 1e-15);
            assert!((x.t_edge - y.t_edge).abs() < 1e-15);
        }
        let mut prev = 0.0;
        for rq in &a {
            assert!((1..=N_MAX).contains(&rq.n));
            assert!((1..=N_MAX).contains(&rq.m_real));
            assert!(rq.arrival_s > prev);
            assert!(rq.t_edge > 0.0 && rq.t_cloud > 0.0);
            prev = rq.arrival_s;
        }
        assert!(cha.mean_m > 1.0 && cha.mean_m < N_MAX as f64);
    }

    #[test]
    fn conservation_and_structure() {
        let sweep = run(&smoke_cfg(vec![10.0])).unwrap();
        assert_eq!(sweep.cells.len(), 1);
        let cell = &sweep.cells[0];
        assert_eq!(cell.results.len(), 4);
        for r in &cell.results {
            assert_eq!(r.offered, 3_000);
            assert_eq!(r.completed + r.rejected, r.offered);
            assert_eq!(r.edge_count + r.cloud_count, r.completed);
            assert!(r.p50_s <= r.p99_s + 1e-12);
        }
    }

    #[test]
    fn policies_coincide_at_low_load() {
        // With idle queues the wait terms vanish, so queue-aware and
        // queue-blind C-NMT make (nearly) the same decisions.
        let sweep = run(&smoke_cfg(vec![2.0])).unwrap();
        let cell = &sweep.cells[0];
        let blind = cell.get("cnmt");
        let aware = cell.get("cnmt+queue");
        assert_eq!(blind.rejected, 0);
        assert_eq!(aware.rejected, 0);
        assert!(
            (blind.p99_s - aware.p99_s).abs() / blind.p99_s < 0.10,
            "low-load p99 diverged: blind {} vs aware {}",
            blind.p99_s,
            aware.p99_s
        );
    }

    #[test]
    fn queue_aware_dominates_blind_at_high_load() {
        // THE acceptance property: at high offered load the queue-aware
        // router has a shorter tail at equal-or-better goodput.
        let sweep = run(&smoke_cfg(vec![96.0])).unwrap();
        let cell = &sweep.cells[0];
        let blind = cell.get("cnmt");
        let aware = cell.get("cnmt+queue");
        assert!(
            aware.p99_s < blind.p99_s,
            "aware p99 {} not below blind p99 {}",
            aware.p99_s,
            blind.p99_s
        );
        assert!(
            aware.throughput_rps >= blind.throughput_rps * 0.999,
            "aware goodput {} fell below blind {}",
            aware.throughput_rps,
            blind.throughput_rps
        );
        // And it beats both static mappings on the tail too.
        assert!(aware.p99_s < cell.get("edge_only").p99_s);
    }

    #[test]
    fn batching_extends_the_stable_region() {
        // At a load beyond the *serial* capacity of both devices
        // combined, disabling micro-batching must shed more (or tail
        // harder) than the batched dispatcher.
        let mut cfg = smoke_cfg(vec![200.0]);
        let sweep_batched = run(&cfg).unwrap();
        cfg.opts.dispatcher.batch = BatchPolicy::serial();
        let sweep_serial = run(&cfg).unwrap();
        let b = sweep_batched.cells[0].get("cnmt+queue").clone();
        let s = sweep_serial.cells[0].get("cnmt+queue").clone();
        assert!(b.mean_batch > 1.2, "batched run never batched: {}", b.mean_batch);
        assert!(
            (s.rejected > b.rejected) || (s.p99_s > b.p99_s * 1.5),
            "serial dispatch not visibly worse: serial(rej {}, p99 {}) \
             batched(rej {}, p99 {})",
            s.rejected,
            s.p99_s,
            b.rejected,
            b.p99_s
        );
    }

    #[test]
    fn rejects_degenerate_sweep_configs() {
        assert!(run(&smoke_cfg(vec![])).is_err());
        assert!(run(&smoke_cfg(vec![0.0])).is_err());
        assert!(run(&smoke_cfg(vec![-4.0])).is_err());
        assert!(run(&smoke_cfg(vec![f64::NAN])).is_err());
        assert!(run(&smoke_cfg(vec![f64::INFINITY])).is_err());
        let mut cfg = smoke_cfg(vec![8.0]);
        cfg.requests_per_point = 0;
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn render_and_json_cover_all_points() {
        let sweep = run(&smoke_cfg(vec![8.0, 64.0])).unwrap();
        let txt = render_text(&sweep);
        assert!(txt.contains("cnmt+queue"));
        assert!(txt.contains("headline"));
        let j = to_json(&sweep);
        assert_eq!(j.get("points").unwrap().as_array().unwrap().len(), 2);
        let p0 = &j.get("points").unwrap().as_array().unwrap()[0];
        assert!(p0.get("policies").unwrap().get("cnmt+queue").is_ok());
    }
}
