//! Load sweep: throughput vs p50/p99 latency per routing policy, under
//! open-loop Poisson arrivals contending for device capacity.
//!
//! This is the first experiment beyond the paper's single-request
//! setting: it measures what happens when the ROADMAP's "heavy traffic"
//! regime meets the C-NMT decision. Five configurations are swept over
//! offered load:
//!
//! * `edge_only`, `cloud_only` — the static mappings;
//! * `cnmt` — the paper's queue-blind eq. 1;
//! * `cnmt+queue` — eq. 1 plus the scheduler's expected-wait term on
//!   each side ([`crate::coordinator::Router::decide_loaded`]);
//! * `cnmt+adaptive` — scheduler v2: `cnmt+queue` plus hedged dispatch
//!   inside the decision error bar and RLS online refit of the T_exe
//!   planes ([`crate::sim::AdaptiveOpts`]).
//!
//! Alongside the stationary sweep, a **drift scenario** ([`run_drift`])
//! slows the edge down mid-run: the static routers keep trusting the
//! stale offline planes while the adaptive router re-learns them from
//! observed completions — the report's second headline is the drifted
//! p99 ratio. A **closed-loop sweep** ([`run_closed`],
//! `--closed-loop`) replaces Poisson arrivals with K
//! bounded-outstanding clients for serving-benchmark-style
//! latency–throughput curves.
//!
//! The expected shape: all five coincide at low load; as offered load
//! approaches the edge's capacity, the queue-blind router keeps sending
//! its short-request share to the edge, whose queue grows without bound
//! (shedding at the admission cap, p99 pinned to the queue drain time),
//! while the queue-aware router diverts the overflow to the cloud and
//! keeps the tail bounded — lower p99 at equal-or-better throughput.
//!
//! ## Workload
//!
//! The sweep uses a self-contained synthetic workload rather than the
//! corpus pipeline: request lengths are exponential (mean
//! [`MEAN_N`]), output lengths follow the FR-EN-like linear N→M law, and
//! ground-truth times are the `gru_fr_en` calibration planes with
//! multiplicative noise, under a fixed CP2-like RTT. Keeping the
//! workload closed-form makes every sweep point cheap, independent of
//! corpus changes, and exactly reproducible by the standalone mirror in
//! `python/tools/load_sweep_mirror.py` (which regenerates
//! `reports/load_sweep.json` byte-for-byte modulo libm rounding when no
//! rust toolchain is available — keep the two in sync when editing any
//! constant here).

use crate::coordinator::PolicyKind;
use crate::devices::DeviceKind;
use crate::predictor::{N2mRegressor, TexeModel};
use crate::sim::harness::RequestTruth;
use crate::sim::{
    run_closed_loop, run_closed_loop_streamed, run_contended, run_contended_streamed,
    AdaptiveOpts, Characterization, ContendedResult, ContentionOpts, DriftSpec, LoadShape,
};
use crate::util::rng::cell_seed;
use crate::util::{Json, Rng};
use crate::{Error, Result};

use super::report::text_table;
use super::runner;

/// Edge ground-truth plane (αN, αM, β) — `gru_fr_en` on the Jetson-like
/// edge ([`crate::devices::Calibration::default_paper`]).
pub const EDGE_PLANE: (f64, f64, f64) = (1.2e-3, 3.0e-3, 6.0e-3);
/// Cloud ground-truth plane (αN, αM, β) — `gru_fr_en` on the
/// Titan-class server.
pub const CLOUD_PLANE: (f64, f64, f64) = (0.22e-3, 0.55e-3, 26.0e-3);
/// FR-EN-like verbosity: M ≈ γ·N + δ.
pub const N2M_GAMMA: f64 = 0.95;
/// FR-EN-like verbosity intercept δ.
pub const N2M_DELTA: f64 = 0.8;
/// Fixed CP2-like round trip (seconds).
pub const RTT_S: f64 = 0.042;
/// Mean source length of the exponential length distribution (tokens).
pub const MEAN_N: f64 = 17.0;
/// Std of the additive noise on the N→M law (tokens).
const M_NOISE_STD: f64 = 2.0;
/// Std of the multiplicative execution-time noise.
const EXEC_NOISE_STD: f64 = 0.05;
/// Length cap (matches the corpus/token budget used elsewhere).
const N_MAX: usize = 62;

// Drift scenario (mirrored in `python/tools/load_sweep_mirror.py`): the
// edge slows down mid-run while the offline planes stay stale.
/// Offered load of the drift scenario (r/s) — inside the pre-drift
/// stable region, outside the drifted edge's solo capacity.
pub const DRIFT_LOAD_RPS: f64 = 48.0;
/// Edge slowdown multiplier once fully drifted.
pub const DRIFT_FACTOR: f64 = 2.5;
/// Fraction of the nominal run duration at which the drift starts.
pub const DRIFT_START_FRAC: f64 = 0.25;
/// Seconds over which the slowdown ramps in.
pub const DRIFT_RAMP_S: f64 = 10.0;
/// Seed tag for the drift workload stream.
const DRIFT_SEED_TAG: u64 = 0xD21F7;
/// Seed tag for the closed-loop request pool.
const CLOSED_SEED_TAG: u64 = 0xC105ED;

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// Master seed of the sweep.
    pub seed: u64,
    /// Requests simulated at each offered-load point.
    pub requests_per_point: usize,
    /// Offered loads to sweep (requests/second).
    pub loads_rps: Vec<f64>,
    /// Scheduler sizing shared by every configuration (`queue_aware` is
    /// overridden per configuration).
    pub opts: ContentionOpts,
    /// OS threads to shard sweep cells across
    /// ([`crate::experiments::runner`]); results are bit-identical at
    /// any value. 1 = serial (the mirror's mode).
    pub threads: usize,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            seed: 20220315,
            requests_per_point: 20_000,
            loads_rps: vec![4.0, 8.0, 16.0, 32.0, 64.0, 96.0, 128.0],
            opts: ContentionOpts::default(),
            threads: 1,
        }
    }
}

/// All configurations evaluated at one offered load.
#[derive(Debug, Clone)]
pub struct LoadCell {
    /// Offered load at this point (r/s).
    pub offered_rps: f64,
    /// One result per swept configuration.
    pub results: Vec<ContendedResult>,
}

impl LoadCell {
    /// Result for a policy id (panics when absent — report bug).
    pub fn get(&self, policy: &str) -> &ContendedResult {
        self.results
            .iter()
            .find(|r| r.policy == policy)
            .unwrap_or_else(|| panic!("missing policy {policy}"))
    }
}

/// One drift scenario: the same workload replayed under every compared
/// policy while the edge's ground truth degrades mid-run.
#[derive(Debug, Clone)]
pub struct DriftReport {
    /// The injected drift.
    pub spec: DriftSpec,
    /// Offered load of the scenario (r/s).
    pub offered_rps: f64,
    /// Per-policy results (same workload, same drift).
    pub results: Vec<ContendedResult>,
}

impl DriftReport {
    /// Result for a policy id (panics when absent — report bug).
    pub fn get(&self, policy: &str) -> &ContendedResult {
        self.results
            .iter()
            .find(|r| r.policy == policy)
            .unwrap_or_else(|| panic!("missing drift policy {policy}"))
    }

    /// p99 ratio (static queue-aware / adaptive) under drift — the
    /// headline "hedge + refit buys an X× shorter drifted tail".
    pub fn headline_p99_ratio(&self) -> f64 {
        self.get("cnmt+queue").p99_s / self.get("cnmt+adaptive").p99_s
    }

    /// Serialise the scenario for the load-sweep report.
    pub fn to_json(&self) -> Json {
        let mut policies = Json::object();
        for r in &self.results {
            policies.set(&r.policy, r.to_json());
        }
        let mut o = Json::object();
        o.set("spec", self.spec.to_json())
            .set("offered_rps", Json::Num(self.offered_rps))
            .set("policies", policies)
            .set("headline_p99_ratio", Json::Num(self.headline_p99_ratio()));
        o
    }
}

/// Full sweep result.
#[derive(Debug, Clone)]
pub struct LoadSweep {
    /// One cell per offered load.
    pub cells: Vec<LoadCell>,
    /// The drift scenario run alongside the stationary sweep.
    pub drift: DriftReport,
    /// Requests simulated at each sweep point.
    pub requests_per_point: usize,
    /// Master seed of the sweep.
    pub seed: u64,
}

impl LoadSweep {
    /// p99 ratio (blind / aware) at the highest swept load — the
    /// headline "queue-awareness buys an X× shorter tail".
    pub fn headline_p99_ratio(&self) -> f64 {
        match self.cells.last() {
            None => f64::NAN,
            Some(c) => c.get("cnmt").p99_s / c.get("cnmt+queue").p99_s,
        }
    }
}

/// Generate the synthetic open-loop workload for one sweep point.
/// Deterministic in `(seed, count, offered_rps)`; mirrored by
/// `python/tools/load_sweep_mirror.py` — keep the draw order stable.
pub fn synth_workload(
    seed: u64,
    count: usize,
    offered_rps: f64,
) -> (Vec<RequestTruth>, Characterization) {
    let texe_edge = TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2);
    let texe_cloud = TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2);
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(count);
    let mut t = 0.0f64;
    let mut sum_m = 0.0f64;
    for _ in 0..count {
        t += rng.exponential(offered_rps);
        let n = 1 + (rng.exponential(1.0 / MEAN_N) as usize).min(N_MAX - 1);
        let m_mean = N2M_GAMMA * n as f64 + N2M_DELTA;
        let m = (m_mean + rng.normal_ms(0.0, M_NOISE_STD))
            .round()
            .clamp(1.0, N_MAX as f64) as usize;
        let noise_e = (1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD)).max(0.2);
        let noise_c = (1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD)).max(0.2);
        requests.push(RequestTruth {
            n,
            m_real: m,
            arrival_s: t,
            t_edge: texe_edge.estimate(n, m as f64) * noise_e,
            t_cloud: texe_cloud.estimate(n, m as f64) * noise_c,
            t_tx: RTT_S,
            rtt: RTT_S,
        });
        sum_m += m as f64;
    }
    let ch = Characterization {
        texe_edge,
        texe_cloud,
        n2m: N2mRegressor::from_coeffs(N2M_GAMMA, N2M_DELTA),
        mean_m: sum_m / count.max(1) as f64,
    };
    (requests, ch)
}

/// [`synth_workload`] under a time-varying offered rate: the inter-
/// arrival gap after clock time `t` is drawn at the *instantaneous*
/// rate `shape.rate(t)` (a non-homogeneous Poisson process by
/// per-arrival thinning-free rate lookup), while every per-request draw
/// (length, verbosity, execution noise) keeps [`synth_workload`]'s
/// exact order — so a flat shape (amplitude 0, no spikes) reproduces
/// `synth_workload(seed, count, base_rps)` bit for bit, and the
/// scenario mirror (`python/tools/scenario_mirror.py`) replays the
/// stream with the same arithmetic. The shape must be validated
/// (rate > 0 everywhere); [`crate::sim::ScenarioSpec`] loaders enforce
/// that.
pub fn synth_shaped_workload(
    seed: u64,
    count: usize,
    shape: &LoadShape,
) -> (Vec<RequestTruth>, Characterization) {
    let texe_edge = TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2);
    let texe_cloud = TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2);
    let mut rng = Rng::new(seed);
    let mut requests = Vec::with_capacity(count);
    let mut t = 0.0f64;
    let mut sum_m = 0.0f64;
    for _ in 0..count {
        t += rng.exponential(shape.rate(t));
        let n = 1 + (rng.exponential(1.0 / MEAN_N) as usize).min(N_MAX - 1);
        let m_mean = N2M_GAMMA * n as f64 + N2M_DELTA;
        let m = (m_mean + rng.normal_ms(0.0, M_NOISE_STD))
            .round()
            .clamp(1.0, N_MAX as f64) as usize;
        let noise_e = (1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD)).max(0.2);
        let noise_c = (1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD)).max(0.2);
        requests.push(RequestTruth {
            n,
            m_real: m,
            arrival_s: t,
            t_edge: texe_edge.estimate(n, m as f64) * noise_e,
            t_cloud: texe_cloud.estimate(n, m as f64) * noise_c,
            t_tx: RTT_S,
            rtt: RTT_S,
        });
        sum_m += m as f64;
    }
    let ch = Characterization {
        texe_edge,
        texe_cloud,
        n2m: N2mRegressor::from_coeffs(N2M_GAMMA, N2M_DELTA),
        mean_m: sum_m / count.max(1) as f64,
    };
    (requests, ch)
}

/// Lazy twin of [`synth_workload`]: the identical draw sequence (the
/// differential tests assert per-request bit-equality), yielded one
/// request at a time so arbitrarily long workloads stream through
/// [`run_contended_streamed`] in O(outstanding) memory. Wrap with
/// `.map(Ok)` to feed the streamed harness entry points.
pub fn synth_stream(
    seed: u64,
    count: usize,
    offered_rps: f64,
) -> impl Iterator<Item = RequestTruth> {
    let texe_edge = TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2);
    let texe_cloud = TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2);
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..count).map(move |_| {
        t += rng.exponential(offered_rps);
        let n = 1 + (rng.exponential(1.0 / MEAN_N) as usize).min(N_MAX - 1);
        let m_mean = N2M_GAMMA * n as f64 + N2M_DELTA;
        let m = (m_mean + rng.normal_ms(0.0, M_NOISE_STD))
            .round()
            .clamp(1.0, N_MAX as f64) as usize;
        let noise_e = (1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD)).max(0.2);
        let noise_c = (1.0 + rng.normal_ms(0.0, EXEC_NOISE_STD)).max(0.2);
        RequestTruth {
            n,
            m_real: m,
            arrival_s: t,
            t_edge: texe_edge.estimate(n, m as f64) * noise_e,
            t_cloud: texe_cloud.estimate(n, m as f64) * noise_c,
            t_tx: RTT_S,
            rtt: RTT_S,
        }
    })
}

/// The [`Characterization`] the materialised [`synth_workload`] returns
/// for `(seed, count, offered_rps)`, computed by a prepass over the
/// stream (only `mean_m` depends on the draws — the planes and the N→M
/// law are constants), so streamed sweeps never materialise the pool.
pub fn synth_characterization(seed: u64, count: usize, offered_rps: f64) -> Characterization {
    let mut sum_m = 0.0f64;
    for truth in synth_stream(seed, count, offered_rps) {
        sum_m += truth.m_real as f64;
    }
    Characterization {
        texe_edge: TexeModel::from_coeffs(EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2),
        texe_cloud: TexeModel::from_coeffs(CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2),
        n2m: N2mRegressor::from_coeffs(N2M_GAMMA, N2M_DELTA),
        mean_m: sum_m / count.max(1) as f64,
    }
}

/// The five configurations swept at each load point:
/// `(policy, queue_aware, adaptive)`.
fn configurations() -> [(PolicyKind, bool, bool); 5] {
    [
        (PolicyKind::EdgeOnly, false, false),
        (PolicyKind::CloudOnly, false, false),
        (PolicyKind::Cnmt, false, false),
        (PolicyKind::Cnmt, true, false),
        (PolicyKind::Cnmt, true, true),
    ]
}

/// The three policies compared inside the drift scenario.
fn drift_configurations() -> [(PolicyKind, bool, bool); 3] {
    [
        (PolicyKind::Cnmt, false, false),
        (PolicyKind::Cnmt, true, false),
        (PolicyKind::Cnmt, true, true),
    ]
}

/// The drift injected alongside the stationary sweep (a function of the
/// sweep size, so smoke runs drift at the same relative point).
fn drift_spec_for(cfg: &LoadConfig) -> DriftSpec {
    DriftSpec {
        device: DeviceKind::Edge,
        lane: None,
        start_s: (cfg.requests_per_point as f64 / DRIFT_LOAD_RPS) * DRIFT_START_FRAC,
        ramp_s: DRIFT_RAMP_S,
        factor: DRIFT_FACTOR,
    }
}

/// The deterministic drift workload (regenerable from the seed alone).
fn drift_workload(cfg: &LoadConfig) -> (Vec<RequestTruth>, Characterization) {
    synth_workload(
        cfg.seed ^ DRIFT_SEED_TAG,
        cfg.requests_per_point,
        DRIFT_LOAD_RPS,
    )
}

/// Run one drift-scenario cell: replay the shared drift workload under
/// configuration `j`.
fn run_drift_cell(
    cfg: &LoadConfig,
    workload: &(Vec<RequestTruth>, Characterization),
    spec: DriftSpec,
    j: usize,
) -> Result<ContendedResult> {
    let (requests, ch) = workload;
    let (policy, queue_aware, adaptive) = drift_configurations()[j];
    let opts = ContentionOpts {
        drift: Some(spec),
        ..opts_for(&cfg.opts, queue_aware, adaptive)
    };
    run_contended(requests, ch, policy, &opts)
}

fn opts_for(base: &ContentionOpts, queue_aware: bool, adaptive: bool) -> ContentionOpts {
    ContentionOpts {
        queue_aware,
        adaptive: if adaptive { Some(AdaptiveOpts::default()) } else { None },
        ..*base
    }
}

/// Run the drift scenario: a fixed-load workload where the edge slows
/// down by [`DRIFT_FACTOR`] a quarter of the way in. The queue-blind
/// router, the static queue-aware router and the adaptive v2 (hedge +
/// RLS refit) replay the identical stream, one cell per policy on the
/// parallel runner.
pub fn run_drift(cfg: &LoadConfig) -> Result<DriftReport> {
    let spec = drift_spec_for(cfg);
    let workload = drift_workload(cfg);
    let n_drift = drift_configurations().len();
    let outcomes = runner::run_cells(cfg.threads, n_drift, |j| {
        run_drift_cell(cfg, &workload, spec, j)
    });
    let mut results = Vec::with_capacity(n_drift);
    for outcome in outcomes {
        results.push(outcome?);
    }
    Ok(DriftReport { spec, offered_rps: DRIFT_LOAD_RPS, results })
}

/// Run the full sweep (stationary load points + the drift scenario).
///
/// All (load × configuration) cells and the drift cells are flattened
/// into one work list and sharded across `cfg.threads` OS threads by
/// [`crate::experiments::runner::run_cells`]; each cell reseeds from
/// [`cell_seed`], so the reports are byte-identical at any thread
/// count (CI diffs 1 vs 4 threads, and both against the python
/// mirror's serial output).
pub fn run(cfg: &LoadConfig) -> Result<LoadSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("load sweep needs requests_per_point > 0".into()));
    }
    if cfg.loads_rps.is_empty() {
        return Err(Error::Config("load sweep needs at least one offered load".into()));
    }
    for &load in &cfg.loads_rps {
        if !load.is_finite() || load <= 0.0 {
            return Err(Error::Config(format!(
                "offered load {load} r/s must be finite and > 0"
            )));
        }
    }
    let n_cfg = configurations().len();
    let n_points = cfg.loads_rps.len();
    let sweep_cells = n_points * n_cfg;
    let spec = drift_spec_for(cfg);
    let total_cells = sweep_cells + drift_configurations().len();
    // Workloads are generated once per point (they are pure functions
    // of the per-point seed split, so precomputing them serially keeps
    // the runner's determinism argument intact) and shared read-only by
    // that point's configuration cells.
    let workloads: Vec<(Vec<RequestTruth>, Characterization)> = cfg
        .loads_rps
        .iter()
        .enumerate()
        .map(|(i, &offered_rps)| {
            synth_workload(cell_seed(cfg.seed, i as u64), cfg.requests_per_point, offered_rps)
        })
        .collect();
    let drift_load = drift_workload(cfg);
    let outcomes = runner::run_cells(cfg.threads, total_cells, |cell| {
        if cell < sweep_cells {
            let (requests, ch) = &workloads[cell / n_cfg];
            let (policy, queue_aware, adaptive) = configurations()[cell % n_cfg];
            run_contended(
                requests,
                ch,
                policy,
                &opts_for(&cfg.opts, queue_aware, adaptive),
            )
        } else {
            run_drift_cell(cfg, &drift_load, spec, cell - sweep_cells)
        }
    });
    let mut outcomes = outcomes.into_iter();
    let mut cells = Vec::with_capacity(n_points);
    for &offered_rps in &cfg.loads_rps {
        let mut results = Vec::with_capacity(n_cfg);
        for _ in 0..n_cfg {
            results.push(outcomes.next().expect("one outcome per sweep cell")?);
        }
        cells.push(LoadCell { offered_rps, results });
    }
    let mut drift_results = Vec::with_capacity(drift_configurations().len());
    for _ in 0..drift_configurations().len() {
        drift_results.push(outcomes.next().expect("one outcome per drift cell")?);
    }
    let drift = DriftReport {
        spec,
        offered_rps: DRIFT_LOAD_RPS,
        results: drift_results,
    };
    Ok(LoadSweep {
        cells,
        drift,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
    })
}

/// Streaming twin of [`run`]: the same sweep (same seeds, same cell
/// order, bit-identical report JSON — the differential tests assert
/// it), but every cell regenerates its workload lazily through
/// [`synth_stream`] and replays it with
/// [`run_contended_streamed`], so peak memory per cell is
/// O(outstanding) instead of O(`requests_per_point`).
pub fn run_streamed(cfg: &LoadConfig) -> Result<LoadSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("load sweep needs requests_per_point > 0".into()));
    }
    if cfg.loads_rps.is_empty() {
        return Err(Error::Config("load sweep needs at least one offered load".into()));
    }
    for &load in &cfg.loads_rps {
        if !load.is_finite() || load <= 0.0 {
            return Err(Error::Config(format!(
                "offered load {load} r/s must be finite and > 0"
            )));
        }
    }
    let n_cfg = configurations().len();
    let n_points = cfg.loads_rps.len();
    let sweep_cells = n_points * n_cfg;
    let spec = drift_spec_for(cfg);
    let total_cells = sweep_cells + drift_configurations().len();
    // Characterisations are O(1)-sized; a serial prepass per point keeps
    // the runner's determinism argument intact while the per-request
    // truths stay lazy inside each cell.
    let chs: Vec<Characterization> = cfg
        .loads_rps
        .iter()
        .enumerate()
        .map(|(i, &offered_rps)| {
            synth_characterization(
                cell_seed(cfg.seed, i as u64),
                cfg.requests_per_point,
                offered_rps,
            )
        })
        .collect();
    let drift_ch = synth_characterization(
        cfg.seed ^ DRIFT_SEED_TAG,
        cfg.requests_per_point,
        DRIFT_LOAD_RPS,
    );
    let outcomes = runner::run_cells(cfg.threads, total_cells, |cell| {
        if cell < sweep_cells {
            let point = cell / n_cfg;
            let (policy, queue_aware, adaptive) = configurations()[cell % n_cfg];
            let arrivals = synth_stream(
                cell_seed(cfg.seed, point as u64),
                cfg.requests_per_point,
                cfg.loads_rps[point],
            )
            .map(Ok);
            run_contended_streamed(
                arrivals,
                &chs[point],
                policy,
                &opts_for(&cfg.opts, queue_aware, adaptive),
            )
        } else {
            let (policy, queue_aware, adaptive) = drift_configurations()[cell - sweep_cells];
            let opts = ContentionOpts {
                drift: Some(spec),
                ..opts_for(&cfg.opts, queue_aware, adaptive)
            };
            let arrivals = synth_stream(
                cfg.seed ^ DRIFT_SEED_TAG,
                cfg.requests_per_point,
                DRIFT_LOAD_RPS,
            )
            .map(Ok);
            run_contended_streamed(arrivals, &drift_ch, policy, &opts)
        }
    });
    let mut outcomes = outcomes.into_iter();
    let mut cells = Vec::with_capacity(n_points);
    for &offered_rps in &cfg.loads_rps {
        let mut results = Vec::with_capacity(n_cfg);
        for _ in 0..n_cfg {
            results.push(outcomes.next().expect("one outcome per sweep cell")?);
        }
        cells.push(LoadCell { offered_rps, results });
    }
    let mut drift_results = Vec::with_capacity(drift_configurations().len());
    for _ in 0..drift_configurations().len() {
        drift_results.push(outcomes.next().expect("one outcome per drift cell")?);
    }
    let drift = DriftReport {
        spec,
        offered_rps: DRIFT_LOAD_RPS,
        results: drift_results,
    };
    Ok(LoadSweep {
        cells,
        drift,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
    })
}

fn result_row(load_label: String, r: &ContendedResult) -> Vec<String> {
    vec![
        load_label,
        r.policy.clone(),
        format!("{:.1}", r.throughput_rps),
        format!("{:.1}", r.shed_rate() * 100.0),
        format!("{:.1}", r.p50_s * 1e3),
        format!("{:.1}", r.p95_s * 1e3),
        format!("{:.1}", r.p99_s * 1e3),
        format!("{:.2}", r.mean_batch),
        format!("{:.1}", r.hedge_rate() * 100.0),
        format!("{:.1}", r.wasted_frac() * 100.0),
        format!("{}/{}", r.edge_count, r.cloud_count),
    ]
}

fn table_header() -> Vec<String> {
    [
        "load r/s",
        "policy",
        "goodput r/s",
        "shed %",
        "p50 ms",
        "p95 ms",
        "p99 ms",
        "batch",
        "hedge %",
        "waste %",
        "edge/cloud",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

/// Render the sweep (stationary points + drift scenario) as aligned
/// text tables.
pub fn render_text(s: &LoadSweep) -> String {
    let mut rows = vec![table_header()];
    for c in &s.cells {
        for r in &c.results {
            rows.push(result_row(format!("{:.0}", c.offered_rps), r));
        }
    }
    let mut out = text_table(&rows);
    out.push_str(&format!(
        "\nheadline: at {:.0} r/s offered, queue-aware C-NMT's p99 is {:.1}x \
         shorter than queue-blind C-NMT's\n",
        s.cells.last().map_or(0.0, |c| c.offered_rps),
        s.headline_p99_ratio()
    ));

    let d = &s.drift;
    out.push_str(&format!(
        "\ndrift scenario: {} slows {:.1}x from t={:.0}s (ramp {:.0}s) at \
         {:.0} r/s offered\n",
        d.spec.device.id(),
        d.spec.factor,
        d.spec.start_s,
        d.spec.ramp_s,
        d.offered_rps
    ));
    let mut drows = vec![table_header()];
    for r in &d.results {
        drows.push(result_row(format!("{:.0}", d.offered_rps), r));
    }
    out.push_str(&text_table(&drows));
    out.push_str(&format!(
        "\ndrift headline: adaptive v2 (hedge + RLS refit) p99 is {:.1}x \
         shorter than the static queue-aware router's under drift\n",
        d.headline_p99_ratio()
    ));
    out
}

/// JSON report (written through [`super::report::write_report`]).
pub fn to_json(s: &LoadSweep) -> Json {
    let mut workload = Json::object();
    let edge_plane = [EDGE_PLANE.0, EDGE_PLANE.1, EDGE_PLANE.2];
    let cloud_plane = [CLOUD_PLANE.0, CLOUD_PLANE.1, CLOUD_PLANE.2];
    workload
        .set("edge_plane", Json::from_f64_slice(&edge_plane))
        .set("cloud_plane", Json::from_f64_slice(&cloud_plane))
        .set("n2m_gamma", Json::Num(N2M_GAMMA))
        .set("n2m_delta", Json::Num(N2M_DELTA))
        .set("rtt_s", Json::Num(RTT_S))
        .set("mean_n", Json::Num(MEAN_N));
    let mut points = Vec::new();
    for c in &s.cells {
        let mut o = Json::object();
        o.set("offered_rps", Json::Num(c.offered_rps));
        let mut policies = Json::object();
        for r in &c.results {
            policies.set(&r.policy, r.to_json());
        }
        o.set("policies", policies);
        points.push(o);
    }
    let mut root = Json::object();
    root.set("workload", workload)
        .set("seed", Json::Num(s.seed as f64))
        .set("requests_per_point", Json::Num(s.requests_per_point as f64))
        .set("points", Json::Array(points))
        .set("drift", s.drift.to_json())
        .set("headline_p99_ratio", Json::Num(s.headline_p99_ratio()));
    root
}

// ---------------------------------------------------------- closed loop

/// Closed-loop sweep configuration (`cnmt experiment load --closed-loop`).
#[derive(Debug, Clone)]
pub struct ClosedLoopConfig {
    /// Master seed for the request pool.
    pub seed: u64,
    /// Request bodies submitted per client-count point.
    pub requests_per_point: usize,
    /// Client counts to sweep (each = max outstanding requests).
    pub clients: Vec<usize>,
    /// Per-client think time between result and next submission (s).
    pub think_s: f64,
    /// Scheduler sizing shared by every configuration.
    pub opts: ContentionOpts,
    /// OS threads to shard (client count × configuration) cells across;
    /// results are bit-identical at any value. 1 = serial.
    pub threads: usize,
}

impl Default for ClosedLoopConfig {
    fn default() -> Self {
        ClosedLoopConfig {
            seed: 20220315,
            requests_per_point: 20_000,
            clients: vec![1, 2, 4, 8, 16, 32, 64],
            think_s: 0.0,
            opts: ContentionOpts::default(),
            threads: 1,
        }
    }
}

/// All configurations evaluated at one client count.
#[derive(Debug, Clone)]
pub struct ClosedLoopCell {
    /// Concurrent clients at this point.
    pub clients: usize,
    /// Per-policy results.
    pub results: Vec<ContendedResult>,
}

impl ClosedLoopCell {
    /// Result for a policy id (panics when absent — report bug).
    pub fn get(&self, policy: &str) -> &ContendedResult {
        self.results
            .iter()
            .find(|r| r.policy == policy)
            .unwrap_or_else(|| panic!("missing policy {policy}"))
    }
}

/// Full closed-loop sweep: latency–throughput curves per policy.
#[derive(Debug, Clone)]
pub struct ClosedLoopSweep {
    /// One cell per client count.
    pub cells: Vec<ClosedLoopCell>,
    /// Request bodies per point.
    pub requests_per_point: usize,
    /// Master seed.
    pub seed: u64,
    /// Per-client think time (s).
    pub think_s: f64,
}

/// The policies traced in the closed-loop curves.
fn closed_configurations() -> [(PolicyKind, bool, bool); 3] {
    [
        (PolicyKind::CloudOnly, false, false),
        (PolicyKind::Cnmt, true, false),
        (PolicyKind::Cnmt, true, true),
    ]
}

/// Run the closed-loop sweep: the same request pool driven by K
/// bounded-outstanding clients, K swept over `cfg.clients`.
pub fn run_closed(cfg: &ClosedLoopConfig) -> Result<ClosedLoopSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("closed loop needs requests_per_point > 0".into()));
    }
    if cfg.clients.is_empty() {
        return Err(Error::Config("closed loop needs at least one client count".into()));
    }
    if cfg.clients.iter().any(|&k| k == 0) {
        return Err(Error::Config("client counts must be > 0".into()));
    }
    // Arrival times in the pool are ignored (completions drive arrivals).
    // The pool is generated once and shared read-only by every cell.
    let (pool, ch) =
        synth_workload(cfg.seed ^ CLOSED_SEED_TAG, cfg.requests_per_point, 1.0);
    let n_cfg = closed_configurations().len();
    let outcomes =
        runner::run_cells(cfg.threads, cfg.clients.len() * n_cfg, |cell| {
            let clients = cfg.clients[cell / n_cfg];
            let (policy, queue_aware, adaptive) = closed_configurations()[cell % n_cfg];
            let opts = opts_for(&cfg.opts, queue_aware, adaptive);
            run_closed_loop(&pool, &ch, policy, &opts, clients, cfg.think_s)
        });
    let mut outcomes = outcomes.into_iter();
    let mut cells = Vec::with_capacity(cfg.clients.len());
    for &clients in &cfg.clients {
        let mut results = Vec::with_capacity(n_cfg);
        for _ in 0..n_cfg {
            results.push(outcomes.next().expect("one outcome per closed cell")?);
        }
        cells.push(ClosedLoopCell { clients, results });
    }
    Ok(ClosedLoopSweep {
        cells,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
        think_s: cfg.think_s,
    })
}

/// Streaming twin of [`run_closed`]: request bodies are pulled lazily
/// from [`synth_stream`] as clients free up and replayed with
/// [`run_closed_loop_streamed`] — bit-identical report JSON in
/// O(clients) memory per cell.
pub fn run_closed_streamed(cfg: &ClosedLoopConfig) -> Result<ClosedLoopSweep> {
    if cfg.requests_per_point == 0 {
        return Err(Error::Config("closed loop needs requests_per_point > 0".into()));
    }
    if cfg.clients.is_empty() {
        return Err(Error::Config("closed loop needs at least one client count".into()));
    }
    if cfg.clients.iter().any(|&k| k == 0) {
        return Err(Error::Config("client counts must be > 0".into()));
    }
    let ch = synth_characterization(cfg.seed ^ CLOSED_SEED_TAG, cfg.requests_per_point, 1.0);
    let n_cfg = closed_configurations().len();
    let outcomes = runner::run_cells(cfg.threads, cfg.clients.len() * n_cfg, |cell| {
        let clients = cfg.clients[cell / n_cfg];
        let (policy, queue_aware, adaptive) = closed_configurations()[cell % n_cfg];
        let opts = opts_for(&cfg.opts, queue_aware, adaptive);
        let bodies =
            synth_stream(cfg.seed ^ CLOSED_SEED_TAG, cfg.requests_per_point, 1.0).map(Ok);
        run_closed_loop_streamed(bodies, &ch, policy, &opts, clients, cfg.think_s)
    });
    let mut outcomes = outcomes.into_iter();
    let mut cells = Vec::with_capacity(cfg.clients.len());
    for &clients in &cfg.clients {
        let mut results = Vec::with_capacity(n_cfg);
        for _ in 0..n_cfg {
            results.push(outcomes.next().expect("one outcome per closed cell")?);
        }
        cells.push(ClosedLoopCell { clients, results });
    }
    Ok(ClosedLoopSweep {
        cells,
        requests_per_point: cfg.requests_per_point,
        seed: cfg.seed,
        think_s: cfg.think_s,
    })
}

/// Render the closed-loop sweep as an aligned text table.
pub fn render_closed_text(s: &ClosedLoopSweep) -> String {
    let mut rows = vec![vec![
        "clients".to_string(),
        "policy".to_string(),
        "goodput r/s".to_string(),
        "mean ms".to_string(),
        "p50 ms".to_string(),
        "p95 ms".to_string(),
        "p99 ms".to_string(),
        "batch".to_string(),
        "hedge %".to_string(),
        "waste %".to_string(),
    ]];
    for c in &s.cells {
        for r in &c.results {
            rows.push(vec![
                format!("{}", c.clients),
                r.policy.clone(),
                format!("{:.1}", r.throughput_rps),
                format!("{:.1}", r.mean_latency_s * 1e3),
                format!("{:.1}", r.p50_s * 1e3),
                format!("{:.1}", r.p95_s * 1e3),
                format!("{:.1}", r.p99_s * 1e3),
                format!("{:.2}", r.mean_batch),
                format!("{:.1}", r.hedge_rate() * 100.0),
                format!("{:.1}", r.wasted_frac() * 100.0),
            ]);
        }
    }
    let mut out = text_table(&rows);
    out.push_str(
        "\nReading: goodput climbs with clients until the devices saturate, \
         then extra concurrency only buys latency — the standard serving \
         latency-throughput curve.\n",
    );
    out
}

/// JSON report for the closed-loop sweep (`closed_loop.json`).
pub fn closed_to_json(s: &ClosedLoopSweep) -> Json {
    let mut points = Vec::new();
    for c in &s.cells {
        let mut o = Json::object();
        o.set("clients", Json::Num(c.clients as f64));
        let mut policies = Json::object();
        for r in &c.results {
            policies.set(&r.policy, r.to_json());
        }
        o.set("policies", policies);
        points.push(o);
    }
    let mut root = Json::object();
    root.set("seed", Json::Num(s.seed as f64))
        .set("requests_per_point", Json::Num(s.requests_per_point as f64))
        .set("think_s", Json::Num(s.think_s))
        .set("points", Json::Array(points));
    root
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::BatchPolicy;
    use crate::sim::Spike;

    fn smoke_cfg(loads: Vec<f64>) -> LoadConfig {
        LoadConfig {
            requests_per_point: 3_000,
            loads_rps: loads,
            ..Default::default()
        }
    }

    #[test]
    fn workload_is_deterministic_and_well_formed() {
        let (a, cha) = synth_workload(7, 500, 20.0);
        let (b, _chb) = synth_workload(7, 500, 20.0);
        assert_eq!(a.len(), 500);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.n, y.n);
            assert_eq!(x.m_real, y.m_real);
            assert!((x.arrival_s - y.arrival_s).abs() < 1e-15);
            assert!((x.t_edge - y.t_edge).abs() < 1e-15);
        }
        let mut prev = 0.0;
        for rq in &a {
            assert!((1..=N_MAX).contains(&rq.n));
            assert!((1..=N_MAX).contains(&rq.m_real));
            assert!(rq.arrival_s > prev);
            assert!(rq.t_edge > 0.0 && rq.t_cloud > 0.0);
            prev = rq.arrival_s;
        }
        assert!(cha.mean_m > 1.0 && cha.mean_m < N_MAX as f64);
    }

    #[test]
    fn flat_shape_reproduces_the_poisson_workload_bit_for_bit() {
        // With amplitude 0 and no spikes the shaped generator must be
        // indistinguishable from the classic one — same seed, same
        // draw order, same bits (the scenario engine's pay-for-use
        // anchor).
        let shape = LoadShape {
            base_rps: 20.0,
            period_s: 60.0,
            amplitude: 0.0,
            spikes: vec![],
        };
        let (a, cha) = synth_shaped_workload(7, 500, &shape);
        let (b, chb) = synth_workload(7, 500, 20.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.n, y.n);
            assert_eq!(x.m_real, y.m_real);
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!(x.t_edge.to_bits(), y.t_edge.to_bits());
            assert_eq!(x.t_cloud.to_bits(), y.t_cloud.to_bits());
        }
        assert_eq!(cha.mean_m.to_bits(), chb.mean_m.to_bits());
    }

    #[test]
    fn shaped_workload_tracks_the_rate_profile() {
        // A 10x flash crowd puts ~10x the arrivals-per-second inside
        // its window compared to the surrounding flat load.
        let shape = LoadShape {
            base_rps: 40.0,
            period_s: 60.0,
            amplitude: 0.0,
            spikes: vec![Spike { start_s: 5.0, duration_s: 5.0, factor: 10.0 }],
        };
        let (reqs, _ch) = synth_shaped_workload(11, 4_000, &shape);
        let in_spike = reqs
            .iter()
            .filter(|r| r.arrival_s >= 5.0 && r.arrival_s < 10.0)
            .count();
        let before = reqs.iter().filter(|r| r.arrival_s < 5.0).count();
        // Window rates: before ≈ 40/s over 5s = 200, spike ≈ 400/s over
        // 5s = 2000. Allow generous noise either side.
        assert!(before > 100 && before < 320, "pre-spike count {before}");
        assert!(in_spike > 1_400, "in-spike count {in_spike}");
        assert!(
            in_spike as f64 > 5.0 * before as f64,
            "spike window not visibly denser: {in_spike} vs {before}"
        );
        let mut prev = 0.0;
        for r in &reqs {
            assert!(r.arrival_s > prev);
            prev = r.arrival_s;
        }
    }

    #[test]
    fn conservation_and_structure() {
        let sweep = run(&smoke_cfg(vec![10.0])).unwrap();
        assert_eq!(sweep.cells.len(), 1);
        let cell = &sweep.cells[0];
        assert_eq!(cell.results.len(), 5);
        for r in &cell.results {
            assert_eq!(r.offered, 3_000);
            assert_eq!(r.completed + r.rejected, r.offered);
            assert_eq!(r.edge_count + r.cloud_count, r.completed);
            assert!(r.p50_s <= r.p99_s + 1e-12);
            // Hedge bookkeeping closes whatever the policy.
            assert_eq!(r.hedge_wins_edge + r.hedge_wins_cloud, r.hedged);
            assert_eq!(r.hedge_cancelled + r.hedge_wasted, r.hedged);
            if !r.adaptive {
                assert_eq!(r.hedged, 0);
                assert_eq!(r.wasted_work_s, 0.0);
            }
        }
        // The drift scenario rides along with its three policies.
        assert_eq!(sweep.drift.results.len(), 3);
        for r in &sweep.drift.results {
            assert_eq!(r.completed + r.rejected, r.offered);
        }
    }

    #[test]
    fn policies_coincide_at_low_load() {
        // With idle queues the wait terms vanish, so queue-aware and
        // queue-blind C-NMT make (nearly) the same decisions.
        let sweep = run(&smoke_cfg(vec![2.0])).unwrap();
        let cell = &sweep.cells[0];
        let blind = cell.get("cnmt");
        let aware = cell.get("cnmt+queue");
        assert_eq!(blind.rejected, 0);
        assert_eq!(aware.rejected, 0);
        assert!(
            (blind.p99_s - aware.p99_s).abs() / blind.p99_s < 0.10,
            "low-load p99 diverged: blind {} vs aware {}",
            blind.p99_s,
            aware.p99_s
        );
    }

    #[test]
    fn queue_aware_dominates_blind_at_high_load() {
        // THE acceptance property: at high offered load the queue-aware
        // router has a shorter tail at equal-or-better goodput.
        let sweep = run(&smoke_cfg(vec![96.0])).unwrap();
        let cell = &sweep.cells[0];
        let blind = cell.get("cnmt");
        let aware = cell.get("cnmt+queue");
        assert!(
            aware.p99_s < blind.p99_s,
            "aware p99 {} not below blind p99 {}",
            aware.p99_s,
            blind.p99_s
        );
        assert!(
            aware.throughput_rps >= blind.throughput_rps * 0.999,
            "aware goodput {} fell below blind {}",
            aware.throughput_rps,
            blind.throughput_rps
        );
        // And it beats both static mappings on the tail too.
        assert!(aware.p99_s < cell.get("edge_only").p99_s);
    }

    #[test]
    fn batching_extends_the_stable_region() {
        // At a load beyond the *serial* capacity of both devices
        // combined, disabling micro-batching must shed more (or tail
        // harder) than the batched dispatcher.
        let mut cfg = smoke_cfg(vec![200.0]);
        let sweep_batched = run(&cfg).unwrap();
        cfg.opts.dispatcher.batch = BatchPolicy::serial();
        let sweep_serial = run(&cfg).unwrap();
        let b = sweep_batched.cells[0].get("cnmt+queue").clone();
        let s = sweep_serial.cells[0].get("cnmt+queue").clone();
        assert!(b.mean_batch > 1.2, "batched run never batched: {}", b.mean_batch);
        assert!(
            (s.rejected > b.rejected) || (s.p99_s > b.p99_s * 1.5),
            "serial dispatch not visibly worse: serial(rej {}, p99 {}) \
             batched(rej {}, p99 {})",
            s.rejected,
            s.p99_s,
            b.rejected,
            b.p99_s
        );
    }

    #[test]
    fn sweep_is_bit_identical_across_thread_counts() {
        // THE determinism acceptance property: the JSON report (the
        // exact bytes CI diffs) must not depend on the thread count.
        let mut cfg = smoke_cfg(vec![8.0, 96.0]);
        cfg.requests_per_point = 1_200;
        let serial = to_json(&run(&cfg).unwrap()).to_string_pretty();
        for threads in [2, 4, 11] {
            cfg.threads = threads;
            let parallel = to_json(&run(&cfg).unwrap()).to_string_pretty();
            assert_eq!(parallel, serial, "{threads}-thread sweep diverged");
        }
        let mut ccfg = ClosedLoopConfig {
            requests_per_point: 600,
            clients: vec![1, 8],
            ..Default::default()
        };
        let serial = closed_to_json(&run_closed(&ccfg).unwrap()).to_string_pretty();
        ccfg.threads = 4;
        let parallel = closed_to_json(&run_closed(&ccfg).unwrap()).to_string_pretty();
        assert_eq!(parallel, serial, "closed-loop sweep diverged under threads");
    }

    #[test]
    fn rejects_degenerate_sweep_configs() {
        assert!(run(&smoke_cfg(vec![])).is_err());
        assert!(run(&smoke_cfg(vec![0.0])).is_err());
        assert!(run(&smoke_cfg(vec![-4.0])).is_err());
        assert!(run(&smoke_cfg(vec![f64::NAN])).is_err());
        assert!(run(&smoke_cfg(vec![f64::INFINITY])).is_err());
        let mut cfg = smoke_cfg(vec![8.0]);
        cfg.requests_per_point = 0;
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn render_and_json_cover_all_points() {
        let sweep = run(&smoke_cfg(vec![8.0, 64.0])).unwrap();
        let txt = render_text(&sweep);
        assert!(txt.contains("cnmt+queue"));
        assert!(txt.contains("cnmt+adaptive"));
        assert!(txt.contains("headline"));
        assert!(txt.contains("drift"));
        let j = to_json(&sweep);
        assert_eq!(j.get("points").unwrap().as_array().unwrap().len(), 2);
        let p0 = &j.get("points").unwrap().as_array().unwrap()[0];
        assert!(p0.get("policies").unwrap().get("cnmt+queue").is_ok());
        assert!(p0.get("policies").unwrap().get("cnmt+adaptive").is_ok());
        let adaptive = p0.get("policies").unwrap().get("cnmt+adaptive").unwrap();
        assert!(adaptive.get("hedge_rate").is_ok());
        assert!(adaptive.get("wasted_frac").is_ok());
        let drift = j.get("drift").unwrap();
        assert!(drift.get("policies").unwrap().get("cnmt+adaptive").is_ok());
        assert!(drift.get("headline_p99_ratio").is_ok());
    }

    #[test]
    fn adaptive_recovers_under_drift_where_static_misroutes() {
        // THE acceptance property of scheduler v2: with the edge
        // drifting 2.5x slower mid-run, hedge + RLS refit must beat the
        // static queue-aware policy on p99 at equal-or-better goodput.
        let drift = run_drift(&smoke_cfg(vec![8.0])).unwrap();
        let stat = drift.get("cnmt+queue");
        let adapt = drift.get("cnmt+adaptive");
        assert!(
            adapt.p99_s < stat.p99_s,
            "adaptive p99 {} not below static p99 {}",
            adapt.p99_s,
            stat.p99_s
        );
        assert!(
            adapt.throughput_rps >= stat.throughput_rps * 0.999,
            "adaptive goodput {} fell below static {}",
            adapt.throughput_rps,
            stat.throughput_rps
        );
        // The adaptive run actually exercised the new machinery.
        assert!(adapt.hedged > 0, "no hedges under drift");
        assert!(adapt.hedge_rate() <= 1.0);
    }

    #[test]
    fn closed_loop_curve_structure_and_saturation() {
        let cfg = ClosedLoopConfig {
            requests_per_point: 2_000,
            clients: vec![1, 16],
            ..Default::default()
        };
        let sweep = run_closed(&cfg).unwrap();
        assert_eq!(sweep.cells.len(), 2);
        for cell in &sweep.cells {
            assert_eq!(cell.results.len(), 3);
            for r in &cell.results {
                assert_eq!(r.completed + r.rejected, r.offered);
                assert_eq!(r.rejected, 0, "closed loop shed at K={}", cell.clients);
            }
        }
        // Concurrency buys throughput on the queue-aware policy.
        let t1 = sweep.cells[0].get("cnmt+queue").throughput_rps;
        let t16 = sweep.cells[1].get("cnmt+queue").throughput_rps;
        assert!(t16 > t1 * 2.0, "K=16 {} r/s vs K=1 {} r/s", t16, t1);
        let j = closed_to_json(&sweep);
        assert_eq!(j.get("points").unwrap().as_array().unwrap().len(), 2);
        let txt = render_closed_text(&sweep);
        assert!(txt.contains("cnmt+adaptive"));
    }

    #[test]
    fn closed_loop_rejects_degenerate_configs() {
        let mut cfg = ClosedLoopConfig { clients: vec![], ..Default::default() };
        assert!(run_closed(&cfg).is_err());
        cfg.clients = vec![0];
        assert!(run_closed(&cfg).is_err());
        cfg.clients = vec![1];
        cfg.requests_per_point = 0;
        assert!(run_closed(&cfg).is_err());
    }
}
