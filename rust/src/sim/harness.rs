//! The request-stream replay harness.

use crate::config::Config;
use crate::coordinator::{PolicyKind, RouterBuilder};
use crate::corpus::{Dataset, LangPair};
use crate::devices::{Calibration, DeviceKind};
use crate::net::trace::ConnectionProfile;
use crate::net::{Network, TraceGenerator, TxModel};
use crate::util::{Json, Rng};
use crate::Result;

use super::characterize::{characterize, Characterization};

/// Ground truth for one request: everything any policy could be charged.
#[derive(Debug, Clone, Copy)]
pub struct RequestTruth {
    pub n: usize,
    pub m_real: usize,
    /// Arrival time on the simulation clock (seconds).
    pub arrival_s: f64,
    /// True edge execution time (seconds).
    pub t_edge: f64,
    /// True cloud execution time (seconds).
    pub t_cloud: f64,
    /// True network cost if offloaded at arrival (seconds).
    pub t_tx: f64,
    /// Instantaneous trace RTT at arrival (what a timestamped offload
    /// would observe).
    pub rtt: f64,
}

/// The shared ground-truth table for one (pair, profile) experiment.
#[derive(Debug, Clone)]
pub struct TruthTable {
    pub pair: LangPair,
    pub profile: ConnectionProfile,
    pub requests: Vec<RequestTruth>,
    pub characterization: Characterization,
}

impl TruthTable {
    /// Build the table: generate corpus + trace, characterise offline,
    /// sample the request stream and both devices' ground-truth times.
    pub fn build(
        cfg: &Config,
        pair: LangPair,
        profile: ConnectionProfile,
        calibration: &Calibration,
    ) -> Result<TruthTable> {
        let seed = cfg.seed
            ^ (pair as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
            ^ (profile as u64 + 1).wrapping_mul(0xBF58476D1CE4E5B9);
        let dataset = Dataset::generate(pair, cfg.fit_inferences, cfg.eval_pool, seed);
        dataset.validate()?;
        let characterization = characterize(&dataset, calibration, seed)?;

        let trace = TraceGenerator::new(seed ^ 0x4E7).profile(profile);
        let network = Network::new(
            trace,
            TxModel { bandwidth_bps: cfg.bandwidth_bps, ..Default::default() },
        );

        let model = pair.model_name();
        let mut edge = calibration.build_device(DeviceKind::Edge, seed ^ 0xE)?;
        let mut cloud = calibration.build_device(DeviceKind::Cloud, seed ^ 0xC)?;
        let mut rng = Rng::new(seed ^ 0x57EA);

        let stream = dataset.sample_eval(cfg.requests, seed ^ 0x5A);
        let mut requests = Vec::with_capacity(stream.len());
        let mut t = 0.0f64;
        for p in stream {
            t += rng.exponential(1.0 / cfg.mean_interarrival_s);
            let n = p.n();
            let m = p.m_real;
            requests.push(RequestTruth {
                n,
                m_real: m,
                arrival_s: t,
                t_edge: edge.exec_time(model, n, m)?,
                t_cloud: cloud.exec_time(model, n, m)?,
                t_tx: network.tx_time(t, n, m),
                rtt: network.rtt_at(t),
            });
        }
        Ok(TruthTable { pair, profile, requests, characterization })
    }
}

/// Aggregated result of evaluating one policy on a [`TruthTable`].
#[derive(Debug, Clone)]
pub struct PolicyResult {
    pub policy: String,
    /// Sum of per-request latencies (the paper's "total ex. time").
    pub total_s: f64,
    pub mean_latency_s: f64,
    pub edge_count: usize,
    pub cloud_count: usize,
    pub requests: usize,
    /// Fraction of requests where the policy picked the truly-faster side.
    pub correct_rate: f64,
}

impl PolicyResult {
    /// Percentage change vs a baseline total (negative = faster).
    pub fn vs(&self, baseline: &PolicyResult) -> f64 {
        (self.total_s - baseline.total_s) / baseline.total_s * 100.0
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("policy", Json::Str(self.policy.clone()))
            .set("total_s", Json::Num(self.total_s))
            .set("mean_latency_s", Json::Num(self.mean_latency_s))
            .set("edge_count", Json::Num(self.edge_count as f64))
            .set("cloud_count", Json::Num(self.cloud_count as f64))
            .set("requests", Json::Num(self.requests as f64))
            .set("correct_rate", Json::Num(self.correct_rate));
        o
    }
}

/// How long without an offload before the gateway's background traffic
/// refreshes the T_tx estimate (paper §II-C: the gateway aggregates many
/// end-nodes and is "almost continuously fed with inference requests").
const TTX_REFRESH_S: f64 = 60.0;

/// Evaluate one policy over the table.
pub fn run_policy(table: &TruthTable, policy: PolicyKind) -> Result<PolicyResult> {
    let ch = &table.characterization;
    let mut router = RouterBuilder::new(policy)
        .texe(ch.texe_edge, ch.texe_cloud)
        .n2m(ch.n2m)
        .build()?;

    let mut total = 0.0f64;
    let (mut edge_count, mut cloud_count, mut correct) = (0usize, 0usize, 0usize);
    for rq in &table.requests {
        // Gateway heartbeat: aggregated end-node traffic keeps the
        // estimator fresh even when this policy never offloads.
        if router.ttx_stale(rq.arrival_s, TTX_REFRESH_S) {
            router.observe_ttx(rq.arrival_s, rq.rtt);
        }
        let device = match policy {
            PolicyKind::Oracle => {
                if rq.t_edge <= rq.t_tx + rq.t_cloud {
                    DeviceKind::Edge
                } else {
                    DeviceKind::Cloud
                }
            }
            _ => router.decide(rq.n).device,
        };
        let latency = match device {
            DeviceKind::Edge => {
                edge_count += 1;
                rq.t_edge
            }
            DeviceKind::Cloud => {
                cloud_count += 1;
                // Timestamped offload: the observed round trip refreshes
                // the estimator (paper §II-C).
                router.observe_ttx(rq.arrival_s, rq.rtt);
                rq.t_tx + rq.t_cloud
            }
        };
        let best = rq.t_edge.min(rq.t_tx + rq.t_cloud);
        if (latency - best).abs() < 1e-12 {
            correct += 1;
        }
        total += latency;
    }
    let n = table.requests.len();
    Ok(PolicyResult {
        policy: policy.id().to_string(),
        total_s: total,
        mean_latency_s: total / n as f64,
        edge_count,
        cloud_count,
        requests: n,
        correct_rate: correct as f64 / n as f64,
    })
}

/// Evaluate the C-NMT decision rule with an arbitrary output-length
/// estimator (the paper's future-work ablation: "more advanced output
/// length estimation methods"). Identical loop to [`run_policy`]'s C-NMT
/// branch, with `est` supplying M̂.
pub fn run_with_estimator(
    table: &TruthTable,
    est: &crate::predictor::LengthEstimator,
) -> Result<PolicyResult> {
    let ch = &table.characterization;
    let mut router = RouterBuilder::new(PolicyKind::Cnmt)
        .texe(ch.texe_edge, ch.texe_cloud)
        .n2m(ch.n2m)
        .build()?;
    let mut total = 0.0f64;
    let (mut edge_count, mut cloud_count, mut correct) = (0usize, 0usize, 0usize);
    for rq in &table.requests {
        if router.ttx_stale(rq.arrival_s, TTX_REFRESH_S) {
            router.observe_ttx(rq.arrival_s, rq.rtt);
        }
        let device = router.decide_given_m(rq.n, est.predict(rq.n)).device;
        let latency = match device {
            DeviceKind::Edge => {
                edge_count += 1;
                rq.t_edge
            }
            DeviceKind::Cloud => {
                cloud_count += 1;
                router.observe_ttx(rq.arrival_s, rq.rtt);
                rq.t_tx + rq.t_cloud
            }
        };
        if (latency - rq.t_edge.min(rq.t_tx + rq.t_cloud)).abs() < 1e-12 {
            correct += 1;
        }
        total += latency;
    }
    let n = table.requests.len();
    Ok(PolicyResult {
        policy: format!("cnmt+{}", est.id()),
        total_s: total,
        mean_latency_s: total / n as f64,
        edge_count,
        cloud_count,
        requests: n,
        correct_rate: correct as f64 / n as f64,
    })
}

/// Evaluate the full Table-I policy set on one table.
pub fn run_all_policies(table: &TruthTable) -> Result<Vec<PolicyResult>> {
    let mean_m = table.characterization.mean_m;
    [
        PolicyKind::EdgeOnly,
        PolicyKind::CloudOnly,
        PolicyKind::Oracle,
        PolicyKind::Naive { mean_m },
        PolicyKind::Cnmt,
    ]
    .iter()
    .map(|&p| run_policy(table, p))
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_table(pair: LangPair, profile: ConnectionProfile) -> TruthTable {
        let cfg = Config::smoke();
        let cal = Calibration::default_paper();
        TruthTable::build(&cfg, pair, profile, &cal).unwrap()
    }

    #[test]
    fn truth_table_is_deterministic() {
        let a = smoke_table(LangPair::FrEn, ConnectionProfile::Cp1);
        let b = smoke_table(LangPair::FrEn, ConnectionProfile::Cp1);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.n, y.n);
            assert!((x.t_edge - y.t_edge).abs() < 1e-15);
            assert!((x.t_tx - y.t_tx).abs() < 1e-15);
        }
    }

    #[test]
    fn oracle_lower_bounds_every_policy() {
        // THE core invariant of the evaluation.
        for pair in LangPair::ALL {
            let table = smoke_table(pair, ConnectionProfile::Cp1);
            let results = run_all_policies(&table).unwrap();
            let oracle = results.iter().find(|r| r.policy == "oracle").unwrap();
            for r in &results {
                assert!(
                    oracle.total_s <= r.total_s + 1e-9,
                    "{}: oracle {} > {} {}",
                    pair.id(),
                    oracle.total_s,
                    r.policy,
                    r.total_s
                );
            }
            assert!((oracle.correct_rate - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn cnmt_beats_static_mappings_in_smoke_runs() {
        // The paper's headline: C-NMT reduces total time vs both GW-only
        // and Server-only, on every dataset/profile.
        for pair in LangPair::ALL {
            for profile in ConnectionProfile::ALL {
                let table = smoke_table(pair, profile);
                let results = run_all_policies(&table).unwrap();
                let get = |id: &str| {
                    results.iter().find(|r| r.policy == id).unwrap().total_s
                };
                let cnmt = get("cnmt");
                assert!(
                    cnmt < get("edge_only") * 1.001,
                    "{}/{}: cnmt {} vs edge {}",
                    pair.id(),
                    profile.id(),
                    cnmt,
                    get("edge_only")
                );
                assert!(
                    cnmt < get("cloud_only") * 1.001,
                    "{}/{}: cnmt {} vs cloud {}",
                    pair.id(),
                    profile.id(),
                    cnmt,
                    get("cloud_only")
                );
            }
        }
    }

    #[test]
    fn cnmt_at_least_matches_naive_overall() {
        // Paper: up to 21% better than Naive; never catastrophically
        // worse. Aggregate over pairs to avoid per-run noise.
        let mut cnmt_total = 0.0;
        let mut naive_total = 0.0;
        for pair in LangPair::ALL {
            let table = smoke_table(pair, ConnectionProfile::Cp1);
            let results = run_all_policies(&table).unwrap();
            cnmt_total += results.iter().find(|r| r.policy == "cnmt").unwrap().total_s;
            naive_total += results.iter().find(|r| r.policy == "naive").unwrap().total_s;
        }
        assert!(
            cnmt_total <= naive_total * 1.01,
            "cnmt {cnmt_total} vs naive {naive_total}"
        );
    }

    #[test]
    fn mixed_routing_happens() {
        // C-NMT must actually split traffic (otherwise it degenerates to
        // a static policy and the experiment is vacuous).
        let table = smoke_table(LangPair::DeEn, ConnectionProfile::Cp2);
        let r = run_policy(&table, PolicyKind::Cnmt).unwrap();
        assert!(r.edge_count > 0, "no edge traffic");
        assert!(r.cloud_count > 0, "no cloud traffic");
        assert_eq!(r.edge_count + r.cloud_count, r.requests);
    }

    #[test]
    fn percentage_helper() {
        let a = PolicyResult {
            policy: "a".into(),
            total_s: 80.0,
            mean_latency_s: 0.0,
            edge_count: 0,
            cloud_count: 0,
            requests: 0,
            correct_rate: 0.0,
        };
        let b = PolicyResult { total_s: 100.0, policy: "b".into(), ..a.clone() };
        assert!((a.vs(&b) + 20.0).abs() < 1e-12);
    }
}
