//! The request-stream replay harness.
//!
//! Two evaluation modes share the same per-request ground truth
//! ([`RequestTruth`]):
//!
//! * [`run_policy`] — the paper's setting: one request at a time, both
//!   devices idle, latency = execution (+ network).
//! * [`run_contended`] — open-loop Poisson arrivals flow through the
//!   [`crate::scheduler`] subsystem, where concurrent requests genuinely
//!   contend for bounded device capacity: they queue behind each other,
//!   get micro-batched, and are shed when the admission bound is hit.

use crate::config::Config;
use crate::coordinator::{PolicyKind, RouterBuilder};
use crate::corpus::{Dataset, LangPair};
use crate::devices::{Calibration, DeviceKind};
use crate::metrics::{Histogram, OnlineStats};
use crate::net::trace::ConnectionProfile;
use crate::net::{Network, TraceGenerator, TxModel};
use crate::scheduler::{
    BatchExecutor, Completion, Dispatcher, DispatcherConfig, QueuedRequest,
};
use crate::util::{Json, Rng};
use crate::{Error, Result};

use super::characterize::{characterize, Characterization};

/// Ground truth for one request: everything any policy could be charged.
#[derive(Debug, Clone, Copy)]
pub struct RequestTruth {
    pub n: usize,
    pub m_real: usize,
    /// Arrival time on the simulation clock (seconds).
    pub arrival_s: f64,
    /// True edge execution time (seconds).
    pub t_edge: f64,
    /// True cloud execution time (seconds).
    pub t_cloud: f64,
    /// True network cost if offloaded at arrival (seconds).
    pub t_tx: f64,
    /// Instantaneous trace RTT at arrival (what a timestamped offload
    /// would observe).
    pub rtt: f64,
}

/// The shared ground-truth table for one (pair, profile) experiment.
#[derive(Debug, Clone)]
pub struct TruthTable {
    pub pair: LangPair,
    pub profile: ConnectionProfile,
    pub requests: Vec<RequestTruth>,
    pub characterization: Characterization,
}

impl TruthTable {
    /// Build the table: generate corpus + trace, characterise offline,
    /// sample the request stream and both devices' ground-truth times.
    pub fn build(
        cfg: &Config,
        pair: LangPair,
        profile: ConnectionProfile,
        calibration: &Calibration,
    ) -> Result<TruthTable> {
        let seed = cfg.seed
            ^ (pair as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
            ^ (profile as u64 + 1).wrapping_mul(0xBF58476D1CE4E5B9);
        let dataset = Dataset::generate(pair, cfg.fit_inferences, cfg.eval_pool, seed);
        dataset.validate()?;
        let characterization = characterize(&dataset, calibration, seed)?;

        let trace = TraceGenerator::new(seed ^ 0x4E7).profile(profile);
        let network = Network::new(
            trace,
            TxModel { bandwidth_bps: cfg.bandwidth_bps, ..Default::default() },
        );

        let model = pair.model_name();
        let mut edge = calibration.build_device(DeviceKind::Edge, seed ^ 0xE)?;
        let mut cloud = calibration.build_device(DeviceKind::Cloud, seed ^ 0xC)?;
        let mut rng = Rng::new(seed ^ 0x57EA);

        let stream = dataset.sample_eval(cfg.requests, seed ^ 0x5A);
        let mut requests = Vec::with_capacity(stream.len());
        let mut t = 0.0f64;
        for p in stream {
            t += rng.exponential(1.0 / cfg.mean_interarrival_s);
            let n = p.n();
            let m = p.m_real;
            requests.push(RequestTruth {
                n,
                m_real: m,
                arrival_s: t,
                t_edge: edge.exec_time(model, n, m)?,
                t_cloud: cloud.exec_time(model, n, m)?,
                t_tx: network.tx_time(t, n, m),
                rtt: network.rtt_at(t),
            });
        }
        Ok(TruthTable { pair, profile, requests, characterization })
    }
}

/// Aggregated result of evaluating one policy on a [`TruthTable`].
#[derive(Debug, Clone)]
pub struct PolicyResult {
    pub policy: String,
    /// Sum of per-request latencies (the paper's "total ex. time").
    pub total_s: f64,
    pub mean_latency_s: f64,
    pub edge_count: usize,
    pub cloud_count: usize,
    pub requests: usize,
    /// Fraction of requests where the policy picked the truly-faster side.
    pub correct_rate: f64,
}

impl PolicyResult {
    /// Percentage change vs a baseline total (negative = faster).
    pub fn vs(&self, baseline: &PolicyResult) -> f64 {
        (self.total_s - baseline.total_s) / baseline.total_s * 100.0
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("policy", Json::Str(self.policy.clone()))
            .set("total_s", Json::Num(self.total_s))
            .set("mean_latency_s", Json::Num(self.mean_latency_s))
            .set("edge_count", Json::Num(self.edge_count as f64))
            .set("cloud_count", Json::Num(self.cloud_count as f64))
            .set("requests", Json::Num(self.requests as f64))
            .set("correct_rate", Json::Num(self.correct_rate));
        o
    }
}

/// How long without an offload before the gateway's background traffic
/// refreshes the T_tx estimate (paper §II-C: the gateway aggregates many
/// end-nodes and is "almost continuously fed with inference requests").
const TTX_REFRESH_S: f64 = 60.0;

/// Evaluate one policy over the table.
pub fn run_policy(table: &TruthTable, policy: PolicyKind) -> Result<PolicyResult> {
    let ch = &table.characterization;
    let mut router = RouterBuilder::new(policy)
        .texe(ch.texe_edge, ch.texe_cloud)
        .n2m(ch.n2m)
        .build()?;

    let mut total = 0.0f64;
    let (mut edge_count, mut cloud_count, mut correct) = (0usize, 0usize, 0usize);
    for rq in &table.requests {
        // Gateway heartbeat: aggregated end-node traffic keeps the
        // estimator fresh even when this policy never offloads.
        if router.ttx_stale(rq.arrival_s, TTX_REFRESH_S) {
            router.observe_ttx(rq.arrival_s, rq.rtt);
        }
        let device = match policy {
            PolicyKind::Oracle => {
                if rq.t_edge <= rq.t_tx + rq.t_cloud {
                    DeviceKind::Edge
                } else {
                    DeviceKind::Cloud
                }
            }
            _ => router.decide(rq.n).device,
        };
        let latency = match device {
            DeviceKind::Edge => {
                edge_count += 1;
                rq.t_edge
            }
            DeviceKind::Cloud => {
                cloud_count += 1;
                // Timestamped offload: the observed round trip refreshes
                // the estimator (paper §II-C).
                router.observe_ttx(rq.arrival_s, rq.rtt);
                rq.t_tx + rq.t_cloud
            }
        };
        let best = rq.t_edge.min(rq.t_tx + rq.t_cloud);
        if (latency - best).abs() < 1e-12 {
            correct += 1;
        }
        total += latency;
    }
    let n = table.requests.len();
    Ok(PolicyResult {
        policy: policy.id().to_string(),
        total_s: total,
        mean_latency_s: total / n as f64,
        edge_count,
        cloud_count,
        requests: n,
        correct_rate: correct as f64 / n as f64,
    })
}

/// Evaluate the C-NMT decision rule with an arbitrary output-length
/// estimator (the paper's future-work ablation: "more advanced output
/// length estimation methods"). Identical loop to [`run_policy`]'s C-NMT
/// branch, with `est` supplying M̂.
pub fn run_with_estimator(
    table: &TruthTable,
    est: &crate::predictor::LengthEstimator,
) -> Result<PolicyResult> {
    let ch = &table.characterization;
    let mut router = RouterBuilder::new(PolicyKind::Cnmt)
        .texe(ch.texe_edge, ch.texe_cloud)
        .n2m(ch.n2m)
        .build()?;
    let mut total = 0.0f64;
    let (mut edge_count, mut cloud_count, mut correct) = (0usize, 0usize, 0usize);
    for rq in &table.requests {
        if router.ttx_stale(rq.arrival_s, TTX_REFRESH_S) {
            router.observe_ttx(rq.arrival_s, rq.rtt);
        }
        let device = router.decide_given_m(rq.n, est.predict(rq.n)).device;
        let latency = match device {
            DeviceKind::Edge => {
                edge_count += 1;
                rq.t_edge
            }
            DeviceKind::Cloud => {
                cloud_count += 1;
                router.observe_ttx(rq.arrival_s, rq.rtt);
                rq.t_tx + rq.t_cloud
            }
        };
        if (latency - rq.t_edge.min(rq.t_tx + rq.t_cloud)).abs() < 1e-12 {
            correct += 1;
        }
        total += latency;
    }
    let n = table.requests.len();
    Ok(PolicyResult {
        policy: format!("cnmt+{}", est.id()),
        total_s: total,
        mean_latency_s: total / n as f64,
        edge_count,
        cloud_count,
        requests: n,
        correct_rate: correct as f64 / n as f64,
    })
}

// ---------------------------------------------------------------- contention

/// Options for the open-loop contended evaluation.
#[derive(Debug, Clone, Copy)]
pub struct ContentionOpts {
    /// Worker pools, queue bound and batching policy.
    pub dispatcher: DispatcherConfig,
    /// Fraction of a batch's non-critical-path work (Σtᵢ − max tᵢ) that
    /// still leaks into its service time: 0 = perfect amortisation of
    /// the serial O(M) decode loop, 1 = no amortisation (serial).
    pub batch_residual: f64,
    /// Add the scheduler's expected-wait term to eq. 1
    /// ([`crate::coordinator::Router::decide_loaded`]); false = the
    /// paper's queue-blind decision.
    pub queue_aware: bool,
}

impl Default for ContentionOpts {
    fn default() -> Self {
        ContentionOpts {
            dispatcher: DispatcherConfig::default(),
            batch_residual: 0.15,
            queue_aware: true,
        }
    }
}

/// Ground-truth batch executor: a batch costs its longest member plus
/// `residual` of the remaining (amortised) work.
struct TruthExecutor<'a> {
    requests: &'a [RequestTruth],
    residual: f64,
}

impl BatchExecutor for TruthExecutor<'_> {
    fn execute(&mut self, device: DeviceKind, batch: &[QueuedRequest], _start_s: f64) -> f64 {
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        for rq in batch {
            let truth = &self.requests[rq.payload];
            let t = match device {
                DeviceKind::Edge => truth.t_edge,
                DeviceKind::Cloud => truth.t_cloud,
            };
            max = max.max(t);
            sum += t;
        }
        max + (sum - max) * self.residual
    }
}

/// Aggregated result of one contended open-loop run.
#[derive(Debug, Clone)]
pub struct ContendedResult {
    /// Policy id, with `+queue` appended when queue-aware.
    pub policy: String,
    pub queue_aware: bool,
    /// Requests offered (admitted + shed).
    pub offered: usize,
    pub completed: usize,
    /// Requests shed at admission (queue depth bound).
    pub rejected: usize,
    pub edge_count: usize,
    pub cloud_count: usize,
    /// Clock time from first arrival to last response (seconds).
    pub makespan_s: f64,
    /// Completed requests per second of makespan (goodput).
    pub throughput_rps: f64,
    pub mean_latency_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    /// Mean micro-batch size actually dispatched.
    pub mean_batch: f64,
    pub edge_peak_depth: usize,
    pub cloud_peak_depth: usize,
}

impl ContendedResult {
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.rejected as f64 / self.offered as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("policy", Json::Str(self.policy.clone()))
            .set("queue_aware", Json::Bool(self.queue_aware))
            .set("offered", Json::Num(self.offered as f64))
            .set("completed", Json::Num(self.completed as f64))
            .set("rejected", Json::Num(self.rejected as f64))
            .set("shed_rate", Json::Num(self.shed_rate()))
            .set("edge_count", Json::Num(self.edge_count as f64))
            .set("cloud_count", Json::Num(self.cloud_count as f64))
            .set("makespan_s", Json::Num(self.makespan_s))
            .set("throughput_rps", Json::Num(self.throughput_rps))
            .set("mean_latency_s", Json::Num(self.mean_latency_s))
            .set("p50_s", Json::Num(self.p50_s))
            .set("p95_s", Json::Num(self.p95_s))
            .set("p99_s", Json::Num(self.p99_s))
            .set("mean_batch", Json::Num(self.mean_batch))
            .set("edge_peak_depth", Json::Num(self.edge_peak_depth as f64))
            .set("cloud_peak_depth", Json::Num(self.cloud_peak_depth as f64));
        o
    }
}

/// Replay `requests` (sorted by arrival) open-loop through the
/// scheduler: each request is routed at its arrival instant using the
/// policy (queue-aware or blind), admitted to the chosen device's
/// bounded queue, micro-batched and executed against the ground truth.
/// Latency = queue wait + batched service (+ recorded network cost when
/// offloaded). The Oracle is not defined under contention (it would
/// need the future arrival process) and is rejected.
pub fn run_contended(
    requests: &[RequestTruth],
    ch: &Characterization,
    policy: PolicyKind,
    opts: &ContentionOpts,
) -> Result<ContendedResult> {
    if matches!(policy, PolicyKind::Oracle) {
        return Err(Error::Sim(
            "oracle is undefined under contention (needs future arrivals)".into(),
        ));
    }
    if !(0.0..=1.0).contains(&opts.batch_residual) {
        return Err(Error::Config(format!(
            "batch_residual {} out of [0,1]",
            opts.batch_residual
        )));
    }
    let mut router = RouterBuilder::new(policy)
        .texe(ch.texe_edge, ch.texe_cloud)
        .n2m(ch.n2m)
        .build()?;
    let mut disp = Dispatcher::new(&opts.dispatcher);
    let mut exec = TruthExecutor { requests, residual: opts.batch_residual };

    let mut hist = Histogram::latency();
    let mut stats = OnlineStats::new();
    let (mut edge_count, mut cloud_count) = (0usize, 0usize);
    let mut completed = 0usize;
    let mut last_done_s = 0.0f64;
    let mut record = |c: Completion| {
        let truth = &requests[c.request.payload];
        let tx_s = if c.device == DeviceKind::Cloud { truth.t_tx } else { 0.0 };
        let latency = (c.done_s - c.request.arrival_s) + tx_s;
        hist.record(latency);
        stats.push(latency);
        match c.device {
            DeviceKind::Edge => edge_count += 1,
            DeviceKind::Cloud => cloud_count += 1,
        }
        completed += 1;
        last_done_s = last_done_s.max(c.done_s + tx_s);
    };

    let mut rejected = 0usize;
    for (i, rq) in requests.iter().enumerate() {
        let now = rq.arrival_s;
        // Execute everything that finishes before this arrival.
        disp.run_until(now, &mut exec, &mut record);
        // Gateway heartbeat keeps T_tx fresh (see run_policy).
        if router.ttx_stale(now, TTX_REFRESH_S) {
            router.observe_ttx(now, rq.rtt);
        }
        let (edge_wait, cloud_wait) = if opts.queue_aware {
            (
                disp.expected_wait_s(DeviceKind::Edge, now),
                disp.expected_wait_s(DeviceKind::Cloud, now),
            )
        } else {
            (0.0, 0.0)
        };
        let device = router.decide_loaded(rq.n, edge_wait, cloud_wait).device;
        if device == DeviceKind::Cloud {
            router.observe_ttx(now, rq.rtt);
        }
        let m_est = ch.n2m.predict(rq.n);
        let est_service_s = match device {
            DeviceKind::Edge => ch.texe_edge.estimate(rq.n, m_est),
            DeviceKind::Cloud => ch.texe_cloud.estimate(rq.n, m_est),
        };
        let queued = QueuedRequest {
            id: i as u64,
            payload: i,
            n: rq.n,
            m_est,
            est_service_s,
            arrival_s: now,
            bucket: 0, // assigned by the dispatcher
        };
        if !disp.submit(device, queued).is_admitted() {
            rejected += 1;
        }
    }
    // Drain: open-loop arrivals have ended; finish the backlog.
    disp.run_until(f64::INFINITY, &mut exec, &mut record);
    drop(record);

    let first_arrival_s = requests.first().map_or(0.0, |r| r.arrival_s);
    let makespan_s = (last_done_s - first_arrival_s).max(0.0);
    let qa_suffix = if opts.queue_aware { "+queue" } else { "" };
    Ok(ContendedResult {
        policy: format!("{}{qa_suffix}", policy.id()),
        queue_aware: opts.queue_aware,
        offered: requests.len(),
        completed,
        rejected,
        edge_count,
        cloud_count,
        makespan_s,
        throughput_rps: if makespan_s > 0.0 {
            completed as f64 / makespan_s
        } else {
            0.0
        },
        mean_latency_s: stats.mean(),
        p50_s: hist.p50(),
        p95_s: hist.p95(),
        p99_s: hist.p99(),
        mean_batch: disp.batch_stats().mean_batch_size(),
        edge_peak_depth: disp.queue_stats(DeviceKind::Edge).peak_depth,
        cloud_peak_depth: disp.queue_stats(DeviceKind::Cloud).peak_depth,
    })
}

/// Evaluate the full Table-I policy set on one table.
pub fn run_all_policies(table: &TruthTable) -> Result<Vec<PolicyResult>> {
    let mean_m = table.characterization.mean_m;
    [
        PolicyKind::EdgeOnly,
        PolicyKind::CloudOnly,
        PolicyKind::Oracle,
        PolicyKind::Naive { mean_m },
        PolicyKind::Cnmt,
    ]
    .iter()
    .map(|&p| run_policy(table, p))
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn smoke_table(pair: LangPair, profile: ConnectionProfile) -> TruthTable {
        let cfg = Config::smoke();
        let cal = Calibration::default_paper();
        TruthTable::build(&cfg, pair, profile, &cal).unwrap()
    }

    #[test]
    fn truth_table_is_deterministic() {
        let a = smoke_table(LangPair::FrEn, ConnectionProfile::Cp1);
        let b = smoke_table(LangPair::FrEn, ConnectionProfile::Cp1);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.n, y.n);
            assert!((x.t_edge - y.t_edge).abs() < 1e-15);
            assert!((x.t_tx - y.t_tx).abs() < 1e-15);
        }
    }

    #[test]
    fn oracle_lower_bounds_every_policy() {
        // THE core invariant of the evaluation.
        for pair in LangPair::ALL {
            let table = smoke_table(pair, ConnectionProfile::Cp1);
            let results = run_all_policies(&table).unwrap();
            let oracle = results.iter().find(|r| r.policy == "oracle").unwrap();
            for r in &results {
                assert!(
                    oracle.total_s <= r.total_s + 1e-9,
                    "{}: oracle {} > {} {}",
                    pair.id(),
                    oracle.total_s,
                    r.policy,
                    r.total_s
                );
            }
            assert!((oracle.correct_rate - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn cnmt_beats_static_mappings_in_smoke_runs() {
        // The paper's headline: C-NMT reduces total time vs both GW-only
        // and Server-only, on every dataset/profile.
        for pair in LangPair::ALL {
            for profile in ConnectionProfile::ALL {
                let table = smoke_table(pair, profile);
                let results = run_all_policies(&table).unwrap();
                let get = |id: &str| {
                    results.iter().find(|r| r.policy == id).unwrap().total_s
                };
                let cnmt = get("cnmt");
                assert!(
                    cnmt < get("edge_only") * 1.001,
                    "{}/{}: cnmt {} vs edge {}",
                    pair.id(),
                    profile.id(),
                    cnmt,
                    get("edge_only")
                );
                assert!(
                    cnmt < get("cloud_only") * 1.001,
                    "{}/{}: cnmt {} vs cloud {}",
                    pair.id(),
                    profile.id(),
                    cnmt,
                    get("cloud_only")
                );
            }
        }
    }

    #[test]
    fn cnmt_at_least_matches_naive_overall() {
        // Paper: up to 21% better than Naive; never catastrophically
        // worse. Aggregate over pairs to avoid per-run noise.
        let mut cnmt_total = 0.0;
        let mut naive_total = 0.0;
        for pair in LangPair::ALL {
            let table = smoke_table(pair, ConnectionProfile::Cp1);
            let results = run_all_policies(&table).unwrap();
            cnmt_total += results.iter().find(|r| r.policy == "cnmt").unwrap().total_s;
            naive_total += results.iter().find(|r| r.policy == "naive").unwrap().total_s;
        }
        assert!(
            cnmt_total <= naive_total * 1.01,
            "cnmt {cnmt_total} vs naive {naive_total}"
        );
    }

    #[test]
    fn mixed_routing_happens() {
        // C-NMT must actually split traffic (otherwise it degenerates to
        // a static policy and the experiment is vacuous).
        let table = smoke_table(LangPair::DeEn, ConnectionProfile::Cp2);
        let r = run_policy(&table, PolicyKind::Cnmt).unwrap();
        assert!(r.edge_count > 0, "no edge traffic");
        assert!(r.cloud_count > 0, "no cloud traffic");
        assert_eq!(r.edge_count + r.cloud_count, r.requests);
    }

    #[test]
    fn percentage_helper() {
        let a = PolicyResult {
            policy: "a".into(),
            total_s: 80.0,
            mean_latency_s: 0.0,
            edge_count: 0,
            cloud_count: 0,
            requests: 0,
            correct_rate: 0.0,
        };
        let b = PolicyResult { total_s: 100.0, policy: "b".into(), ..a.clone() };
        assert!((a.vs(&b) + 20.0).abs() < 1e-12);
    }
}
