//! Offline characterisation (paper §III: "The T_exe model of (2) is
//! fitted on the result of 10k inferences per device, with inputs not
//! included in the 100k set").

use crate::corpus::{Dataset, PrefilterRules};
use crate::devices::{Calibration, DeviceKind};
use crate::predictor::{N2mRegressor, TexeModel};
use crate::Result;

/// Everything the router needs, produced offline.
#[derive(Debug, Clone)]
pub struct Characterization {
    /// Edge execution-time plane (eq. 2, fitted offline).
    pub texe_edge: TexeModel,
    /// Cloud execution-time plane (eq. 2, fitted offline).
    pub texe_cloud: TexeModel,
    /// The N→M output-length regressor (paper §II-B).
    pub n2m: N2mRegressor,
    /// Mean M of the fit split (the Naive baseline's constant estimate).
    pub mean_m: f64,
}

/// Run the offline phase for one (dataset, calibration) combination.
///
/// For each fit-split pair, both devices "run" the inference (sampling
/// their ground-truth time models) and the measured `(N, M_real, T)`
/// triples are plane-fitted per device. The N→M regressor is fitted on
/// the prefiltered corpus pairs, as in the paper.
pub fn characterize(
    dataset: &Dataset,
    calibration: &Calibration,
    seed: u64,
) -> Result<Characterization> {
    let model = dataset.pair.model_name();
    let mut edge = calibration.build_device(DeviceKind::Edge, seed ^ 0xED6E)?;
    let mut cloud = calibration.build_device(DeviceKind::Cloud, seed ^ 0xC10D)?;

    let mut samples_e = Vec::with_capacity(dataset.fit.len());
    let mut samples_c = Vec::with_capacity(dataset.fit.len());
    for p in &dataset.fit {
        let n = p.n();
        let m = p.m_real;
        samples_e.push((n as f64, m as f64, edge.exec_time(model, n, m)?));
        samples_c.push((n as f64, m as f64, cloud.exec_time(model, n, m)?));
    }
    let texe_edge = TexeModel::fit(&samples_e)?;
    let texe_cloud = TexeModel::fit(&samples_c)?;
    texe_edge.validate()?;
    texe_cloud.validate()?;

    let n2m = N2mRegressor::fit(&dataset.fit, &PrefilterRules::default())?;

    Ok(Characterization {
        texe_edge,
        texe_cloud,
        n2m,
        mean_m: dataset.mean_m_fit(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::LangPair;

    #[test]
    fn characterisation_recovers_calibration_planes() {
        let cal = Calibration::default_paper();
        for pair in LangPair::ALL {
            let ds = Dataset::generate(pair, 5_000, 100, 33);
            let ch = characterize(&ds, &cal, 33).unwrap();
            let truth = cal.get(DeviceKind::Edge, pair.model_name()).unwrap().texe;
            // The fitted plane should be close to the generating plane.
            assert!(
                (ch.texe_edge.alpha_m - truth.alpha_m).abs() / truth.alpha_m < 0.15,
                "{}: alpha_m {} vs truth {}",
                pair.id(),
                ch.texe_edge.alpha_m,
                truth.alpha_m
            );
            assert!(ch.texe_edge.r2 > 0.7, "{}: edge r2 {}", pair.id(), ch.texe_edge.r2);
            // N→M close to corpus verbosity.
            assert!(
                (ch.n2m.gamma - pair.params().gamma).abs() < 0.05,
                "{}: gamma {}",
                pair.id(),
                ch.n2m.gamma
            );
            assert!(ch.mean_m > 1.0 && ch.mean_m < 62.0);
        }
    }

    #[test]
    fn rnn_models_keep_alpha_n_transformer_does_not() {
        let cal = Calibration::default_paper();
        let ds_rnn = Dataset::generate(LangPair::DeEn, 5_000, 100, 7);
        let ch_rnn = characterize(&ds_rnn, &cal, 7).unwrap();
        let ds_tr = Dataset::generate(LangPair::EnZh, 5_000, 100, 7);
        let ch_tr = characterize(&ds_tr, &cal, 7).unwrap();
        // Paper: transformer encoder ~constant in N; RNN linear in N.
        assert!(ch_rnn.texe_edge.alpha_n > 5.0 * ch_tr.texe_edge.alpha_n.abs());
    }
}
