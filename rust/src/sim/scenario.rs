//! The declarative scenario layer: one JSON [`ScenarioSpec`] composing
//! time-varying offered load, correlated multi-device drift, fault
//! sequences and latency-SLO service classes — plus the unified
//! [`run_scenario`] facade every public `run_*` harness entry point is
//! a thin wrapper over.
//!
//! Two pieces live here:
//!
//! * **The facade** — [`RunSpec`] names a harness configuration
//!   (pair/fleet scope, open/closed loop, outage/detect machinery,
//!   optional recorder and detector) and [`run_scenario`] dispatches it
//!   to the single core implementation each legacy signature used to
//!   own. The wrappers in [`super::harness`] are proven bit-identical
//!   to the cores by the differential tests below — the refactor is an
//!   API collapse, not a behaviour change.
//! * **The scenario engine** — [`run_scenario_engine`] replays a
//!   workload over a fleet topology under a [`ScenarioSpec`]: every
//!   request is tagged with a service class (interactive / batch /
//!   background shares via the deterministic [`ClassAssigner`]),
//!   scheduled FIFO (class-blind baseline) or earliest-deadline-first
//!   within per-class [`crate::scheduler::FairQueue`] quotas, optionally
//!   hedged with a class-scaled error bar (spending the waste budget on
//!   interactive traffic first), and charged ground truth scaled by any
//!   number of concurrent [`DriftSpec`]s and [`FaultSpec`]s. The result
//!   carries per-class SLO-attainment alongside the classic fleet
//!   aggregates, mirrored float-exactly by
//!   `python/tools/scenario_mirror.py`.
//!
//! Loading is **fail-closed** like [`crate::fleet::Topology::load`]:
//! unknown keys anywhere in the spec, crash faults (v1 composes
//! slow/link only — crash + failover stays with `cnmt experiment
//! outage`), overlapping same-lane fault windows, and share vectors
//! that do not sum to 1 are all rejected at parse time.

use std::path::Path;

use crate::coordinator::PolicyKind;
use crate::devices::DeviceKind;
use crate::fleet::{FleetSelector, FleetStrategy, Topology};
use crate::metrics::{Histogram, OnlineStats};
use crate::obs::{ClassPhases, Detector, Event as ObsEvent, FlightRecorder, Phases, TraceMeta};
use crate::scheduler::{
    Completion, CompletionKind, Dispatcher, HedgeBudget, LaneExecutor, LaneHedgeOutcome,
    QueuedRequest, RetryPolicy, TenantSpec,
};
use crate::util::Json;
use crate::{Error, Result};

use super::characterize::Characterization;
use super::fault::{FaultMode, FaultSpec};
use super::harness::{
    run_closed_loop_core, run_closed_loop_streamed_core, run_contended_impl,
    run_contended_streamed_impl, run_fleet_closed_core, run_fleet_closed_streamed_core,
    run_fleet_core, run_fleet_outage_detect_core, run_fleet_outage_impl,
    run_fleet_streamed_core, ContendedResult, ContentionOpts, DetectRunOut, DriftSpec,
    FleetOpts, FleetResult, OutageResult, RequestTruth,
};

/// Gateway heartbeat cadence for the shared T_tx estimate (seconds) —
/// the same constant the harness replay loops use (private there; the
/// engine keeps its own copy so the arithmetic stays identical).
const TTX_REFRESH_S: f64 = 60.0;

// ------------------------------------------------------------------ spec

/// Time-varying offered load: a base rate modulated by an optional
/// diurnal sinusoid and any number of multiplicative flash-crowd
/// spikes.
#[derive(Debug, Clone)]
pub struct LoadShape {
    /// Base offered rate (requests/second).
    pub base_rps: f64,
    /// Sinusoid period (seconds); only read when `amplitude > 0`.
    pub period_s: f64,
    /// Sinusoid amplitude as a fraction of the base rate, in `[0, 1)`
    /// (0 = flat load).
    pub amplitude: f64,
    /// Flash-crowd windows, each multiplying the instantaneous rate.
    pub spikes: Vec<Spike>,
}

/// One flash-crowd window: the offered rate is multiplied by `factor`
/// while `t ∈ [start_s, start_s + duration_s)`.
#[derive(Debug, Clone, Copy)]
pub struct Spike {
    /// Window start (seconds).
    pub start_s: f64,
    /// Window length (seconds).
    pub duration_s: f64,
    /// Rate multiplier while open.
    pub factor: f64,
}

impl LoadShape {
    /// Instantaneous offered rate at clock time `t_s` (requests/s):
    /// `base · (1 + amplitude·sin(2πt/period)) · Π active spike factors`.
    pub fn rate(&self, t_s: f64) -> f64 {
        let mut r = self.base_rps;
        if self.amplitude > 0.0 {
            r *= 1.0 + self.amplitude * (2.0 * std::f64::consts::PI * t_s / self.period_s).sin();
        }
        for s in &self.spikes {
            if t_s >= s.start_s && t_s < s.start_s + s.duration_s {
                r *= s.factor;
            }
        }
        r
    }

    fn validate(&self) -> Result<()> {
        if !(self.base_rps.is_finite() && self.base_rps > 0.0) {
            return Err(Error::Config(format!(
                "scenario load: base_rps {} must be finite and > 0",
                self.base_rps
            )));
        }
        if !(self.amplitude >= 0.0 && self.amplitude < 1.0) {
            return Err(Error::Config(format!(
                "scenario load: amplitude {} must be in [0, 1)",
                self.amplitude
            )));
        }
        if self.amplitude > 0.0 && !(self.period_s.is_finite() && self.period_s > 0.0) {
            return Err(Error::Config(format!(
                "scenario load: period_s {} must be finite and > 0",
                self.period_s
            )));
        }
        for (i, s) in self.spikes.iter().enumerate() {
            if !(s.start_s.is_finite() && s.start_s >= 0.0)
                || !(s.duration_s.is_finite() && s.duration_s > 0.0)
                || !(s.factor.is_finite() && s.factor > 0.0)
            {
                return Err(Error::Config(format!(
                    "scenario load: spike {i} needs start_s >= 0, duration_s > 0, factor > 0"
                )));
            }
        }
        Ok(())
    }
}

/// One service class: a latency SLO plus its share of the offered
/// stream and its scheduling/hedging knobs.
#[derive(Debug, Clone)]
pub struct ClassSpec {
    /// Class label (`interactive`, `batch`, …) — report key.
    pub name: String,
    /// Latency SLO (seconds): a request meets its deadline when
    /// end-to-end latency ≤ this.
    pub deadline_s: f64,
    /// Fraction of offered requests in this class; shares sum to 1.
    pub share: f64,
    /// Weighted-round-robin weight of the class's fair-queue tenant.
    pub weight: f64,
    /// Per-lane queued-depth quota of the class's fair-queue tenant.
    pub quota: usize,
    /// Class-aware hedging: this class's hedge error bar is the global
    /// bar × this scale (interactive > 1 spends the waste budget first;
    /// 0 never hedges the class).
    pub hedge_scale: f64,
}

/// How admitted requests are ordered for dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheduling {
    /// Class-blind arrival order (the baseline): requests go straight
    /// to the per-lane queues.
    Fifo,
    /// Earliest-deadline-first within per-class quotas of the fair
    /// front-end ([`crate::scheduler::FairQueue::new_edf`]).
    Edf,
}

impl Scheduling {
    /// The JSON tag / report label.
    pub fn tag(&self) -> &'static str {
        match self {
            Scheduling::Fifo => "fifo",
            Scheduling::Edf => "edf",
        }
    }
}

/// Hedged-dispatch shape for a scenario run.
#[derive(Debug, Clone, Copy)]
pub struct HedgeShape {
    /// Hedge error bar (seconds); 0 disables hedging.
    pub margin_s: f64,
    /// Wasted-work budget handed to [`HedgeBudget`] (fraction in
    /// `(0, 1)`); 0 runs the fixed margin with no controller.
    pub waste_budget: f64,
    /// Scale each class's bar by its `hedge_scale` (class-aware
    /// hedging) instead of one global bar.
    pub class_aware: bool,
}

/// A declarative scenario: workload shape, service classes, scheduling
/// discipline, hedging, and the drift/fault timeline — everything one
/// `cnmt experiment scenario` cell needs, loadable from JSON like
/// [`Topology::load`].
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Scenario label — report key.
    pub name: String,
    /// Topology preset name ([`Topology::preset`]).
    pub topology: String,
    /// Master seed of the synthetic workload.
    pub seed: u64,
    /// Requests offered over the run.
    pub requests: usize,
    /// Time-varying offered load.
    pub load: LoadShape,
    /// Service classes; shares sum to 1.
    pub classes: Vec<ClassSpec>,
    /// Dispatch ordering discipline.
    pub scheduling: Scheduling,
    /// Hedged dispatch (None = never hedge).
    pub hedge: Option<HedgeShape>,
    /// Concurrent drifts, each scoped by tier or pinned lane.
    pub drifts: Vec<DriftSpec>,
    /// Fault timeline (slow/link only; non-overlapping per lane).
    pub faults: Vec<FaultSpec>,
    /// Feed observed batch-cost ratios back into the expected-wait
    /// estimate ([`crate::scheduler::CapacityTracker`] batch-aware
    /// mode).
    pub batch_aware_wait: bool,
}

/// Reject any key of `j` outside `allowed` — the fail-closed loader
/// discipline ([`crate::obs::event`]'s `check_keys`, applied to specs).
fn check_spec_keys(j: &Json, what: &str, allowed: &[&str]) -> Result<()> {
    for k in j.as_object()?.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(Error::Config(format!(
                "scenario {what}: unknown key `{k}`"
            )));
        }
    }
    Ok(())
}

impl ScenarioSpec {
    /// Parse a scenario from its JSON spec. Fails closed: unknown keys
    /// at the root or in any sub-object, crash faults, overlapping
    /// same-lane fault windows, and malformed class shares are all
    /// errors.
    pub fn from_json(j: &Json) -> Result<ScenarioSpec> {
        check_spec_keys(
            j,
            "spec",
            &[
                "name", "topology", "seed", "requests", "load", "classes", "scheduling",
                "hedge", "drifts", "faults", "batch_aware_wait",
            ],
        )?;
        let load_j = j.get("load")?;
        check_spec_keys(load_j, "load", &["base_rps", "period_s", "amplitude", "spikes"])?;
        let mut spikes = Vec::new();
        if let Some(arr) = load_j.get_opt("spikes")? {
            for s in arr.as_array()? {
                check_spec_keys(s, "spike", &["start_s", "duration_s", "factor"])?;
                spikes.push(Spike {
                    start_s: s.get("start_s")?.as_f64()?,
                    duration_s: s.get("duration_s")?.as_f64()?,
                    factor: s.get("factor")?.as_f64()?,
                });
            }
        }
        let load = LoadShape {
            base_rps: load_j.get("base_rps")?.as_f64()?,
            period_s: match load_j.get_opt("period_s")? {
                Some(p) => p.as_f64()?,
                None => 60.0,
            },
            amplitude: match load_j.get_opt("amplitude")? {
                Some(a) => a.as_f64()?,
                None => 0.0,
            },
            spikes,
        };
        let mut classes = Vec::new();
        for c in j.get("classes")?.as_array()? {
            check_spec_keys(
                c,
                "class",
                &["name", "deadline_s", "share", "weight", "quota", "hedge_scale"],
            )?;
            classes.push(ClassSpec {
                name: c.get("name")?.as_str()?.to_string(),
                deadline_s: c.get("deadline_s")?.as_f64()?,
                share: c.get("share")?.as_f64()?,
                weight: match c.get_opt("weight")? {
                    Some(w) => w.as_f64()?,
                    None => 1.0,
                },
                quota: c.get("quota")?.as_usize()?,
                hedge_scale: match c.get_opt("hedge_scale")? {
                    Some(h) => h.as_f64()?,
                    None => 1.0,
                },
            });
        }
        let scheduling = match j.get("scheduling")?.as_str()? {
            "fifo" => Scheduling::Fifo,
            "edf" => Scheduling::Edf,
            other => {
                return Err(Error::Config(format!(
                    "scenario scheduling `{other}` is not fifo|edf"
                )))
            }
        };
        let hedge = match j.get_opt("hedge")? {
            Some(Json::Null) | None => None,
            Some(h) => {
                check_spec_keys(h, "hedge", &["margin_s", "waste_budget", "class_aware"])?;
                Some(HedgeShape {
                    margin_s: h.get("margin_s")?.as_f64()?,
                    waste_budget: match h.get_opt("waste_budget")? {
                        Some(b) => b.as_f64()?,
                        None => 0.0,
                    },
                    class_aware: match h.get_opt("class_aware")? {
                        Some(c) => c.as_bool()?,
                        None => false,
                    },
                })
            }
        };
        let mut drifts = Vec::new();
        if let Some(arr) = j.get_opt("drifts")? {
            for d in arr.as_array()? {
                // DriftSpec::from_json is lenient about extras; the
                // scenario loader is not.
                check_spec_keys(d, "drift", &["device", "lane", "start_s", "ramp_s", "factor"])?;
                drifts.push(DriftSpec::from_json(d)?);
            }
        }
        let mut faults = Vec::new();
        if let Some(arr) = j.get_opt("faults")? {
            for f in arr.as_array()? {
                check_spec_keys(f, "fault", &["lane", "mode", "start_s", "recover_s", "factor"])?;
                faults.push(FaultSpec::from_json(f)?);
            }
        }
        let spec = ScenarioSpec {
            name: j.get("name")?.as_str()?.to_string(),
            topology: j.get("topology")?.as_str()?.to_string(),
            seed: j.get("seed")?.as_i64()? as u64,
            requests: j.get("requests")?.as_usize()?,
            load,
            classes,
            scheduling,
            hedge,
            drifts,
            faults,
            batch_aware_wait: match j.get_opt("batch_aware_wait")? {
                Some(b) => b.as_bool()?,
                None => false,
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Load a scenario spec from a JSON file.
    pub fn load(path: &Path) -> Result<ScenarioSpec> {
        ScenarioSpec::from_json(&Json::parse_file(path)?)
    }

    /// Serialise for reports / spec round-trips.
    pub fn to_json(&self) -> Json {
        let mut load = Json::object();
        load.set("base_rps", Json::Num(self.load.base_rps))
            .set("period_s", Json::Num(self.load.period_s))
            .set("amplitude", Json::Num(self.load.amplitude))
            .set(
                "spikes",
                Json::Array(
                    self.load
                        .spikes
                        .iter()
                        .map(|s| {
                            let mut o = Json::object();
                            o.set("start_s", Json::Num(s.start_s))
                                .set("duration_s", Json::Num(s.duration_s))
                                .set("factor", Json::Num(s.factor));
                            o
                        })
                        .collect(),
                ),
            );
        let classes = Json::Array(
            self.classes
                .iter()
                .map(|c| {
                    let mut o = Json::object();
                    o.set("name", Json::Str(c.name.clone()))
                        .set("deadline_s", Json::Num(c.deadline_s))
                        .set("share", Json::Num(c.share))
                        .set("weight", Json::Num(c.weight))
                        .set("quota", Json::Num(c.quota as f64))
                        .set("hedge_scale", Json::Num(c.hedge_scale));
                    o
                })
                .collect(),
        );
        let mut o = Json::object();
        o.set("name", Json::Str(self.name.clone()))
            .set("topology", Json::Str(self.topology.clone()))
            .set("seed", Json::Num(self.seed as f64))
            .set("requests", Json::Num(self.requests as f64))
            .set("load", load)
            .set("classes", classes)
            .set("scheduling", Json::Str(self.scheduling.tag().to_string()));
        if let Some(h) = &self.hedge {
            let mut hj = Json::object();
            hj.set("margin_s", Json::Num(h.margin_s))
                .set("waste_budget", Json::Num(h.waste_budget))
                .set("class_aware", Json::Bool(h.class_aware));
            o.set("hedge", hj);
        }
        o.set(
            "drifts",
            Json::Array(self.drifts.iter().map(|d| d.to_json()).collect()),
        )
        .set(
            "faults",
            Json::Array(self.faults.iter().map(|f| f.to_json()).collect()),
        )
        .set("batch_aware_wait", Json::Bool(self.batch_aware_wait));
        o
    }

    /// Structural validation (everything not needing the topology).
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            return Err(Error::Config("scenario needs a non-empty name".into()));
        }
        if self.requests == 0 {
            return Err(Error::Config("scenario needs requests > 0".into()));
        }
        self.load.validate()?;
        if self.classes.is_empty() {
            return Err(Error::Config("scenario needs at least one class".into()));
        }
        let mut share_sum = 0.0f64;
        for (i, c) in self.classes.iter().enumerate() {
            if c.name.is_empty() {
                return Err(Error::Config(format!("scenario class {i}: empty name")));
            }
            if self.classes.iter().take(i).any(|o| o.name == c.name) {
                return Err(Error::Config(format!(
                    "scenario class `{}` appears twice",
                    c.name
                )));
            }
            if !(c.deadline_s.is_finite() && c.deadline_s > 0.0) {
                return Err(Error::Config(format!(
                    "scenario class `{}`: deadline_s {} must be finite and > 0",
                    c.name, c.deadline_s
                )));
            }
            if !(c.share.is_finite() && c.share > 0.0) {
                return Err(Error::Config(format!(
                    "scenario class `{}`: share {} must be finite and > 0",
                    c.name, c.share
                )));
            }
            if !(c.weight.is_finite() && c.weight > 0.0) {
                return Err(Error::Config(format!(
                    "scenario class `{}`: weight {} must be finite and > 0",
                    c.name, c.weight
                )));
            }
            if c.quota == 0 {
                return Err(Error::Config(format!(
                    "scenario class `{}`: quota must be >= 1",
                    c.name
                )));
            }
            if !(c.hedge_scale.is_finite() && c.hedge_scale >= 0.0) {
                return Err(Error::Config(format!(
                    "scenario class `{}`: hedge_scale {} must be finite and >= 0",
                    c.name, c.hedge_scale
                )));
            }
            share_sum += c.share;
        }
        if (share_sum - 1.0).abs() > 1e-9 {
            return Err(Error::Config(format!(
                "scenario class shares sum to {share_sum}, need 1"
            )));
        }
        if let Some(h) = &self.hedge {
            if !(h.margin_s.is_finite() && h.margin_s >= 0.0) {
                return Err(Error::Config(format!(
                    "scenario hedge: margin_s {} must be finite and >= 0",
                    h.margin_s
                )));
            }
            if !(h.waste_budget >= 0.0 && h.waste_budget < 1.0) {
                return Err(Error::Config(format!(
                    "scenario hedge: waste_budget {} must be in [0, 1)",
                    h.waste_budget
                )));
            }
        }
        for f in &self.faults {
            f.validate()?;
            if matches!(f.mode, FaultMode::Crash) {
                return Err(Error::Config(
                    "scenario faults compose slow|link only (crash + failover \
                     lives in `cnmt experiment outage`)"
                        .into(),
                ));
            }
        }
        for (i, a) in self.faults.iter().enumerate() {
            for b in self.faults.iter().skip(i + 1) {
                if a.lane == b.lane && a.start_s < b.recover_s && b.start_s < a.recover_s {
                    return Err(Error::Config(format!(
                        "scenario faults on lane {} overlap: [{}, {}) and [{}, {})",
                        a.lane, a.start_s, a.recover_s, b.start_s, b.recover_s
                    )));
                }
            }
        }
        Ok(())
    }

    /// Validate against the topology the scenario will run over.
    pub fn validate_for(&self, topo: &Topology) -> Result<()> {
        self.validate()?;
        for f in &self.faults {
            f.validate_for(topo)?;
        }
        for (i, d) in self.drifts.iter().enumerate() {
            if let Some(lane) = d.lane {
                if lane >= topo.len() {
                    return Err(Error::Config(format!(
                        "scenario drift {i}: lane {lane} out of range for topology {} \
                         ({} devices)",
                        topo.name,
                        topo.len()
                    )));
                }
            }
        }
        Ok(())
    }

    /// Resolve the spec's topology preset.
    pub fn topology(&self) -> Result<Topology> {
        Topology::preset(&self.topology)
    }
}

/// Deterministic share-tracking class assignment: request `i` joins the
/// class with the largest share deficit `share·(i+1) − assigned`
/// (lowest index on ties), so every prefix of the stream matches the
/// share vector to within one request — and the Python mirror can
/// replay the exact sequence with the same integer arithmetic.
#[derive(Debug, Clone)]
pub struct ClassAssigner {
    shares: Vec<f64>,
    assigned: Vec<u64>,
    seen: u64,
}

impl ClassAssigner {
    /// Build the assigner from the spec's class shares.
    pub fn new(classes: &[ClassSpec]) -> ClassAssigner {
        ClassAssigner {
            shares: classes.iter().map(|c| c.share).collect(),
            assigned: vec![0; classes.len()],
            seen: 0,
        }
    }

    /// The class of the next request.
    pub fn next(&mut self) -> usize {
        let target = (self.seen + 1) as f64;
        let mut best = 0usize;
        let mut best_deficit = self.shares[0] * target - self.assigned[0] as f64;
        for k in 1..self.shares.len() {
            let deficit = self.shares[k] * target - self.assigned[k] as f64;
            if deficit > best_deficit {
                best = k;
                best_deficit = deficit;
            }
        }
        self.assigned[best] += 1;
        self.seen += 1;
        best
    }
}

// ---------------------------------------------------------------- facade

/// How the workload drives the harness.
#[derive(Debug, Clone, Copy)]
pub enum ScenarioMode {
    /// Open-loop: requests arrive at their trace timestamps.
    Open,
    /// Closed-loop: `clients` bounded-outstanding clients with
    /// `think_s` seconds of think time.
    Closed {
        /// Concurrent clients.
        clients: usize,
        /// Think time between a result and the next submission (s).
        think_s: f64,
    },
}

/// What the workload runs against.
#[derive(Clone, Copy)]
pub enum ScenarioScope<'a> {
    /// The classic edge/cloud pair under one routing policy.
    Pair {
        /// Routing policy.
        policy: PolicyKind,
        /// Pair harness options.
        opts: &'a ContentionOpts,
    },
    /// An N-device fleet topology.
    Fleet {
        /// The fleet shape.
        topo: &'a Topology,
        /// Fleet harness options.
        opts: &'a FleetOpts,
    },
}

/// Failure-injection machinery attached to a fleet run.
#[derive(Clone, Copy)]
pub enum ScenarioOutage<'a> {
    /// No outage machinery.
    Off,
    /// One injected fault with retry/failover handling.
    Failover {
        /// The injected fault.
        fault: &'a FaultSpec,
        /// Timeout/backoff/budget policy.
        retry: &'a RetryPolicy,
        /// Health-tracking failover on, or the health-blind baseline.
        failover: bool,
    },
    /// Failover armed plus an online anomaly detector
    /// (observation-only).
    Detect {
        /// The injected fault (None = fault-free twin).
        fault: Option<&'a FaultSpec>,
        /// Timeout/backoff/budget policy.
        retry: &'a RetryPolicy,
    },
}

/// One harness configuration for [`run_scenario`] — the product every
/// legacy `run_*` signature is a point of.
pub struct RunSpec<'a> {
    /// Pair or fleet scope.
    pub scope: ScenarioScope<'a>,
    /// Open- or closed-loop drive.
    pub mode: ScenarioMode,
    /// Outage machinery (fleet only).
    pub outage: ScenarioOutage<'a>,
    /// Declarative scenario overlay (fleet + open + pool only).
    pub scenario: Option<&'a ScenarioSpec>,
    /// Decision-log flight recorder to attach.
    pub rec: Option<FlightRecorder>,
    /// Online anomaly detector (detect outage mode only).
    pub det: Option<Detector>,
}

impl<'a> RunSpec<'a> {
    fn base(scope: ScenarioScope<'a>) -> RunSpec<'a> {
        RunSpec {
            scope,
            mode: ScenarioMode::Open,
            outage: ScenarioOutage::Off,
            scenario: None,
            rec: None,
            det: None,
        }
    }

    /// Open-loop pair replay ([`super::harness::run_contended`]).
    pub fn contended(policy: PolicyKind, opts: &'a ContentionOpts) -> RunSpec<'a> {
        RunSpec::base(ScenarioScope::Pair { policy, opts })
    }

    /// Traced open-loop pair replay.
    pub fn contended_traced(
        policy: PolicyKind,
        opts: &'a ContentionOpts,
        rec: FlightRecorder,
    ) -> RunSpec<'a> {
        RunSpec { rec: Some(rec), ..RunSpec::base(ScenarioScope::Pair { policy, opts }) }
    }

    /// Closed-loop pair run ([`super::harness::run_closed_loop`]).
    pub fn closed_loop(
        policy: PolicyKind,
        opts: &'a ContentionOpts,
        clients: usize,
        think_s: f64,
    ) -> RunSpec<'a> {
        RunSpec {
            mode: ScenarioMode::Closed { clients, think_s },
            ..RunSpec::base(ScenarioScope::Pair { policy, opts })
        }
    }

    /// Open-loop fleet replay ([`super::harness::run_fleet`]).
    pub fn fleet(topo: &'a Topology, opts: &'a FleetOpts) -> RunSpec<'a> {
        RunSpec::base(ScenarioScope::Fleet { topo, opts })
    }

    /// Closed-loop fleet run ([`super::harness::run_fleet_closed`]).
    pub fn fleet_closed(
        topo: &'a Topology,
        opts: &'a FleetOpts,
        clients: usize,
        think_s: f64,
    ) -> RunSpec<'a> {
        RunSpec {
            mode: ScenarioMode::Closed { clients, think_s },
            ..RunSpec::base(ScenarioScope::Fleet { topo, opts })
        }
    }

    /// Outage replay ([`super::harness::run_fleet_outage`]).
    pub fn fleet_outage(
        topo: &'a Topology,
        opts: &'a FleetOpts,
        fault: &'a FaultSpec,
        retry: &'a RetryPolicy,
        failover: bool,
    ) -> RunSpec<'a> {
        RunSpec {
            outage: ScenarioOutage::Failover { fault, retry, failover },
            ..RunSpec::base(ScenarioScope::Fleet { topo, opts })
        }
    }

    /// Traced outage replay.
    #[allow(clippy::too_many_arguments)]
    pub fn fleet_outage_traced(
        topo: &'a Topology,
        opts: &'a FleetOpts,
        fault: &'a FaultSpec,
        retry: &'a RetryPolicy,
        failover: bool,
        rec: FlightRecorder,
    ) -> RunSpec<'a> {
        RunSpec {
            outage: ScenarioOutage::Failover { fault, retry, failover },
            rec: Some(rec),
            ..RunSpec::base(ScenarioScope::Fleet { topo, opts })
        }
    }

    /// Detection replay ([`super::harness::run_fleet_outage_detect`]).
    pub fn fleet_outage_detect(
        topo: &'a Topology,
        opts: &'a FleetOpts,
        fault: Option<&'a FaultSpec>,
        retry: &'a RetryPolicy,
        det: Detector,
        rec: Option<FlightRecorder>,
    ) -> RunSpec<'a> {
        RunSpec {
            outage: ScenarioOutage::Detect { fault, retry },
            rec,
            det: Some(det),
            ..RunSpec::base(ScenarioScope::Fleet { topo, opts })
        }
    }

    /// Declarative scenario run (the engine).
    pub fn scenario(
        topo: &'a Topology,
        opts: &'a FleetOpts,
        spec: &'a ScenarioSpec,
        rec: Option<FlightRecorder>,
    ) -> RunSpec<'a> {
        RunSpec {
            scenario: Some(spec),
            rec,
            ..RunSpec::base(ScenarioScope::Fleet { topo, opts })
        }
    }
}

/// The never-yielding stream type pool-sourced runs pin the facade's
/// iterator parameter to.
pub type EmptyStream = std::iter::Empty<Result<RequestTruth>>;

/// Where the workload comes from: a materialised pool or a lazy stream.
pub enum ScenarioSource<'a, I = EmptyStream>
where
    I: Iterator<Item = Result<RequestTruth>>,
{
    /// A materialised, arrival-sorted pool.
    Pool(&'a [RequestTruth]),
    /// A lazy arrival/body stream (O(outstanding) memory).
    Stream(I),
}

impl<'a> ScenarioSource<'a, EmptyStream> {
    /// A pool source (pins the stream parameter so callers need no
    /// turbofish).
    pub fn pool(requests: &'a [RequestTruth]) -> ScenarioSource<'a, EmptyStream> {
        ScenarioSource::Pool(requests)
    }
}

impl<I> ScenarioSource<'static, I>
where
    I: Iterator<Item = Result<RequestTruth>>,
{
    /// A stream source.
    pub fn stream(arrivals: I) -> ScenarioSource<'static, I> {
        ScenarioSource::Stream(arrivals)
    }
}

/// What [`run_scenario`] returns — one variant per result shape.
#[derive(Debug)]
pub enum ScenarioOutcome {
    /// Pair result ([`ContendedResult`]).
    Contended(ContendedResult),
    /// Pair result plus the round-tripped recorder.
    ContendedTraced(ContendedResult, FlightRecorder),
    /// Fleet result ([`FleetResult`]).
    Fleet(FleetResult),
    /// Outage result ([`OutageResult`]).
    Outage(OutageResult),
    /// Outage result plus the round-tripped recorder.
    OutageTraced(OutageResult, FlightRecorder),
    /// Detection output plus the recorder, when one was attached.
    Detect(DetectRunOut, Option<FlightRecorder>),
    /// Scenario-engine result plus the recorder, when one was attached.
    Scenario(ScenarioResult, Option<FlightRecorder>),
}

impl ScenarioOutcome {
    /// Unwrap a [`ScenarioOutcome::Contended`].
    pub fn expect_contended(self) -> ContendedResult {
        match self {
            ScenarioOutcome::Contended(r) => r,
            _ => panic!("run_scenario returned a non-contended outcome"),
        }
    }

    /// Unwrap a [`ScenarioOutcome::ContendedTraced`].
    pub fn expect_contended_traced(self) -> (ContendedResult, FlightRecorder) {
        match self {
            ScenarioOutcome::ContendedTraced(r, rec) => (r, rec),
            _ => panic!("run_scenario returned a non-traced-contended outcome"),
        }
    }

    /// Unwrap a [`ScenarioOutcome::Fleet`].
    pub fn expect_fleet(self) -> FleetResult {
        match self {
            ScenarioOutcome::Fleet(r) => r,
            _ => panic!("run_scenario returned a non-fleet outcome"),
        }
    }

    /// Unwrap a [`ScenarioOutcome::Outage`].
    pub fn expect_outage(self) -> OutageResult {
        match self {
            ScenarioOutcome::Outage(r) => r,
            _ => panic!("run_scenario returned a non-outage outcome"),
        }
    }

    /// Unwrap a [`ScenarioOutcome::OutageTraced`].
    pub fn expect_outage_traced(self) -> (OutageResult, FlightRecorder) {
        match self {
            ScenarioOutcome::OutageTraced(r, rec) => (r, rec),
            _ => panic!("run_scenario returned a non-traced-outage outcome"),
        }
    }

    /// Unwrap a [`ScenarioOutcome::Detect`].
    pub fn expect_detect(self) -> (DetectRunOut, Option<FlightRecorder>) {
        match self {
            ScenarioOutcome::Detect(out, rec) => (out, rec),
            _ => panic!("run_scenario returned a non-detect outcome"),
        }
    }

    /// Unwrap a [`ScenarioOutcome::Scenario`].
    pub fn expect_scenario(self) -> (ScenarioResult, Option<FlightRecorder>) {
        match self {
            ScenarioOutcome::Scenario(r, rec) => (r, rec),
            _ => panic!("run_scenario returned a non-scenario outcome"),
        }
    }
}

/// The unified harness entry point: dispatch one [`RunSpec`] over one
/// workload source to the core implementation it names. Every public
/// `run_*` wrapper in [`super::harness`] routes through here and is
/// bit-identical to the pre-collapse signature (the differential tests
/// below prove it per wrapper). Invalid combinations — outage machinery
/// on the pair, a recorder on a closed loop, a scenario overlay
/// anywhere but an open-loop fleet pool — fail closed with a config
/// error.
pub fn run_scenario<'a, I>(
    source: ScenarioSource<'a, I>,
    ch: &Characterization,
    spec: RunSpec<'_>,
) -> Result<ScenarioOutcome>
where
    I: Iterator<Item = Result<RequestTruth>>,
{
    let RunSpec { scope, mode, outage, scenario, rec, det } = spec;
    if let Some(sc) = scenario {
        let ScenarioScope::Fleet { topo, opts } = scope else {
            return Err(Error::Config("a scenario spec needs a fleet scope".into()));
        };
        if !matches!(mode, ScenarioMode::Open) {
            return Err(Error::Config("scenario replay is open-loop".into()));
        }
        if !matches!(outage, ScenarioOutage::Off) {
            return Err(Error::Config(
                "scenario replay carries its own fault timeline; outage \
                 machinery does not compose"
                    .into(),
            ));
        }
        if det.is_some() {
            return Err(Error::Config(
                "scenario replay does not take a detector".into(),
            ));
        }
        let ScenarioSource::Pool(requests) = source else {
            return Err(Error::Config(
                "scenario replay needs a materialised pool".into(),
            ));
        };
        let (result, rec) = run_scenario_engine(requests, ch, topo, opts, sc, rec)?;
        return Ok(ScenarioOutcome::Scenario(result, rec));
    }
    match scope {
        ScenarioScope::Pair { policy, opts } => {
            if !matches!(outage, ScenarioOutage::Off) {
                return Err(Error::Config(
                    "outage injection needs a fleet scope".into(),
                ));
            }
            if det.is_some() {
                return Err(Error::Config(
                    "a detector needs the detect outage mode".into(),
                ));
            }
            match (mode, source) {
                (ScenarioMode::Open, ScenarioSource::Pool(requests)) => {
                    let traced = rec.is_some();
                    let (r, rec) = run_contended_impl(requests, ch, policy, opts, rec)?;
                    Ok(if traced {
                        ScenarioOutcome::ContendedTraced(
                            r,
                            rec.expect("recorder was attached"),
                        )
                    } else {
                        ScenarioOutcome::Contended(r)
                    })
                }
                (ScenarioMode::Open, ScenarioSource::Stream(arrivals)) => {
                    let traced = rec.is_some();
                    let (r, rec) =
                        run_contended_streamed_impl(arrivals, ch, policy, opts, rec)?;
                    Ok(if traced {
                        ScenarioOutcome::ContendedTraced(
                            r,
                            rec.expect("recorder was attached"),
                        )
                    } else {
                        ScenarioOutcome::Contended(r)
                    })
                }
                (ScenarioMode::Closed { clients, think_s }, ScenarioSource::Pool(pool)) => {
                    if rec.is_some() {
                        return Err(Error::Config(
                            "closed-loop runs do not take a flight recorder".into(),
                        ));
                    }
                    Ok(ScenarioOutcome::Contended(run_closed_loop_core(
                        pool, ch, policy, opts, clients, think_s,
                    )?))
                }
                (ScenarioMode::Closed { clients, think_s }, ScenarioSource::Stream(bodies)) => {
                    if rec.is_some() {
                        return Err(Error::Config(
                            "closed-loop runs do not take a flight recorder".into(),
                        ));
                    }
                    Ok(ScenarioOutcome::Contended(run_closed_loop_streamed_core(
                        bodies, ch, policy, opts, clients, think_s,
                    )?))
                }
            }
        }
        ScenarioScope::Fleet { topo, opts } => match outage {
            ScenarioOutage::Off => {
                if det.is_some() {
                    return Err(Error::Config(
                        "a detector needs the detect outage mode".into(),
                    ));
                }
                if rec.is_some() {
                    return Err(Error::Config(
                        "plain fleet runs do not take a flight recorder (use the \
                         outage or scenario entry points)"
                            .into(),
                    ));
                }
                match (mode, source) {
                    (ScenarioMode::Open, ScenarioSource::Pool(requests)) => Ok(
                        ScenarioOutcome::Fleet(run_fleet_core(requests, ch, topo, opts)?),
                    ),
                    (ScenarioMode::Open, ScenarioSource::Stream(arrivals)) => {
                        Ok(ScenarioOutcome::Fleet(run_fleet_streamed_core(
                            arrivals, ch, topo, opts,
                        )?))
                    }
                    (
                        ScenarioMode::Closed { clients, think_s },
                        ScenarioSource::Pool(pool),
                    ) => Ok(ScenarioOutcome::Fleet(run_fleet_closed_core(
                        pool, ch, topo, opts, clients, think_s,
                    )?)),
                    (
                        ScenarioMode::Closed { clients, think_s },
                        ScenarioSource::Stream(bodies),
                    ) => Ok(ScenarioOutcome::Fleet(run_fleet_closed_streamed_core(
                        bodies, ch, topo, opts, clients, think_s,
                    )?)),
                }
            }
            ScenarioOutage::Failover { fault, retry, failover } => {
                if det.is_some() {
                    return Err(Error::Config(
                        "a detector needs the detect outage mode".into(),
                    ));
                }
                match (mode, source) {
                    (ScenarioMode::Open, ScenarioSource::Pool(requests)) => {
                        let traced = rec.is_some();
                        let (r, rec, _det) = run_fleet_outage_impl(
                            requests, ch, topo, opts, fault, retry, failover, rec, None,
                            None,
                        )?;
                        Ok(if traced {
                            ScenarioOutcome::OutageTraced(
                                r,
                                rec.expect("recorder round-trips through the dispatcher"),
                            )
                        } else {
                            ScenarioOutcome::Outage(r)
                        })
                    }
                    _ => Err(Error::Config(
                        "outage replay is open-loop over a materialised pool".into(),
                    )),
                }
            }
            ScenarioOutage::Detect { fault, retry } => {
                let Some(det) = det else {
                    return Err(Error::Config(
                        "the detect outage mode needs a detector".into(),
                    ));
                };
                match (mode, source) {
                    (ScenarioMode::Open, ScenarioSource::Pool(requests)) => {
                        let (out, rec) = run_fleet_outage_detect_core(
                            requests, ch, topo, opts, fault, retry, det, rec,
                        )?;
                        Ok(ScenarioOutcome::Detect(out, rec))
                    }
                    _ => Err(Error::Config(
                        "detection replay is open-loop over a materialised pool".into(),
                    )),
                }
            }
        },
    }
}

// ---------------------------------------------------------------- engine

/// True execution seconds of one request copy on scenario device
/// `lane` for a batch starting at `start_s`: the device's tier time ×
/// its slowdown × every applicable drift factor × every slow-fault
/// factor — [`super::harness`]'s fleet charging generalised to
/// concurrent drifts and a fault timeline.
fn scenario_true_service_s(
    truth: &RequestTruth,
    tier: &[DeviceKind],
    slowdown: &[f64],
    lane: usize,
    start_s: f64,
    drifts: &[DriftSpec],
    faults: &[FaultSpec],
) -> f64 {
    let base = match tier[lane] {
        DeviceKind::Edge => truth.t_edge,
        DeviceKind::Cloud => truth.t_cloud,
    };
    let mut t = base * slowdown[lane];
    for d in drifts {
        if d.applies_to(tier[lane], lane) {
            t *= d.factor_at(start_s);
        }
    }
    for f in faults {
        t *= f.exec_factor_at(lane, start_s);
    }
    t
}

/// The scenario ground-truth executor: fleet batching semantics
/// (critical path + residual serial cost) over the scenario charging.
struct ScenarioExecutor<'a> {
    requests: &'a [RequestTruth],
    tier: &'a [DeviceKind],
    slowdown: &'a [f64],
    residual: f64,
    drifts: &'a [DriftSpec],
    faults: &'a [FaultSpec],
}

impl LaneExecutor for ScenarioExecutor<'_> {
    fn execute_lane(
        &mut self,
        lane: usize,
        _device: DeviceKind,
        batch: &[QueuedRequest],
        start_s: f64,
    ) -> f64 {
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        for rq in batch {
            let truth = &self.requests[rq.payload];
            let t = scenario_true_service_s(
                truth,
                self.tier,
                self.slowdown,
                lane,
                start_s,
                self.drifts,
                self.faults,
            );
            max = max.max(t);
            sum += t;
        }
        max + (sum - max) * self.residual
    }
}

/// Per-class outcome of one scenario run: the SLO ledger.
#[derive(Debug, Clone)]
pub struct ClassOutcome {
    /// Class label.
    pub name: String,
    /// The class's latency SLO (seconds).
    pub deadline_s: f64,
    /// Requests assigned to the class.
    pub offered: usize,
    /// Requests shed at admission.
    pub shed: usize,
    /// Requests that got a result.
    pub completed: usize,
    /// Completions within the SLO.
    pub within_deadline: usize,
    /// Requests duplicated on two lanes.
    pub hedged: usize,
    /// Mean end-to-end latency of completions (seconds).
    pub mean_latency_s: f64,
    /// Median latency (seconds).
    pub p50_s: f64,
    /// 95th-percentile latency (seconds).
    pub p95_s: f64,
    /// 99th-percentile latency (seconds).
    pub p99_s: f64,
    /// Latency phase decomposition of the class's completions.
    pub phases: Phases,
}

impl ClassOutcome {
    /// SLO attainment on the **offered** basis: shed requests count as
    /// misses, so shedding a class cannot inflate its attainment.
    pub fn attainment(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.within_deadline as f64 / self.offered as f64
        }
    }

    /// Serialise for the scenario report.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("name", Json::Str(self.name.clone()))
            .set("deadline_s", Json::Num(self.deadline_s))
            .set("offered", Json::Num(self.offered as f64))
            .set("shed", Json::Num(self.shed as f64))
            .set("completed", Json::Num(self.completed as f64))
            .set("within_deadline", Json::Num(self.within_deadline as f64))
            .set("attainment", Json::Num(self.attainment()))
            .set("hedged", Json::Num(self.hedged as f64))
            .set("mean_latency_s", Json::Num(self.mean_latency_s))
            .set("p50_s", Json::Num(self.p50_s))
            .set("p95_s", Json::Num(self.p95_s))
            .set("p99_s", Json::Num(self.p99_s))
            .set("phases", self.phases.to_json());
        o
    }
}

/// Aggregated result of one scenario replay: the classic fleet
/// aggregates plus the per-class SLO ledger.
#[derive(Debug, Clone)]
pub struct ScenarioResult {
    /// Scenario label (spec name).
    pub scenario: String,
    /// Scheduling discipline label (`fifo` | `edf`).
    pub scheduling: String,
    /// Logical requests offered.
    pub offered: usize,
    /// Logical requests that got a result.
    pub completed: usize,
    /// Requests shed at admission.
    pub rejected: usize,
    /// Results served by the edge tier.
    pub edge_count: usize,
    /// Results served by the cloud tier.
    pub cloud_count: usize,
    /// Clock time from first arrival to last response (seconds).
    pub makespan_s: f64,
    /// Completed requests per second of makespan (goodput).
    pub throughput_rps: f64,
    /// Mean end-to-end latency of completed requests (seconds).
    pub mean_latency_s: f64,
    /// Median latency (seconds).
    pub p50_s: f64,
    /// 95th-percentile latency (seconds).
    pub p95_s: f64,
    /// 99th-percentile latency (seconds).
    pub p99_s: f64,
    /// Mean micro-batch size actually dispatched.
    pub mean_batch: f64,
    /// Requests duplicated on two lanes (both copies admitted).
    pub hedged: usize,
    /// Hedged requests won by an edge-tier copy.
    pub hedge_wins_edge: usize,
    /// Hedged requests won by a cloud-tier copy.
    pub hedge_wins_cloud: usize,
    /// Losing twins cancelled while still queued.
    pub hedge_cancelled: usize,
    /// Losing twins that ran to completion (wasted work).
    pub hedge_wasted: usize,
    /// Serial work content of result-producing executions (seconds).
    pub useful_work_s: f64,
    /// Serial work content burnt by hedge losers that ran anyway.
    pub wasted_work_s: f64,
    /// Final hedge error bar of the waste-budget controller (seconds);
    /// NaN when the run used a fixed margin or never hedged.
    pub hedge_final_margin_s: f64,
    /// Results served per device, indexed by device id.
    pub device_results: Vec<usize>,
    /// Per-device queue-depth high-water marks, indexed by device id.
    pub peak_depths: Vec<usize>,
    /// Per-class SLO ledger, in spec class order.
    pub classes: Vec<ClassOutcome>,
}

impl ScenarioResult {
    /// Serialise for the scenario report (superset of the fleet row
    /// schema, plus the per-class ledger).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("scenario", Json::Str(self.scenario.clone()))
            .set("scheduling", Json::Str(self.scheduling.clone()))
            .set("offered", Json::Num(self.offered as f64))
            .set("completed", Json::Num(self.completed as f64))
            .set("rejected", Json::Num(self.rejected as f64))
            .set("edge_count", Json::Num(self.edge_count as f64))
            .set("cloud_count", Json::Num(self.cloud_count as f64))
            .set("makespan_s", Json::Num(self.makespan_s))
            .set("throughput_rps", Json::Num(self.throughput_rps))
            .set("mean_latency_s", Json::Num(self.mean_latency_s))
            .set("p50_s", Json::Num(self.p50_s))
            .set("p95_s", Json::Num(self.p95_s))
            .set("p99_s", Json::Num(self.p99_s))
            .set("mean_batch", Json::Num(self.mean_batch))
            .set("hedged", Json::Num(self.hedged as f64))
            .set("hedge_wins_edge", Json::Num(self.hedge_wins_edge as f64))
            .set("hedge_wins_cloud", Json::Num(self.hedge_wins_cloud as f64))
            .set("hedge_cancelled", Json::Num(self.hedge_cancelled as f64))
            .set("hedge_wasted", Json::Num(self.hedge_wasted as f64))
            .set("useful_work_s", Json::Num(self.useful_work_s))
            .set("wasted_work_s", Json::Num(self.wasted_work_s))
            .set(
                "device_results",
                Json::Array(
                    self.device_results.iter().map(|&c| Json::Num(c as f64)).collect(),
                ),
            )
            .set(
                "peak_depths",
                Json::Array(self.peak_depths.iter().map(|&d| Json::Num(d as f64)).collect()),
            );
        if self.hedge_final_margin_s.is_finite() {
            o.set("hedge_final_margin_s", Json::Num(self.hedge_final_margin_s));
        }
        o.set(
            "classes",
            Json::Array(self.classes.iter().map(|c| c.to_json()).collect()),
        );
        o
    }
}

/// Scenario-side accounting: the fleet ledger split per class.
struct ScenarioAcct {
    hist: Histogram,
    stats: OnlineStats,
    edge_count: usize,
    cloud_count: usize,
    completed: usize,
    last_done_s: f64,
    useful_work_s: f64,
    wasted_work_s: f64,
    device_results: Vec<usize>,
    class_hist: Vec<Histogram>,
    class_stats: Vec<OnlineStats>,
    class_completed: Vec<usize>,
    class_within: Vec<usize>,
    phases: ClassPhases,
}

impl ScenarioAcct {
    fn new(devices: usize, class_names: &[String]) -> ScenarioAcct {
        let k = class_names.len();
        ScenarioAcct {
            hist: Histogram::latency(),
            stats: OnlineStats::new(),
            edge_count: 0,
            cloud_count: 0,
            completed: 0,
            last_done_s: 0.0,
            useful_work_s: 0.0,
            wasted_work_s: 0.0,
            device_results: vec![0; devices],
            class_hist: (0..k).map(|_| Histogram::latency()).collect(),
            class_stats: (0..k).map(|_| OnlineStats::new()).collect(),
            class_completed: vec![0; k],
            class_within: vec![0; k],
            phases: ClassPhases::new(class_names),
        }
    }

    /// Account a drained batch of completions — the scenario analogue
    /// of the harness accounting (hedge-loss waste, budget-controller
    /// feedback, margin events, phase decomposition), split per class.
    #[allow(clippy::too_many_arguments)]
    fn process(
        &mut self,
        comps: &[Completion],
        requests: &[RequestTruth],
        class_of: &[usize],
        spec: &ScenarioSpec,
        tier: &[DeviceKind],
        slowdown: &[f64],
        link_scale: &[f64],
        ctl: &mut Option<HedgeBudget>,
        mut rec: Option<&mut FlightRecorder>,
    ) {
        for c in comps {
            let truth = &requests[c.request.payload];
            let t_true = scenario_true_service_s(
                truth,
                tier,
                slowdown,
                c.lane,
                c.start_s,
                &spec.drifts,
                &spec.faults,
            );
            let mut tx_s = match tier[c.lane] {
                DeviceKind::Edge => 0.0,
                DeviceKind::Cloud => truth.t_tx * link_scale[c.lane],
            };
            if tier[c.lane] == DeviceKind::Cloud {
                // A response transfers at completion time: it pays the
                // link state the fault timeline says is live *then*.
                for f in &spec.faults {
                    tx_s *= f.link_factor_at(c.lane, c.done_s);
                }
            }
            if let Some(rec) = rec.as_deref_mut() {
                for d in &spec.drifts {
                    if d.applies_to(tier[c.lane], c.lane) {
                        let factor = d.factor_at(c.start_s);
                        if factor != 1.0 {
                            rec.record(
                                c.done_s,
                                ObsEvent::DriftTick { lane: c.lane as u32, factor },
                            );
                        }
                    }
                }
            }
            if c.kind == CompletionKind::HedgeLoss {
                self.wasted_work_s += t_true;
                if let Some(ctl) = ctl.as_mut() {
                    ctl.observe(t_true, true);
                    if let Some(rec) = rec.as_deref_mut() {
                        rec.record(
                            c.done_s,
                            ObsEvent::MarginAdjust {
                                margin_s: ctl.margin_s(),
                                useful_s: ctl.useful_s(),
                                wasted_s: ctl.wasted_s(),
                            },
                        );
                    }
                }
                continue;
            }
            self.useful_work_s += t_true;
            if let Some(ctl) = ctl.as_mut() {
                ctl.observe(t_true, false);
                if let Some(rec) = rec.as_deref_mut() {
                    rec.record(
                        c.done_s,
                        ObsEvent::MarginAdjust {
                            margin_s: ctl.margin_s(),
                            useful_s: ctl.useful_s(),
                            wasted_s: ctl.wasted_s(),
                        },
                    );
                }
            }
            let k = class_of[c.request.payload];
            // The four phases partition the latency below exactly:
            // (start - arrival) + ((done - start) - t_true) + t_true + tx.
            self.phases.record(
                k,
                c.start_s - c.request.arrival_s,
                (c.done_s - c.start_s) - t_true,
                t_true,
                tx_s,
            );
            let latency = (c.done_s - c.request.arrival_s) + tx_s;
            self.hist.record(latency);
            self.stats.push(latency);
            self.class_hist[k].record(latency);
            self.class_stats[k].push(latency);
            self.class_completed[k] += 1;
            if latency <= spec.classes[k].deadline_s {
                self.class_within[k] += 1;
            }
            match tier[c.lane] {
                DeviceKind::Edge => self.edge_count += 1,
                DeviceKind::Cloud => self.cloud_count += 1,
            }
            self.completed += 1;
            self.device_results[c.lane] += 1;
            self.last_done_s = self.last_done_s.max(c.done_s + tx_s);
        }
    }
}

/// Replay `requests` (sorted by arrival) over `topo` under the
/// scenario spec: class tagging, FIFO or EDF-within-quota scheduling,
/// class-aware hedging, multi-drift/multi-fault ground truth. The
/// request stream itself is generated by
/// [`crate::experiments::scenario`] from the spec's [`LoadShape`]; the
/// engine only replays it.
///
/// Hedged copies take an express lane: they race the best edge against
/// the best cloud placement directly in the lane queues, bypassing the
/// EDF front-end in both disciplines (a hedge is already a latency
/// splurge — making it wait in the fair queue would defeat it).
///
/// Per-class conservation is asserted: every class's
/// `offered == shed + completed` (the v1 fault vocabulary — slow and
/// link — cannot strand admitted requests).
pub fn run_scenario_engine(
    requests: &[RequestTruth],
    ch: &Characterization,
    topo: &Topology,
    opts: &FleetOpts,
    spec: &ScenarioSpec,
    rec: Option<FlightRecorder>,
) -> Result<(ScenarioResult, Option<FlightRecorder>)> {
    if !matches!(opts.strategy, FleetStrategy::Select) {
        return Err(Error::Config(
            "scenario replay supports the select strategy only (hedging via \
             the spec's hedge block)"
                .into(),
        ));
    }
    if opts.adaptive.is_some() {
        return Err(Error::Config(
            "scenario replay does not compose with adaptive opts".into(),
        ));
    }
    if opts.drift.is_some() {
        return Err(Error::Config(
            "scenario replay takes drift from the spec's drifts list".into(),
        ));
    }
    if opts.telemetry.is_some() {
        return Err(Error::Config(
            "scenario replay does not compose with telemetry opts".into(),
        ));
    }
    if opts.max_queue_depth == 0 {
        return Err(Error::Config("max_queue_depth must be >= 1".into()));
    }
    if !(opts.batch_residual.is_finite()
        && (0.0..=1.0).contains(&opts.batch_residual))
    {
        return Err(Error::Config(format!(
            "batch_residual {} must be in [0, 1]",
            opts.batch_residual
        )));
    }
    spec.validate_for(topo)?;

    let mut sel = FleetSelector::new(topo, ch.texe_edge, ch.texe_cloud, ch.n2m)?;
    let n_dev = topo.len();
    let tier: Vec<DeviceKind> = topo.devices.iter().map(|d| d.tier).collect();
    let slowdown: Vec<f64> = topo.devices.iter().map(|d| d.slowdown()).collect();
    let link_scale: Vec<f64> = topo.devices.iter().map(|d| d.link_scale).collect();
    let mut disp = Dispatcher::with_lanes(&topo.lane_specs(opts.max_queue_depth), opts.batch);
    if spec.scheduling == Scheduling::Edf {
        let tenants: Vec<TenantSpec> = spec
            .classes
            .iter()
            .map(|c| TenantSpec { weight: c.weight, quota: c.quota })
            .collect();
        disp.enable_fair_tenants_spec(&tenants, true);
    }
    if spec.batch_aware_wait {
        disp.enable_batch_aware_wait();
    }
    let mut ctl = match &spec.hedge {
        Some(h) if h.waste_budget > 0.0 => Some(HedgeBudget::new(h.waste_budget, h.margin_s)?),
        _ => None,
    };
    if let Some(mut rec) = rec {
        rec.set_meta(TraceMeta {
            tiers: tier.clone(),
            waste_budget: ctl.as_ref().map(|c| c.budget_frac()),
            init_margin_s: ctl
                .as_ref()
                .and_then(|_| spec.hedge.as_ref().map(|h| h.margin_s)),
        });
        disp.attach_recorder(rec);
    }
    let mut exec = ScenarioExecutor {
        requests,
        tier: &tier,
        slowdown: &slowdown,
        residual: opts.batch_residual,
        drifts: &spec.drifts,
        faults: &spec.faults,
    };
    let class_names: Vec<String> = spec.classes.iter().map(|c| c.name.clone()).collect();
    let mut acct = ScenarioAcct::new(n_dev, &class_names);
    let mut assigner = ClassAssigner::new(&spec.classes);
    let mut class_of = vec![0usize; requests.len()];
    let mut class_offered = vec![0usize; spec.classes.len()];
    let mut class_shed = vec![0usize; spec.classes.len()];
    let mut class_hedged = vec![0usize; spec.classes.len()];
    let mut waits = vec![0.0f64; n_dev];
    let mut rejected = 0usize;
    let mut comps: Vec<Completion> = Vec::new();

    for (i, rq) in requests.iter().enumerate() {
        let now = rq.arrival_s;
        comps.clear();
        disp.run_until(now, &mut exec, &mut |c| comps.push(c));
        acct.process(
            &comps,
            requests,
            &class_of,
            spec,
            &tier,
            &slowdown,
            &link_scale,
            &mut ctl,
            disp.recorder_mut(),
        );
        let class = assigner.next();
        class_of[i] = class;
        class_offered[class] += 1;
        // Gateway heartbeat keeps the shared T_tx fresh.
        if sel.ttx_stale(now, TTX_REFRESH_S) {
            sel.observe_ttx(now, rq.rtt);
        }
        for (d, w) in waits.iter_mut().enumerate() {
            *w = disp.expected_wait_lane(d, now);
        }
        let trace = sel.select(rq.n, &waits);
        disp.record(
            now,
            ObsEvent::Placement {
                id: i as u64,
                edge_lane: trace.best_edge.device as u32,
                edge_score_s: trace.best_edge.score_s,
                cloud_lane: trace.best_cloud.device as u32,
                cloud_score_s: trace.best_cloud.score_s,
                chosen: trace.device as u32,
                margin_s: trace.best_edge.score_s - trace.best_cloud.score_s,
            },
        );
        disp.record(now, ObsEvent::ClassTag { id: i as u64, class: class as u32 });
        let mut queued = QueuedRequest {
            id: i as u64,
            payload: i,
            n: rq.n,
            m_est: trace.m_est,
            est_service_s: 0.0,
            arrival_s: now,
            bucket: 0,
            hedge: None,
        };
        let hedge = match &spec.hedge {
            Some(h) => {
                let bar = match &ctl {
                    Some(c) => c.margin_s(),
                    None => h.margin_s,
                };
                let bar = if h.class_aware {
                    bar * spec.classes[class].hedge_scale
                } else {
                    bar
                };
                let margin = trace.margin_s();
                bar > 0.0 && margin.is_finite() && margin.abs() <= bar
            }
            None => false,
        };
        let copies = if hedge {
            let outcome = disp.submit_hedged_lanes(
                queued,
                trace.best_edge.device,
                trace.best_edge.est_service_s,
                trace.best_cloud.device,
                trace.best_cloud.est_service_s,
            );
            let cloud_in_flight = match outcome {
                LaneHedgeOutcome::Hedged => true,
                LaneHedgeOutcome::Single(l) => tier[l] == DeviceKind::Cloud,
                LaneHedgeOutcome::Rejected => false,
            };
            if cloud_in_flight {
                sel.observe_ttx(now, rq.rtt);
            }
            match outcome {
                LaneHedgeOutcome::Hedged => {
                    class_hedged[class] += 1;
                    2
                }
                LaneHedgeOutcome::Single(_) => 1,
                LaneHedgeOutcome::Rejected => 0,
            }
        } else {
            queued.est_service_s = trace.est_service_s;
            if tier[trace.device] == DeviceKind::Cloud {
                sel.observe_ttx(now, rq.rtt);
            }
            let admitted = match spec.scheduling {
                Scheduling::Edf => disp.submit_lane_tenant_deadline(
                    trace.device,
                    class,
                    queued,
                    now + spec.classes[class].deadline_s,
                ),
                Scheduling::Fifo => disp.submit_lane(trace.device, queued),
            };
            u8::from(admitted.is_admitted())
        };
        if copies == 0 {
            rejected += 1;
            class_shed[class] += 1;
        }
    }
    // Drain: open-loop arrivals have ended; finish the backlog.
    comps.clear();
    disp.run_until(f64::INFINITY, &mut exec, &mut |c| comps.push(c));
    acct.process(
        &comps,
        requests,
        &class_of,
        spec,
        &tier,
        &slowdown,
        &link_scale,
        &mut ctl,
        disp.recorder_mut(),
    );
    // Per-class conservation: slow/link faults cannot strand admitted
    // requests, so every class's ledger closes exactly.
    for k in 0..spec.classes.len() {
        assert_eq!(
            class_offered[k],
            class_shed[k] + acct.class_completed[k],
            "class `{}` leaked requests",
            spec.classes[k].name
        );
    }

    let first_arrival_s = requests.first().map_or(0.0, |r| r.arrival_s);
    let makespan_s = (acct.last_done_s - first_arrival_s).max(0.0);
    let hs = disp.hedge_stats();
    let classes = spec
        .classes
        .iter()
        .enumerate()
        .map(|(k, c)| ClassOutcome {
            name: c.name.clone(),
            deadline_s: c.deadline_s,
            offered: class_offered[k],
            shed: class_shed[k],
            completed: acct.class_completed[k],
            within_deadline: acct.class_within[k],
            hedged: class_hedged[k],
            mean_latency_s: acct.class_stats[k].mean(),
            p50_s: acct.class_hist[k].p50(),
            p95_s: acct.class_hist[k].p95(),
            p99_s: acct.class_hist[k].p99(),
            phases: acct.phases.class(k).clone(),
        })
        .collect();
    let result = ScenarioResult {
        scenario: spec.name.clone(),
        scheduling: spec.scheduling.tag().to_string(),
        offered: requests.len(),
        completed: acct.completed,
        rejected,
        edge_count: acct.edge_count,
        cloud_count: acct.cloud_count,
        makespan_s,
        throughput_rps: if makespan_s > 0.0 {
            acct.completed as f64 / makespan_s
        } else {
            0.0
        },
        mean_latency_s: acct.stats.mean(),
        p50_s: acct.hist.p50(),
        p95_s: acct.hist.p95(),
        p99_s: acct.hist.p99(),
        mean_batch: disp.batch_stats().mean_batch_size(),
        hedged: hs.hedged as usize,
        hedge_wins_edge: hs.wins_edge as usize,
        hedge_wins_cloud: hs.wins_cloud as usize,
        hedge_cancelled: hs.cancelled_unrun as usize,
        hedge_wasted: hs.losers_run as usize,
        useful_work_s: acct.useful_work_s,
        wasted_work_s: acct.wasted_work_s,
        hedge_final_margin_s: ctl.as_ref().map_or(f64::NAN, |c| c.margin_s()),
        device_results: acct.device_results,
        peak_depths: (0..n_dev).map(|d| disp.queue_stats_lane(d).peak_depth).collect(),
        classes,
    };
    let mut rec = disp.take_recorder();
    if let Some(rec) = rec.as_mut() {
        rec.flush();
    }
    Ok((result, rec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::load::{synth_stream, synth_workload};
    use crate::obs::{DetectCfg, Detector};
    use crate::sim::harness::{
        run_closed_loop, run_closed_loop_streamed, run_contended, run_contended_streamed,
        run_fleet, run_fleet_closed, run_fleet_closed_streamed, run_fleet_outage,
        run_fleet_outage_detect, run_fleet_streamed,
    };

    fn spec_json() -> String {
        r#"{
            "name": "diurnal-flash",
            "topology": "hetero",
            "seed": 42,
            "requests": 400,
            "load": {
                "base_rps": 60.0,
                "period_s": 40.0,
                "amplitude": 0.5,
                "spikes": [ { "start_s": 2.0, "duration_s": 1.5, "factor": 3.0 } ]
            },
            "classes": [
                { "name": "interactive", "deadline_s": 0.25, "share": 0.5,
                  "weight": 4.0, "quota": 64, "hedge_scale": 2.0 },
                { "name": "batch", "deadline_s": 1.0, "share": 0.3,
                  "weight": 2.0, "quota": 64, "hedge_scale": 1.0 },
                { "name": "background", "deadline_s": 4.0, "share": 0.2,
                  "weight": 1.0, "quota": 64, "hedge_scale": 0.0 }
            ],
            "scheduling": "edf",
            "hedge": { "margin_s": 0.02, "waste_budget": 0.1, "class_aware": true },
            "drifts": [ { "device": "cloud", "lane": 5, "start_s": 1.0,
                          "ramp_s": 2.0, "factor": 1.5 } ],
            "faults": [ { "lane": 4, "mode": "slow", "start_s": 1.0,
                          "recover_s": 3.0, "factor": 2.0 } ],
            "batch_aware_wait": true
        }"#
        .to_string()
    }

    #[test]
    fn spec_round_trips_through_json() {
        let spec = ScenarioSpec::from_json(&Json::parse(&spec_json()).unwrap()).unwrap();
        assert_eq!(spec.name, "diurnal-flash");
        assert_eq!(spec.classes.len(), 3);
        assert_eq!(spec.scheduling, Scheduling::Edf);
        assert!(spec.batch_aware_wait);
        let again = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec.to_json().to_string(), again.to_json().to_string());
    }

    #[test]
    fn loader_fails_closed() {
        let base = spec_json();
        // Every mutation of a valid spec must be rejected, not ignored.
        let bad = [
            base.replacen("\"name\"", "\"nmae\"", 1),
            base.replacen("\"base_rps\"", "\"bsae_rps\"", 1),
            base.replacen("\"duration_s\"", "\"duration\"", 1),
            base.replacen("\"deadline_s\"", "\"deadline\"", 1),
            base.replacen("\"class_aware\"", "\"classaware\"", 1),
            base.replacen("\"ramp_s\"", "\"ramp\"", 1),
            base.replacen("\"recover_s\": 3.0", "\"recovers\": 3.0", 1),
            base.replacen("\"mode\": \"slow\"", "\"mode\": \"crash\"", 1),
            base.replacen("\"share\": 0.5", "\"share\": 0.6", 1),
            base.replacen("\"amplitude\": 0.5", "\"amplitude\": 1.0", 1),
            base.replacen("\"quota\": 64, \"hedge_scale\": 0.0", "\"quota\": 0, \"hedge_scale\": 0.0", 1),
            base.replacen("\"edf\"", "\"lifo\"", 1),
        ];
        for (i, b) in bad.iter().enumerate() {
            let j = Json::parse(b).unwrap();
            assert!(ScenarioSpec::from_json(&j).is_err(), "case {i} accepted");
        }
        // Overlapping same-lane fault windows are rejected.
        let overlap = base.replacen(
            "\"faults\": [ { \"lane\": 4, \"mode\": \"slow\", \"start_s\": 1.0,\n                          \"recover_s\": 3.0, \"factor\": 2.0 } ]",
            "\"faults\": [ { \"lane\": 4, \"mode\": \"slow\", \"start_s\": 1.0, \"recover_s\": 3.0, \"factor\": 2.0 }, { \"lane\": 4, \"mode\": \"slow\", \"start_s\": 2.5, \"recover_s\": 4.0, \"factor\": 3.0 } ]",
            1,
        );
        assert_ne!(overlap, base, "replacen must have matched");
        let j = Json::parse(&overlap).unwrap();
        assert!(ScenarioSpec::from_json(&j).is_err(), "overlap accepted");
    }

    #[test]
    fn class_assigner_tracks_shares_within_one() {
        let spec = ScenarioSpec::from_json(&Json::parse(&spec_json()).unwrap()).unwrap();
        let mut assigner = ClassAssigner::new(&spec.classes);
        let mut counts = [0usize; 3];
        for _ in 0..1000 {
            counts[assigner.next()] += 1;
        }
        assert!((counts[0] as f64 - 500.0).abs() <= 1.0, "{counts:?}");
        assert!((counts[1] as f64 - 300.0).abs() <= 1.0, "{counts:?}");
        assert!((counts[2] as f64 - 200.0).abs() <= 1.0, "{counts:?}");
        // Deterministic: a fresh assigner replays the same sequence.
        let mut a = ClassAssigner::new(&spec.classes);
        let mut b = ClassAssigner::new(&spec.classes);
        for _ in 0..100 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn load_shape_rate_composes_exactly() {
        let shape = LoadShape {
            base_rps: 10.0,
            period_s: 100.0,
            amplitude: 0.5,
            spikes: vec![Spike { start_s: 20.0, duration_s: 10.0, factor: 3.0 }],
        };
        // t = 25: sin(2π·25/100) = sin(π/2) = 1 → 10·1.5, spiked ×3.
        let expected = 10.0 * (1.0 + 0.5 * (2.0 * std::f64::consts::PI * 25.0 / 100.0).sin());
        assert_eq!(shape.rate(25.0).to_bits(), (expected * 3.0).to_bits());
        // Outside the spike window the sinusoid alone applies.
        let expected = 10.0 * (1.0 + 0.5 * (2.0 * std::f64::consts::PI * 35.0 / 100.0).sin());
        assert_eq!(shape.rate(35.0).to_bits(), expected.to_bits());
        // Flat shape: rate is exactly the base everywhere.
        let flat = LoadShape { base_rps: 7.0, period_s: 60.0, amplitude: 0.0, spikes: vec![] };
        assert_eq!(flat.rate(123.0).to_bits(), 7.0f64.to_bits());
    }

    // ------------------------------------------------ wrapper differentials
    //
    // Each public `run_*` signature is a thin wrapper over the facade;
    // these prove wrapper ≡ core bit-for-bit on a real workload (the
    // serialised result includes every float).

    #[test]
    fn contended_wrappers_are_bit_identical_to_cores() {
        let (requests, ch) = synth_workload(7, 300, 80.0);
        let opts = ContentionOpts::default();
        let a = run_contended(&requests, &ch, PolicyKind::Cnmt, &opts).unwrap();
        let (b, _) = run_contended_impl(&requests, &ch, PolicyKind::Cnmt, &opts, None).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());

        let a = run_contended_streamed(
            synth_stream(7, 300, 80.0).map(Ok),
            &ch,
            PolicyKind::Cnmt,
            &opts,
        )
        .unwrap();
        let (b, _) = run_contended_streamed_impl(
            synth_stream(7, 300, 80.0).map(Ok),
            &ch,
            PolicyKind::Cnmt,
            &opts,
            None,
        )
        .unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn closed_loop_wrappers_are_bit_identical_to_cores() {
        let (pool, ch) = synth_workload(11, 250, 60.0);
        let opts = ContentionOpts::default();
        let a = run_closed_loop(&pool, &ch, PolicyKind::Cnmt, &opts, 8, 0.01).unwrap();
        let b = run_closed_loop_core(&pool, &ch, PolicyKind::Cnmt, &opts, 8, 0.01).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());

        let a = run_closed_loop_streamed(
            synth_stream(11, 250, 60.0).map(Ok),
            &ch,
            PolicyKind::Cnmt,
            &opts,
            8,
            0.01,
        )
        .unwrap();
        let b = run_closed_loop_streamed_core(
            synth_stream(11, 250, 60.0).map(Ok),
            &ch,
            PolicyKind::Cnmt,
            &opts,
            8,
            0.01,
        )
        .unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn fleet_wrappers_are_bit_identical_to_cores() {
        let (requests, ch) = synth_workload(13, 300, 120.0);
        let topo = Topology::hetero();
        let opts = FleetOpts::default();
        let a = run_fleet(&requests, &ch, &topo, &opts).unwrap();
        let b = run_fleet_core(&requests, &ch, &topo, &opts).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());

        let a = run_fleet_streamed(synth_stream(13, 300, 120.0).map(Ok), &ch, &topo, &opts)
            .unwrap();
        let b = run_fleet_streamed_core(synth_stream(13, 300, 120.0).map(Ok), &ch, &topo, &opts)
            .unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());

        let a = run_fleet_closed(&requests, &ch, &topo, &opts, 6, 0.02).unwrap();
        let b = run_fleet_closed_core(&requests, &ch, &topo, &opts, 6, 0.02).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());

        let a = run_fleet_closed_streamed(
            synth_stream(13, 300, 120.0).map(Ok),
            &ch,
            &topo,
            &opts,
            6,
            0.02,
        )
        .unwrap();
        let b = run_fleet_closed_streamed_core(
            synth_stream(13, 300, 120.0).map(Ok),
            &ch,
            &topo,
            &opts,
            6,
            0.02,
        )
        .unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn outage_wrappers_are_bit_identical_to_cores() {
        let (requests, ch) = synth_workload(17, 300, 120.0);
        let topo = Topology::hetero();
        let opts = FleetOpts::default();
        let fault = FaultSpec {
            lane: 0,
            mode: FaultMode::Crash,
            start_s: 0.5,
            recover_s: 1.5,
        };
        let retry = RetryPolicy::default();
        let a = run_fleet_outage(&requests, &ch, &topo, &opts, &fault, &retry, true).unwrap();
        let (b, _, _) = run_fleet_outage_impl(
            &requests, &ch, &topo, &opts, &fault, &retry, true, None, None, None,
        )
        .unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());

        let tiers: Vec<DeviceKind> = topo.devices.iter().map(|d| d.tier).collect();
        let (a, _) = run_fleet_outage_detect(
            &requests,
            &ch,
            &topo,
            &opts,
            Some(&fault),
            &retry,
            Detector::new(&tiers, DetectCfg::default()),
            None,
        )
        .unwrap();
        let (b, _) = run_fleet_outage_detect_core(
            &requests,
            &ch,
            &topo,
            &opts,
            Some(&fault),
            &retry,
            Detector::new(&tiers, DetectCfg::default()),
            None,
        )
        .unwrap();
        assert_eq!(a.result.to_json().to_string(), b.result.to_json().to_string());
        assert_eq!(a.raised, b.raised);
        assert_eq!(a.cleared, b.cleared);
        assert_eq!(a.alerts.len(), b.alerts.len());
        assert_eq!(a.blame.len(), b.blame.len());
    }

    // ------------------------------------------------------- engine tests

    fn engine_spec(scheduling: Scheduling) -> ScenarioSpec {
        let mut spec =
            ScenarioSpec::from_json(&Json::parse(&spec_json()).unwrap()).unwrap();
        spec.scheduling = scheduling;
        spec
    }

    #[test]
    fn scenario_engine_conserves_per_class() {
        let (requests, ch) = synth_workload(42, 400, 150.0);
        let topo = Topology::hetero();
        let opts = FleetOpts::default();
        let spec = engine_spec(Scheduling::Edf);
        let outcome = run_scenario(
            ScenarioSource::pool(&requests),
            &ch,
            RunSpec::scenario(&topo, &opts, &spec, None),
        )
        .unwrap();
        let (r, rec) = outcome.expect_scenario();
        assert!(rec.is_none());
        assert_eq!(r.scheduling, "edf");
        assert_eq!(r.offered, 400);
        assert_eq!(r.completed + r.rejected, r.offered);
        assert_eq!(r.device_results.iter().sum::<usize>(), r.completed);
        assert_eq!(r.edge_count + r.cloud_count, r.completed);
        let mut offered = 0;
        for c in &r.classes {
            assert_eq!(c.offered, c.shed + c.completed, "class {}", c.name);
            assert!(c.within_deadline <= c.completed);
            assert!((0.0..=1.0).contains(&c.attainment()));
            assert_eq!(c.phases.count(), c.completed as u64);
            offered += c.offered;
        }
        assert_eq!(offered, r.offered);
        // Shares: 50/30/20 of 400, within one request each.
        assert!((r.classes[0].offered as f64 - 200.0).abs() <= 1.0);
        assert!((r.classes[1].offered as f64 - 120.0).abs() <= 1.0);
        assert!((r.classes[2].offered as f64 - 80.0).abs() <= 1.0);
        // The report schema carries the ledger.
        let j = r.to_json();
        assert!(j.get("classes").is_ok());
        assert!(j.get("throughput_rps").is_ok());
    }

    #[test]
    fn fifo_baseline_runs_the_same_workload_class_blind() {
        let (requests, ch) = synth_workload(42, 400, 150.0);
        let topo = Topology::hetero();
        let opts = FleetOpts::default();
        let fifo = engine_spec(Scheduling::Fifo);
        let edf = engine_spec(Scheduling::Edf);
        let (rf, _) = run_scenario(
            ScenarioSource::pool(&requests),
            &ch,
            RunSpec::scenario(&topo, &opts, &fifo, None),
        )
        .unwrap()
        .expect_scenario();
        let (re, _) = run_scenario(
            ScenarioSource::pool(&requests),
            &ch,
            RunSpec::scenario(&topo, &opts, &edf, None),
        )
        .unwrap()
        .expect_scenario();
        assert_eq!(rf.scheduling, "fifo");
        assert_eq!(re.scheduling, "edf");
        // Same workload, same class tagging: the offered ledgers match.
        for (a, b) in rf.classes.iter().zip(&re.classes) {
            assert_eq!(a.offered, b.offered);
            assert_eq!(a.name, b.name);
        }
        // Both conserve.
        assert_eq!(rf.completed + rf.rejected, rf.offered);
        assert_eq!(re.completed + re.rejected, re.offered);
    }

    #[test]
    fn scenario_engine_rejects_bad_composition() {
        let (requests, ch) = synth_workload(3, 50, 40.0);
        let topo = Topology::hetero();
        let spec = engine_spec(Scheduling::Edf);
        let hedged = FleetOpts {
            strategy: FleetStrategy::Hedged { margin_s: 0.01 },
            ..FleetOpts::default()
        };
        assert!(run_scenario_engine(&requests, &ch, &topo, &hedged, &spec, None).is_err());
        let drifted = FleetOpts {
            drift: Some(DriftSpec {
                device: DeviceKind::Edge,
                lane: None,
                start_s: 0.0,
                ramp_s: 0.0,
                factor: 2.0,
            }),
            ..FleetOpts::default()
        };
        assert!(run_scenario_engine(&requests, &ch, &topo, &drifted, &spec, None).is_err());
        // A fault lane outside the topology fails validate_for.
        let mut bad = engine_spec(Scheduling::Edf);
        bad.faults[0].lane = 99;
        assert!(
            run_scenario_engine(&requests, &ch, &topo, &FleetOpts::default(), &bad, None)
                .is_err()
        );
    }

    #[test]
    fn facade_rejects_invalid_combinations() {
        let (requests, ch) = synth_workload(3, 50, 40.0);
        let copts = ContentionOpts::default();
        let topo = Topology::hetero();
        let fopts = FleetOpts::default();
        let spec = engine_spec(Scheduling::Edf);
        // Scenario overlay needs a fleet scope.
        let rs = RunSpec {
            scenario: Some(&spec),
            ..RunSpec::contended(PolicyKind::Cnmt, &copts)
        };
        assert!(run_scenario(ScenarioSource::pool(&requests), &ch, rs).is_err());
        // Scenario overlay is open-loop.
        let rs = RunSpec {
            scenario: Some(&spec),
            ..RunSpec::fleet_closed(&topo, &fopts, 4, 0.0)
        };
        assert!(run_scenario(ScenarioSource::pool(&requests), &ch, rs).is_err());
        // Detect mode without a detector fails closed.
        let retry = RetryPolicy::default();
        let rs = RunSpec {
            det: None,
            ..RunSpec::fleet(&topo, &fopts)
        };
        let rs = RunSpec {
            outage: ScenarioOutage::Detect { fault: None, retry: &retry },
            ..rs
        };
        assert!(run_scenario(ScenarioSource::pool(&requests), &ch, rs).is_err());
    }

    #[test]
    fn traced_scenario_records_class_tags() {
        let (requests, ch) = synth_workload(5, 120, 100.0);
        let topo = Topology::hetero();
        let opts = FleetOpts::default();
        let spec = engine_spec(Scheduling::Edf);
        let rec = FlightRecorder::new(4096);
        let (r, rec) = run_scenario(
            ScenarioSource::pool(&requests),
            &ch,
            RunSpec::scenario(&topo, &opts, &spec, Some(rec)),
        )
        .unwrap()
        .expect_scenario();
        let rec = rec.expect("recorder round-trips");
        let tags = rec
            .events()
            .filter(|s| matches!(s.ev, ObsEvent::ClassTag { .. }))
            .count();
        assert!(tags > 0, "no class tags recorded");
        assert_eq!(r.offered, 120);
    }
}
