//! Declarative failure injection — the fault twin of
//! [`super::harness::DriftSpec`].
//!
//! A [`FaultSpec`] names one device of a [`crate::fleet::Topology`] and
//! a window `[start_s, recover_s)` during which something goes wrong
//! with it:
//!
//! * [`FaultMode::Crash`] — the device goes dark: its queue and
//!   in-flight batches are destroyed, admissions refuse, and at
//!   `recover_s` it comes back empty and idle
//!   ([`crate::scheduler::Dispatcher::fail_lane`] /
//!   [`crate::scheduler::Dispatcher::recover_lane`]).
//! * [`FaultMode::Slow`] — a fail-slow device: ground-truth execution
//!   times are multiplied by `factor` while the window is open. Unlike
//!   drift, which the online refit is meant to learn, a slow fault is a
//!   transient the timeout/retry machinery has to ride out.
//! * [`FaultMode::Link`] — the device's network path degrades: the
//!   ground-truth transfer cost is multiplied by `factor` (cloud
//!   replicas only — edges are local).
//!
//! Specs are plain data, JSON-loadable like [`crate::fleet::Topology`]
//! (`FaultSpec::load` / [`FaultSpec::from_json`]) so an outage scenario
//! can live next to its topology file. The scheduler reacts to a fault
//! only through what it can observe — timeouts firing, completions
//! slowing, a lane refusing admissions — never by reading the spec.

use std::path::Path;

use crate::fleet::Topology;
use crate::util::Json;
use crate::{Error, Result};

/// What goes wrong during the fault window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultMode {
    /// Hard outage: queue and in-flight work destroyed, admissions
    /// refused, clean empty recovery.
    Crash,
    /// Fail-slow: ground-truth execution times multiplied by `factor`
    /// (> 1 = slower) while the fault is active.
    Slow {
        /// Execution-time multiplier during the window.
        factor: f64,
    },
    /// Degraded network path: ground-truth transfer cost multiplied by
    /// `factor` while the fault is active (cloud replicas only).
    Link {
        /// Transfer-cost multiplier during the window.
        factor: f64,
    },
}

impl FaultMode {
    /// The JSON `mode` tag.
    pub fn tag(&self) -> &'static str {
        match self {
            FaultMode::Crash => "crash",
            FaultMode::Slow { .. } => "slow",
            FaultMode::Link { .. } => "link",
        }
    }
}

/// One injected fault: a device, a mode, and the window it is broken.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Device id / dispatcher lane the fault strikes.
    pub lane: usize,
    /// What goes wrong.
    pub mode: FaultMode,
    /// Clock time the fault begins (s).
    pub start_s: f64,
    /// Clock time the device recovers (s; `f64::INFINITY` = never).
    pub recover_s: f64,
}

impl FaultSpec {
    /// Is the fault window open at clock time `t_s`?
    pub fn active_at(&self, t_s: f64) -> bool {
        t_s >= self.start_s && t_s < self.recover_s
    }

    /// The execution-time multiplier this fault applies to `lane` at
    /// `t_s` (1.0 when inactive, another lane, or not a slow fault).
    pub fn exec_factor_at(&self, lane: usize, t_s: f64) -> f64 {
        match self.mode {
            FaultMode::Slow { factor } if lane == self.lane && self.active_at(t_s) => factor,
            _ => 1.0,
        }
    }

    /// The transfer-cost multiplier this fault applies to `lane` at
    /// `t_s` (1.0 when inactive, another lane, or not a link fault).
    pub fn link_factor_at(&self, lane: usize, t_s: f64) -> f64 {
        match self.mode {
            FaultMode::Link { factor } if lane == self.lane && self.active_at(t_s) => factor,
            _ => 1.0,
        }
    }

    /// Structural validation (window ordering, factor sanity). Use
    /// [`FaultSpec::validate_for`] when the target topology is known.
    pub fn validate(&self) -> Result<()> {
        if !self.start_s.is_finite() || self.start_s < 0.0 {
            return Err(Error::Config(format!(
                "fault start_s {} must be finite and >= 0",
                self.start_s
            )));
        }
        if self.recover_s.is_nan() || self.recover_s <= self.start_s {
            return Err(Error::Config(format!(
                "fault recover_s {} must be > start_s {} (inf = never)",
                self.recover_s, self.start_s
            )));
        }
        match self.mode {
            FaultMode::Crash => {}
            FaultMode::Slow { factor } | FaultMode::Link { factor } => {
                if !(factor.is_finite() && factor > 0.0) {
                    return Err(Error::Config(format!(
                        "fault factor {factor} must be finite and > 0"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Validate against the topology the fault will be injected into:
    /// the lane must exist, and link faults only make sense on cloud
    /// replicas (edges have no network path to degrade).
    pub fn validate_for(&self, topo: &Topology) -> Result<()> {
        self.validate()?;
        if self.lane >= topo.len() {
            return Err(Error::Config(format!(
                "fault lane {} out of range for topology {} ({} devices)",
                self.lane,
                topo.name,
                topo.len()
            )));
        }
        if matches!(self.mode, FaultMode::Link { .. })
            && topo.devices[self.lane].tier != crate::devices::DeviceKind::Cloud
        {
            return Err(Error::Config(format!(
                "link fault on lane {} ({}): only cloud replicas have a \
                 link to degrade",
                self.lane, topo.devices[self.lane].name
            )));
        }
        Ok(())
    }

    /// Parse a fault from its JSON spec:
    ///
    /// ```json
    /// { "lane": 0, "mode": "crash", "start_s": 22.3, "recover_s": 52.3 }
    /// ```
    ///
    /// `slow` and `link` modes carry a `factor` key; `recover_s` may be
    /// omitted (the fault never clears).
    pub fn from_json(j: &Json) -> Result<FaultSpec> {
        let lane = j.get("lane")?.as_usize()?;
        let start_s = j.get("start_s")?.as_f64()?;
        let recover_s = match j.get_opt("recover_s")? {
            Some(r) => match r {
                Json::Null => f64::INFINITY,
                other => other.as_f64()?,
            },
            None => f64::INFINITY,
        };
        let mode = match j.get("mode")?.as_str()? {
            "crash" => FaultMode::Crash,
            "slow" => FaultMode::Slow { factor: j.get("factor")?.as_f64()? },
            "link" => FaultMode::Link { factor: j.get("factor")?.as_f64()? },
            other => {
                return Err(Error::Config(format!(
                    "fault mode `{other}` is not crash|slow|link"
                )))
            }
        };
        let spec = FaultSpec { lane, mode, start_s, recover_s };
        spec.validate()?;
        Ok(spec)
    }

    /// Load a fault spec from a JSON file.
    pub fn load(path: &Path) -> Result<FaultSpec> {
        FaultSpec::from_json(&Json::parse_file(path)?)
    }

    /// Serialise for reports / spec round-trips (`recover_s` becomes
    /// `null` when the fault never clears; `factor` only appears for
    /// slow/link modes).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("lane", Json::Num(self.lane as f64))
            .set("mode", Json::Str(self.tag().to_string()))
            .set("start_s", Json::Num(self.start_s));
        if self.recover_s.is_finite() {
            o.set("recover_s", Json::Num(self.recover_s));
        } else {
            o.set("recover_s", Json::Null);
        }
        match self.mode {
            FaultMode::Crash => {}
            FaultMode::Slow { factor } | FaultMode::Link { factor } => {
                o.set("factor", Json::Num(factor));
            }
        }
        o
    }

    /// The JSON `mode` tag (forwarded from [`FaultMode::tag`]).
    pub fn tag(&self) -> &'static str {
        self.mode.tag()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_spec_round_trips_bit_exact() {
        let spec = FaultSpec {
            lane: 0,
            mode: FaultMode::Crash,
            start_s: 22.321428571428573,
            recover_s: 52.32142857142857,
        };
        spec.validate().unwrap();
        let again = FaultSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(again, spec);
        assert_eq!(again.start_s.to_bits(), spec.start_s.to_bits());
        assert_eq!(again.recover_s.to_bits(), spec.recover_s.to_bits());
    }

    #[test]
    fn slow_and_link_factors_gate_on_window_and_lane() {
        let slow = FaultSpec {
            lane: 3,
            mode: FaultMode::Slow { factor: 4.0 },
            start_s: 10.0,
            recover_s: 20.0,
        };
        assert_eq!(slow.exec_factor_at(3, 9.99), 1.0);
        assert_eq!(slow.exec_factor_at(3, 10.0), 4.0);
        assert_eq!(slow.exec_factor_at(3, 19.99), 4.0);
        assert_eq!(slow.exec_factor_at(3, 20.0), 1.0); // half-open window
        assert_eq!(slow.exec_factor_at(2, 15.0), 1.0); // other lane
        assert_eq!(slow.link_factor_at(3, 15.0), 1.0); // wrong knob

        let link = FaultSpec {
            lane: 5,
            mode: FaultMode::Link { factor: 8.0 },
            start_s: 0.0,
            recover_s: f64::INFINITY,
        };
        assert_eq!(link.link_factor_at(5, 1e9), 8.0); // never recovers
        assert_eq!(link.exec_factor_at(5, 1e9), 1.0);
    }

    #[test]
    fn json_defaults_and_permanent_faults() {
        let j = Json::parse(r#"{"lane": 1, "mode": "crash", "start_s": 5}"#).unwrap();
        let spec = FaultSpec::from_json(&j).unwrap();
        assert_eq!(spec.recover_s, f64::INFINITY);
        assert!(spec.active_at(1e12));
        // Round trip: the permanent fault serialises recover_s as null.
        let again = FaultSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(again, spec);

        let j = Json::parse(
            r#"{"lane": 5, "mode": "slow", "factor": 2.5, "start_s": 1, "recover_s": 2}"#,
        )
        .unwrap();
        let spec = FaultSpec::from_json(&j).unwrap();
        assert_eq!(spec.mode, FaultMode::Slow { factor: 2.5 });
    }

    #[test]
    fn malformed_specs_fail_closed() {
        for bad in [
            r#"{"lane": 0, "mode": "crash", "start_s": -1}"#,
            r#"{"lane": 0, "mode": "crash", "start_s": 10, "recover_s": 10}"#,
            r#"{"lane": 0, "mode": "crash", "start_s": 10, "recover_s": 5}"#,
            r#"{"lane": 0, "mode": "slow", "factor": 0, "start_s": 0}"#,
            r#"{"lane": 0, "mode": "slow", "start_s": 0}"#,
            r#"{"lane": 0, "mode": "gone", "start_s": 0}"#,
            r#"{"mode": "crash", "start_s": 0}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(FaultSpec::from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn topology_validation_scopes_link_faults_to_cloud() {
        let topo = Topology::hetero(); // lanes 0-3 edge, 4-5 cloud
        let crash = FaultSpec {
            lane: 0,
            mode: FaultMode::Crash,
            start_s: 1.0,
            recover_s: 2.0,
        };
        crash.validate_for(&topo).unwrap();
        let link_on_edge = FaultSpec {
            lane: 0,
            mode: FaultMode::Link { factor: 2.0 },
            start_s: 1.0,
            recover_s: 2.0,
        };
        assert!(link_on_edge.validate_for(&topo).is_err());
        let link_on_cloud = FaultSpec { lane: 5, ..link_on_edge };
        link_on_cloud.validate_for(&topo).unwrap();
        let out_of_range = FaultSpec { lane: 6, ..crash };
        assert!(out_of_range.validate_for(&topo).is_err());
    }
}
