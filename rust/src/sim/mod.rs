//! Discrete-event experiment harness — the machinery behind Table I.
//!
//! [`characterize`] reproduces the paper's offline phase: 10k profiled
//! inferences per device → per-device T_exe planes, plus the prefiltered
//! corpus fit of the N→M regressor. [`harness`] then replays a request
//! stream (arrivals spread over the RTT trace timeline) under every
//! policy **on identical ground truth**: for each request the true edge
//! time, cloud time and network cost are sampled once, and each policy is
//! charged from the same table — so policy deltas are never noise.
//!
//! [`scenario`] is the unified front door: every public `run_*` entry
//! point in [`harness`] is a thin wrapper over one [`scenario::RunSpec`]
//! dispatch, and a declarative [`scenario::ScenarioSpec`] (time-varying
//! load, SLO service classes, drift and fault timelines) drives the
//! scenario engine behind `cnmt experiment scenario`.

pub mod characterize;
pub mod fault;
pub mod harness;
pub mod scenario;

pub use characterize::{characterize, Characterization};
pub use fault::{FaultMode, FaultSpec};
pub use harness::{
    run_all_policies, run_closed_loop, run_closed_loop_streamed, run_contended,
    run_contended_streamed, run_contended_streamed_traced, run_contended_traced, run_fleet,
    run_fleet_closed, run_fleet_closed_streamed, run_fleet_outage, run_fleet_outage_detect,
    run_fleet_outage_traced, run_fleet_streamed, run_policy, run_with_estimator, AdaptiveOpts,
    ContendedResult, ContentionOpts, DetectRunOut, DriftSpec, FleetOpts, FleetResult,
    OutageResult, PolicyResult, RequestTruth, RetryPolicy, TruthTable,
};
pub use scenario::{
    run_scenario, run_scenario_engine, ClassAssigner, ClassOutcome, ClassSpec, EmptyStream,
    HedgeShape, LoadShape, RunSpec, ScenarioMode, ScenarioOutage, ScenarioOutcome,
    ScenarioResult, ScenarioScope, ScenarioSource, ScenarioSpec, Scheduling, Spike,
};
