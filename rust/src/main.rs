//! `cnmt` — the C-NMT launcher.
//!
//! ```text
//! cnmt experiment table1|fig2a|fig3|fig4|all [flags]   reproduce the paper
//! cnmt calibrate [flags]                               real-PJRT device characterisation
//! cnmt translate --model <name> --ids 5,6,7            one translation through the runtime
//! cnmt selfcheck                                       load + run every artifact
//! cnmt help
//! ```
//!
//! Common flags: `--config <json>`, `--seed <u64>`, `--requests <n>`,
//! `--out <dir>`, `--artifacts <dir>`, `--calibration <json>`.

use std::path::PathBuf;
use std::process::ExitCode;

use cnmt::config::Config;
use cnmt::corpus::LangPair;
#[cfg(feature = "pjrt")]
use cnmt::corpus::Tokenizer;
use cnmt::devices::Calibration;
use cnmt::experiments::{
    ablation, energy, fig2a, fig3, fig4, load, multilevel, report, table1,
};
#[cfg(feature = "pjrt")]
use cnmt::runtime::{ArtifactManifest, Seq2SeqEngine, TranslateOptions};
use cnmt::util::Args;
#[cfg(feature = "pjrt")]
use cnmt::util::Json;
use cnmt::{Error, Result};

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("experiment") => cmd_experiment(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("translate") => cmd_translate(&args),
        Some("selfcheck") => cmd_selfcheck(&args),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => Err(Error::Config(format!(
            "unknown subcommand `{other}` (try `cnmt help`)"
        ))),
    }
}

const HELP: &str = "\
cnmt — C-NMT: collaborative inference for neural machine translation

USAGE:
  cnmt experiment <table1|fig2a|fig3|fig4|ablation|energy|multilevel|load|all> [flags]
      --config <json>       load a Config (defaults = paper setup)
      --requests <n>        evaluation requests (default 100000)
      --fit <n>             characterisation inferences (default 10000)
      --seed <u64>          master seed
      --out <dir>           report directory (default reports/)
      --calibration <json>  measured calibration (default: built-in)
      --samples <n>         fig2a/fig3 sample count
      --loads <a,b,..>      load sweep: offered loads in r/s
      --load-requests <n>   load sweep: requests per point (default 20000)
      --closed-loop         load sweep: closed-loop clients instead of
                            open-loop Poisson arrivals (writes closed_loop.json)
      --clients <a,b,..>    closed loop: client counts (default 1,2,4,8,16,32,64)
      --think-ms <f>        closed loop: per-client think time (default 0)
  cnmt calibrate [flags]    measure real PJRT latencies, fit T_exe planes
                            (needs the `pjrt` build feature)
      --samples <n>         measured translations per model (default 120)
      --edge-slowdown <f>   edge = local CPU x f (default 1.0)
      --cloud-speedup <f>   cloud = local CPU / f (default 5.0)
      --artifacts <dir>     artifacts directory (default artifacts/)
      --out <path>          output (default artifacts/calibration.json)
      --models <a,b>        subset of models
  cnmt translate --model <name> --ids 5,6,7 [--text \"ba de ga\"]
  cnmt selfcheck            load + execute every artifact end to end
";

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.str_opt("config") {
        Some(p) => Config::load(&PathBuf::from(p))?,
        None => Config::default(),
    };
    cfg.requests = args.usize("requests", cfg.requests)?;
    cfg.fit_inferences = args.usize("fit", cfg.fit_inferences)?;
    cfg.seed = args.u64("seed", cfg.seed)?;
    if let Some(out) = args.str_opt("out") {
        cfg.out_dir = PathBuf::from(out);
    }
    if let Some(a) = args.str_opt("artifacts") {
        cfg.artifacts_dir = PathBuf::from(a);
    }
    if let Some(c) = args.str_opt("calibration") {
        cfg.calibration = Some(PathBuf::from(c));
    }
    cfg.validate()?;
    Ok(cfg)
}

fn load_calibration(cfg: &Config) -> Result<Calibration> {
    match &cfg.calibration {
        Some(path) => {
            eprintln!("using measured calibration: {}", path.display());
            Calibration::load(path)
        }
        None => Ok(Calibration::default_paper()),
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let cfg = load_config(args)?;
    let cal = load_calibration(&cfg)?;
    let samples = args.usize("samples", 30_000)?;
    // Only the load sweep consumes its flags; on other experiments a
    // stray `--loads` stays unknown and is rejected below.
    let (load_cfg, closed_cfg) = if matches!(which.as_str(), "load" | "all") {
        let closed = args.bool("closed-loop");
        if closed {
            let mut cc = load::ClosedLoopConfig { seed: cfg.seed, ..Default::default() };
            if let Some(clients) = args.str_opt("clients") {
                cc.clients = clients
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<usize>().map_err(|_| {
                            Error::Config(format!("--clients: `{s}` is not an integer"))
                        })
                    })
                    .collect::<Result<_>>()?;
            }
            cc.think_s = args.f64("think-ms", 0.0)? / 1e3;
            cc.requests_per_point = args.usize("load-requests", cc.requests_per_point)?;
            (None, Some(cc))
        } else {
            let mut lc = load::LoadConfig { seed: cfg.seed, ..Default::default() };
            if let Some(loads) = args.str_opt("loads") {
                lc.loads_rps = loads
                    .split(',')
                    .map(|s| {
                        s.trim().parse::<f64>().map_err(|_| {
                            Error::Config(format!("--loads: `{s}` is not a number"))
                        })
                    })
                    .collect::<Result<_>>()?;
            }
            lc.requests_per_point = args.usize("load-requests", lc.requests_per_point)?;
            (Some(lc), None)
        }
    } else {
        (None, None)
    };
    args.reject_unknown()?;

    let run_fig2a = |cfg: &Config| -> Result<()> {
        let f = fig2a::run(LangPair::EnZh, &cal, samples, cfg.seed)?;
        print!("{}", fig2a::render_text(&f));
        let p = report::write_report(&cfg.out_dir, "fig2a", &fig2a::to_json(&f))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };
    let run_fig3 = |cfg: &Config| -> Result<()> {
        let f = fig3::run(samples, cfg.seed)?;
        print!("{}", fig3::render_text(&f));
        let p = report::write_report(&cfg.out_dir, "fig3", &fig3::to_json(&f))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };
    let run_fig4 = |cfg: &Config| -> Result<()> {
        let f = fig4::run(cfg.seed)?;
        print!("{}", fig4::render_text(&f));
        fig4::write_traces(&f, &cfg.out_dir)?;
        let p = report::write_report(&cfg.out_dir, "fig4", &fig4::to_json(&f))?;
        eprintln!("wrote {} (+ trace CSVs)\n", p.display());
        Ok(())
    };
    let run_table1 = |cfg: &Config| -> Result<()> {
        eprintln!(
            "table1: {} requests x {} pairs x {} profiles (seed {})",
            cfg.requests,
            cfg.pairs.len(),
            cfg.profiles.len(),
            cfg.seed
        );
        let t = table1::run(cfg, &cal)?;
        print!("{}", table1::render_text(&t));
        let p = report::write_report(&cfg.out_dir, "table1", &table1::to_json(&t))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_ablation = |cfg: &Config| -> Result<()> {
        eprintln!("ablation: estimator zoo over the Table-I grid...");
        let a = ablation::run(cfg, &cal)?;
        print!("{}", ablation::render_text(&a));
        let p = report::write_report(&cfg.out_dir, "ablation", &ablation::to_json(&a))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_energy = |cfg: &Config| -> Result<()> {
        eprintln!("energy: gateway-energy view of the policy grid...");
        let e = energy::run(cfg, &cal, cnmt::devices::EnergyModel::default())?;
        print!("{}", energy::render_text(&e));
        let p = report::write_report(&cfg.out_dir, "energy", &energy::to_json(&e))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_load = |cfg: &Config| -> Result<()> {
        if let Some(closed_cfg) = closed_cfg.as_ref() {
            eprintln!(
                "load (closed-loop): {} requests/point over {} client counts (seed {})",
                closed_cfg.requests_per_point,
                closed_cfg.clients.len(),
                closed_cfg.seed
            );
            let s = load::run_closed(closed_cfg)?;
            print!("{}", load::render_closed_text(&s));
            let p =
                report::write_report(&cfg.out_dir, "closed_loop", &load::closed_to_json(&s))?;
            eprintln!("wrote {}\n", p.display());
            return Ok(());
        }
        let load_cfg = load_cfg.as_ref().expect("load_cfg built for load/all");
        eprintln!(
            "load: {} requests/point over {} offered loads (seed {})",
            load_cfg.requests_per_point,
            load_cfg.loads_rps.len(),
            load_cfg.seed
        );
        let s = load::run(load_cfg)?;
        print!("{}", load::render_text(&s));
        let p = report::write_report(&cfg.out_dir, "load_sweep", &load::to_json(&s))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    let run_multilevel = |cfg: &Config| -> Result<()> {
        eprintln!("multilevel: 3-tier CI (end-device/gateway/cloud)...");
        let m = multilevel::run(cfg, &cal)?;
        print!("{}", multilevel::render_text(&m));
        let p = report::write_report(&cfg.out_dir, "multilevel", &multilevel::to_json(&m))?;
        eprintln!("wrote {}\n", p.display());
        Ok(())
    };

    match which.as_str() {
        "fig2a" => run_fig2a(&cfg),
        "fig3" => run_fig3(&cfg),
        "fig4" => run_fig4(&cfg),
        "table1" => run_table1(&cfg),
        "ablation" => run_ablation(&cfg),
        "energy" => run_energy(&cfg),
        "multilevel" => run_multilevel(&cfg),
        "load" => run_load(&cfg),
        "all" => {
            run_fig4(&cfg)?;
            run_fig3(&cfg)?;
            run_fig2a(&cfg)?;
            run_table1(&cfg)?;
            run_ablation(&cfg)?;
            run_energy(&cfg)?;
            run_multilevel(&cfg)?;
            run_load(&cfg)
        }
        other => Err(Error::Config(format!("unknown experiment `{other}`"))),
    }
}

/// Stubs for the PJRT-backed commands when built without the `pjrt`
/// feature (the default: the offline environment has no XLA library).
#[cfg(not(feature = "pjrt"))]
fn pjrt_unavailable(cmd: &str) -> Result<()> {
    Err(Error::Config(format!(
        "`cnmt {cmd}` needs the real PJRT runtime — rebuild with \
         `--features pjrt`"
    )))
}

#[cfg(not(feature = "pjrt"))]
fn cmd_calibrate(_args: &Args) -> Result<()> {
    pjrt_unavailable("calibrate")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_translate(_args: &Args) -> Result<()> {
    pjrt_unavailable("translate")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_selfcheck(_args: &Args) -> Result<()> {
    pjrt_unavailable("selfcheck")
}

/// Real-PJRT characterisation: measure translations over an (N, M) grid
/// per model, fit the T_exe planes, derive edge/cloud device models.
#[cfg(feature = "pjrt")]
fn cmd_calibrate(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.str("artifacts", "artifacts"));
    let out = PathBuf::from(args.str("out", "artifacts/calibration.json"));
    let samples = args.usize("samples", 120)?;
    let edge_slowdown = args.f64("edge-slowdown", 1.0)?;
    let cloud_speedup = args.f64("cloud-speedup", 5.0)?;
    let models_filter = args.str("models", "");
    let seed = args.u64("seed", 7)?;
    args.reject_unknown()?;

    let manifest = ArtifactManifest::load(&artifacts)?;
    let mut rng = cnmt::util::Rng::new(seed);
    let mut all_samples = std::collections::BTreeMap::new();
    for model in &manifest.models {
        if !models_filter.is_empty()
            && !models_filter.split(',').any(|m| m == model.name)
        {
            continue;
        }
        eprintln!("calibrating {} ({samples} translations)...", model.name);
        let engine = Seq2SeqEngine::from_manifest(&manifest, &model.name)?;
        // Warm up (first executions pay one-time lazy initialisation).
        let warm: Vec<u16> = vec![7; 8];
        for _ in 0..3 {
            engine.translate(
                &warm,
                TranslateOptions { force_steps: Some(4), ..Default::default() },
            )?;
        }
        let mut sm = Vec::with_capacity(samples);
        for i in 0..samples {
            let n = 1 + rng.usize(manifest.n_max - 2);
            let m = 1 + rng.usize(manifest.m_max - 2);
            let src: Vec<u16> = (0..n).map(|_| 3 + rng.usize(4093) as u16).collect();
            let tr = engine.translate(
                &src,
                TranslateOptions { force_steps: Some(m), ..Default::default() },
            )?;
            sm.push((n as f64, m as f64, tr.total_s()));
            if (i + 1) % 40 == 0 {
                eprintln!("  {}/{samples}", i + 1);
            }
        }
        all_samples.insert(model.name.clone(), sm);
    }
    let cal = Calibration::from_measurements(&all_samples, edge_slowdown, cloud_speedup)?;
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent)?;
    }
    cal.save(&out)?;
    eprintln!("wrote {}", out.display());
    for model in cal.models() {
        for dev in cnmt::devices::DeviceKind::ALL {
            let tm = cal.get(dev, &model)?;
            eprintln!(
                "  {}/{}: aN={:.3}ms aM={:.3}ms b={:.3}ms (r2 {:.3})",
                dev.id(),
                model,
                tm.texe.alpha_n * 1e3,
                tm.texe.alpha_m * 1e3,
                tm.texe.beta * 1e3,
                tm.texe.r2,
            );
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_translate(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.str("artifacts", "artifacts"));
    let model = args.str_req("model")?;
    let ids_flag = args.str_opt("ids");
    let text_flag = args.str_opt("text");
    let max_steps = args.usize("max-steps", 64)?;
    args.reject_unknown()?;

    let tok = Tokenizer::new(4096);
    let src: Vec<u16> = match (ids_flag, text_flag) {
        (Some(ids), _) => ids
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u16>()
                    .map_err(|_| Error::Config(format!("bad token id `{s}`")))
            })
            .collect::<Result<_>>()?,
        (None, Some(text)) => tok.tokenize(&text)?,
        (None, None) => {
            return Err(Error::Config("need --ids or --text".into()));
        }
    };
    let engine = Seq2SeqEngine::load(&artifacts, &model)?;
    let tr = engine.translate(
        &src,
        TranslateOptions { max_steps: Some(max_steps), ..Default::default() },
    )?;
    println!("source ({} tokens): {}", src.len(), tok.detokenize(&src));
    let out_u16: Vec<u16> = tr.tokens.iter().map(|&t| t as u16).collect();
    println!("output ({} steps):  {}", tr.steps, tok.detokenize(&out_u16));
    println!(
        "encode {:.2} ms, decode {:.2} ms ({:.2} ms/token)",
        tr.encode_s * 1e3,
        tr.decode_s * 1e3,
        tr.decode_s * 1e3 / tr.steps.max(1) as f64
    );
    Ok(())
}

/// Load + execute every artifact; verifies determinism and reports a
/// per-model latency sketch. This is the post-`make artifacts` sanity
/// gate.
#[cfg(feature = "pjrt")]
fn cmd_selfcheck(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.str("artifacts", "artifacts"));
    args.reject_unknown()?;
    let manifest = ArtifactManifest::load(&artifacts)?;
    let mut summary = Json::object();
    for model in &manifest.models {
        eprintln!("== {}", model.name);
        let engine = Seq2SeqEngine::from_manifest(&manifest, &model.name)?;
        let src: Vec<u16> = vec![10, 17, 23, 99, 5];
        let opts = TranslateOptions { force_steps: Some(8), ..Default::default() };
        let a = engine.translate(&src, opts)?;
        let b = engine.translate(&src, opts)?;
        if a.tokens != b.tokens {
            return Err(Error::Serve(format!(
                "{}: nondeterministic decode",
                model.name
            )));
        }
        let long: Vec<u16> = (100..160).collect();
        let c = engine.translate(
            &long,
            TranslateOptions { force_steps: Some(30), ..Default::default() },
        )?;
        eprintln!(
            "   n=5 m=8: enc {:.2}ms dec {:.2}ms | n=60 m=30: enc {:.2}ms dec {:.2}ms",
            a.encode_s * 1e3,
            a.decode_s * 1e3,
            c.encode_s * 1e3,
            c.decode_s * 1e3
        );
        let mut o = Json::object();
        o.set("dec_ms_per_step", Json::Num(c.decode_s * 1e3 / 30.0));
        summary.set(&model.name, o);
    }
    println!("selfcheck OK: {}", summary.to_string());
    Ok(())
}
